"""Allocate LM serving requests across a heterogeneous fleet — the second
domain on the shared runtime (the paper's workflow beyond pricing, §3/§7).

A smoke-scale qwen25_3b request workload is characterised online (eq. 7:
latency = beta * tokens + gamma per platform), allocated by all three
solvers, and executed with predicted vs measured makespan reported.

Run:  PYTHONPATH=src python examples/allocate_lm_fleet.py [--requests 4]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.obs.log import get_logger  # noqa: E402

log = get_logger("examples.allocate_lm_fleet")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen25_3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--no-local", action="store_true",
                    help="simulated fleet only (skip the real JAX engine)")
    ap.add_argument("--mode", choices=("concurrent", "sequential"),
                    default="concurrent",
                    help="dispatch: overlap platforms (default) or the "
                         "legacy serial loop for A/B")
    args = ap.parse_args()

    from repro.domains.lm_serving import build_lm_fleet, smoke_requests
    from repro.runtime import Scheduler, make_domain

    reqs = smoke_requests(args.requests, arch=args.arch)
    fleet = build_lm_fleet(include_local=not args.no_local)
    sched = Scheduler(make_domain("lm_serving", reqs, fleet), mode=args.mode)

    log.info(f"characterising {len(fleet)} platforms x {len(reqs)} requests "
          f"({args.mode} dispatch) ...")
    sched.characterise(seed=1)
    for (pname, tid), m in sorted(sched.models.items()):
        if tid == reqs[0].task_id:
            log.info(f"  {pname:18s} beta={m.latency.beta*1e3:8.3f} ms/tok  "
                  f"gamma={m.latency.gamma*1e3:8.3f} ms")

    for method, kw in (("heuristic", {}),
                       ("ml", dict(chains=16, steps=2000, rounds=1)),
                       ("milp", dict(time_limit=30))):
        alloc = sched.allocate(method=method, **kw)
        rep = sched.execute(alloc)
        log.info(f"{method:9s} predicted={rep.predicted_makespan*1e3:9.2f} ms  "
              f"measured={rep.measured_makespan*1e3:9.2f} ms  "
              f"err={rep.makespan_error:.1%}  "
              f"wall={rep.wall_s*1e3:7.1f} ms ({rep.mode})")
    served = rep.summary["tokens"]
    asked = rep.summary["requested_tokens"]
    log.info("tokens served vs requested:",
          {tid: f"{served[tid]}/{int(asked[tid])}" for tid in served})


if __name__ == "__main__":
    main()
