"""Render a run's span tree + prediction ledger as text.

Two modes:

* ``--trace PATH`` — load an existing Chrome trace-event JSON (e.g. one
  written by ``REPRO_TRACE=1 python examples/adaptive_cluster.py``),
  validate it, and print the per-track span tree.
* default — run a small instrumented pricing smoke workload (three
  simulated Table 2 platforms, a handful of tasks, a few online rounds),
  print the span tree *and* the prediction-accountability ledger, and
  write the trace JSON to ``--out`` (default ``trace_report.json``) for
  Perfetto (https://ui.perfetto.dev).

Run:  PYTHONPATH=src python examples/trace_report.py [--out trace.json]
"""
import argparse
import json
import sys

sys.path.insert(0, "src")


def render_file(path: str) -> int:
    from repro.obs import render_span_tree, validate_chrome_trace

    with open(path) as fh:
        doc = json.load(fh)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    stats = validate_chrome_trace(events)
    print(f"{path}: {stats['events']} events, {stats['spans']} spans, "
          f"{stats['instants']} instants on {stats['tracks']} tracks")
    print(render_span_tree(events))
    return 0


def smoke_run(args) -> int:
    from repro.obs import Tracer, render_span_tree, validate_chrome_trace
    from repro.pricing import SimulatedPlatform, TABLE2_SPECS, table1_workload
    from repro.pricing.platforms import _TaskMoments
    from repro.runtime import OnlineConfig, OnlineScheduler, Scheduler, make_domain

    tasks = table1_workload(seed=2015, n_steps=16)[:args.tasks]
    moments = _TaskMoments(calib_paths=2048)
    rows = (0, 9, 14)  # Desktop, Local GPU 1, Local FPGA 1
    platforms = [SimulatedPlatform(TABLE2_SPECS[i], moments=moments, seed=7)
                 for i in rows]

    tracer = Tracer(enabled=True)
    sched = Scheduler(make_domain("pricing", tasks, platforms), trace=tracer)
    sched.characterise(seed=1, path_ladder=(256, 1024))
    report = OnlineScheduler(sched, OnlineConfig(rounds=args.rounds)).run(
        args.accuracy, method=args.method, seed=3, time_limit=10)

    events = tracer.chrome_events()
    stats = validate_chrome_trace(events)
    print(f"smoke run: {len(tasks)} tasks x {len(platforms)} platforms, "
          f"{args.rounds} rounds ({args.method}); measured makespan "
          f"{report.measured_makespan:.3f}s")
    print(f"trace: {stats['events']} events, {stats['spans']} spans, "
          f"{stats['instants']} instants on {stats['tracks']} tracks\n")
    print(render_span_tree(events))
    print()
    print(sched.ledger.render())
    tracer.write(args.out)
    print(f"\nwrote {args.out} — open it at https://ui.perfetto.dev")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default="",
                    help="render an existing Chrome trace JSON instead of "
                         "running the smoke workload")
    ap.add_argument("--out", default="trace_report.json",
                    help="where the smoke run writes its trace JSON")
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--accuracy", type=float, default=0.05)
    ap.add_argument("--method", default="heuristic",
                    choices=("heuristic", "ml", "milp"))
    args = ap.parse_args()
    if args.trace:
        return render_file(args.trace)
    return smoke_run(args)


if __name__ == "__main__":
    raise SystemExit(main())
