"""Quickstart: price a small derivatives portfolio with repro.

Run:  PYTHONPATH=src python examples/quickstart.py

Covers the public API end to end: define contracts, price them on the
local JAX engine (jnp + Pallas backends), distribute across the local
device mesh, fit the domain metric models, and ask "how long to price
this to a penny?" — the question the paper's whole machinery answers.
"""
import sys

import jax
import numpy as np
from jax.sharding import Mesh

sys.path.insert(0, "src")

from repro.core.metrics import CombinedModel  # noqa: E402
from repro.pricing import (  # noqa: E402
    BlackScholes, Heston, LocalJaxPlatform, PricingTask, asian, barrier,
    benchmark, european, price, price_sharded,
)
from repro.pricing.platforms import fit_models  # noqa: E402


def main():
    # --- 1. describe the domain objects (the F3 flow, step 1) -----------
    btc = BlackScholes(spot=100.0, rate=0.05, volatility=0.35)
    spx = Heston(spot=100.0, rate=0.03, v0=0.04, kappa=2.0, theta=0.05,
                 xi=0.4, rho=-0.6)
    portfolio = [
        PricingTask(btc, european(105.0), maturity=1.0, n_steps=64, task_id=0),
        PricingTask(btc, asian(100.0), maturity=1.0, n_steps=64, task_id=1),
        PricingTask(spx, barrier(95.0, upper=140.0), maturity=0.5,
                    n_steps=64, task_id=2),
    ]

    # --- 2. price (jnp engine, then the Pallas TPU kernel) --------------
    print("== pricing ==")
    for task in portfolio:
        res = price(task, n_paths=100_000)
        res_k = price(task, n_paths=8_192, backend="pallas", block_paths=1024)
        print(f"  task {task.task_id} ({task.option.code:3s}) "
              f"price={float(res.price):8.4f} +- {float(res.ci95):.4f}  "
              f"[pallas check: {float(res_k.price):8.4f}]")

    # --- 3. distribute across the local mesh ----------------------------
    mesh = Mesh(np.array(jax.devices()), ("data",))
    res = price_sharded(portfolio[0], 100_000, mesh)
    print(f"\n== sharded over {len(jax.devices())} device(s): "
          f"{float(res.price):.4f} +- {float(res.ci95):.4f}")

    # --- 4. characterise: fit the domain metric models (paper eq. 7-9) --
    platform = LocalJaxPlatform()
    models = fit_models(benchmark(platform, portfolio[0],
                                  (4_096, 16_384, 65_536)))
    comb = CombinedModel.from_models(models.latency, models.accuracy)
    print("\n== metric models (paper eq. 7/8/9) ==")
    print(f"  latency : {models.latency.beta*1e6:.3f} us/path "
          f"+ {models.latency.gamma*1e3:.2f} ms")
    print(f"  accuracy: alpha={models.accuracy.alpha:.2f} "
          f"(CI = alpha / sqrt(paths))")
    for target in (0.5, 0.05):
        print(f"  to price within ${target:.2f} (95% CI): "
              f"{models.accuracy.paths_for_accuracy(target):,.0f} paths "
              f"~= {comb(target):.2f}s on this machine")


if __name__ == "__main__":
    main()
