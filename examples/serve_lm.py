"""Serve a small LM with batched requests: prefill + token-by-token decode
with a KV cache, reporting the serving latency model (beta, gamma).

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6_1b6]

Defaults to the qwen family; try --arch rwkv6_1b6 or recurrentgemma_9b to
see the O(1)/O(window) state architectures (their decode beta does not
grow with context — the long_500k argument in miniature).
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen25_3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    import repro.launch.serve as S
    raise SystemExit(S.main([
        "--arch", args.arch, "--smoke", "--batch", str(args.batch),
        "--prompt-len", str(args.prompt_len), "--gen", str(args.gen)]))


if __name__ == "__main__":
    main()
