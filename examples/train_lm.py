"""End-to-end driver: train a ~100M-parameter qwen2.5-family model for a
few hundred steps on the local device, with checkpointing and the online
latency model (the paper's eq. 7 populated from live step times).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]

This wraps repro.launch.train with a ~100M config (the assigned configs
are multi-billion-parameter; this is the same family scaled to fit CPU).
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    from repro.configs import get_config

    # ~100M params: 12L x 512d x 8H, 32k vocab (qwen-family: GQA+bias+swiglu)
    cfg = dataclasses.replace(
        get_config("qwen25_3b"),
        name="qwen2.5-100m", n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=2, head_dim=64, d_ff=1408, vocab=32_768,
        param_dtype="float32", compute_dtype="float32",
    )
    total, _ = cfg.param_count()
    total += 2 * cfg.vocab * cfg.d_model
    print(f"config: {cfg.name}  ~{total/1e6:.0f}M params")

    import repro.launch.train as T

    raise SystemExit(T.main(
        ["--steps", str(args.steps), "--batch", str(args.batch),
         "--seq", str(args.seq), "--ckpt-dir", args.ckpt_dir,
         "--ckpt-every", "100", "--lr", "6e-4",
         "--warmup", "50", "--log-every", "20"],
        cfg=cfg))


if __name__ == "__main__":
    main()
