"""The paper's full flow (Fig 1): characterise a 16-platform heterogeneous
cluster, allocate a 128-task derivatives workload three ways (heuristic /
ML / MILP), execute, and compare predicted vs measured makespan.

Run:  PYTHONPATH=src python examples/allocate_cluster.py [--full]

--full uses all 128 Table 1 tasks (minutes); default is an 18-task subset.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.obs.log import get_logger  # noqa: E402

log = get_logger("examples.allocate_cluster")

from repro.pricing import PricingSolver, build_cluster, table1_workload  # noqa: E402
from repro.pricing.workload import TABLE1_CATEGORIES  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="all 128 tasks")
    ap.add_argument("--accuracy", type=float, default=0.05,
                    help="target 95%% CI in $ for every task")
    ap.add_argument("--mode", choices=("concurrent", "sequential"),
                    default="concurrent",
                    help="dispatch: overlap platforms (default) or the "
                         "legacy serial loop for A/B")
    args = ap.parse_args()

    if args.full:
        tasks = table1_workload(n_steps=64)
    else:
        cats = [(c, 2) for c, _ in TABLE1_CATEGORIES]
        tasks = table1_workload(n_steps=64, categories=cats)
    cluster = build_cluster(include_local=False)
    log.info(f"workload: {len(tasks)} tasks; cluster: {len(cluster)} platforms")

    solver = PricingSolver(tasks, cluster, mode=args.mode)
    log.info(f"characterising (online benchmarking, §3.1.4; {args.mode} dispatch)...")
    solver.characterise()  # adaptive online benchmarking

    reports = {}
    for method, kw in (("heuristic", {}),
                       ("ml", dict(chains=24, steps=4000, time_limit=60)),
                       ("milp", dict(time_limit=60))):
        alloc = solver.allocate(args.accuracy, method=method, **kw)
        rep = solver.execute(alloc, args.accuracy)
        reports[method] = rep
        nz = (alloc.A > 1e-9).sum()
        log.info(f"\n== {method} ==")
        log.info(f"  predicted makespan: {rep.predicted_makespan:10.2f} s")
        log.info(f"  measured  makespan: {rep.measured_makespan:10.2f} s "
              f"(model error {rep.makespan_error:.1%})")
        log.info(f"  allocation support: {nz} (platform,task) pairs; "
              f"solve {alloc.solve_time:.2f}s"
              + (f"; certified optimal (gap<=1e-4)" if alloc.optimal else ""))

    h = reports["heuristic"].measured_makespan
    log.info("\n== improvement over the proportional heuristic ==")
    for m in ("ml", "milp"):
        log.info(f"  {m:5s}: {h / reports[m].measured_makespan:8.2f}x")
    worst = max(reports["milp"].measured_ci.values())
    log.info(f"\nworst achieved CI: ${worst:.4f} (requested ${args.accuracy})")


if __name__ == "__main__":
    main()
