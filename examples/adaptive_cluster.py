"""Online re-allocation demo: static vs adaptive under mid-run drift.

A seeded pricing workload is characterised on three simulated Table 2
platforms, then executed twice under the same scenario — the busiest
platform slows down 4x at the static plan's half-makespan:

* **static**: the one-shot characterise -> solve -> execute flow; the
  slowed platform drags the whole makespan.
* **adaptive**: :class:`repro.runtime.OnlineScheduler` executes in rounds,
  notices predicted-vs-measured latency drifting, re-fits the metric
  models from the execute-time records, and re-solves the allocation for
  the remaining work (warm-started by the incumbent).

Run:  PYTHONPATH=src python examples/adaptive_cluster.py [--factor 4]
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=8)
    ap.add_argument("--accuracy", type=float, default=0.05)
    ap.add_argument("--factor", type=float, default=4.0,
                    help="mid-run slowdown factor for the busiest platform")
    ap.add_argument("--rounds", type=int, default=8,
                    help="dispatch tranches for the online loop")
    ap.add_argument("--method", default="milp",
                    choices=("heuristic", "ml", "milp"))
    ap.add_argument("--mode", choices=("concurrent", "sequential"),
                    default="concurrent")
    args = ap.parse_args()

    import numpy as np

    from repro.core import platform_latencies
    from repro.pricing import SimulatedPlatform, TABLE2_SPECS, table1_workload
    from repro.pricing.platforms import _TaskMoments
    from repro.runtime import (
        OnlineConfig, OnlineScheduler, Scenario, Scheduler, make_domain,
    )

    tasks = table1_workload(seed=2015, n_steps=64)[:args.tasks]
    moments = _TaskMoments(calib_paths=8192)
    rows = (0, 9, 14)  # Desktop, Local GPU 1, Local FPGA 1

    def fresh_scheduler(scenario=None):
        platforms = [SimulatedPlatform(TABLE2_SPECS[i], moments=moments, seed=7)
                     for i in rows]
        sched = Scheduler(make_domain("pricing", tasks, platforms),
                          mode=args.mode)
        sched.characterise(seed=1, path_ladder=(512, 2048, 8192, 32768))
        if scenario is not None:
            for p in platforms:
                p.attach_scenario(scenario)
        return sched, platforms

    print(f"workload: {len(tasks)} tasks on {len(rows)} simulated platforms "
          f"({args.mode} dispatch)")
    base, base_platforms = fresh_scheduler()
    alloc = base.allocate(args.accuracy, method=args.method, time_limit=30)
    lat = platform_latencies(alloc.A, base.problem(args.accuracy))
    hot = int(np.argmax(lat))
    slow_name = base_platforms[hot].spec.name
    t_half = alloc.makespan / 2
    print(f"scenario: {slow_name} slows {args.factor}x at "
          f"t={t_half:.2f}s (half the planned makespan {alloc.makespan:.2f}s)")
    scenario = Scenario().slowdown(slow_name, t_half, args.factor)

    # -- static: solve once, ride out the drift ---------------------------
    s_static, _ = fresh_scheduler(scenario)
    static = s_static.execute(
        s_static.allocate(args.accuracy, method=args.method, time_limit=30),
        args.accuracy, seed=3)
    print(f"\n== static ==\n  measured makespan: {static.measured_makespan:8.2f} s")

    # -- adaptive: the feedback loop ---------------------------------------
    s_online, _ = fresh_scheduler(scenario)
    online = OnlineScheduler(s_online, OnlineConfig(rounds=args.rounds))
    adaptive = online.run(args.accuracy, method=args.method, seed=3,
                          time_limit=30)
    drift_rounds = [r.round for r in adaptive.rounds if r.drifted]
    print(f"\n== adaptive ({len(adaptive.rounds)} rounds) ==")
    print(f"  measured makespan: {adaptive.measured_makespan:8.2f} s")
    print(f"  drift fired in rounds {drift_rounds}; "
          f"re-solved {adaptive.n_resolves}x "
          f"(+{adaptive.n_skipped} warm-start skips), "
          f"re-fit {adaptive.n_refits}x, "
          f"solver wall {adaptive.solve_wall_s:.2f}s")
    worst = max(adaptive.summary["measured_ci"].values())
    print(f"  worst achieved CI: ${worst:.4f} (requested ${args.accuracy})")

    speedup = static.measured_makespan / adaptive.measured_makespan
    print(f"\nadaptation speedup: {speedup:.2f}x")


if __name__ == "__main__":
    main()
