"""Fault-tolerant runtime: taxonomy + retry/backoff, executor failure
isolation, circuit-breaker recovery, and graceful degradation.

Three tiers:

* **Unit** — ``check_records`` sanity splitting, ``RetryPolicy``
  determinism, the ``CircuitBreaker`` state machine, executor
  timeout/cancel/error semantics, ``salvage_runs``, and the scenario-level
  flaky/corrupt injection physics.
* **Acceptance** — the canonical fault storm (flaky Desktop + finite GPU
  outage + corrupt FPGA window) on the pricing workload: with the fault
  layer armed every task still prices to target and the dead platform is
  re-admitted through OPEN -> HALF_OPEN -> CLOSED; without the layer the
  same storm kills the run. Deadline-pressure degradation trades accuracy
  for latency on cue, and an LM outage+recovery cycle stays within KV
  budgets.
* **Property** (hypothesis; profile in pyproject.toml, registered by
  conftest.py) — randomized storms asserting (a) every task completes to
  its (possibly degraded) quality target or is in the degradation log,
  (b) concurrent == sequential records bitwise under faults, and (c) no
  KV oversubscription across an outage/recovery cycle.
"""
import dataclasses
import math
import threading
import time

import pytest

from repro.runtime import (
    CircuitBreaker,
    CorruptResult,
    DispatchTimeout,
    Executor,
    FaultEvent,
    JobCancelled,
    OnlineConfig,
    OnlineScheduler,
    PlatformOutage,
    PlatformSpec,
    RetryPolicy,
    Scenario,
    Scheduler,
    TransientFault,
    check_records,
    dump_records,
    load_records,
    make_domain,
)
from repro.runtime.faults import CLOSED, HALF_OPEN, OPEN, count_retries, fault_kind
from repro.runtime.scenario import apply_scenario, salvage_runs

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic tiers still run
    HAVE_HYPOTHESIS = False

LADDER = (512, 2048, 8192)
ROWS = (0, 9, 14)  # Desktop, Local GPU 1, Local FPGA 1
QUALITY = 0.05
#: no-fault online makespan of the 6-task instance below (rounds=6, milp);
#: re-measured by test_storm_recovery_completes_all_tasks rather than
#: trusted, but documented here for the storm-cost assertions.
BASELINE_MAKESPAN = 0.083


def _tasks(n=3):
    from repro.pricing import table1_workload

    return table1_workload(seed=12, n_steps=8,
                           categories=[("BS-A", n), ("H-A", n)])


#: shared across tests: the moments cache is a pure function of the task
#: set, and rebuilding its 4096-path calibration per test dominates runtime
_MOMENTS = None


def _fresh(scenario=None, tasks=None):
    """A characterised scheduler on fresh simulated platforms (clocks and
    re-fit state are per-run, so A/B legs must not share platforms)."""
    global _MOMENTS
    from repro.pricing import SimulatedPlatform, TABLE2_SPECS
    from repro.pricing.platforms import _TaskMoments

    if _MOMENTS is None:
        _MOMENTS = _TaskMoments(calib_paths=4096)
    platforms = [SimulatedPlatform(TABLE2_SPECS[i], moments=_MOMENTS, seed=7)
                 for i in ROWS]
    sched = Scheduler(make_domain("pricing", list(tasks or _tasks()), platforms))
    sched.characterise(seed=1, path_ladder=LADDER)
    if scenario is not None:
        for p in platforms:
            p.attach_scenario(scenario)
    return sched


def _storm():
    """The canonical three-kind fault storm over the three platforms."""
    return (Scenario()
            .flaky("Desktop", p=0.2, seed=5, t=0.0, end=0.03)
            .outage("Local GPU 1", t=0.01, end=0.05)
            .corrupt("Local FPGA 1", t=0.015, end=0.02))


def _storm_cfg(**kw):
    kw.setdefault("rounds", 6)
    kw.setdefault("breaker_cooldown", 0.02)
    kw.setdefault("retry", RetryPolicy(max_attempts=3, budget=8))
    return OnlineConfig(**kw)


# ---------------------------------------------------------------- unit tier

@dataclasses.dataclass(frozen=True)
class _Rec:
    platform: str
    task_id: int
    latency: float
    price: float = 0.0


def test_check_records_passes_sane_batch():
    # a negative price is a legitimate estimate (deep OTM noise), not
    # corruption; only non-finite fields and non-positive latency are
    check_records([_Rec("p", 0, 0.5), _Rec("p", 1, 1e-9, price=-0.2)])


def test_check_records_splits_good_from_bad():
    good = [_Rec("p", 0, 0.5), _Rec("p", 3, 0.1)]
    bad = [_Rec("p", 1, -0.5),            # negated latency (corrupt window)
           _Rec("p", 2, 0.5, math.nan),   # NaN field
           _Rec("p", 4, math.inf)]        # non-finite latency
    with pytest.raises(CorruptResult) as ei:
        check_records([good[0], bad[0], bad[1], good[1], bad[2]])
    assert ei.value.records == good
    assert ei.value.bad == bad
    assert isinstance(ei.value, CorruptResult) and fault_kind(ei.value) == "corrupt"


def test_retry_policy_validation_and_retryable():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="budget"):
        RetryPolicy(budget=-1)
    pol = RetryPolicy()
    assert pol.retryable(TransientFault("x"))
    assert pol.retryable(CorruptResult("x"))
    assert pol.retryable(DispatchTimeout("x"))     # a transient
    assert not pol.retryable(PlatformOutage("x"))  # the breaker's business
    assert not pol.retryable(ValueError("x"))


def test_retry_delay_deterministic_and_capped():
    pol = RetryPolicy(backoff_base_s=0.01, backoff_cap_s=0.04, jitter=0.1)
    delays = [pol.delay(3, "Desktop", 2, k) for k in range(1, 6)]
    # pure function of its coordinates: replaying gives the same schedule
    assert delays == [pol.delay(3, "Desktop", 2, k) for k in range(1, 6)]
    for k, d in enumerate(delays, start=1):
        base = min(0.01 * 2.0 ** (k - 1), 0.04)
        assert base * 0.9 <= d <= base * 1.1
    assert max(delays) <= 0.04 * 1.1  # capped, jitter included
    # zero base disables backoff entirely (the virtual-time default)
    assert RetryPolicy().delay(3, "Desktop", 2, 1) == 0.0


def test_circuit_breaker_full_recovery_cycle():
    br = CircuitBreaker(failure_threshold=2, cooldown_s=1.0)
    assert br.state("gpu") == CLOSED and br.available("gpu")
    assert br.record_failure("gpu", now=0.1) == CLOSED  # streak 1 of 2
    assert br.record_failure("gpu", now=0.2) == OPEN
    assert not br.available("gpu") and br.open_platforms() == ("gpu",)
    assert br.poll("gpu", now=0.5) == OPEN          # cooldown not elapsed
    assert br.poll("gpu", now=1.3) == HALF_OPEN     # 1.3 >= 0.2 + 1.0
    assert not br.available("gpu")                  # probes only, no work
    assert br.record_failure("gpu", now=1.4) == OPEN  # probe failed
    assert br.poll("gpu", now=2.5) == HALF_OPEN
    assert br.record_success("gpu", now=2.6) == CLOSED
    assert br.available("gpu") and br.open_platforms() == ()
    assert [(t.frm, t.to) for t in br.transitions] == [
        (CLOSED, OPEN), (OPEN, HALF_OPEN), (HALF_OPEN, OPEN),
        (OPEN, HALF_OPEN), (HALF_OPEN, CLOSED)]
    with pytest.raises(ValueError, match="failure_threshold"):
        CircuitBreaker(failure_threshold=0)


def test_circuit_breaker_streak_resets():
    br = CircuitBreaker(failure_threshold=2)
    br.record_failure("a", 0.0)
    br.record_success("a", 0.1)   # a clean round clears the streak
    br.record_failure("a", 0.2)
    br.reset_streak("a")          # an idle round does too
    br.record_failure("a", 0.3)
    assert br.state("a") == CLOSED  # never two *consecutive* failures
    br.record_failure("a", 0.4)
    assert br.state("a") == OPEN


def test_fault_event_records_roundtrip_jsonl(tmp_path):
    from repro.runtime.faults import BreakerTransition, DegradationEvent

    events = [
        FaultEvent("Desktop", -1, 2, "transient", "retried", 1, 0.0011),
        DegradationEvent(3, 1, 0.05, 0.1, 1, "deadline"),
        BreakerTransition("gpu", OPEN, HALF_OPEN, at=0.25, round=4),
    ]
    path = tmp_path / "faults.jsonl"
    assert dump_records(events, path) == 3
    assert load_records(path) == events


# ------------------------------------------------- executor fault semantics

def _boom(x):
    if x % 2:
        raise TransientFault(f"boom {x}")
    return x * 10


@pytest.mark.parametrize("mode", ["concurrent", "sequential"])
def test_executor_isolates_per_job_errors(mode):
    out = Executor(mode=mode).map_timed(_boom, [0, 1, 2, 3], raise_errors=False)
    assert [r.value for r in out] == [0, None, 20, None]  # input order
    assert [r.ok for r in out] == [True, False, True, False]
    assert all(isinstance(r.error, TransientFault) for r in out if not r.ok)


@pytest.mark.parametrize("mode", ["concurrent", "sequential"])
def test_executor_raise_errors_runs_all_jobs_first(mode):
    ran, lock = [], threading.Lock()

    def fn(x):
        with lock:
            ran.append(x)
        if x in (1, 2):
            raise TransientFault(f"boom {x}")
        return x

    with pytest.raises(TransientFault, match="boom 1"):  # first in input order
        Executor(mode=mode).map_timed(fn, [0, 1, 2, 3])
    assert sorted(ran) == [0, 1, 2, 3]  # siblings were not discarded


def test_executor_timeout_concurrent_abandons_straggler():
    def fn(x):
        time.sleep(x)
        return x

    out = Executor(mode="concurrent").map_timed(
        fn, [0.0, 0.8], raise_errors=False, timeout_s=0.15)
    assert out[0].ok and out[0].value == 0.0
    assert isinstance(out[1].error, DispatchTimeout)


def test_executor_timeout_sequential_flags_post_hoc():
    out = Executor(mode="sequential").map_timed(
        lambda x: time.sleep(x) or x, [0.2], raise_errors=False, timeout_s=0.05)
    assert isinstance(out[0].error, DispatchTimeout)
    assert out[0].wall_s > 0.05  # the job ran to completion, then was flagged


def test_executor_cancel_skips_unstarted_jobs():
    cancel = threading.Event()
    cancel.set()
    out = Executor(mode="sequential").map_timed(
        lambda x: x, [1, 2], raise_errors=False, cancel=cancel)
    assert all(isinstance(r.error, JobCancelled) for r in out)


# ------------------------------------------------------- salvage + scenario

@pytest.mark.parametrize("exc_type", [TransientFault, PlatformOutage])
def test_salvage_runs_attaches_partial_output(exc_type):
    def run_one(x):
        if x == 2:
            raise exc_type("fault on 2")
        return x * 10

    with pytest.raises(exc_type) as ei:
        salvage_runs(run_one, [0, 1, 2, 3])
    assert ei.value.records == [0, 10]  # completed before the fault


class _FakeSpec:
    def __init__(self, name, rtt_ms=1.0):
        self.name, self.rtt_ms = name, rtt_ms


class _FakePlat:
    def __init__(self, name, scenario, rtt_ms=1.0):
        self.spec = _FakeSpec(name, rtt_ms)
        self.scenario = scenario
        self.clock = 0.0


def test_scenario_flaky_storm_is_finite_and_deterministic():
    sc = Scenario().flaky("p", p=1.0, t=0.0, end=0.0035)
    plat = _FakePlat("p", sc)
    fails = 0
    while True:
        try:
            lat = apply_scenario(plat, 0.01)
            break
        except TransientFault:
            fails += 1
            assert fails < 100, "finite flaky window never ended"
    # p=1.0 fails every draw inside the window; each failure burns one
    # retry cost (1 ms here) until the clock escapes at 0.0035
    assert fails == 4 and lat == pytest.approx(0.01)
    assert plat.clock == pytest.approx(4e-3 + 0.01)
    # pure in (seed, platform, clock): a replay sees the identical storm
    replay = _FakePlat("p", Scenario().flaky("p", p=1.0, t=0.0, end=0.0035))
    refails = 0
    while True:
        try:
            apply_scenario(replay, 0.01)
            break
        except TransientFault:
            refails += 1
    assert refails == fails and replay.clock == plat.clock
    with pytest.raises(ValueError, match="probability"):
        Scenario().flaky("p", p=1.5)


def test_scenario_flaky_p_zero_never_fires():
    plat = _FakePlat("p", Scenario().flaky("p", p=0.0))
    for _ in range(20):
        assert apply_scenario(plat, 0.01) == pytest.approx(0.01)


def test_scenario_corrupt_negates_latency_but_charges_clock():
    sc = Scenario().corrupt("p", t=0.0, end=0.015)
    plat = _FakePlat("p", sc)
    assert apply_scenario(plat, 0.01) == pytest.approx(-0.01)  # poisoned
    assert plat.clock == pytest.approx(0.01)     # the work still ran
    assert apply_scenario(plat, 0.01) == pytest.approx(-0.01)  # still inside
    assert apply_scenario(plat, 0.01) == pytest.approx(0.01)   # escaped
    with pytest.raises(CorruptResult):
        check_records([_Rec("p", 0, -0.01)])     # what dispatchers see


# -------------------------------------------------- dispatch-level retries

def test_execute_retries_through_flaky_window():
    sc = Scenario().flaky("Desktop", p=1.0, t=0.0, end=0.003)
    sched = _fresh(sc)
    alloc = sched.allocate(QUALITY, method="milp", time_limit=20)
    rep = sched.execute(alloc, QUALITY,
                        retry=RetryPolicy(max_attempts=6, budget=8))
    assert {r.task_id for r in rep.records} == {t.task_id for t in sched.tasks}
    retried = [e for e in rep.fault_events if e.action == "retried"]
    assert retried and all(e.fault == "transient" for e in retried)
    assert 1 <= count_retries(rep.fault_events) <= 8
    # the burned retry costs are charged to the flaky platform's latency
    assert rep.platform_latencies["Desktop"] > sum(
        r.latency for r in rep.records if r.platform == "Desktop")


def test_execute_retry_budget_bounds_infinite_storm():
    sc = Scenario().flaky("Desktop", p=1.0, t=0.0)  # never ends
    sched = _fresh(sc)
    alloc = sched.allocate(QUALITY, method="milp", time_limit=20)
    with pytest.raises(TransientFault):  # exhausted, not spinning forever
        sched.execute(alloc, QUALITY, retry=RetryPolicy(max_attempts=3, budget=4))


def test_execute_discards_corrupt_records_and_redispatches():
    sc = Scenario().corrupt("Local FPGA 1", t=0.0, end=0.004)
    sched = _fresh(sc)
    alloc = sched.allocate(QUALITY, method="milp", time_limit=20)
    rep = sched.execute(alloc, QUALITY, retry=RetryPolicy(max_attempts=6, budget=8))
    assert all(r.latency > 0 for r in rep.records)  # no poison in the output
    assert any(e.fault == "corrupt" and e.action == "retried"
               for e in rep.fault_events)
    assert {r.task_id for r in rep.records} == {t.task_id for t in sched.tasks}


# --------------------------------------------------------- acceptance tier

def test_storm_recovery_completes_all_tasks():
    """The canonical storm: transient blips retried, the GPU outage opens
    its breaker and a cooldown later a probe re-admits it, corrupt records
    are discarded — and every task still prices to target."""
    base = OnlineScheduler(_fresh(), OnlineConfig(rounds=6)).run(
        QUALITY, method="milp", seed=3, time_limit=20)
    rep = OnlineScheduler(_fresh(_storm()), _storm_cfg()).run(
        QUALITY, method="milp", seed=3, time_limit=20)

    assert rep.dead_platforms == ()
    assert rep.recovered_platforms == ("Local GPU 1",)
    assert rep.n_probes >= 1
    assert 1 <= rep.n_retries <= 8  # bounded by the policy budget
    gpu = [(t.frm, t.to) for t in rep.breaker_transitions
           if t.platform == "Local GPU 1"]
    assert gpu == [(CLOSED, OPEN), (OPEN, HALF_OPEN), (HALF_OPEN, CLOSED)]
    assert any(r.revived == ("Local GPU 1",) for r in rep.rounds)
    kinds = {e.fault for e in rep.fault_events}
    assert {"transient", "outage", "corrupt"} <= kinds
    # every task priced to the *undegraded* target despite the storm
    for t in _tasks():
        assert rep.summary["measured_ci"][t.task_id] <= QUALITY * 1.25
    # the storm costs makespan (burned retries, stranded GPU work re-run
    # elsewhere) but bounded: within 2x of the fault-free run
    assert base.measured_makespan < rep.measured_makespan
    assert rep.measured_makespan <= 2.0 * base.measured_makespan


def test_storm_without_fault_layer_kills_the_run():
    """The same storm against the legacy loop (no retry policy): the first
    transient blip is unhandled and the workload dies — the demonstrable
    failure the fault layer exists to prevent."""
    with pytest.raises(TransientFault):
        OnlineScheduler(_fresh(_storm()), OnlineConfig(rounds=6)).run(
            QUALITY, method="milp", seed=3, time_limit=20)


def test_storm_mode_parity():
    """Concurrent and sequential dispatch see the identical storm: same
    records (bitwise), same fault log, same breaker history."""
    runs = {}
    for mode in ("concurrent", "sequential"):
        runs[mode] = OnlineScheduler(_fresh(_storm()), _storm_cfg()).run(
            QUALITY, method="milp", seed=3, time_limit=20, mode=mode)
    conc, seq = runs["concurrent"], runs["sequential"]
    assert conc.records == seq.records
    assert conc.measured_makespan == seq.measured_makespan
    assert conc.fault_events == seq.fault_events
    assert conc.breaker_transitions == seq.breaker_transitions
    assert conc.recovered_platforms == seq.recovered_platforms


def test_deadline_pressure_degrades_quality_on_cue():
    """An unmeetable deadline walks every task one rung down the
    degradation ladder (pricing: a looser CI target) and the run then
    finishes inside the deadline instead of blowing it."""
    sched = _fresh()
    predicted = sched.allocate(QUALITY, method="milp", time_limit=20).makespan
    cfg = OnlineConfig(rounds=6, deadline_s=predicted * 0.5,
                       degrade_steps=(1.0, 3.0))
    rep = OnlineScheduler(_fresh(), cfg).run(
        QUALITY, method="milp", seed=3, time_limit=20)
    assert rep.degradations, "deadline pressure never degraded"
    assert all(d.reason == "deadline" for d in rep.degradations)
    degraded = {d.task_id: d.quality_to for d in rep.degradations}
    assert degraded.keys() == {t.task_id for t in _tasks()}
    for tid, target in degraded.items():
        assert target == pytest.approx(QUALITY * 2.0)  # rung 1: step 1.0
        assert rep.summary["measured_ci"][tid] <= target * 1.25
    assert rep.measured_makespan <= cfg.deadline_s


def _lm_fleet():
    from repro.domains.lm_serving import (
        LMRequest, SimulatedLMPlatform, kv_bytes_per_token,
    )

    reqs = [LMRequest("qwen25_3b", prompt_len=8, gen_tokens=32 + 4 * i,
                      batch=2, max_new_tokens=64, task_id=i)
            for i in range(8)]
    per = kv_bytes_per_token(reqs[0].config(), reqs[0].batch)
    total_kv = per * sum(r.gen_tokens for r in reqs)
    specs = [
        PlatformSpec("Fast", "GPU", "sim", "loc", 400.0, 1.0,
                     mem_bytes=total_kv * 0.35),
        PlatformSpec("Steady A", "CPU", "sim", "loc", 40.0, 1.0,
                     mem_bytes=total_kv * 2),
        PlatformSpec("Steady B", "CPU", "sim", "loc", 40.0, 1.0,
                     mem_bytes=total_kv * 2),
    ]
    fleet = [SimulatedLMPlatform(s, seed=0) for s in specs]
    sched = Scheduler(make_domain("lm_serving", reqs, fleet))
    sched.characterise(seed=1, token_ladder=(2, 4, 8, 16))
    return sched, fleet, reqs, specs, per


def _assert_no_kv_oversubscription(rep, specs, per):
    # tasks complete only at the end of the run, so everything served on a
    # platform was resident together (couple of tokens of ceil rounding ok)
    held = {s.name: 0.0 for s in specs}
    for rec in rep.records:
        held[rec.platform] += per * rec.n_tokens
    for s in specs:
        assert held[s.name] <= s.mem_bytes * 1.02 + 2 * per, \
            (s.name, held[s.name], s.mem_bytes)


def test_lm_outage_recovery_respects_kv_budgets():
    """A capacity-constrained fleet loses its fast platform, re-solves
    without it, re-admits it after a probe — and at no point does the
    re-shuffled plan oversubscribe anyone's KV budget."""
    sched, fleet, reqs, specs, per = _lm_fleet()
    m0 = sched.allocate(method="milp", time_limit=20).makespan
    scenario = Scenario().outage("Fast", t=0.0, end=0.002)
    for p in fleet:
        p.attach_scenario(scenario)
    cfg = OnlineConfig(rounds=6, gamma_duty=0.0, breaker_cooldown=m0 * 0.3,
                       retry=RetryPolicy())
    rep = OnlineScheduler(sched, cfg).run(method="milp", seed=3, time_limit=20)
    assert rep.recovered_platforms == ("Fast",)
    assert [(t.frm, t.to) for t in rep.breaker_transitions
            if t.platform == "Fast"] == [
        (CLOSED, OPEN), (OPEN, HALF_OPEN), (HALF_OPEN, CLOSED)]
    for req in reqs:
        assert rep.summary["tokens"][req.task_id] >= req.gen_tokens
    _assert_no_kv_oversubscription(rep, specs, per)


# ----------------------------------------------------------- property tier

if HAVE_HYPOTHESIS:

    def _small_storm_run(p_flaky, seed, mode="concurrent", deadline_frac=None):
        """One 4-task online run under a randomized (but escape-proof)
        storm: the flaky window spans at most 10 retry costs and the
        policy budget exceeds that, so completion is guaranteed."""
        storm = (Scenario()
                 .flaky("Desktop", p=p_flaky, seed=seed, t=0.0, end=0.01)
                 .corrupt("Local FPGA 1", t=0.0, end=0.002)
                 .outage("Local GPU 1", t=0.005, end=0.02))
        sched = _fresh(storm, tasks=_tasks(n=2))
        deadline = None
        if deadline_frac is not None:
            deadline = sched.allocate(QUALITY, method="heuristic").makespan \
                * deadline_frac
        cfg = OnlineConfig(rounds=4, breaker_cooldown=0.01,
                           retry=RetryPolicy(max_attempts=12, budget=32),
                           degrade_steps=(1.0, 3.0), deadline_s=deadline)
        return OnlineScheduler(sched, cfg).run(
            QUALITY, method="heuristic", seed=3, mode=mode)

    @given(p_flaky=st.floats(0.0, 1.0), seed=st.integers(0, 10**6),
           deadline_frac=st.one_of(st.none(), st.floats(0.3, 1.5)))
    @settings(deadline=None)
    def test_property_tasks_complete_to_target_or_are_logged_degraded(
            p_flaky, seed, deadline_frac):
        """Invariant (a): under any escape-proof storm, every task either
        prices to the full quality target or every relaxation it received
        is in the degradation log — no silent accuracy loss."""
        rep = _small_storm_run(p_flaky, seed, deadline_frac=deadline_frac)
        degraded = {}
        for d in rep.degradations:
            degraded[d.task_id] = max(degraded.get(d.task_id, 0.0), d.quality_to)
        for t in _tasks(n=2):
            target = degraded.get(t.task_id, QUALITY)
            assert rep.summary["measured_ci"][t.task_id] <= target * 1.3, \
                (t.task_id, rep.summary["measured_ci"][t.task_id], target)

    @given(p_flaky=st.floats(0.0, 1.0), seed=st.integers(0, 10**6))
    @settings(deadline=None)
    def test_property_mode_parity_under_faults(p_flaky, seed):
        """Invariant (b): records, fault log and breaker history are
        bitwise identical across executor modes for any storm."""
        conc = _small_storm_run(p_flaky, seed, mode="concurrent")
        seq = _small_storm_run(p_flaky, seed, mode="sequential")
        assert conc.records == seq.records
        assert conc.fault_events == seq.fault_events
        assert conc.breaker_transitions == seq.breaker_transitions
        assert conc.degradations == seq.degradations
        assert conc.measured_makespan == seq.measured_makespan

    @given(end_frac=st.floats(0.1, 1.0), cool_frac=st.floats(0.05, 1.0))
    @settings(deadline=None, max_examples=10)  # LM characterise dominates
    def test_property_no_kv_oversubscription_across_recovery(
            end_frac, cool_frac):
        """Invariant (c): however the outage window and breaker cooldown
        land relative to the workload, the re-shuffled plans never
        oversubscribe a platform's KV budget."""
        sched, fleet, reqs, specs, per = _lm_fleet()
        m0 = sched.allocate(method="milp", time_limit=20).makespan
        scenario = Scenario().outage("Fast", t=0.0, end=m0 * end_frac)
        for p in fleet:
            p.attach_scenario(scenario)
        cfg = OnlineConfig(rounds=5, gamma_duty=0.0,
                           breaker_cooldown=m0 * cool_frac,
                           retry=RetryPolicy())
        rep = OnlineScheduler(sched, cfg).run(method="milp", seed=3,
                                              time_limit=20)
        for req in reqs:
            assert rep.summary["tokens"][req.task_id] >= req.gen_tokens
        _assert_no_kv_oversubscription(rep, specs, per)

else:  # pragma: no cover - exercised only without hypothesis
    @pytest.mark.skip(reason="hypothesis not installed — property tier "
                             "(storm invariants) skipped")
    def test_property_tier_requires_hypothesis():
        ...
