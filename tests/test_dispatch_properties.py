"""Property tests for the §Perf-critical numerical paths.

The hillclimb swaps MoE dispatch strategies and recurrence chunkings for
sharding-efficiency; these tests pin the invariant that every variant
computes the SAME function (up to float reassociation), so a perf change
can never silently change the model.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import build_model
from repro.models import moe as moe_mod
from repro.models.common import Rules
from repro.models.moe import moe_ffn


def _moe_setup(seed, b=2, s=8, d=16, e=8, k=2, cf=8.0):
    """Tiny MoE layer with capacity high enough that nothing drops."""
    cfg = dataclasses.replace(
        get_config("moonshot_v1_16b_a3b").smoke(),
        d_model=d, n_experts=e, top_k=k, d_ff=24, capacity_factor=cf)
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    p = {
        "moe/router": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.3,
        "moe/w_gate": jax.random.normal(ks[1], (e, d, 24), jnp.float32) * 0.2,
        "moe/w_in": jax.random.normal(ks[2], (e, d, 24), jnp.float32) * 0.2,
        "moe/w_out": jax.random.normal(ks[3], (e, 24, d), jnp.float32) * 0.2,
    }
    x = jax.random.normal(ks[4], (b, s, d), jnp.float32)
    return cfg, p, x


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_moe_dispatch_modes_equivalent_when_no_drops(seed):
    cfg, p, x = _moe_setup(seed)
    rules = Rules({})
    outs = {m: np.asarray(moe_ffn(p, cfg, x, rules, dispatch=m))
            for m in ("scatter", "a2a", "a2a_sp")}
    np.testing.assert_allclose(outs["scatter"], outs["a2a"], rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(outs["scatter"], outs["a2a_sp"], rtol=2e-5, atol=2e-6)


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_moe_decode_batch_group_matches_per_seq_when_no_drops(seed):
    """S=1 routes through the batch-global group; with ample capacity the
    result must equal the per-sequence (scatter) formulation."""
    cfg, p, x = _moe_setup(seed, b=4, s=1, cf=16.0)
    rules = Rules({})
    got = np.asarray(moe_ffn(p, cfg, x, rules))               # decode path
    want = np.asarray(moe_mod._moe_ffn_sp(p, cfg, x, rules))  # generic path
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_moe_gate_weights_normalised():
    """Combine weights over the top-k must sum to ~1 per token (pre-drop):
    zeroing all experts' outputs must zero the MoE contribution exactly."""
    cfg, p, x = _moe_setup(0)
    p0 = dict(p, **{k: jnp.zeros_like(v) for k, v in p.items() if k != "moe/router"})
    out = np.asarray(moe_ffn(p0, cfg, x, Rules({})))
    np.testing.assert_array_equal(out, np.zeros_like(out))


@pytest.mark.parametrize("s", [24, 32, 48])  # splits at 8 (ragged), 16, 32
def test_rwkv_output_invariant_to_sequence_factorisation(s):
    """Prefill(s) last-token logits == prefill(s - CHUNK) + CHUNK decode
    steps — the chunked-parallel algebra equals the exact recurrence at
    every boundary split, not just the one smoke-tested length."""
    from repro.models.rwkv import CHUNK
    cfg = get_config("rwkv6_1b6").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, s), 0, cfg.vocab)
    _, want = jax.jit(lambda p, t: model.prefill(p, {"tokens": t}, s + 8))(params, toks)
    cache, _ = jax.jit(lambda p, t: model.prefill(p, {"tokens": t}, s + 8))(
        params, toks[:, :s - CHUNK])
    got = None
    for i in range(s - CHUNK, s):
        cache, got = jax.jit(model.decode_step)(params, cache, toks[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=2e-2, atol=2e-3)


def test_griffin_scan_chunk_invariance():
    """RG-LRU associative scan must be invariant to the SCAN_CHUNK size."""
    from repro.models import griffin
    cfg = get_config("recurrentgemma_9b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(6), (2, 64),
                                          0, cfg.vocab)}
    old = griffin.SCAN_CHUNK
    try:
        losses = []
        for chunk in (8, 32, 4096):
            griffin.SCAN_CHUNK = chunk
            losses.append(float(jax.jit(model.loss)(params, batch)))
    finally:
        griffin.SCAN_CHUNK = old
    np.testing.assert_allclose(losses[0], losses[1], rtol=1e-5)
    np.testing.assert_allclose(losses[0], losses[2], rtol=1e-5)
