"""Example scripts + drivers must run end to end (subprocess smoke)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def run(args, timeout=600):
    return subprocess.run([sys.executable] + args, env=ENV, cwd=REPO,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_quickstart():
    r = run(["examples/quickstart.py"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "metric models" in r.stdout
    assert "pallas check" in r.stdout


@pytest.mark.slow
def test_serve_lm():
    r = run(["examples/serve_lm.py", "--gen", "4", "--prompt-len", "16"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "decode:" in r.stdout


def test_adaptive_cluster():
    # deliberately not slow-marked: the online re-allocation loop must be
    # exercised by the fast CI leg (simulated platforms, ~seconds)
    r = run(["examples/adaptive_cluster.py", "--tasks", "6"], timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "drift fired in rounds" in r.stdout
    assert "adaptation speedup" in r.stdout


@pytest.mark.slow
def test_allocate_lm_fleet():
    r = run(["examples/allocate_lm_fleet.py", "--requests", "2"])
    assert r.returncode == 0, r.stdout + r.stderr
    for solver in ("heuristic", "ml", "milp"):
        assert solver in r.stdout
    assert "tokens served vs requested" in r.stdout


@pytest.mark.slow
def test_train_driver_straggler_and_loss():
    r = run(["-m", "repro.launch.train", "--arch", "qwen25_3b", "--smoke",
             "--steps", "12", "--batch", "2", "--seq", "16",
             "--log-every", "4"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "training complete" in r.stdout


@pytest.mark.slow
def test_serve_driver_skips_nondecoder():
    # every assigned arch has a decoder; exercise the guard via the flag API
    r = run(["-m", "repro.launch.serve", "--arch", "rwkv6_1b6", "--smoke",
             "--batch", "2", "--prompt-len", "16", "--gen", "4"])
    assert r.returncode == 0, r.stdout + r.stderr
