"""Solver quality ordering + certificates on small random instances,
plus the scale layer's correctness contracts: task-family clustering
(exactness for identical families, bounded error with capacity intact)
and the O(k) incremental patch (bound test + full-solve fallback).
"""
import dataclasses

import numpy as np
import pytest

try:  # property sweep widens when hypothesis is available; the
    # deterministic grid below keeps minimal environments covered
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

from repro.core import (
    AllocationProblem,
    capacity_ok,
    check_allocation,
    cluster_tasks,
    clustered_allocation,
    makespan,
    milp_allocation,
    ml_allocation,
    patch_allocation,
    platform_usage,
    proportional_allocation,
    restrict_problem,
    synthetic,
)


def small_problem(seed=0, mu=4, tau=12, psi=2.0, case="Het-Inc"):
    return synthetic.generate_case(case, tau=tau, mu=mu, psi=psi, seed=seed)


@pytest.mark.parametrize("seed", [0, 7, 42])
def test_solver_quality_ordering(seed):
    """On small instances the three approaches are totally ordered:
    milp <= annealing <= heuristic makespan (§6.3's hierarchy)."""
    p = small_problem(seed)
    h = proportional_allocation(p)
    a = ml_allocation(p, chains=8, steps=1500, rounds=1, seed=0)
    m = milp_allocation(p, time_limit=30)
    for alloc in (h, a, m):
        check_allocation(alloc.A, p)
    assert a.makespan <= h.makespan * (1 + 1e-6)
    if m.optimal:  # certified optimum bounds every other solver
        assert m.makespan <= a.makespan * (1 + 1e-4)
        assert m.makespan <= h.makespan * (1 + 1e-4)


def test_milp_dual_bound_sanity():
    """The HiGHS dual bound is the paper's external quality certificate
    (§2.2.4): a true lower bound on every feasible allocation's makespan."""
    p = small_problem(9)
    m = milp_allocation(p, time_limit=30)
    assert m.bound is not None
    assert 0 <= m.bound <= m.makespan * (1 + 1e-3)
    for other in (proportional_allocation(p),
                  ml_allocation(p, chains=8, steps=1000, rounds=1, seed=1)):
        assert m.bound <= other.makespan * (1 + 1e-3)


def test_heuristic_degenerate_zero_latency_platform():
    """An all-zero (delta, gamma) row means zero standalone latency; the
    1/L_i share rule must not divide by zero — free platforms take a
    uniform share and the makespan collapses to 0 (optimal)."""
    rng = np.random.default_rng(0)
    delta = rng.uniform(1, 10, size=(4, 6))
    gamma = rng.uniform(0.1, 1.0, size=(4, 6))
    delta[1] = 0.0
    gamma[1] = 0.0
    delta[3] = 0.0
    gamma[3] = 0.0
    p = AllocationProblem(delta=delta, gamma=gamma, c=np.full(6, 0.5))
    a = proportional_allocation(p)
    check_allocation(a.A, p)
    assert np.isfinite(a.A).all()
    np.testing.assert_allclose(a.A[[0, 2]], 0.0)   # paid platforms idle
    np.testing.assert_allclose(a.A[[1, 3]], 0.5)   # uniform over free ones
    assert a.makespan == 0.0


# -- task-family clustering ------------------------------------------------

def tiled_problem(case="Het-Inc", families=4, mult=8, mu=4, psi=0.5, seed=0,
                  capacity=False):
    """A fleet instance with exact duplicated task families: ``families``
    base signatures tiled ``mult`` times each."""
    base = synthetic.generate_case(case, tau=families, mu=mu, psi=psi,
                                   seed=seed)
    idx = np.arange(families * mult) % families
    p = dataclasses.replace(base, delta=base.delta[:, idx],
                            gamma=base.gamma[:, idx], c=base.c[idx])
    if capacity:
        rng = np.random.default_rng(seed + 1)
        # per-family resource columns, tiled like the work columns — a
        # family member must share its whole signature, resource included
        R = rng.uniform(0.5, 2.0, size=(mu, families))[:, idx]
        usage = (R * proportional_allocation(p).A).sum(axis=1)
        p = dataclasses.replace(p, resource=R, capacity=usage * 1.3 + 1e-9)
    return p


def test_clustering_exactness_identical_families():
    """The exactness anchor: under the ``sum`` gamma model the reduced
    objective equals the proportionally-expanded full-frame makespan
    *identically* — no tolerance."""
    for seed in (0, 3):
        p = tiled_problem(seed=seed, families=5, mult=7)
        plan = cluster_tasks(p)
        assert plan.n_clusters == 5
        reduced = plan.reduce(p, gamma_model="sum")
        sub = milp_allocation(reduced, time_limit=20)
        A = plan.expand(sub.A, mode="proportional")
        check_allocation(A, p)
        assert makespan(A, p) == pytest.approx(sub.makespan, rel=1e-12)


def test_contiguous_expansion_never_worse_than_proportional():
    """The contiguous split sheds gamma constants vs the proportional one
    (same per-platform mass, fewer members touched)."""
    p = tiled_problem(families=6, mult=6, mu=5, seed=2)
    plan = cluster_tasks(p)
    sub = milp_allocation(plan.reduce(p, gamma_model="sum"), time_limit=20)
    m_prop = makespan(plan.expand(sub.A, mode="proportional"), p)
    A_cont = plan.expand(sub.A, mode="contiguous")
    check_allocation(A_cont, p)
    assert makespan(A_cont, p) <= m_prop * (1 + 1e-9)


#: bounded-error bar for the default clustered pipeline (contiguous
#: expansion + member descent + LP polish) on family-structured instances.
CLUSTER_TOL = 1.15

_SOLVER_KW = {
    "heuristic": {},
    "ml": dict(chains=6, steps=800, rounds=1, seed=0, time_limit=10),
    "milp": dict(time_limit=10),
}


def _check_clustered_matches(method, case, psi, seed, with_capacity):
    """Clustered vs unclustered on a duplicated-family instance: valid
    allocation, makespan within tolerance, zero capacity oversubscription.
    Shapes are fixed across calls so the ML solver JIT-compiles once."""
    p = tiled_problem(case=case, families=4, mult=8, mu=4, psi=psi,
                      seed=seed, capacity=with_capacity)
    kw = _SOLVER_KW[method]
    un = {"heuristic": proportional_allocation,
          "ml": ml_allocation, "milp": milp_allocation}[method](p, **kw)
    clus = clustered_allocation(p, method, **kw)
    check_allocation(clus.A, p)
    assert clus.meta["n_clusters"] == 4
    assert clus.makespan <= un.makespan * CLUSTER_TOL
    if with_capacity:
        usage = platform_usage(clus.A, p)
        assert (usage <= p.capacity * (1 + 1e-6)).all()


@pytest.mark.parametrize("method", ["heuristic", "ml", "milp"])
@pytest.mark.parametrize("case,psi,seed,with_capacity", [
    ("Het-Inc", 0.25, 3, False),
    ("Het-Mix", 1.0, 7, True),
    ("Hom-Con", 0.25, 11, True),
    ("Het-Con", 1.0, 19, False),
])
def test_clustered_solve_matches_unclustered(method, case, psi, seed,
                                             with_capacity):
    _check_clustered_matches(method, case, psi, seed, with_capacity)


if st is not None:
    @pytest.mark.parametrize("method", ["heuristic", "ml", "milp"])
    @settings(deadline=None, max_examples=8)
    @given(case=st.sampled_from(["Hom-Con", "Het-Con", "Het-Mix", "Het-Inc"]),
           psi=st.sampled_from([0.25, 1.0]),
           seed=st.integers(0, 10_000),
           with_capacity=st.booleans())
    def test_clustered_solve_matches_unclustered_property(
            method, case, psi, seed, with_capacity):
        """The hypothesis-widened sweep of the deterministic grid above."""
        _check_clustered_matches(method, case, psi, seed, with_capacity)


def test_clustered_milp_within_5pct_at_scale():
    """The bench acceptance bar, pinned as a test: on the canonical
    family-structured Het-Inc instance the clustered MILP stays within 5%
    of the unclustered solve."""
    p = tiled_problem(case="Het-Inc", families=12, mult=10, mu=8, psi=0.25,
                      seed=11)
    un = milp_allocation(p, time_limit=30)
    clus = clustered_allocation(p, "milp", time_limit=30)
    check_allocation(clus.A, p)
    assert clus.meta["n_clusters"] == 12
    assert clus.makespan <= un.makespan * 1.05


def test_clustering_rtol_merges_near_identical():
    """Near-identical families (1e-4 relative jitter) merge under a
    quantised signature and the solution stays within the bounded-error
    bar of the exact-clustering solve."""
    p = tiled_problem(families=4, mult=8, mu=4, seed=5)
    rng = np.random.default_rng(9)
    jitter = 1 + rng.uniform(-1e-4, 1e-4, size=p.delta.shape)
    p_jit = dataclasses.replace(p, delta=p.delta * jitter)
    assert cluster_tasks(p_jit).n_clusters == p.tau           # exact: no merge
    plan = cluster_tasks(p_jit, rtol=1e-2)
    assert plan.n_clusters == 4                               # quantised: merged
    clus = clustered_allocation(p_jit, "milp", rtol=1e-2, time_limit=10)
    un = milp_allocation(p_jit, time_limit=10)
    check_allocation(clus.A, p_jit)
    assert clus.makespan <= un.makespan * CLUSTER_TOL


# -- O(k) incremental patch ------------------------------------------------

def test_patch_allocation_patched_path():
    """k arrivals patch the incumbent: only the new columns move, the
    result honours the bound test, and both paths stay within tolerance
    of a from-scratch solve."""
    p = tiled_problem(families=6, mult=6, mu=4, seed=4)
    old = np.arange(p.tau - 4)
    new = np.arange(p.tau - 4, p.tau)
    base = milp_allocation(restrict_problem(p, tasks=old), time_limit=20)
    A_base = np.zeros((p.mu, p.tau))
    A_base[:, old] = base.A
    patched = patch_allocation(p, A_base, new, "milp", time_limit=20)
    assert patched.meta["incremental"] == "patched"
    assert patched.meta["patch_tasks"] == 4
    # old columns untouched, new columns valid
    np.testing.assert_allclose(patched.A[:, old], A_base[:, old])
    np.testing.assert_allclose(patched.A.sum(axis=0), 1.0, atol=1e-6)
    # the designed guarantee: within patch_tol of the fresh heuristic bound
    bound = patched.meta["heuristic_bound"]
    assert patched.makespan <= bound * (1 + patched.meta["patch_tol"]) * (1 + 1e-9)
    # and therefore within tolerance of the from-scratch solve
    scratch = milp_allocation(p, time_limit=20)
    assert patched.makespan <= max(scratch.makespan, bound) * (1 + 0.25 + 1e-9)


def test_patch_allocation_full_fallback():
    """A patch that cannot stay within tolerance of the fresh heuristic
    bound is discarded for a full solve (and says so in meta): the
    incumbent parks the old task entirely on the platform where it runs
    100x slow, so holding that share fixed costs ~100 while any fresh
    solve rebalances it to ~2."""
    p = AllocationProblem.from_work(np.array([[100.0, 1.0], [1.0, 1.0]]),
                                    np.zeros((2, 2)))
    A_base = np.array([[1.0, 0.0], [0.0, 0.0]])
    fb = patch_allocation(p, A_base, [1], "milp", time_limit=20)
    assert fb.meta["incremental"] == "full_fallback"
    assert fb.meta["patched_makespan"] is not None   # the patch was tried
    scratch = milp_allocation(p, time_limit=20)
    assert fb.makespan <= scratch.makespan * (1 + 1e-6)
    np.testing.assert_allclose(fb.A.sum(axis=0), 1.0, atol=1e-6)
