"""Solver quality ordering + certificates on small random instances.

Deliberately hypothesis-free (unlike test_allocation.py) so these run in
minimal environments too: the §6.3 hierarchy and the MILP dual bound are
tier-1 invariants of the allocation back-end every domain relies on.
"""
import numpy as np
import pytest

from repro.core import (
    AllocationProblem,
    check_allocation,
    milp_allocation,
    ml_allocation,
    proportional_allocation,
    synthetic,
)


def small_problem(seed=0, mu=4, tau=12, psi=2.0, case="Het-Inc"):
    return synthetic.generate_case(case, tau=tau, mu=mu, psi=psi, seed=seed)


@pytest.mark.parametrize("seed", [0, 7, 42])
def test_solver_quality_ordering(seed):
    """On small instances the three approaches are totally ordered:
    milp <= annealing <= heuristic makespan (§6.3's hierarchy)."""
    p = small_problem(seed)
    h = proportional_allocation(p)
    a = ml_allocation(p, chains=8, steps=1500, rounds=1, seed=0)
    m = milp_allocation(p, time_limit=30)
    for alloc in (h, a, m):
        check_allocation(alloc.A, p)
    assert a.makespan <= h.makespan * (1 + 1e-6)
    if m.optimal:  # certified optimum bounds every other solver
        assert m.makespan <= a.makespan * (1 + 1e-4)
        assert m.makespan <= h.makespan * (1 + 1e-4)


def test_milp_dual_bound_sanity():
    """The HiGHS dual bound is the paper's external quality certificate
    (§2.2.4): a true lower bound on every feasible allocation's makespan."""
    p = small_problem(9)
    m = milp_allocation(p, time_limit=30)
    assert m.bound is not None
    assert 0 <= m.bound <= m.makespan * (1 + 1e-3)
    for other in (proportional_allocation(p),
                  ml_allocation(p, chains=8, steps=1000, rounds=1, seed=1)):
        assert m.bound <= other.makespan * (1 + 1e-3)


def test_heuristic_degenerate_zero_latency_platform():
    """An all-zero (delta, gamma) row means zero standalone latency; the
    1/L_i share rule must not divide by zero — free platforms take a
    uniform share and the makespan collapses to 0 (optimal)."""
    rng = np.random.default_rng(0)
    delta = rng.uniform(1, 10, size=(4, 6))
    gamma = rng.uniform(0.1, 1.0, size=(4, 6))
    delta[1] = 0.0
    gamma[1] = 0.0
    delta[3] = 0.0
    gamma[3] = 0.0
    p = AllocationProblem(delta=delta, gamma=gamma, c=np.full(6, 0.5))
    a = proportional_allocation(p)
    check_allocation(a.A, p)
    assert np.isfinite(a.A).all()
    np.testing.assert_allclose(a.A[[0, 2]], 0.0)   # paid platforms idle
    np.testing.assert_allclose(a.A[[1, 3]], 0.5)   # uniform over free ones
    assert a.makespan == 0.0
