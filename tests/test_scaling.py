"""CI fast slice of the scaling bench (ISSUE 7 satellite): the 100x16
cell for the heuristic and ML solvers, asserted under generous wall-clock
bars so a scalability regression (accidental densification, a dropped
vectorisation, an un-warmed JIT in the timed region) fails the non-slow
leg instead of waiting for the weekly bench sweep.

The full {10,100,1000} x {4,16,64} x {heuristic,ml,milp} sweep stays in
``benchmarks/allocation_bench.py`` (weekly chaos/bench workflow); this
module re-uses its cell builder so the test measures exactly what the
bench measures.
"""
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.allocation_bench import scaling_cell, scaling_instance  # noqa: E402

from repro.core import cluster_tasks  # noqa: E402

#: generous wall-clock bars (seconds) for the 100x16 cell — an order of
#: magnitude above observed timings (heuristic ~2ms, ml ~0.3s after JIT
#: warm-up) so CI machine jitter never flakes, while a complexity-class
#: regression (e.g. O(tau*mu) -> O((tau*mu)^2)) still trips them.
SOLVE_BAR_S = {"heuristic": 5.0, "ml": 60.0}


def test_scaling_instance_has_family_structure():
    """The bench instance really is family-tiled: clustering finds the
    24 base signatures, so the clustered leg of the cell is exercised."""
    p = scaling_instance(100, 16, seed=0)
    assert p.tau == 100 and p.mu == 16
    assert cluster_tasks(p).n_clusters == 24


@pytest.mark.parametrize("method", ["heuristic", "ml"])
def test_scaling_cell_100x16_under_bar(method):
    cell = scaling_cell(100, 16, method, fast=True)
    for leg in ("unclustered", "clustered"):
        assert cell[leg]["total_s"] <= SOLVE_BAR_S[method], (
            f"{method}/{leg} solve took {cell[leg]['total_s']:.2f}s "
            f"(bar {SOLVE_BAR_S[method]}s) — scalability regression?")
    # quality + feasibility ride along with the timing bar
    assert cell["capacity_ok"]
    assert cell["makespan_ratio"] <= 1.05
    # telemetry satellite: per-phase meta is populated on both legs
    assert cell["clustered"]["n_clusters"] == 24
    assert cell["unclustered"]["total_s"] is not None
