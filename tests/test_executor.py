"""Concurrency semantics of the runtime Executor and concurrent Scheduler:
mode parity (identical records/summary), true wall-clock overlap, seed
derivation stability, and the zero-makespan guard."""
import time
import types

import numpy as np
import pytest

from repro.core import AllocationProblem
from repro.runtime import (
    Executor,
    RuntimeReport,
    Scheduler,
    make_domain,
    seed_for,
)
from repro.runtime.domain import Domain


# ------------------------------------------------------------- the executor

def test_executor_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown executor mode"):
        Executor(mode="parallel-ish")
    with pytest.raises(ValueError, match="unknown executor mode"):
        Scheduler(types.SimpleNamespace(), mode="parallel-ish")


def test_executor_preserves_order_both_modes():
    for mode in ("sequential", "concurrent"):
        out = Executor(mode=mode).map(lambda x: x * x, range(10))
        assert out == [x * x for x in range(10)]


def test_executor_propagates_exceptions():
    def boom(x):
        raise RuntimeError(f"job {x} failed")

    for mode in ("sequential", "concurrent"):
        with pytest.raises(RuntimeError, match="job"):
            Executor(mode=mode).map(boom, [1, 2])


def test_executor_concurrent_overlaps_sleeps():
    """Four 0.15s sleeps must overlap: concurrent wall well under the sum."""
    def job(_):
        time.sleep(0.15)
        return True

    t0 = time.perf_counter()
    timed = Executor(mode="concurrent").map_timed(job, range(4))
    wall = time.perf_counter() - t0
    assert all(r.value for r in timed)
    assert all(r.wall_s >= 0.15 for r in timed)  # each job's own clock
    assert wall < 0.45  # vs 0.6s back-to-back


# --------------------------------------------------------- seed derivation

def test_seed_for_is_stable_and_order_free():
    s = seed_for(1, "Desktop", ("qwen25_3b", True, 2, 8, 104), 0)
    assert s == seed_for(1, "Desktop", ("qwen25_3b", True, 2, 8, 104), 0)
    assert 0 <= s < 2**31
    # every coordinate matters
    base = (1, "Desktop", "key", 0)
    variants = [(2, "Desktop", "key", 0), (1, "GPU", "key", 0),
                (1, "Desktop", "other", 0), (1, "Desktop", "key", 1)]
    assert len({seed_for(*base), *[seed_for(*v) for v in variants]}) == 5


# ------------------------------------------------------ zero-makespan guard

def test_makespan_error_guard_on_empty_dispatch():
    rep = RuntimeReport(allocation=None, predicted_makespan=1.0,
                        measured_makespan=0.0, platform_latencies={},
                        records=[])
    assert rep.makespan_error == np.inf


def test_pricing_execution_report_guard_on_empty_dispatch():
    from repro.pricing.solver import ExecutionReport

    rep = ExecutionReport(allocation=None, predicted_makespan=1.0,
                          measured_makespan=0.0, platform_latencies={},
                          prices={}, predicted_ci={}, measured_ci={},
                          records=[])
    assert rep.makespan_error == np.inf


# ------------------------------------------- mode parity: pricing domain

def _pricing_scheduler(mode):
    from repro.pricing import SimulatedPlatform, TABLE2_SPECS, table1_workload
    from repro.pricing.platforms import _TaskMoments

    tasks = table1_workload(seed=12, n_steps=8,
                            categories=[("BS-A", 2), ("H-A", 2)])
    moments = _TaskMoments(calib_paths=4096)
    platforms = [SimulatedPlatform(TABLE2_SPECS[0], moments=moments),
                 SimulatedPlatform(TABLE2_SPECS[9], moments=moments),
                 SimulatedPlatform(TABLE2_SPECS[14], moments=moments)]
    sched = Scheduler(make_domain("pricing", tasks, platforms), mode=mode)
    sched.characterise(seed=1, path_ladder=(512, 2048))
    return sched


def test_pricing_concurrent_matches_sequential():
    """Characterise + execute must be bitwise-identical across modes."""
    seq = _pricing_scheduler("sequential")
    conc = _pricing_scheduler("concurrent")
    assert set(seq.models) == set(conc.models)
    for key in seq.models:
        assert seq.models[key].latency.beta == conc.models[key].latency.beta
        assert seq.models[key].accuracy.alpha == conc.models[key].accuracy.alpha

    alloc = seq.allocate(0.5, method="milp", time_limit=20)
    r_seq = seq.execute(alloc, 0.5, seed=3)
    r_conc = conc.execute(alloc, 0.5, seed=3)
    assert r_seq.mode == "sequential" and r_conc.mode == "concurrent"
    assert r_seq.records == r_conc.records
    assert r_seq.summary == r_conc.summary
    assert r_seq.measured_makespan == r_conc.measured_makespan


def test_pricing_concurrent_makespan_is_max_not_sum():
    """Measured makespan is the slowest platform, bounded by the latency sum."""
    sched = _pricing_scheduler("concurrent")
    rep = sched.execute(sched.allocate(0.5, method="heuristic"), 0.5)
    loaded = [v for v in rep.platform_latencies.values() if v > 0]
    assert len(loaded) >= 2  # the heuristic spreads a 3-platform instance
    assert rep.measured_makespan == pytest.approx(max(loaded))
    assert rep.measured_makespan <= sum(loaded) + 1e-12
    assert set(rep.platform_wall_s) == set(rep.platform_latencies)


# ------------------------------------------- mode parity: LM serving domain

def test_lm_concurrent_matches_sequential():
    from repro.domains.lm_serving import build_lm_fleet, smoke_requests

    reqs = smoke_requests(3)
    scheds = {}
    for mode in ("sequential", "concurrent"):
        sched = Scheduler(
            make_domain("lm_serving", reqs, build_lm_fleet(include_local=False)),
            mode=mode)
        sched.characterise(seed=1, token_ladder=(2, 4, 8))
        scheds[mode] = sched
    seq, conc = scheds["sequential"], scheds["concurrent"]
    for key in seq.models:
        assert seq.models[key].latency.beta == conc.models[key].latency.beta
        assert seq.models[key].latency.gamma == conc.models[key].latency.gamma

    alloc = seq.allocate(method="heuristic")
    r_seq = seq.execute(alloc, seed=3)
    r_conc = conc.execute(alloc, seed=3)
    assert r_seq.records == r_conc.records
    assert r_seq.summary == r_conc.summary


def test_lm_continuous_batching_join_leave_matches_across_modes():
    """Continuous batching under join/leave traffic is mode-invariant.

    Heterogeneous generation targets make requests *leave* the running
    decode batch at different steps, and a KV budget of ~2 residents makes
    queued requests *join* as pages free — the full continuous-batching
    state machine. The timeline is a pure function of each platform's
    dispatch, so concurrent and sequential executors must still produce
    bitwise-identical records."""
    import dataclasses

    from repro.domains.lm_serving import (
        LM_FLEET_SPECS,
        SimulatedLMPlatform,
        request_kv_bytes,
        smoke_requests,
    )

    reqs = smoke_requests(6)
    assert len({r.gen_tokens for r in reqs}) > 2  # genuinely staggered leaves
    biggest = max(request_kv_bytes(r, r.gen_tokens) for r in reqs)
    specs = [dataclasses.replace(s, mem_bytes=2.2 * biggest)
             for s in LM_FLEET_SPECS]
    reports = {}
    for mode in ("sequential", "concurrent"):
        fleet = [SimulatedLMPlatform(s) for s in specs]
        sched = Scheduler(make_domain("lm_serving", reqs, fleet), mode=mode)
        sched.characterise(seed=1, token_ladder=(2, 4, 8))
        alloc = sched.allocate(method="milp", time_limit=20)
        reports[mode] = sched.execute(alloc, seed=3)
    assert reports["sequential"].records == reports["concurrent"].records
    assert reports["sequential"].summary == reports["concurrent"].summary


# ----------------------------------------------- true wall-clock overlap

class _SleepDomain(Domain):
    """Minimal domain whose dispatch occupies real wall clock: the overlap
    test measures *concurrency*, not simulation bookkeeping."""

    name = "_sleep"

    def __init__(self, n_tasks, platforms, sleep_s=0.2):
        super().__init__([types.SimpleNamespace(task_id=i) for i in range(n_tasks)],
                         platforms)
        self.sleep_s = sleep_s

    def launch_key(self, task):
        return 0  # one launch group per platform

    def characterise_batch(self, platform, tasks, seed=1, **kw):
        return [[types.SimpleNamespace(platform=platform.spec.name,
                                       task_id=t.task_id, latency=0.01)
                 for t in tasks] for _ in range(2)]

    def fit_models(self, records):
        return types.SimpleNamespace(
            combined=types.SimpleNamespace(delta=1.0, gamma=0.0))

    def work_units(self, model, quality):
        return quality

    def dispatch_batch(self, platform, tasks, units, seed=0):
        time.sleep(self.sleep_s)  # one device busy-window per launch group
        return [types.SimpleNamespace(platform=platform.spec.name,
                                      task_id=t.task_id, latency=self.sleep_s)
                for t in tasks]


def _spec_platform(name):
    return types.SimpleNamespace(spec=types.SimpleNamespace(name=name))


def test_concurrent_execute_overlaps_wall_clock():
    platforms = [_spec_platform("p0"), _spec_platform("p1"),
                 _spec_platform("p2")]
    domain = _SleepDomain(2, platforms, sleep_s=0.2)
    sched = Scheduler(domain)
    sched.characterise()
    alloc = sched.allocate(quality=8.0, method="heuristic")
    r_seq = sched.execute(alloc, 8.0, mode="sequential")
    r_conc = sched.execute(alloc, 8.0, mode="concurrent")
    assert r_seq.wall_s >= 3 * 0.2 * 0.95        # sum of platform sleeps
    assert r_conc.wall_s < r_seq.wall_s * 0.75   # genuine overlap
    assert [r.task_id for r in r_conc.records] == [r.task_id for r in r_seq.records]
    # per-platform wall clocks span only that platform's dispatches
    for wall in r_conc.platform_wall_s.values():
        assert wall == pytest.approx(0.2, rel=0.5)


def test_realtime_simulated_platform_occupies_wall_clock():
    """realtime=x makes a simulated run sleep x * latency, records unchanged."""
    from repro.domains.lm_serving import (
        LM_FLEET_SPECS, SimulatedLMPlatform, smoke_requests,
    )

    (req,) = smoke_requests(1)
    spec = LM_FLEET_SPECS[3]  # Cloud Pod: 120ms RTT dominates
    fast = SimulatedLMPlatform(spec)
    slow = SimulatedLMPlatform(spec, realtime=1.0)
    rec_fast = fast.run(req, 8, seed=0)
    t0 = time.perf_counter()
    rec_slow = slow.run(req, 8, seed=0)
    wall = time.perf_counter() - t0
    assert rec_slow == rec_fast  # realtime never changes the record
    assert wall >= rec_slow.latency * 0.9
