"""Per-architecture smoke tests (reduced same-family configs, CPU):
one forward/train step asserting output shapes + no NaNs, plus decode
consistency (prefill+decode == forward) where the family allows exact
incremental evaluation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, cells_for, get_config
from repro.data.pipeline import batch_for
from repro.models import build_model
from repro.optim.adamw import AdamW
from repro.train.train_step import make_train_step

B, S = 2, 32


def make_batch(cfg, b=B, s=S, seed=0):
    return {k: jnp.asarray(v) for k, v in batch_for(cfg, b, s, seed=seed).items()}


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).smoke()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        out[arch] = (cfg, model, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(built, arch):
    cfg, model, params = built[arch]
    loss = jax.jit(model.loss)(params, make_batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # random-init loss should be near ln(vocab) for a uniform predictor
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.5 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_updates_and_finite(built, arch):
    cfg, model, params = built[arch]
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, opt))
    p1, s1, m1 = step(params, opt.init(params), make_batch(cfg))
    assert np.isfinite(float(m1["loss"]))
    assert np.isfinite(float(m1["grad_norm"]))
    # params actually moved
    deltas = [float(jnp.abs(p1[k] - params[k]).max()) for k in params]
    assert max(deltas) > 0
    # all leaves stay finite
    for k, v in p1.items():
        assert np.isfinite(np.asarray(v)).all(), k
    # shapes preserved
    for k in params:
        assert p1[k].shape == params[k].shape


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_shapes_and_finite(built, arch):
    cfg, model, params = built[arch]
    if not cfg.has_decoder:
        pytest.skip("no decoder")
    batch = make_batch(cfg)
    cache, logits = jax.jit(lambda p, b: model.prefill(p, b, S + 8))(params, batch)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for _ in range(3):
        cache, logits = jax.jit(model.decode_step)(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["yi_9b", "qwen25_3b", "whisper_tiny",
                                  "rwkv6_1b6", "recurrentgemma_9b"])
def test_incremental_decode_matches_full_forward(built, arch):
    """Causal consistency: decoding token-by-token must reproduce the
    full-sequence forward logits at each position."""
    cfg, model, params = built[arch]
    batch = make_batch(cfg, s=16)
    # full-forward logits at the last position via prefill on all 16 tokens
    _, full_last = jax.jit(lambda p, b: model.prefill(p, b, 24))(params, batch)
    # prefill on 15 tokens, then decode the 16th
    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :15]
    cache, _ = jax.jit(lambda p, b: model.prefill(p, b, 24))(params, short)
    _, dec_last = jax.jit(model.decode_step)(params, cache,
                                             batch["tokens"][:, 15:16])
    np.testing.assert_allclose(np.asarray(full_last)[:, 0],
                               np.asarray(dec_last)[:, 0],
                               rtol=2e-2, atol=2e-3)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and balanced-ish routing, most tokens
    must be processed (output differs from a zeroed-MoE baseline)."""
    cfg = get_config("moonshot_v1_16b_a3b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss = float(jax.jit(model.loss)(params, batch))
    # zero the expert weights: loss must change (experts contribute)
    p2 = dict(params)
    for k in p2:
        if "/moe/" in k and "router" not in k:
            p2[k] = jnp.zeros_like(p2[k])
    loss2 = float(jax.jit(model.loss)(p2, batch))
    assert loss != pytest.approx(loss2, rel=1e-4)


def test_rwkv_decode_matches_chunked_prefill():
    """The exact recurrence (decode) must continue the chunked-parallel
    form (prefill) — validates the chunk factorisation algebra."""
    cfg = get_config("rwkv6_1b6").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 32), 0, cfg.vocab)
    cache, last = jax.jit(lambda p, t: model.prefill(p, {"tokens": t}, 40))(params, toks)
    # decode the same 32nd token from a 31-token prefill... chunk=16 needs
    # multiples; decode 16 extra tokens one by one and compare state flow
    c2, _ = jax.jit(lambda p, t: model.prefill(p, {"tokens": t}, 40))(params, toks[:, :16])
    logits = None
    for i in range(16, 32):
        c2, logits = jax.jit(model.decode_step)(params, c2, toks[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits),
                               rtol=2e-2, atol=2e-3)


def test_cells_for_skips():
    skips = {a: {s.name for s in cells_for(get_config(a))} for a in ARCHS}
    assert "long_500k" not in skips["yi_9b"]          # full attention
    assert "long_500k" in skips["rwkv6_1b6"]          # SSM
    assert "long_500k" in skips["recurrentgemma_9b"]  # hybrid
    assert {"train_4k", "prefill_32k", "decode_32k"} <= skips["arctic_480b"]
    total = sum(len(v) for v in skips.values())
    assert total == 32  # 40 cells - 8 long_500k skips


def test_param_counts_match_published_scale():
    """Full configs should land near the published parameter counts."""
    import math
    expect = {"starcoder2_7b": 7e9, "yi_9b": 8.8e9, "qwen25_3b": 3e9,
              "internvl2_76b": 69e9, "arctic_480b": 450e9,
              "moonshot_v1_16b_a3b": 28e9,  # as-assigned: 48L x 64e x 1408
              "recurrentgemma_9b": 8.5e9,
              "rwkv6_1b6": 1.5e9, "minitron_8b": 7.5e9}
    for arch, target in expect.items():
        cfg = get_config(arch)
        total, active = cfg.param_count()
        total += 2 * cfg.vocab * cfg.d_model  # embeddings
        assert 0.5 * target < total < 1.8 * target, (arch, total, target)
        assert active <= total
