"""Pricing-domain tests: engine correctness, convergence, platform layer."""
import math

import jax
import numpy as np
import pytest
from scipy.stats import norm

from repro.pricing import (
    BlackScholes,
    Heston,
    LocalJaxPlatform,
    PricingTask,
    SimulatedPlatform,
    TABLE2_SPECS,
    asian,
    barrier,
    benchmark,
    double_barrier,
    digital_double_barrier,
    european,
    price,
    price_sharded,
    table1_workload,
)
from repro.pricing.platforms import fit_models
from jax.sharding import Mesh


BS = BlackScholes(spot=100.0, rate=0.05, volatility=0.2)
HESTON = Heston(spot=100.0, rate=0.03, v0=0.04, kappa=2.0, theta=0.04, xi=0.3, rho=-0.7)


def bs_closed_form(s, k, r, sigma, t, call=True):
    d1 = (math.log(s / k) + (r + sigma**2 / 2) * t) / (sigma * math.sqrt(t))
    d2 = d1 - sigma * math.sqrt(t)
    if call:
        return s * norm.cdf(d1) - k * math.exp(-r * t) * norm.cdf(d2)
    return k * math.exp(-r * t) * norm.cdf(-d2) - s * norm.cdf(-d1)


@pytest.mark.parametrize("strike,call", [(90.0, True), (105.0, True), (110.0, False)])
def test_european_vs_closed_form(strike, call):
    task = PricingTask(underlying=BS, option=european(strike, call),
                       maturity=1.0, n_steps=32, task_id=0)
    res = price(task, 200_000)
    ref = bs_closed_form(100, strike, 0.05, 0.2, 1.0, call)
    assert abs(float(res.price) - ref) < max(float(res.ci95), 1e-3), \
        f"MC {float(res.price)} vs closed form {ref} outside CI {float(res.ci95)}"


def test_ci_shrinks_as_sqrt_n():
    """The accuracy model's n^-1/2 law, measured from the engine itself."""
    task = PricingTask(underlying=BS, option=european(100.0), maturity=1.0,
                       n_steps=16, task_id=1)
    ci_small = float(price(task, 4_096, seed=5).ci95)
    ci_big = float(price(task, 65_536, seed=5).ci95)
    assert ci_small / ci_big == pytest.approx(4.0, rel=0.15)  # sqrt(16)=4


def test_price_ordering_invariants():
    """Domain no-arbitrage orderings: knock-outs <= vanilla, DB <= B."""
    mk = lambda o, i: PricingTask(underlying=HESTON, option=o, maturity=1.0,
                                  n_steps=32, task_id=i)
    n = 50_000
    vanilla = float(price(mk(european(100.0), 2), n).price)
    barr = float(price(mk(barrier(100.0, upper=140.0), 2), n).price)
    dbarr = float(price(mk(double_barrier(100.0, 70.0, 140.0), 2), n).price)
    assert barr <= vanilla + 1e-6
    assert dbarr <= barr + 1e-6


def test_digital_bounded_by_payout():
    task = PricingTask(underlying=BS, option=digital_double_barrier(10.0, 70.0, 140.0),
                       maturity=1.0, n_steps=32, task_id=3)
    res = price(task, 20_000)
    assert 0.0 <= float(res.price) <= 10.0


def test_sharded_equals_unsharded():
    task = PricingTask(underlying=BS, option=asian(95.0), maturity=1.5,
                       n_steps=16, task_id=4)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    a = price(task, 32_768, seed=2)
    b = price_sharded(task, 32_768, mesh, seed=2)
    assert float(a.price) == pytest.approx(float(b.price), rel=1e-6)


def test_path_decomposition_independence():
    """Counter-based RNG: two half-runs with offsets == one full run."""
    from repro.pricing.mc import path_stats
    task = PricingTask(underlying=BS, option=european(100.0), maturity=1.0,
                       n_steps=8, task_id=5)
    full = path_stats(task, 1024, seed=9)
    lo = path_stats(task, 512, seed=9, path_offset=0)
    hi = path_stats(task, 512, seed=9, path_offset=512)
    for f, l, h in zip(full, lo, hi):
        np.testing.assert_array_equal(np.asarray(f), np.concatenate([l, h]))


def test_workload_matches_table1():
    tasks = table1_workload()
    assert len(tasks) == 128
    from collections import Counter
    counts = Counter(t.category for t in tasks)
    assert counts == {"BS-A": 10, "BS-B": 10, "BS-DB": 10, "BS-DDB": 5,
                      "H-A": 25, "H-B": 29, "H-DB": 29, "H-DDB": 5, "H-E": 5}
    assert len({t.task_id for t in tasks}) == 128


def test_table2_has_16_platforms():
    assert len(TABLE2_SPECS) == 16
    cats = {s.category for s in TABLE2_SPECS}
    assert cats == {"CPU", "GPU", "FPGA"}


def test_simulated_platform_latency_model():
    """Simulated latency must follow work/gflops + rtt within jitter."""
    spec = TABLE2_SPECS[4]  # AWS Server EC1
    p = SimulatedPlatform(spec, jitter=1e-6)
    task = table1_workload()[0]
    rec = p.run(task, 100_000)
    from repro.pricing.platforms import kflop_per_path
    expect = kflop_per_path(task) * 1e3 * 100_000 / (spec.gflops * 1e9) + spec.rtt_ms / 1e3
    assert rec.latency == pytest.approx(expect, rel=1e-3)


def test_online_benchmarking_fits_simulated_platform():
    """End-to-end §3.1.4: bench a simulated platform, recover its beta."""
    spec = TABLE2_SPECS[9]  # Local GPU 1: fast, negligible RTT
    p = SimulatedPlatform(spec, jitter=0.001)
    task = table1_workload()[3]
    m = fit_models(benchmark(p, task, (2_000, 8_000, 32_000, 128_000)))
    from repro.pricing.platforms import kflop_per_path
    beta_true = kflop_per_path(task) * 1e3 / (spec.gflops * 1e9)
    assert m.latency.beta == pytest.approx(beta_true, rel=0.05)


def test_local_platform_runs_real_wallclock():
    p = LocalJaxPlatform()
    task = PricingTask(underlying=BS, option=european(100.0), maturity=1.0,
                       n_steps=8, task_id=6)
    rec = p.run(task, 4_096)
    assert rec.latency > 0
    assert rec.ci95 > 0
