"""Capacity-constrained allocation: the resource dimension across the
whole stack.

Deterministic tier: container validation, the water-filling heuristic
clamp, MILP capacity rows, the warm-start "rejected" contract, the
continuous-batching KV accounting of the LM platforms, and the online
regression where drift fires while a platform is near capacity (an
offsets-only restriction would oversubscribe it).

Property tier (hypothesis; profile in pyproject.toml, registered by
conftest.py): random *feasible-by-construction* instances asserting, for
all three solvers — (a) no platform exceeds its capacity, (b) the
milp <= ml <= heuristic makespan hierarchy survives the extra constraint
dimension, (c) restrict_problem -> solve -> expand_allocation round-trips
capacities exactly, and (d) infeasible instances raise the same typed
:class:`repro.core.CapacityError` from every solver.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    AllocationProblem,
    CapacityError,
    check_allocation,
    expand_allocation,
    makespan,
    milp_allocation,
    ml_allocation,
    platform_usage,
    proportional_allocation,
    restrict_problem,
)
from repro.core.heuristic import clamp_to_capacity, incumbent_shortcut

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic tier still runs
    HAVE_HYPOTHESIS = False

# fixed shapes so every property example reuses one annealer compilation
MU, TAU = 3, 5

SOLVERS = {
    "heuristic": lambda p: proportional_allocation(p),
    "ml": lambda p: ml_allocation(p, chains=6, steps=400, rounds=1, seed=0),
    "milp": lambda p: milp_allocation(p, time_limit=20),
}


def build_problem(delta, gamma, resource, split, headroom):
    """A capacity instance that is feasible by construction: the capacity
    vector is a known allocation's usage plus headroom."""
    delta = np.asarray(delta, dtype=float).reshape(MU, TAU)
    gamma = np.asarray(gamma, dtype=float).reshape(MU, TAU)
    resource = np.asarray(resource, dtype=float).reshape(MU, TAU)
    A0 = np.asarray(split, dtype=float).reshape(MU, TAU)
    A0 = A0 / A0.sum(axis=0, keepdims=True)
    capacity = (resource * A0).sum(axis=1) * (1.0 + headroom) + 1e-9
    return AllocationProblem(delta=delta, gamma=gamma, c=np.ones(TAU),
                             resource=resource, capacity=capacity)


# ---------------------------------------------------------- deterministic

def det_problem(seed=0, headroom=0.25):
    rng = np.random.default_rng(seed)
    return build_problem(rng.uniform(0.5, 10, MU * TAU),
                         rng.uniform(0.0, 1.0, MU * TAU),
                         rng.uniform(0.5, 4.0, MU * TAU),
                         rng.uniform(0.05, 1.0, MU * TAU),
                         headroom)


def test_resource_capacity_validation():
    rng = np.random.default_rng(0)
    delta = rng.uniform(1, 2, (2, 3))
    gamma = np.zeros((2, 3))
    with pytest.raises(ValueError, match="together"):
        AllocationProblem(delta=delta, gamma=gamma, c=np.ones(3),
                          resource=np.ones((2, 3)))
    with pytest.raises(ValueError, match="capacity must be"):
        AllocationProblem(delta=delta, gamma=gamma, c=np.ones(3),
                          resource=np.ones((2, 3)), capacity=np.ones(3))
    with pytest.raises(ValueError, match="resource must match"):
        AllocationProblem(delta=delta, gamma=gamma, c=np.ones(3),
                          resource=np.ones((3, 2)), capacity=np.ones(2))
    with pytest.raises(ValueError, match=">= 0"):
        AllocationProblem(delta=delta, gamma=gamma, c=np.ones(3),
                          resource=-np.ones((2, 3)), capacity=np.ones(2))


def test_platform_usage_and_check_allocation():
    p = det_problem()
    A = np.full((MU, TAU), 1.0 / MU)
    np.testing.assert_allclose(platform_usage(A, p),
                               (p.resource * A).sum(axis=1))
    # a capacity-free problem reports zero usage
    free = AllocationProblem(delta=p.delta, gamma=p.gamma, c=p.c)
    assert platform_usage(A, free).sum() == 0.0
    over = dataclasses.replace(p, capacity=p.capacity * 0.0 + 1e-12)
    with pytest.raises(AssertionError, match="capacity"):
        check_allocation(A, over)


def test_water_fill_repairs_per_task_not_just_per_platform():
    """Uniform per-platform shares cannot fit this geometry (each platform
    is cheap for one task and ruinous for the other); the clamp must move
    *task-specific* mass, or fall back to the capacity-aware LP."""
    p = AllocationProblem(
        delta=np.array([[1.0, 2.0], [2.0, 1.0]]),
        gamma=np.zeros((2, 2)),
        c=np.ones(2),
        resource=np.array([[1.0, 100.0], [100.0, 1.0]]),
        capacity=np.array([1.0, 1.0]),
    )
    h = proportional_allocation(p)
    check_allocation(h.A, p)
    assert h.meta.get("capacity") in ("clamped", "lp")


def test_clamp_to_capacity_is_noop_when_feasible():
    p = det_problem(headroom=5.0)
    A = proportional_allocation(p).A
    np.testing.assert_allclose(clamp_to_capacity(A, p), A)


def test_milp_capacity_binds_and_costs_makespan():
    """A binding budget must push work off the preferred platform: the
    unconstrained optimum violates this instance's capacities, and the
    constrained solve trades makespan for feasibility."""
    p = det_problem(seed=3, headroom=0.05)
    un = milp_allocation(dataclasses.replace(p, resource=None, capacity=None),
                         time_limit=20)
    con = milp_allocation(p, time_limit=20)
    check_allocation(con.A, p)
    assert not (platform_usage(un.A, p)
                <= p.capacity * (1 + 1e-6)).all(), "instance must bind"
    assert con.makespan >= un.makespan - 1e-9


def test_warm_start_rejected_when_incumbent_violates_capacity():
    """PR-4 follow-up fix: an incumbent that no longer fits the (remaining)
    capacities must not be waved through on its makespan — the shortcut
    reports warm_start="rejected", both solvers solve for real, and the
    result is feasible."""
    p = det_problem(seed=5, headroom=0.10)
    # concentrate everything on the platform with the least capacity slack:
    # excellent makespan geometry or not, it cannot fit
    worst = int(np.argmin(p.capacity / p.resource.sum(axis=1)))
    A_bad = np.zeros((MU, TAU))
    A_bad[worst] = 1.0
    assert not (platform_usage(A_bad, p) <= p.capacity).all()
    _, shortcut, meta = incumbent_shortcut(p, A_bad, "milp", warm_tol=1e9, t0=0.0)
    assert shortcut is None and meta == {"warm_start": "rejected"}
    for solve, kw in ((milp_allocation, dict(time_limit=20)),
                      (ml_allocation, dict(chains=6, steps=400, rounds=1,
                                           seed=0))):
        alloc = solve(p, incumbent=A_bad, warm_tol=1e9, **kw)
        assert alloc.meta["warm_start"] == "rejected"
        check_allocation(alloc.A, p)


def test_warm_start_still_skips_feasible_good_incumbent():
    p = det_problem(seed=5, headroom=0.5)
    good = proportional_allocation(p)
    alloc = milp_allocation(p, incumbent=good, warm_tol=0.5)
    assert alloc.meta["warm_start"] == "skipped"


def test_restrict_problem_carries_remaining_capacity():
    p = det_problem(seed=7)
    remaining_cap = p.capacity * np.array([0.5, 1.0, 0.25])
    sub = restrict_problem(p, [0, 2], [1, 3, 4], remaining=[0.5, 1.0, 0.25],
                           capacity=remaining_cap)
    # capacities round-trip exactly (no arithmetic on the carried budget)
    assert (sub.capacity == remaining_cap[[0, 2]]).all()
    # resource columns scale with the remaining work, like delta
    np.testing.assert_allclose(
        sub.resource,
        p.resource[np.ix_([0, 2], [1, 3, 4])] * np.array([0.5, 1.0, 0.25]))
    with pytest.raises(ValueError, match="capacity override"):
        restrict_problem(dataclasses.replace(p, resource=None, capacity=None),
                         [0], [0], capacity=p.capacity)


# --------------------------------------------------------------- property

if HAVE_HYPOTHESIS:

    unit = st.floats(0.05, 1.0, allow_nan=False, width=64)

    @st.composite
    def instances(draw, headroom=st.floats(0.05, 1.5)):
        return build_problem(
            draw(st.lists(st.floats(0.5, 20.0), min_size=MU * TAU,
                          max_size=MU * TAU)),
            draw(st.lists(st.floats(0.0, 2.0), min_size=MU * TAU,
                          max_size=MU * TAU)),
            draw(st.lists(st.floats(0.1, 8.0), min_size=MU * TAU,
                          max_size=MU * TAU)),
            draw(st.lists(unit, min_size=MU * TAU, max_size=MU * TAU)),
            draw(headroom),
        )

    @given(instances())
    def test_property_no_solver_oversubscribes(p):
        """(a) every solver returns usage <= capacity on every platform."""
        for name, solve in SOLVERS.items():
            alloc = solve(p)
            check_allocation(alloc.A, p)
            usage = platform_usage(alloc.A, p)
            assert (usage <= p.capacity * (1 + 1e-6) + 1e-9).all(), \
                (name, usage, p.capacity)

    @given(instances())
    def test_property_solver_hierarchy_survives_capacity(p):
        """(b) milp <= ml <= heuristic (§6.3) still holds with the second
        constraint dimension in play."""
        h = SOLVERS["heuristic"](p)
        a = SOLVERS["ml"](p)
        m = SOLVERS["milp"](p)
        assert a.makespan <= h.makespan * (1 + 1e-6)
        if m.optimal:
            assert m.makespan <= a.makespan * (1 + 1e-4)
            assert m.makespan <= h.makespan * (1 + 1e-4)

    @given(instances(),
           st.lists(st.floats(0.1, 1.0), min_size=TAU, max_size=TAU),
           st.sets(st.integers(0, TAU - 1), min_size=1))
    def test_property_restrict_solve_expand_roundtrip(p, remaining, cols):
        """(c) restriction carries capacities exactly; the expanded
        sub-solution stays within the original budgets."""
        cols = sorted(cols)
        rem = [remaining[j] for j in cols]
        sub = restrict_problem(p, None, cols, rem, capacity=p.capacity)
        assert (sub.capacity == p.capacity).all()  # exact, bitwise
        np.testing.assert_allclose(
            sub.resource, p.resource[:, cols] * np.asarray(rem)[None, :])
        dropped = [j for j in range(p.tau) if j not in cols]
        scaled = dataclasses.replace(
            p, resource=p.resource * _remaining_frame(rem, cols, p.tau))
        for name, solve in SOLVERS.items():
            alloc = solve(sub)
            A_full = expand_allocation(alloc.A, p.mu, p.tau,
                                       list(range(p.mu)), cols)
            # dropped columns receive nothing; the held budget is respected
            assert A_full[:, dropped].sum() == 0.0
            assert (platform_usage(A_full, scaled)
                    <= p.capacity * (1 + 1e-6) + 1e-9).all(), name

    def _remaining_frame(rem, cols, tau):
        frame = np.zeros(tau)
        frame[cols] = rem
        return frame[None, :]

    @given(instances())
    def test_property_infeasible_raises_same_typed_error(p):
        """(d) when even best-case placement exceeds the summed budget,
        every solver raises the one CapacityError."""
        starved = dataclasses.replace(
            p, capacity=np.full(MU, p.resource.min(axis=0).sum() * 0.3 / MU))
        for name, solve in SOLVERS.items():
            with pytest.raises(CapacityError):
                solve(starved)

else:

    @pytest.mark.skip(reason="hypothesis not installed — property tier "
                             "(a)-(d) over the three solvers did not run")
    def test_property_tier_requires_hypothesis():
        """Visible skip so a green run cannot silently mask the absent
        property suite (mirrors the importorskip modules' behaviour)."""


# ------------------------------------------------ LM serving: KV capacity

def test_kv_bytes_per_token_follows_model_shapes():
    from repro.configs import get_config
    from repro.domains.lm_serving import kv_bytes_per_token, request_kv_bytes
    from repro.domains.lm_serving import LMRequest

    cfg = get_config("qwen25_3b").smoke()
    per = kv_bytes_per_token(cfg, batch=2)
    expect = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 4 * 2  # f32 smoke
    assert per == expect
    # recurrent state does not grow per token
    assert kv_bytes_per_token(get_config("rwkv6_1b6").smoke()) == 0.0
    req = LMRequest("qwen25_3b", prompt_len=8, gen_tokens=4, batch=2,
                    max_new_tokens=16)
    assert request_kv_bytes(req) == per * (8 + 16)
    assert request_kv_bytes(req, 4) == per * (8 + 4)


def test_lm_problem_carries_kv_resource_and_hbm_capacity():
    from repro.domains.lm_serving import (
        build_lm_fleet, kv_bytes_per_token, smoke_requests,
    )
    from repro.runtime import Scheduler, make_domain

    reqs = smoke_requests(3)
    fleet = build_lm_fleet(include_local=False)
    sched = Scheduler(make_domain("lm_serving", reqs, fleet))
    sched.characterise(seed=1, token_ladder=(2, 4, 8))
    p = sched.problem()
    assert p.resource is not None and p.capacity is not None
    per = kv_bytes_per_token(reqs[0].config(), reqs[0].batch)
    # whole-task resource = bytes/token x requested tokens, on every row
    np.testing.assert_allclose(
        p.resource,
        np.broadcast_to(per * np.array([r.gen_tokens for r in reqs]),
                        p.resource.shape))
    np.testing.assert_allclose(p.capacity,
                               [pl.spec.mem_bytes for pl in fleet])


def test_simulated_continuous_batching_amortises_shared_steps():
    """Solo serves reproduce the analytic formula; a shared batch costs
    strictly less engine-busy time than the same requests served solo
    (decode is memory-bound), and a KV budget that only admits one request
    at a time degrades gracefully back to solo costs."""
    from repro.domains.lm_serving import (
        LM_FLEET_SPECS, SimulatedLMPlatform, request_kv_bytes, smoke_requests,
    )

    reqs = smoke_requests(3)
    roomy = SimulatedLMPlatform(LM_FLEET_SPECS[1], jitter=0.0)
    solo = [roomy.run(r, 12, seed=1) for r in reqs]
    batched = roomy.run_batch(reqs, 12, seed=1)
    assert sum(r.latency for r in batched) < sum(r.latency for r in solo)
    # pinched budget: one resident max -> every request pays solo cost
    tight_spec = dataclasses.replace(
        LM_FLEET_SPECS[1],
        mem_bytes=float(max(request_kv_bytes(r, 12) for r in reqs)) + 1.0)
    tight = SimulatedLMPlatform(tight_spec, jitter=0.0)
    serial = tight.run_batch(reqs, 12, seed=1)
    for got, want in zip(serial, solo):
        assert got.latency == pytest.approx(want.latency)


def test_single_request_larger_than_hbm_raises_capacity_error():
    from repro.domains.lm_serving import LM_FLEET_SPECS, SimulatedLMPlatform, smoke_requests

    spec = dataclasses.replace(LM_FLEET_SPECS[0], mem_bytes=64.0)
    platform = SimulatedLMPlatform(spec)
    with pytest.raises(CapacityError, match="budget"):
        platform.run_batch(smoke_requests(1), 8, seed=0)


def test_local_engine_streams_leave_running_batch():
    """generate_many: per-stream attributed latencies sum to the engine's
    busy time, and a stream's cost stops accruing once it leaves."""
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine

    eng = ServeEngine(get_config("qwen25_3b").smoke(), batch=2, prompt_len=8,
                      max_seq=40)
    outs = eng.generate_many([2, 6], seed=0)
    assert len(outs[0].decode_latencies) == 2
    assert len(outs[1].decode_latencies) == 6
    # shared steps split two ways; after stream 0 leaves, stream 1 pays full
    assert outs[0].tokens.shape[1] == 3 and outs[1].tokens.shape[1] == 7
    with pytest.raises(ValueError, match="max_seq"):
        eng.generate_many([2, 64], seed=0)


# ------------------------------------- online: near-capacity drift re-solve

def test_online_resolve_near_capacity_stays_feasible():
    """PR-4 follow-up regression: a re-solve fires (drift on a steady
    platform + a fat arrival) while the fast platform's KV budget is
    already committed to its executing plan. The re-solve carries
    *remaining* capacity (pages held by in-flight tasks), so the uniform
    warm-start share of the newcomer on the fast platform is detected as
    infeasible (warm_start="rejected") and the real solve places work
    within the budget. Under the old offsets-only restriction the full
    budget reappears at the re-solve: the incumbent is waved through
    ("skipped") and the fast platform ends ~1.2x oversubscribed."""
    from repro.domains.lm_serving import (
        LMRequest, SimulatedLMPlatform, kv_bytes_per_token,
    )
    from repro.runtime import (
        OnlineConfig, OnlineScheduler, PlatformSpec, Scenario, Scheduler,
        make_domain,
    )

    reqs = [LMRequest("qwen25_3b", prompt_len=8, gen_tokens=32 + 4 * i,
                      batch=2, max_new_tokens=64, task_id=i)
            for i in range(8)]
    per = kv_bytes_per_token(reqs[0].config(), reqs[0].batch)
    total_kv = per * sum(r.gen_tokens for r in reqs)
    # the fast platform can hold ~35% of the workload's pages; the steady
    # ones have room to spare
    specs = [
        PlatformSpec("Fast", "GPU", "sim", "loc", 400.0, 1.0,
                     mem_bytes=total_kv * 0.35),
        PlatformSpec("Steady A", "CPU", "sim", "loc", 40.0, 1.0,
                     mem_bytes=total_kv * 2),
        PlatformSpec("Steady B", "CPU", "sim", "loc", 40.0, 1.0,
                     mem_bytes=total_kv * 2),
    ]
    fleet = [SimulatedLMPlatform(s, seed=0) for s in specs]
    sched = Scheduler(make_domain("lm_serving", reqs, fleet))
    sched.characterise(seed=1, token_ladder=(2, 4, 8, 16))
    m0 = sched.allocate(method="milp", time_limit=20).makespan
    fat = LMRequest("qwen25_3b", prompt_len=8, gen_tokens=64, batch=2,
                    max_new_tokens=64, task_id=100)
    scenario = (Scenario()
                .slowdown("Steady A", t=m0 * 0.3, factor=8.0)
                .arrive(t=m0 * 0.5, task=fat))
    for p in fleet:
        p.attach_scenario(scenario)
    # gamma_duty=0: at smoke scale the consolidation floor would flush the
    # whole quota in round 0 (beta is ~1e-6 s/token vs a ~1e-3 s constant)
    # and there would be nothing left for the re-solve to place
    rep = OnlineScheduler(sched, OnlineConfig(rounds=6, gamma_duty=0.0)).run(
        method="milp", seed=3, time_limit=20, scenario=scenario)
    assert rep.arrivals == 1
    assert any(r.drifted for r in rep.rounds), "drift never fired"
    # the infeasible warm start was caught, not silently kept
    assert any(r.solve_outcome == "rejected" for r in rep.rounds)
    for req in reqs + [fat]:
        assert rep.summary["tokens"][req.task_id] >= req.gen_tokens
    # cumulative KV pages per platform: tasks complete only at the end of
    # the run, so everything served on a platform was resident together —
    # the capacity carry keeps even the re-solved plan within budget
    # (a couple of tokens of per-tranche ceil rounding allowed)
    held = {s.name: 0.0 for s in specs}
    for rec in rep.records:
        held[rec.platform] += per * rec.n_tokens
    for s in specs:
        assert held[s.name] <= s.mem_bytes * 1.02 + 2 * per, \
            (s.name, held[s.name], s.mem_bytes)
