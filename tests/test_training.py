"""Training-substrate tests: optimizer, microbatching, checkpoints,
preemption resume, data determinism, gradient compression."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import SyntheticTokens, batch_for
from repro.models import build_model
from repro.optim.adamw import AdamW, cosine_schedule, global_norm
from repro.train.train_step import make_train_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen25_3b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_adamw_decreases_loss(setup):
    cfg, model, params = setup
    opt = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(model, opt))
    state = opt.init(params)
    batch = {k: jnp.asarray(v) for k, v in batch_for(cfg, 4, 32).items()}
    losses = []
    for _ in range(20):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_microbatching_matches_full_batch(setup):
    """Grad accumulation must equal the full-batch gradient step."""
    cfg, model, params = setup
    opt = AdamW(lr=1e-3)
    batch = {k: jnp.asarray(v) for k, v in batch_for(cfg, 8, 32).items()}
    p1, _, m1 = jax.jit(make_train_step(model, opt))(params, opt.init(params), batch)
    p4, _, m4 = jax.jit(make_train_step(model, opt, microbatches=4))(
        params, opt.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    for k in p1:
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p4[k]),
                                   rtol=5e-3, atol=5e-5)


def test_cosine_schedule():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-9)
    assert float(lr(55)) < float(lr(20))


def test_grad_clip():
    opt = AdamW(lr=1e-3, clip_norm=1e-9)  # absurdly tight clip
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 100.0)}
    newp, _, m = opt.update(g, opt.init(p), p)
    # with clip ~0, the update is ~ -lr * sign-ish tiny step + decay only
    assert float(jnp.abs(newp["w"] - p["w"]).max()) < 1e-3
    assert float(m["grad_norm"]) == pytest.approx(400.0)


def test_data_pipeline_deterministic_and_step_indexed():
    ds = SyntheticTokens(vocab=1000, batch=4, seq=16, seed=7)
    a = ds.get_batch(3)["tokens"]
    b = ds.get_batch(3)["tokens"]
    c = ds.get_batch(4)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert (a != c).any()
    assert a.max() < 1000 and a.min() >= 0


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, model, params = setup
    opt = AdamW()
    state = opt.init(params)
    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"params": params, "opt": state}, blocking=True)
    ck.save(10, {"params": params, "opt": state}, blocking=True)
    assert ck.latest_step() == 10
    out = ck.restore(10, {"params": params, "opt": state})
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]),
                                      np.asarray(out["params"][k]))
    assert int(out["opt"]["step"]) == int(state["step"])


def test_checkpoint_gc_keeps_latest(tmp_path, setup):
    cfg, model, params = setup
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"params": params}, blocking=True)
    assert ck.steps() == [3, 4]


def test_preemption_restart_resumes_exactly(tmp_path):
    """Kill training hard at step 6, restart, and the final params must
    equal an uninterrupted run (data is step-indexed; ckpt every 3)."""
    ckpt_a = str(tmp_path / "a")
    ckpt_b = str(tmp_path / "b")
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen25_3b",
            "--smoke", "--steps", "9", "--batch", "2", "--seq", "16",
            "--ckpt-every", "3", "--log-every", "100"]
    # uninterrupted
    r = subprocess.run(base + ["--ckpt-dir", ckpt_a], env=ENV,
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    # preempted at 6 (exit code 42), then resumed
    r = subprocess.run(base + ["--ckpt-dir", ckpt_b, "--preempt-at", "7"],
                       env=ENV, capture_output=True, text=True)
    assert r.returncode == 42, r.stdout + r.stderr
    r = subprocess.run(base + ["--ckpt-dir", ckpt_b], env=ENV,
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "resumed from checkpoint step 6" in r.stdout, r.stdout

    import numpy as np
    za = np.load(os.path.join(ckpt_a, "step_9", "arrays.npz"))
    zb = np.load(os.path.join(ckpt_b, "step_9", "arrays.npz"))
    assert set(za.files) == set(zb.files)
    for k in za.files:
        np.testing.assert_allclose(za[k], zb[k], rtol=1e-5, atol=1e-6,
                                   err_msg=k)


def test_elastic_restore_onto_different_mesh(tmp_path, setup):
    """Checkpoints are mesh-agnostic: save from a 1-device run, restore
    with explicit shardings onto a (1,1) mesh (degenerate but exercises
    the device_put resharding path)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    cfg, model, params = setup
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"params": params}, blocking=True)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shardings = {k: NamedSharding(mesh, P()) for k in params}
    out = ck.restore(1, {"params": params}, {"params": shardings})
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]),
                                      np.asarray(out["params"][k]))


def test_compressed_psum_single_device():
    """int8 compressed all-reduce: on a 1-device axis it must round-trip
    within quantisation error."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.train.train_step import compressed_psum
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    g = {"w": jnp.linspace(-3.0, 3.0, 128).reshape(8, 16)}

    def f(g):
        return compressed_psum(g, "pod")

    from repro import compat
    out = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(P(),),
                                   out_specs=P()))(g)
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max()
    assert err <= 3.0 / 127 + 1e-6  # one quantisation bucket


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(3 + 16))
