"""Mesh-sharded platforms: host-mesh construction, compat shard_map
axis-name forwarding, MeshPlatformSpec latency/capacity modelling, the
tensor-parallel ServeEngine path, and the solvers' wide-vs-narrow choice.

The real-TP parity tests need multiple local devices; they skip unless
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` forced a host
mesh (the ci.yml mesh leg does), with a slow subprocess variant that
always runs so tier-1 covers the sharded path everywhere.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.allocation import CapacityError, capacity_ok, platform_usage
from repro.domains.lm_serving import (
    LM_MESH_FLEET_SPECS,
    LMRequest,
    LMServingDomain,
    SimulatedLMPlatform,
    build_lm_fleet,
    request_kv_bytes,
)
from repro.launch.mesh import HostMeshError, make_host_mesh, rules_for
from repro.runtime.domain import MeshPlatformSpec, PlatformSpec
from repro.runtime.registry import make_domain
from repro.runtime.scheduler import Scheduler

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices (force with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


# --------------------------------------------------------------------------
# make_host_mesh (bugfix: validation + model axis)
# --------------------------------------------------------------------------

def test_make_host_mesh_defaults_to_all_devices_on_data_axis():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["data"] == jax.device_count()
    assert mesh.shape["model"] == 1
    assert rules_for(mesh).axis_sizes == dict(mesh.shape)


def test_make_host_mesh_raises_typed_error_naming_device_count():
    avail = jax.device_count()
    with pytest.raises(HostMeshError, match=rf"only {avail} are available"):
        make_host_mesh(data=avail + 1)
    # the error must hand the user the exact flag that fixes it
    with pytest.raises(HostMeshError,
                       match="xla_force_host_platform_device_count"):
        make_host_mesh(data=avail, model=2)


def test_make_host_mesh_validates_axis_sizes():
    with pytest.raises(HostMeshError, match="model axis"):
        make_host_mesh(model=0)
    with pytest.raises(HostMeshError, match="data axis"):
        make_host_mesh(data=0)
    with pytest.raises(HostMeshError, match="does not divide"):
        make_host_mesh(model=jax.device_count() + 1)


@multi_device
def test_make_host_mesh_model_axis_builds_tp_mesh():
    mesh = make_host_mesh(data=1, model=2)
    assert mesh.shape == {"data": 1, "model": 2}


# --------------------------------------------------------------------------
# compat.shard_map axis_names (bugfix: forwarded, not silently dropped)
# --------------------------------------------------------------------------

def test_shard_map_rejects_axis_names_outside_mesh():
    mesh = make_host_mesh()
    with pytest.raises(ValueError, match="not a subset"):
        compat.shard_map(lambda x: x, mesh, in_specs=P(), out_specs=P(),
                         axis_names={"nonexistent"})


@multi_device
def test_shard_map_subset_axis_names_keeps_collectives_correct():
    """axis_names={"model"} on a ("data", "model") mesh: the model axis is
    manual (collectives see it), the data axis stays automatic. On the
    jax-0.4.x fallback this exercises the `auto=` forwarding that the shim
    used to silently drop."""
    mesh = make_host_mesh(data=1, model=2)
    x = np.arange(8, dtype=np.float32).reshape(2, 4)

    def worker(x):  # local shard [2, 2] -> gathered [2, 4]
        return jax.lax.all_gather(x, "model", axis=1, tiled=True)

    f = compat.shard_map(worker, mesh, in_specs=P(None, "model"),
                         out_specs=P(None, None), axis_names={"model"})
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)), x)


# --------------------------------------------------------------------------
# MeshPlatformSpec: eq. 7 per shape + pooled capacity
# --------------------------------------------------------------------------

def test_bare_spec_is_the_trivial_mesh():
    s = PlatformSpec("p", "GPU", "d", "l", 10.0, 1.0, mem_bytes=100.0)
    assert s.mesh_shape == (1, 1) and s.n_devices == 1
    assert s.effective_gflops == s.gflops
    assert s.effective_rtt_ms == s.rtt_ms
    assert s.total_mem_bytes == s.mem_bytes


def test_mesh_spec_beta_falls_gamma_rises_kv_pools():
    m = MeshPlatformSpec("p 1x4", "GPU", "d", "l", 10.0, 1.0,
                         mem_bytes=100.0, mesh_shape=(1, 4),
                         tp_efficiency=0.85, collective_ms=2.0)
    assert m.model_parallel == 4 and m.n_devices == 4
    assert m.tp_speedup == pytest.approx(1 + 0.85 * 3)
    assert m.effective_gflops == pytest.approx(10.0 * 3.55)
    assert m.effective_rtt_ms == pytest.approx(1.0 + 2.0 * 3)
    assert m.total_mem_bytes == pytest.approx(400.0)


def test_mesh_spec_validates():
    with pytest.raises(ValueError, match="mesh_shape"):
        MeshPlatformSpec("x", "GPU", "d", "l", 1.0, 1.0, mesh_shape=(0, 2))
    with pytest.raises(ValueError, match="tp_efficiency"):
        MeshPlatformSpec("x", "GPU", "d", "l", 1.0, 1.0, tp_efficiency=1.5)


def test_simulated_mesh_platform_fits_per_shape_latency_model():
    """Fitted eq. 7 over mesh shapes: beta shrinks by the efficiency-
    discounted width, gamma grows by the collective cost."""
    (req,) = [LMRequest("qwen25_3b", prompt_len=8, gen_tokens=32,
                        max_new_tokens=64, task_id=0)]
    domain = LMServingDomain([req], [])
    fits = {}
    for spec in (LM_MESH_FLEET_SPECS[0], LM_MESH_FLEET_SPECS[-1]):
        plat = SimulatedLMPlatform(spec, jitter=1e-5)
        rungs = domain.characterise_batch(plat, [req], seed=1,
                                          token_ladder=(4, 8, 16, 32))
        fits[spec.model_parallel] = domain.fit_models(
            [r[0] for r in rungs]).latency
    wide = LM_MESH_FLEET_SPECS[-1]
    assert fits[1].beta / fits[wide.model_parallel].beta == pytest.approx(
        wide.tp_speedup, rel=0.05)
    assert fits[wide.model_parallel].gamma > fits[1].gamma
    assert fits[wide.model_parallel].gamma == pytest.approx(
        wide.effective_rtt_ms * 1e-3, rel=0.2)


def test_domain_capacity_pools_kv_across_the_mesh():
    wide = SimulatedLMPlatform(LM_MESH_FLEET_SPECS[-1])
    narrow = SimulatedLMPlatform(LM_MESH_FLEET_SPECS[0])
    domain = LMServingDomain([], [narrow, wide])
    assert domain.platform_capacity(narrow) == pytest.approx(512 * 1024)
    assert domain.platform_capacity(wide) == pytest.approx(
        512 * 1024 * wide.spec.n_devices)


def test_pooled_kv_admits_what_a_single_device_cannot():
    # ~720 KiB of KV: beyond one 512 KiB device, within the 8-way pool
    req = LMRequest("qwen25_3b", prompt_len=8, gen_tokens=1400, batch=2,
                    max_new_tokens=1432, task_id=0)
    assert request_kv_bytes(req, 1400) > 512 * 1024
    narrow = SimulatedLMPlatform(LM_MESH_FLEET_SPECS[0], jitter=1e-5)
    wide = SimulatedLMPlatform(LM_MESH_FLEET_SPECS[-1], jitter=1e-5)
    with pytest.raises(CapacityError, match="exceed"):
        narrow.run(req, 1400)
    rec = wide.run(req, 1400)
    assert rec.n_tokens == 1400 and rec.latency > 0


# --------------------------------------------------------------------------
# the allocator's wide-vs-narrow choice
# --------------------------------------------------------------------------

def _solve_tokens(reqs, method, **kw):
    fleet = build_lm_fleet(include_local=False, mesh=True)
    sched = Scheduler(make_domain("lm_serving", reqs, fleet))
    sched.characterise(seed=1, token_ladder=(2, 8, 16))
    alloc = sched.allocate(method=method, **kw)
    problem = sched.problem()
    assert capacity_ok(alloc.A, problem)
    tokens = (alloc.A * problem.c[None, :]).sum(axis=1)
    return {p.spec.name: t for p, t in zip(fleet, tokens)}, alloc, problem


def _latency_reqs(n=6):
    return [LMRequest("qwen25_3b", prompt_len=8, gen_tokens=8, batch=2,
                      max_new_tokens=16, task_id=i) for i in range(n)]


def _capacity_reqs(n=14):
    # at 1 KiB of KV per decoded token the narrow shapes hold 512 + 1024 +
    # 2048 tokens pooled; 14 x 450 = 6300 tokens forces >= 2716 of them
    # onto the 1x8 (cap 4096) — more than any narrow shape can hold at all
    return [LMRequest("qwen25_3b", prompt_len=8, gen_tokens=450, batch=2,
                      max_new_tokens=512, task_id=i) for i in range(n)]


@pytest.mark.parametrize("method,kw", [
    ("heuristic", {}),
    ("milp", dict(time_limit=20)),
])
def test_solvers_flip_mesh_shape_under_latency_vs_capacity_pressure(method, kw):
    lat_tokens, _, _ = _solve_tokens(_latency_reqs(), method, **kw)
    cap_tokens, alloc, problem = _solve_tokens(_capacity_reqs(), method, **kw)
    widest = LM_MESH_FLEET_SPECS[-1].name
    # latency pressure (short gens, gamma-dominated): the collective-
    # inflated wide mesh is the worst buy — narrow shapes carry the work
    assert lat_tokens[widest] < max(lat_tokens.values())
    assert max(lat_tokens, key=lat_tokens.get) != widest
    # capacity pressure: pooled KV forces the bulk onto the widest mesh
    assert max(cap_tokens, key=cap_tokens.get) == widest
    # and the pooled capacity row is genuinely binding + respected
    usage = platform_usage(alloc.A, problem)
    assert (usage <= problem.capacity * (1 + 1e-6)).all()
    narrow_pool = problem.capacity[:-1].sum()
    assert usage.sum() > narrow_pool  # the narrow shapes alone cannot hold it


def test_mesh_fleet_end_to_end_execute_and_ledger_accountability():
    """The wide mesh is allocatable end-to-end and per-shape predictions
    stay inside the paper's 10% band in the obs ledger.

    Uses an uncapped equal-length workload: capacity clamping skews the
    per-platform batch composition away from the one characterisation
    measured, which is a (known, documented) model limit, not a mesh bug.
    """
    reqs = [LMRequest("qwen25_3b", prompt_len=8, gen_tokens=48, batch=2,
                      max_new_tokens=64, task_id=i) for i in range(6)]
    fleet = build_lm_fleet(include_local=False, mesh=True)
    sched = Scheduler(make_domain("lm_serving", reqs, fleet), trace=True)
    sched.characterise(seed=1, token_ladder=(2, 8, 16))
    alloc = sched.allocate(method="heuristic")
    rep = sched.execute(alloc)
    assert rep.measured_makespan > 0
    for req in reqs:
        # unit rounding across shards may drop a token or two
        assert rep.summary["tokens"][req.task_id] >= req.gen_tokens - 4
    by_plat = sched.ledger.platform_summary("latency")
    mesh_names = {s.name for s in LM_MESH_FLEET_SPECS}
    seen = mesh_names & set(by_plat)
    assert seen, f"no mesh platform in ledger: {sorted(by_plat)}"
    for name in seen:
        p50 = by_plat[name]["p50"]
        assert p50 is not None and p50 <= 0.10, (name, by_plat[name])


# --------------------------------------------------------------------------
# tensor-parallel ServeEngine: validation + bitwise parity
# --------------------------------------------------------------------------

def test_tp_validation_rejects_unshardable_shapes():
    from repro.configs import get_config
    from repro.launch.tp import TPShardingError, validate_tp

    cfg = get_config("qwen25_3b").smoke()
    with pytest.raises(TPShardingError, match=">= 2"):
        validate_tp(cfg, 1)
    with pytest.raises(TPShardingError, match="indivisible"):
        validate_tp(cfg, 3)
    with pytest.raises(TPShardingError, match="n_kv_heads"):
        validate_tp(cfg, 4)       # kvh=2: kv-head replication not offered
    rwkv = get_config("rwkv7_3b").smoke() if _has_arch("rwkv7_3b") else None
    if rwkv is not None:
        with pytest.raises(TPShardingError, match="dense family"):
            validate_tp(rwkv, 2)


@multi_device
def test_serve_engine_rejects_data_parallel_mesh():
    # a data axis > 1 would abort the whole process inside XLA's SPMD
    # partitioner (uncatchable SIGABRT) — the engine must refuse it with
    # a catchable error before anything reaches the compiler
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine

    cfg = get_config("qwen25_3b").smoke()
    with pytest.raises(ValueError, match="data axis"):
        ServeEngine(cfg, batch=2, prompt_len=8, max_seq=16,
                    mesh=make_host_mesh(data=2, model=1))


def _has_arch(name):
    from repro.configs import get_config
    try:
        get_config(name)
        return True
    except Exception:
        return False


@multi_device
def test_sharded_engine_logits_match_single_device_bitwise():
    from repro.configs import get_config
    from repro.launch.serve import ServeEngine

    cfg = get_config("qwen25_3b").smoke()
    ref = ServeEngine(cfg, batch=2, prompt_len=8, max_seq=16)
    tp = ServeEngine(cfg, batch=2, prompt_len=8, max_seq=16,
                     mesh=make_host_mesh(data=1, model=2))
    for a, b in zip(ref.probe_logits(), tp.probe_logits()):
        np.testing.assert_array_equal(a, b)
    r0, r1 = ref.generate(4, seed=0), tp.generate(4, seed=0)
    np.testing.assert_array_equal(r0.tokens, r1.tokens)


_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import ServeEngine

    # kvh=4 variant so the widest exact shape (tp=4) is exercised too
    for cfg, widths in [
        (get_config("qwen25_3b").smoke(), (2,)),
        (dataclasses.replace(get_config("qwen25_3b").smoke(),
                             n_heads=8, n_kv_heads=4, head_dim=16), (2, 4)),
    ]:
        ref = ServeEngine(cfg, batch=2, prompt_len=8, max_seq=16)
        base = ref.probe_logits()
        for tp in widths:
            eng = ServeEngine(cfg, batch=2, prompt_len=8, max_seq=16,
                              mesh=make_host_mesh(data=1, model=tp))
            for a, b in zip(base, eng.probe_logits()):
                assert np.array_equal(a, b), (cfg.n_kv_heads, tp)
    print("PARITY_OK")
""")


@pytest.mark.slow
def test_sharded_engine_parity_on_forced_host_mesh_subprocess():
    """Bitwise parity on a real 8-device host mesh, regardless of how the
    outer pytest process was launched (XLA_FLAGS must precede jax init,
    hence the subprocess — same idiom as launch/dryrun.py)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", _PARITY_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "PARITY_OK" in proc.stdout
