"""Batched runtime-parameter engine tests.

Two contracts pinned here:

  * parity — ``price_batch`` agrees with the per-task oracle on every
    Table 1 category, for both the jnp and pallas-interpret backends, to
    float32 reduction tolerance (the batched engine draws the identical
    Threefry stream per (task, path, step));
  * compile count — a multi-task characterise traces O(#families)
    computations, not O(#platforms x #tasks x #rungs), which is the whole
    point of making task parameters runtime operands.
"""
import numpy as np
import pytest

from repro.kernels import ref
from repro.pricing import (
    LocalJaxPlatform,
    SimulatedPlatform,
    TABLE2_SPECS,
    TaskBatch,
    group_by_family,
    group_by_launch,
    price,
    price_batch,
)
from repro.pricing import mc
from repro.pricing.platforms import _TaskMoments
from repro.pricing.solver import PricingSolver
from repro.pricing.workload import TABLE1_CATEGORIES, table1_workload

#: One task from every Table 1 category (mixed BS/Heston mini-workload).
ALL_CATS = [(c, 1) for c, _ in TABLE1_CATEGORIES]


def _ref_price(task, n, seed):
    s, s2 = ref.mc_moments_ref(task, n, seed=seed)
    return mc._finalize(task, s, s2, n)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_price_batch_matches_per_task_all_categories(backend):
    tasks = table1_workload(seed=21, n_steps=8, categories=ALL_CATS)
    n = 2048
    results = price_batch(tasks, n, seed=5, backend=backend)
    for t, r in zip(tasks, results):
        want = _ref_price(t, n, seed=5)
        np.testing.assert_allclose(float(r.price), float(want.price),
                                   rtol=1e-4, atol=1e-5, err_msg=t.category)
        np.testing.assert_allclose(float(r.ci95), float(want.ci95),
                                   rtol=1e-3, atol=1e-6, err_msg=t.category)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_price_batch_ragged_path_counts(backend):
    """Padded/masked batching: each task uses exactly its own first n draws."""
    tasks = table1_workload(seed=22, n_steps=8,
                            categories=[("BS-A", 2), ("H-B", 2)])
    ns = [2048, 100, 4096, 64]
    results = price_batch(tasks, ns, seed=2, backend=backend)
    for t, n, r in zip(tasks, ns, results):
        want = _ref_price(t, n, seed=2)
        np.testing.assert_allclose(float(r.price), float(want.price),
                                   rtol=1e-4, atol=1e-5)
        assert int(r.n_paths) == n


def test_ragged_buckets_bound_padding_waste():
    """Extreme per-task path spreads split into bounded-ratio buckets, so a
    64-path shard never simulates a co-batched task's 100k paths; uniform
    counts (the ladder/calibration hot path) stay a single launch."""
    assert mc._ragged_buckets([1024, 1024, 1024]) == [[0, 1, 2]]
    buckets = mc._ragged_buckets([100_000, 64, 90_000, 80])
    assert sorted(sum(buckets, [])) == [0, 1, 2, 3]
    for b in buckets:
        lo = min(max(1, [100_000, 64, 90_000, 80][k]) for k in b)
        hi = max([100_000, 64, 90_000, 80][k] for k in b)
        assert hi <= lo * mc._RAGGED_RATIO
    # and parity survives the split
    tasks = table1_workload(seed=26, n_steps=8, categories=[("BS-A", 3)])
    ns = [50_000, 128, 200]
    for r, t, n in zip(price_batch(tasks, ns, seed=3), tasks, ns):
        want = _ref_price(t, n, seed=3)
        np.testing.assert_allclose(float(r.price), float(want.price),
                                   rtol=1e-4, atol=1e-5)


def test_price_is_thin_wrapper_over_batch_of_one():
    task = table1_workload(seed=23, n_steps=8, categories=[("H-DB", 1)])[0]
    a = price(task, 1024, seed=7)
    (b,) = price_batch([task], 1024, seed=7)
    assert float(a.price) == float(b.price)
    assert float(a.ci95) == float(b.ci95)


def test_task_batch_requires_family_uniformity():
    bs, heston = table1_workload(seed=24, n_steps=8,
                                 categories=[("BS-A", 1), ("H-A", 1)])
    with pytest.raises(ValueError):
        TaskBatch.from_tasks([bs, heston])
    with pytest.raises(ValueError):
        TaskBatch.from_tasks([])


def test_task_batch_rejects_unknown_payoff_kind():
    """Inside jit the coded-payoff where-chain cannot raise, so bad codes
    must be caught at packing time (the legacy path raised ValueError)."""
    import dataclasses

    from repro.pricing import Option

    (bs,) = table1_workload(seed=24, n_steps=8, categories=[("BS-A", 1)])
    bad = dataclasses.replace(bs, option=Option(payoff=7, strike=100.0))
    with pytest.raises(ValueError, match="unknown payoff"):
        TaskBatch.from_tasks([bad])


def test_group_by_family_partitions_table1():
    tasks = table1_workload(seed=25, n_steps=8)
    groups = group_by_family(tasks)
    assert len(groups) == 9  # the 9 Table 1 families
    seen = sorted(i for _, g in groups for i, _ in g)
    assert seen == list(range(len(tasks)))


def test_characterise_compile_count_is_per_family():
    """2 platforms x 16 tasks (3 families) x 2 rungs: O(#families) traces.

    The per-task scheme traces (and compiles) every (platform, task, rung)
    plus one calibration per task: >= 48 here.  The batched engine is
    bounded above by one trace per (platform, family, ladder shape) plus
    one calibration launch per family; in practice it is tighter still —
    payoff kind is a runtime code and the path count a runtime chunk-loop
    bound, so the whole run needs one trace per (model kind, batch size),
    and every platform shares the jit cache because task parameters are
    runtime operands.
    """
    tasks = table1_workload(seed=11, n_steps=8,
                            categories=[("BS-A", 6), ("BS-DB", 5), ("H-A", 5)])
    assert len(tasks) == 16 and len(group_by_family(tasks)) == 3
    platforms = [
        SimulatedPlatform(TABLE2_SPECS[0], moments=_TaskMoments(calib_paths=4096)),
        LocalJaxPlatform(),
    ]
    ladder = (256, 1024)
    mc.reset_trace_counts()
    solver = PricingSolver(tasks, platforms)
    solver.characterise(path_ladder=ladder, seed=1)
    counts = mc.trace_counts()
    traces = sum(counts.values())
    n_families, n_rungs = 3, len(ladder)
    # The acceptance-level bound: one compile per (family, ladder shape)
    # (+1 per family for the calibration launch shape) ...
    assert 0 < traces <= n_families * (n_rungs + 1), counts
    # ... and the runtime-chunked engine's actual bound: one per launch
    # group (model kind x n_steps x batch size), ladder shapes free.
    assert traces <= len(group_by_launch(tasks)), counts
    assert traces < len(tasks) * n_rungs, counts  # beats per-task compile

    # The fitted models must still be per-(platform, task) and sane.
    assert len(solver.models) == len(platforms) * len(tasks)
    for m in solver.models.values():
        assert m.latency.beta > 0 and m.accuracy.alpha > 0


def test_execute_batches_per_platform_family():
    """The solver's execute path prices every task via batched launches."""
    tasks = table1_workload(seed=12, n_steps=8,
                            categories=[("BS-A", 3), ("H-A", 3)])
    platforms = [
        SimulatedPlatform(TABLE2_SPECS[0], moments=_TaskMoments(calib_paths=4096)),
        SimulatedPlatform(TABLE2_SPECS[9], moments=_TaskMoments(calib_paths=4096)),
    ]
    solver = PricingSolver(tasks, platforms)
    solver.characterise(path_ladder=(512, 2048), seed=1)
    alloc = solver.allocate(accuracy=0.5, method="heuristic")
    report = solver.execute(alloc, accuracy=0.5)
    assert set(report.prices) == {t.task_id for t in tasks}
    assert report.measured_makespan > 0
    assert all(np.isfinite(list(report.prices.values())))
