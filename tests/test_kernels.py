"""Pallas kernel tests: shape/dtype/payoff sweeps vs the pure-jnp oracle."""
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.mc_paths import LANES, mc_moments_kernel_call
from repro.kernels.prng import normal_pair, threefry2x32, uniforms
from repro.pricing import (
    BlackScholes,
    Heston,
    PricingTask,
    asian,
    barrier,
    digital_double_barrier,
    double_barrier,
    european,
)

BS = BlackScholes(spot=100.0, rate=0.05, volatility=0.25)
HESTON = Heston(spot=90.0, rate=0.02, v0=0.09, kappa=1.5, theta=0.06, xi=0.4, rho=-0.6)

OPTIONS = [
    ("european", european(100.0)),
    ("asian", asian(95.0, call=False)),
    ("barrier", barrier(100.0, upper=135.0)),
    ("double_barrier", double_barrier(100.0, 60.0, 150.0)),
    ("digital", digital_double_barrier(7.5, 65.0, 145.0)),
]


# ------------------------------------------------------------------ RNG layer

def test_threefry_matches_jax_reference():
    import jax.numpy as jnp
    from jax._src.prng import threefry_2x32

    key = jnp.array([0xDEADBEEF, 0xCAFEF00D], dtype=jnp.uint32)
    ctr = jnp.arange(64, dtype=jnp.uint32)
    expect = np.asarray(threefry_2x32(key, ctr))
    got0, got1 = threefry2x32(key[0], key[1], ctr[:32], ctr[32:])
    np.testing.assert_array_equal(expect, np.concatenate([got0, got1]))


def test_uniforms_open_interval():
    import jax.numpy as jnp
    u0, u1 = uniforms(jnp.uint32(1), jnp.uint32(2),
                      jnp.arange(1 << 16, dtype=jnp.uint32), jnp.uint32(0))
    for u in (u0, u1):
        assert float(u.min()) > 0.0
        assert float(u.max()) < 1.0


def test_normals_moments():
    import jax.numpy as jnp
    z0, z1 = normal_pair(jnp.uint32(3), jnp.uint32(4),
                         jnp.arange(1 << 17, dtype=jnp.uint32), jnp.uint32(0))
    z = np.concatenate([np.asarray(z0), np.asarray(z1)])
    assert abs(z.mean()) < 0.01
    assert abs(z.std() - 1.0) < 0.01
    assert not np.isnan(z).any()


# ------------------------------------------------------------- kernel sweeps

@pytest.mark.parametrize("name,option", OPTIONS)
@pytest.mark.parametrize("underlying", [BS, HESTON], ids=["bs", "heston"])
def test_kernel_matches_oracle_payoff_sweep(name, option, underlying):
    task = PricingTask(underlying=underlying, option=option, maturity=1.0,
                       n_steps=12, task_id=11)
    ks, ks2 = ops.mc_moments(task, 4096, seed=17, block_paths=1024)
    rs, rs2 = ref.mc_moments_ref(task, 4096, seed=17)
    np.testing.assert_allclose(float(ks), float(rs), rtol=3e-5)
    np.testing.assert_allclose(float(ks2), float(rs2), rtol=3e-5)


@pytest.mark.parametrize("block_paths", [128, 256, 1024, 2048])
def test_kernel_block_shape_sweep(block_paths):
    """Result must be invariant to the VMEM tile size chosen."""
    task = PricingTask(underlying=BS, option=european(100.0), maturity=0.5,
                       n_steps=8, task_id=12)
    n = 4096
    s, s2 = ops.mc_moments(task, n, seed=1, block_paths=block_paths)
    rs, rs2 = ref.mc_moments_ref(task, n, seed=1)
    np.testing.assert_allclose(float(s), float(rs), rtol=3e-5)
    np.testing.assert_allclose(float(s2), float(rs2), rtol=3e-5)


@pytest.mark.parametrize("n_steps", [1, 7, 64])
def test_kernel_steps_sweep(n_steps):
    task = PricingTask(underlying=HESTON, option=asian(90.0), maturity=2.0,
                       n_steps=n_steps, task_id=13)
    s, s2 = ops.mc_moments(task, 2048, seed=2, block_paths=512)
    rs, rs2 = ref.mc_moments_ref(task, 2048, seed=2)
    np.testing.assert_allclose(float(s), float(rs), rtol=5e-5)
    np.testing.assert_allclose(float(s2), float(rs2), rtol=5e-5)


def test_kernel_per_block_partials_match_blocked_oracle():
    """Block-level partial sums agree with the oracle blocked identically."""
    task = PricingTask(underlying=BS, option=double_barrier(100.0, 70.0, 140.0),
                       maturity=1.0, n_steps=8, task_id=14)
    part = np.asarray(mc_moments_kernel_call(task, 2048, seed=3, block_paths=256))
    expect = np.asarray(ref.mc_block_moments_ref(task, 2048, 3, 256))
    assert part.shape == (8, 2)
    np.testing.assert_allclose(part, expect, rtol=3e-5)


def test_kernel_rejects_bad_blocks():
    task = PricingTask(underlying=BS, option=european(100.0), maturity=1.0,
                       n_steps=4, task_id=15)
    with pytest.raises(ValueError):
        mc_moments_kernel_call(task, 1000, seed=0, block_paths=100)  # not LANES-mult
    with pytest.raises(ValueError):
        mc_moments_kernel_call(task, 1000, seed=0, block_paths=256)  # not divisible


def test_lanes_constant_is_tpu_native():
    assert LANES == 128  # VREG lane width — BlockSpec alignment contract
