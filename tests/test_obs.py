"""Observability: span tracing, metrics registry, prediction ledger.

Three tiers:

* **Unit** — tracer mechanics (nesting, null-span off path, retroactive
  spans), Chrome trace-event schema validation, metric snapshot JSONL
  round-trip, the ledger's zero-measured ``inf`` convention, and the
  normalised solver ``meta`` phase keys across every solver path.
* **Acceptance** — an instrumented online pricing run emits a
  schema-valid trace with per-platform dispatch tracks and lifted solver
  phases; on the unperturbed workload the ledger's live within-10% view
  reproduces the paper's §5 claim and agrees with
  ``RuntimeReport.makespan_error``.
* **Parity** — the concurrent and sequential executors produce bitwise
  identical span/instant multisets (wall-clock args excluded) under the
  canonical PR 6 fault storm.
"""
import json
import math
import threading

import numpy as np
import pytest

from repro.core import (
    AllocationProblem,
    milp_allocation,
    ml_allocation,
    proportional_allocation,
)
from repro.core.clustering import clustered_allocation
from repro.core.incremental import patch_allocation
from repro.obs import (
    MetricSnapshot,
    MetricsRegistry,
    PredictionLedger,
    Tracer,
    lift_solver_phases,
    relative_error,
    render_span_tree,
    resolve_tracer,
    validate_chrome_trace,
)
from repro.obs.trace import PHASE_KEYS
from repro.runtime import (
    OnlineConfig,
    OnlineScheduler,
    RetryPolicy,
    Scenario,
    Scheduler,
    dump_records,
    load_records,
    make_domain,
)

LADDER = (512, 2048, 8192)
ROWS = (0, 9, 14)  # Desktop, Local GPU 1, Local FPGA 1

_MOMENTS = None


def _moments(paths=4096):
    global _MOMENTS
    if _MOMENTS is None:
        from repro.pricing.platforms import _TaskMoments

        _MOMENTS = _TaskMoments(calib_paths=paths)
    return _MOMENTS


def _tasks(n=3):
    from repro.pricing import table1_workload

    return table1_workload(seed=12, n_steps=8,
                           categories=[("BS-A", n), ("H-A", n)])


def _fresh(scenario=None, tasks=None, rows=ROWS, ladder=LADDER, **sched_kw):
    from repro.pricing import SimulatedPlatform, TABLE2_SPECS

    platforms = [SimulatedPlatform(TABLE2_SPECS[i], moments=_moments(),
                                   seed=7) for i in rows]
    sched = Scheduler(make_domain("pricing", list(tasks or _tasks()),
                                  platforms), **sched_kw)
    sched.characterise(seed=1, path_ladder=ladder)
    if scenario is not None:
        for p in platforms:
            p.attach_scenario(scenario)
    return sched


def _storm():
    return (Scenario()
            .flaky("Desktop", p=0.2, seed=5, t=0.0, end=0.03)
            .outage("Local GPU 1", t=0.01, end=0.05)
            .corrupt("Local FPGA 1", t=0.015, end=0.02))


# ---------------------------------------------------------------- unit tier


def test_disabled_tracer_is_a_shared_noop():
    t = Tracer(enabled=False)
    sp = t.span("work", track="x", n=1)
    with sp as s:
        s.args["k"] = "v"        # writes go nowhere, never raise
        s.set_virtual(0.0, 1.0)
    t.instant("boom", track="x")
    t.add_span("late", "x", 0.0, 1.0)
    assert t.spans == [] and t.instants == []
    assert t.span("again", track="y") is sp  # one shared null span


def test_spans_nest_per_thread_and_export_balanced():
    t = Tracer()
    with t.span("outer", track="main") as outer:
        with t.span("inner", track="main"):
            assert t.current().name == "inner"
        assert t.current() is outer

    def worker():
        with t.span("job", track="pool"):
            assert t.current().name == "job"

    th = threading.Thread(target=worker)
    th.start()
    th.join()
    stats = validate_chrome_trace(t.chrome_events())
    assert stats["spans"] == 3 and stats["tracks"] == 2


def test_retroactive_spans_nest_even_when_parent_added_last():
    # lift_solver_phases records children inside a parent window added
    # *after* the fact, sharing exact boundary timestamps — the export
    # must still emit a properly nested B/E stream
    t = Tracer()
    lift_solver_phases(t, {"build_s": 0.01, "solve_s": 0.02,
                           "polish_s": 0.0, "n_vars": 8}, 0.05)
    t.add_span("round[0]", "online", 0.0, 0.05)
    t.add_span("probe", "online", 0.01, 0.02)
    events = t.chrome_events()
    validate_chrome_trace(events)
    tree = render_span_tree(events)
    assert "build" in tree and "solve" in tree and "round[0]" in tree


def test_chrome_trace_schema_and_json_round_trip(tmp_path):
    t = Tracer()
    with t.span("a", track="m", n=1):
        t.instant("tick", track="m", round=0)
    path = t.write(tmp_path / "trace.json")
    doc = json.loads(open(path).read())
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    stats = validate_chrome_trace(events)
    assert stats["instants"] == 1 and stats["spans"] == 1
    meta = [e for e in events if e["ph"] == "M"]
    assert {m["name"] for m in meta} == {"process_name", "thread_name"}
    ts = [e["ts"] for e in events if e["ph"] != "M"]
    assert ts == sorted(ts)


def test_validate_rejects_malformed_streams():
    base = {"cat": "c", "pid": 1, "tid": 1, "ts": 0.0}
    with pytest.raises(ValueError, match="non-empty"):
        validate_chrome_trace([])
    with pytest.raises(ValueError, match="no open B"):
        validate_chrome_trace([{"name": "x", "ph": "E", **base}])
    with pytest.raises(ValueError, match="bad nesting"):
        validate_chrome_trace([
            {"name": "a", "ph": "B", **base},
            {"name": "b", "ph": "B", **base},
            {"name": "a", "ph": "E", **base},
        ])
    with pytest.raises(ValueError, match="still open"):
        validate_chrome_trace([{"name": "a", "ph": "B", **base}])
    with pytest.raises(ValueError, match="not monotone"):
        validate_chrome_trace([
            {"name": "a", "ph": "B", **base, "ts": 2.0},
            {"name": "a", "ph": "E", **base, "ts": 1.0},
        ])


def test_resolve_tracer_contract(monkeypatch):
    t = Tracer()
    assert resolve_tracer(t) is t
    assert resolve_tracer(True).enabled
    assert not resolve_tracer(False).enabled
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    assert not resolve_tracer(None).enabled  # env off -> disabled default


def test_metrics_registry_and_jsonl_round_trip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("runtime.records").inc(3)
    reg.gauge("online.brownout_rung").set(2)
    h = reg.histogram("solver.solve_s")
    for v in (0.1, 0.2, 0.3, math.inf):   # non-finite observations skipped
        h.observe(v)
    snaps = reg.snapshot(at=1.5)
    assert [s.name for s in snaps] == sorted(s.name for s in snaps)
    hist = next(s for s in snaps if s.metric == "histogram")
    assert hist.stats["count"] == 3
    assert hist.stats["p50"] == pytest.approx(0.2, rel=0.5)
    path = tmp_path / "metrics.jsonl"
    assert dump_records(snaps, path) == len(snaps)
    back = load_records(path)
    assert [type(s) for s in back] == [MetricSnapshot] * len(snaps)
    assert back == snaps
    with pytest.raises(ValueError, match="registered as"):
        reg.counter("online.brownout_rung")


def test_ledger_zero_measured_is_inf_never_zero_division():
    assert relative_error(0.0, 0.0) == 0.0
    assert relative_error(1.0, 0.0) == math.inf
    assert relative_error(1.1, 1.0) == pytest.approx(0.1)
    led = PredictionLedger(tol=0.1)
    led.observe("makespan", "*", "-", -1, 1.0, 0.0)   # all-shed round
    led.observe("makespan", "*", "-", 0, 1.05, 1.0)
    s = led.summary()["makespan"]
    assert s["inf_errors"] == 1 and s["count"] == 2
    assert s["within_10pct"] == pytest.approx(0.5)    # inf counts as a miss
    assert led.entries("makespan")[0].error == math.inf
    assert "inf" in led.render()


def test_solver_meta_phase_keys_normalised():
    rng = np.random.default_rng(0)
    prob = AllocationProblem(delta=rng.uniform(0.5, 2.0, (3, 6)),
                             gamma=rng.uniform(0.05, 0.2, (3, 6)),
                             c=np.ones(6))
    allocs = {
        "heuristic": proportional_allocation(prob),
        "ml": ml_allocation(prob, seed=1, chains=4, steps=40, rounds=1),
        "milp": milp_allocation(prob, time_limit=10),
    }
    for name, alloc in allocs.items():
        for k in PHASE_KEYS:
            assert isinstance(alloc.meta.get(k), float), (name, k)
    # warm-start shortcut: skipped solves still carry zeroed phase keys
    skip = milp_allocation(prob, incumbent=allocs["milp"], warm_tol=10.0)
    assert skip.meta["warm_start"] == "skipped"
    assert all(skip.meta[k] == 0.0 for k in PHASE_KEYS)


def test_clustered_and_patched_meta_carry_inner_solver_meta():
    rng = np.random.default_rng(1)
    # 3 families x 4 members: identical (work, gamma) columns cluster
    D = rng.uniform(0.5, 2.0, (3, 3))
    G = rng.uniform(0.05, 0.2, (3, 3))
    prob = AllocationProblem(delta=np.repeat(D, 4, axis=1),
                             gamma=np.repeat(G, 4, axis=1),
                             c=np.ones(12))
    cl = clustered_allocation(prob, method="heuristic")
    assert cl.meta["n_clusters"] == 3
    assert isinstance(cl.meta["inner"], list) and cl.meta["inner"]
    for m in cl.meta["inner"]:
        assert all(k in m for k in PHASE_KEYS)
    # aggregated phase totals cover the inner solves
    assert cl.meta["solve_s"] >= max(m["solve_s"] for m in cl.meta["inner"])

    base = proportional_allocation(
        AllocationProblem(delta=prob.delta[:, :10], gamma=prob.gamma[:, :10],
                          c=np.ones(10)))
    A = np.zeros((3, 12))
    A[:, :10] = base.A
    patched = patch_allocation(prob, A, [10, 11], method="heuristic")
    assert patched.meta["incremental"] in ("patched", "full_fallback")
    inner = patched.meta["inner"]
    assert isinstance(inner, dict)
    assert all(k in inner for k in PHASE_KEYS)
    assert all(k in patched.meta for k in PHASE_KEYS)


# ---------------------------------------------------------- acceptance tier


@pytest.fixture(scope="module")
def traced_run():
    tracer = Tracer()
    sched = _fresh(trace=tracer)
    cfg = OnlineConfig(rounds=3)
    report = OnlineScheduler(sched, cfg).run(0.05, method="milp", seed=3,
                                             time_limit=15)
    return tracer, sched, report


def test_instrumented_run_emits_schema_valid_trace(traced_run):
    tracer, sched, _report = traced_run
    events = tracer.chrome_events()
    stats = validate_chrome_trace(events)
    assert stats["spans"] >= 10
    names = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    # per-platform dispatch tracks + the pipeline-stage tracks
    assert {"scheduler", "online", "solver"} <= names
    assert {sched.domain.platform_name(p) for p in sched.platforms} <= names
    span_names = {e["name"] for e in events if e["ph"] == "B"}
    assert {"characterise", "dispatch", "launch", "solve[initial]",
            "round[0]"} <= span_names
    assert "build" in span_names or "solve" in span_names  # lifted phases
    tree = render_span_tree(events)
    assert "dispatch" in tree and "ms" in tree


def test_ledger_within_ten_percent_on_unperturbed_run(traced_run):
    tracer, sched, report = traced_run
    led = sched.ledger
    assert led.count > 0
    # paper §5: predictions generally within 10% of measured performance
    assert led.error_quantiles("latency")["p50"] <= 0.10
    mk = [e for e in led.entries("makespan") if e.round == -1]
    assert mk and mk[-1].error == pytest.approx(report.makespan_error)
    assert mk[-1].error <= 0.10
    acc = led.summary().get("accuracy")
    assert acc and acc["count"] > 0
    assert "within" in led.render()


def test_trace_overhead_under_five_percent_is_measured_in_bench():
    # the <5% gate itself runs on the canonical bench (chaos.yml asserts
    # BENCH_allocation.json["telemetry"]); here we sanity-check the
    # mechanism: a disabled tracer adds no spans and no ledger entries
    sched = _fresh(tasks=_tasks(1), trace=False)
    rep = sched.execute(sched.allocate(0.05, method="heuristic"), 0.05)
    assert rep.records
    assert sched.tracer.spans == [] and sched.ledger.count == 0


# -------------------------------------------------------------- parity tier


def test_concurrent_sequential_span_parity_under_storm():
    keys = {}
    for mode in ("concurrent", "sequential"):
        tracer = Tracer()
        sched = _fresh(_storm(), trace=tracer, mode=mode)
        cfg = OnlineConfig(rounds=6, breaker_cooldown=0.02,
                           retry=RetryPolicy(max_attempts=3, budget=8))
        OnlineScheduler(sched, cfg).run(0.05, method="milp", seed=3,
                                        time_limit=15)
        keys[mode] = tracer.parity_keys()
        validate_chrome_trace(tracer.chrome_events())
    assert keys["concurrent"] == keys["sequential"]


# ---------------------------------------------------- all-shed regression


def test_all_shed_open_loop_round_reports_through_ledger():
    from repro.core.slo import SLOConfig
    from repro.domains.lm_serving import (
        LMRequest, SimulatedLMPlatform, kv_bytes_per_token)
    from repro.runtime import AdmissionConfig, PlatformSpec
    from repro.runtime.loadgen import (
        ConstantRate, LoadGenerator, lm_request_factory)

    reqs = [LMRequest("qwen25_3b", prompt_len=8, gen_tokens=8, batch=1,
                      max_new_tokens=32, task_id=0)]
    per = kv_bytes_per_token(reqs[0].config(), 1)
    fleet = [SimulatedLMPlatform(
        PlatformSpec("Edge", "CPU", "sim", "loc", 4.0, 0.2,
                     mem_bytes=per * 40 * 64), seed=0)]
    tracer = Tracer()
    sched = Scheduler(make_domain("lm_serving", reqs, fleet), trace=tracer)
    sched.characterise(seed=1, token_ladder=(2, 4, 8))

    factory = lm_request_factory(archs=("qwen25_3b",), prompt_buckets=(8,),
                                 batch=1, max_new_tokens=32)
    gen = LoadGenerator(ConstantRate(200.0), factory, seed=0, start_id=100)
    scenario = gen.scenario(0.2)
    for p in fleet:
        p.attach_scenario(scenario)
    cfg = OnlineConfig(
        rounds=4, gamma_duty=0.0, open_loop=True,
        admission=AdmissionConfig(queue_s=0.001, max_queue=0),
        slo=SLOConfig(target_s=10.0, metric="e2e"))
    rep = OnlineScheduler(sched, cfg).run(method="heuristic", seed=3,
                                          scenario=scenario)
    # every offered arrival was shed; the seed task still ran, so the
    # run's makespan entry is finite and matches the report
    assert rep.n_offered > 0 and rep.n_shed == rep.n_offered
    shed_rounds = [r for r in rep.rounds if r.offered and r.shed == r.offered]
    assert shed_rounds, "no all-shed round exercised"
    led = sched.ledger
    summary = led.summary()   # must compute cleanly with shed rounds
    mk = [e for e in led.entries("makespan") if e.round == -1]
    assert mk and mk[-1].error == pytest.approx(rep.makespan_error)
    assert math.isfinite(summary["makespan"]["p50"] or 0.0)
    events = tracer.chrome_events()
    validate_chrome_trace(events)
    sheds = [e for e in events if e["ph"] == "i"
             and e["name"].startswith("shed:")]
    assert sheds and all(e["tid"] for e in sheds)
