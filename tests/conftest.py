"""Shared test configuration: hypothesis settings profiles.

The property suites (test_allocation.py, test_metrics.py,
test_dispatch_properties.py, test_capacity.py) run under a named
hypothesis profile declared in ``pyproject.toml``
(``[tool.hypothesis.profiles.*]``), selected with the
``HYPOTHESIS_PROFILE`` environment variable — ``fast`` (default, the CI
matrix legs) keeps them cheap, ``full`` (the CI full leg) widens the
sweep. Both are derandomized so neither leg flakes: a failing example
reproduces on every run.

Python 3.10 ships no tomllib, so the flat profile tables are parsed with
a minimal line parser; the in-code defaults below mirror the file and are
used if the file is unreadable. Environments without hypothesis installed
skip all of this (the property modules importorskip it).
"""
from __future__ import annotations

import os
import pathlib
import re

try:
    from hypothesis import settings
except ImportError:  # property-test modules importorskip hypothesis
    settings = None

#: mirrors [tool.hypothesis.profiles.*] in pyproject.toml
_DEFAULTS: dict[str, dict] = {
    "fast": {"max_examples": 25, "derandomize": True},
    "full": {"max_examples": 100, "derandomize": True},
}


def _profiles_from_pyproject() -> dict[str, dict]:
    path = pathlib.Path(__file__).resolve().parent.parent / "pyproject.toml"
    try:
        text = path.read_text()
    except OSError:
        return _DEFAULTS
    profiles: dict[str, dict] = {}
    current: dict | None = None
    for raw in text.splitlines():
        line = raw.strip()
        head = re.fullmatch(r"\[tool\.hypothesis\.profiles\.([\w-]+)\]", line)
        if head:
            current = profiles.setdefault(head.group(1), {})
            continue
        if line.startswith("["):
            current = None
            continue
        if current is None:
            continue
        kv = re.fullmatch(r"(\w+)\s*=\s*([\w-]+)\s*(?:#.*)?", line)
        if kv:
            key, value = kv.groups()
            current[key] = ({"true": True, "false": False}[value]
                            if value in ("true", "false") else int(value))
    return profiles or _DEFAULTS


if settings is not None:
    for _name, _kw in _profiles_from_pyproject().items():
        # no deadline: property examples run real solvers (HiGHS, the JAX
        # annealer) whose first call includes compile time
        settings.register_profile(_name, deadline=None, **_kw)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "fast"))
