"""Overload robustness: trace-driven open-loop load, bounded admission,
SLO guardrails, and the brownout ladder.

The headline A/B (the PR's acceptance criterion): at 2x offered load
over measured capacity, the guarded run (admission + SLO brownout)
keeps its dispatch backlog bounded, sheds the excess with typed,
persistable events, and holds the p99 end-to-end latency of *admitted*
requests inside the SLO — while the control run (admission disabled)
shows monotonically growing backlog and a p99 far past the target.
Identical seeds reproduce identical shed/degrade/record streams in
concurrent and sequential executor modes.

Everything is calibrated at test time against a *measured* closed-loop
task rate, not the fitted models' optimistic token rates: at smoke
scale the per-dispatch constant (gamma) dominates real throughput, so
"2x capacity" must mean 2x what the fleet actually sustains.
"""
import json
import math

import numpy as np
import pytest

from repro.core.slo import SLOConfig
from repro.domains.lm_serving import (
    LMRequest,
    SimulatedLMPlatform,
    kv_bytes_per_token,
)
from repro.runtime import (
    AdmissionConfig,
    AdmissionController,
    BrownoutTransition,
    OnlineConfig,
    OnlineScheduler,
    PlatformSpec,
    Scheduler,
    ShedEvent,
    dump_records,
    load_records,
    make_domain,
    predicted_unit_rates,
)
from repro.runtime.faults import CLOSED, HALF_OPEN, OPEN
from repro.runtime.loadgen import (
    BurstyRate,
    ConstantRate,
    DiurnalRate,
    LoadGenerator,
    lm_request_factory,
)

MEAN_TOK = 12.0
QUEUE_TASKS = 40


def _seed_requests():
    # one seed task per trace family so arrivals adopt fitted models
    return [
        LMRequest("qwen25_3b", prompt_len=8, gen_tokens=16, batch=1,
                  max_new_tokens=64, task_id=0),
        LMRequest("qwen25_3b", prompt_len=16, gen_tokens=16, batch=1,
                  max_new_tokens=64, task_id=1),
    ]


def _specs(per):
    return [
        PlatformSpec("Edge", "CPU", "sim", "loc", 4.0, 0.2,
                     mem_bytes=per * 72 * 120),
        PlatformSpec("Rack", "GPU", "sim", "loc", 20.0, 1.0,
                     mem_bytes=per * 72 * 240),
        PlatformSpec("Big", "GPU", "sim", "loc", 80.0, 5.0,
                     mem_bytes=per * 72 * 480),
    ]


@pytest.fixture(scope="module")
def task_rate():
    """Closed-loop calibration: tasks/sec the fleet actually sustains."""
    n = 40
    reqs = [LMRequest("qwen25_3b", prompt_len=(8, 16)[i % 2],
                      gen_tokens=int(MEAN_TOK), batch=1,
                      max_new_tokens=64, task_id=i)
            for i in range(n)]
    per = kv_bytes_per_token(reqs[0].config(), 1)
    fleet = [SimulatedLMPlatform(s, seed=0) for s in _specs(per)]
    sched = Scheduler(make_domain("lm_serving", reqs, fleet))
    sched.characterise(seed=1, token_ladder=(2, 4, 8, 16))
    rep = sched.execute(sched.allocate(method="heuristic"))
    busy: dict[str, float] = {}
    for r in rep.records:
        busy[r.platform] = busy.get(r.platform, 0.0) + abs(r.latency)
    return n / max(busy.values())


def _run_trace(ratio, task_rate, *, guarded, seed=0, n_target=600,
               mode=None, rate_fn=None, scenario_hook=None,
               target_scale=3.0, degrade_steps=(0.75, 0.5), rounds=60):
    """One open-loop serving run against a seeded trace."""
    reqs = _seed_requests()
    per = kv_bytes_per_token(reqs[0].config(), 1)
    fleet = [SimulatedLMPlatform(s, seed=0) for s in _specs(per)]
    sched = Scheduler(make_domain("lm_serving", reqs, fleet))
    sched.characterise(seed=1, token_ladder=(2, 4, 8, 16))

    R = sum(predicted_unit_rates(sched.models,
                                 typical_units=MEAN_TOK).values())
    lam = ratio * task_rate
    horizon = n_target / lam
    queue_s = QUEUE_TASKS * MEAN_TOK / R     # predicted-cost queue budget
    target = target_scale * QUEUE_TASKS / task_rate   # in real drain time

    factory = lm_request_factory(archs=("qwen25_3b",),
                                 prompt_buckets=(8, 16),
                                 batch=1, max_new_tokens=64)
    gen = LoadGenerator(rate_fn or ConstantRate(lam), factory,
                        seed=seed, start_id=1000)
    scenario = gen.scenario(horizon)
    if scenario_hook is not None:
        scenario_hook(scenario, horizon)
    for p in fleet:
        p.attach_scenario(scenario)

    cfg = OnlineConfig(
        rounds=rounds, gamma_duty=0.0, open_loop=True,
        adopt_family_models=True,
        admission=AdmissionConfig(queue_s=queue_s,
                                  max_wait_s=target) if guarded else None,
        slo=SLOConfig(target_s=target, metric="e2e", quantile=0.99,
                      window=32, min_window=8) if guarded else None,
        degrade_steps=degrade_steps if guarded else (),
        breaker_cooldown=horizon * 0.15)
    rep = OnlineScheduler(sched, cfg).run(method="heuristic", seed=3,
                                          mode=mode, scenario=scenario)
    return rep, dict(queue_s=queue_s, target=target, horizon=horizon,
                     lam=lam)


def _p99(rep):
    e2e = sorted(m["e2e"] for m in rep.task_metrics.values())
    return e2e[max(int(len(e2e) * 0.99) - 1, 0)]


# --------------------------------------------------------------------------
# load generator determinism and shapes
# --------------------------------------------------------------------------

def test_loadgen_same_seed_reproduces_identical_trace():
    factory = lm_request_factory()
    a = LoadGenerator(ConstantRate(50.0), factory, seed=4).arrivals(2.0)
    b = LoadGenerator(ConstantRate(50.0), factory, seed=4).arrivals(2.0)
    c = LoadGenerator(ConstantRate(50.0), factory, seed=5).arrivals(2.0)
    assert [(t, r) for t, r in a] == [(t, r) for t, r in b]
    assert a != c
    assert all(0.0 <= t <= 2.0 for t, _ in a)
    assert [t for t, _ in a] == sorted(t for t, _ in a)


def test_loadgen_rate_curves_shape_the_trace():
    factory = lm_request_factory()
    lam = 200.0
    flat = LoadGenerator(ConstantRate(lam), factory, seed=0).arrivals(1.0)
    assert len(flat) == pytest.approx(lam, rel=0.3)

    burst = BurstyRate(base_per_s=10.0, burst_per_s=500.0,
                       period_s=1.0, duty=0.2)
    b = LoadGenerator(burst, factory, seed=0).arrivals(1.0)
    in_burst = sum(1 for t, _ in b if t < 0.2)
    assert in_burst > 0.7 * len(b)           # the burst window dominates

    diurnal = DiurnalRate(base_per_s=lam, amplitude=0.9, period_s=1.0)
    d = LoadGenerator(diurnal, factory, seed=0).arrivals(1.0)
    first, second = (sum(1 for t, _ in d if (t < 0.5) == half)
                     for half in (True, False))
    assert first > 2 * second                # peak half vs trough half


def test_loadgen_requests_are_heavy_tailed_and_family_tagged():
    factory = lm_request_factory(archs=("qwen25_3b",),
                                 prompt_buckets=(8, 16), tail_alpha=1.3)
    trace = LoadGenerator(ConstantRate(500.0), factory, seed=2).arrivals(2.0)
    reqs = [r for _, r in trace]
    assert {r.prompt_len for r in reqs} == {8, 16}
    toks = sorted(r.gen_tokens for r in reqs)
    assert toks[0] >= 4 and toks[-1] <= 64   # bounded-Pareto support
    assert toks[-1] > 3 * toks[len(toks) // 2]   # a real tail
    ids = [r.task_id for r in reqs]
    assert len(set(ids)) == len(ids)


def test_bounded_pareto_validates_and_covers_both_endpoints():
    from repro.runtime.loadgen import _bounded_pareto

    with pytest.raises(ValueError, match="alpha"):
        _bounded_pareto(0.5, 4, 64, 0.0)
    with pytest.raises(ValueError, match="lo"):
        _bounded_pareto(0.5, 64, 4, 1.5)
    assert _bounded_pareto(0.0, 4, 64, 1.5) == 4
    # u -> 1 must land in the hi bucket: before the fix int() truncation
    # mapped the top unit interval to hi - 1 and hi was unreachable
    assert _bounded_pareto(1.0 - 1e-12, 4, 64, 1.5) == 64
    assert _bounded_pareto(0.3, 7, 7, 2.0) == 7   # degenerate support


def test_bounded_pareto_bucket_masses_match_analytic_cdf():
    """Distribution-shape regression: each integer bucket k carries the
    continuous bounded-Pareto mass of [k, k+1) on [lo, hi+1) — including
    the hi bucket, which used to get (truncated) zero mass."""
    from repro.runtime.loadgen import _bounded_pareto

    lo, hi, alpha, n = 4, 64, 1.5, 200_000
    rng = np.random.default_rng(7)
    draws = np.array([_bounded_pareto(u, lo, hi, alpha) for u in rng.random(n)])
    assert draws.min() >= lo and draws.max() == hi

    la, ha = lo ** -alpha, (hi + 1.0) ** -alpha
    cdf = lambda x: (la - x ** -alpha) / (la - ha)  # noqa: E731
    for k in (lo, 5, 8, 16, 32, 63, hi):
        want = cdf(k + 1.0) - cdf(float(k))
        got = (draws == k).mean()
        assert got == pytest.approx(want, rel=0.08, abs=2e-3), k


def test_loadgen_scenario_feeds_existing_scenario_object():
    factory = lm_request_factory()
    gen = LoadGenerator(ConstantRate(100.0), factory, seed=0)
    sc = gen.scenario(1.0)
    n = len(gen.arrivals(1.0))
    assert len(sc.take_arrivals(math.inf, force=True)) == n


# --------------------------------------------------------------------------
# admission controller unit behaviour
# --------------------------------------------------------------------------

def _mk_task(tid):
    return LMRequest("qwen25_3b", prompt_len=8, gen_tokens=8, batch=1,
                     max_new_tokens=64, task_id=tid)


def test_admission_queue_bound_from_rate_and_capacity():
    ac = AdmissionController(AdmissionConfig(queue_s=2.0))
    # fast fleet, roomy capacity: rate bound wins (100/s * 2 s / 10 units)
    ac.update_fleet({"a": 100.0}, {"a": 1e9}, task_units=10.0,
                    task_resource=1.0)
    assert ac.queue_limit == 20
    # same rate, tight capacity: capacity bound wins (5 footprints left)
    ac.update_fleet({"a": 100.0}, {"a": 50.0}, task_units=10.0,
                    task_resource=10.0)
    assert ac.queue_limit == 5
    # dead fleet still has a floor of 1 (never a zero-size queue)
    ac.update_fleet({"a": 0.0}, {"a": 0.0}, task_units=10.0,
                    task_resource=10.0)
    assert ac.queue_limit == 1


def test_admission_sheds_queue_full_and_capacity_with_typed_events():
    ac = AdmissionController(AdmissionConfig(queue_s=1.0, max_queue=2))
    ac.update_fleet({"a": 100.0}, {"a": 1e9}, 10.0, 1.0)
    assert ac.offer(_mk_task(1), t=0.0, round_idx=0, cost_s=0.1,
                    fits=True) is None
    assert ac.offer(_mk_task(2), t=0.0, round_idx=0, cost_s=0.1,
                    fits=True) is None
    rej = ac.offer(_mk_task(3), t=0.1, round_idx=0, cost_s=0.1, fits=True)
    assert rej.event.reason == "queue-full" and rej.event.queue_depth == 2
    rej = ac.offer(_mk_task(4), t=0.2, round_idx=1, cost_s=0.1, fits=False)
    assert rej.event.reason == "capacity" and rej.event.round == 1
    assert ac.n_offered == 4 and ac.n_shed == 2


def test_admission_backpressure_shrinks_budget_and_timeout_sheds():
    cfg = AdmissionConfig(queue_s=1.0, util_high=0.5,
                          backpressure_factor=0.5, max_wait_s=1.0,
                          ewma_alpha=1.0)
    ac = AdmissionController(cfg)
    ac.update_fleet({"a": 10.0}, {"a": 1e9}, 1.0, 1.0)
    for i in range(6):
        ac.offer(_mk_task(i), t=0.0, round_idx=0, cost_s=0.25, fits=True)
    # idle fleet: full 1.0 s budget admits four 0.25 s tasks, two wait
    admitted, timed_out = ac.admit(now=0.5, round_idx=0, backlog_s=0.0)
    assert len(admitted) == 4 and not timed_out
    assert ac.queue_depth == 2
    # saturated fleet: the budget halves, so the same two queued tasks
    # would have fit before but only two 0.25 s costs fit under 0.5 s
    ac.observe_utilisation(busy_s=10.0, span_s=10.0, n_platforms=1)
    ac.offer(_mk_task(10), t=0.6, round_idx=1, cost_s=0.3, fits=True)
    admitted, _ = ac.admit(now=0.7, round_idx=1, backlog_s=0.0)
    assert len(admitted) == 2 and ac.queue_depth == 1
    # the leftover ages past max_wait_s and sheds as a timeout
    admitted, timed_out = ac.admit(now=5.0, round_idx=2, backlog_s=9.9)
    assert not admitted
    assert [r.event.reason for r in timed_out] == ["timeout"]


def test_predicted_unit_rates_amortise_gamma_and_skip_placeholders():
    class _Lat:
        def __init__(self, beta, gamma):
            self.beta, self.gamma = beta, gamma

    class _M:
        def __init__(self, beta, gamma):
            self.latency = _Lat(beta, gamma)

    models = {
        ("fast", 0): _M(1e-12, 0.1),      # RTT-bound: rate ~= u/gamma
        ("slow", 0): _M(0.5, 0.0),
        ("dead", 0): _M(1e9, 1e9),        # unreachable placeholder
    }
    rates = predicted_unit_rates(models, alive=("fast", "slow", "dead"),
                                 typical_units=10.0)
    assert rates["fast"] == pytest.approx(100.0, rel=1e-6)
    assert rates["slow"] == pytest.approx(2.0)
    assert rates["dead"] == 0.0           # no finite model -> no headroom


# --------------------------------------------------------------------------
# the 2x overload A/B — the PR's acceptance criterion
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_overload_guarded_bounds_backlog_and_holds_slo(task_rate):
    guarded, g = _run_trace(2.0, task_rate, guarded=True)
    control, c = _run_trace(2.0, task_rate, guarded=False)

    # the control run admits everything and its backlog diverges: while
    # the trace is still offering load (round t inside the horizon) the
    # backlog grows monotonically, peaking far above the guarded plateau
    g_back = [r.backlog_units for r in guarded.rounds]
    c_active = [r.backlog_units for r in control.rounds
                if r.t <= c["horizon"]]
    assert max(c_active) > 4 * max(g_back)
    tail = c_active[-4:]
    assert all(a < b for a, b in zip(tail, tail[1:])), tail

    # guarded: bounded queue, deterministic typed sheds, SLO held
    assert guarded.n_shed > 0
    assert guarded.shed_fraction == pytest.approx(0.5, abs=0.25)
    assert all(ev.reason in ("queue-full", "capacity", "timeout")
               for ev in guarded.shed_events)
    limit = max(r.queue_depth for r in guarded.rounds)
    assert limit <= 3 * QUEUE_TASKS
    assert _p99(guarded) <= g["target"]
    assert guarded.slo["attainment"] >= 0.95
    # control blows straight through the same target
    assert _p99(control) > g["target"]
    assert control.n_shed == 0 and not control.shed_events

    # offered arrivals are conserved: admitted + shed == offered
    assert guarded.n_offered == guarded.arrivals + guarded.n_shed
    assert control.n_offered == control.arrivals

    # the admission barrier's KV audit never went negative: no platform
    # was ever committed past its cache budget
    assert min(r.kv_headroom for r in guarded.rounds) >= 0.0


@pytest.mark.slow
def test_overload_streams_are_deterministic_across_modes(task_rate):
    seq, _ = _run_trace(2.0, task_rate, guarded=True, mode="sequential",
                        n_target=300)
    conc, _ = _run_trace(2.0, task_rate, guarded=True, mode="concurrent",
                         n_target=300)
    again, _ = _run_trace(2.0, task_rate, guarded=True, mode="sequential",
                          n_target=300)
    assert seq.mode == "sequential" and conc.mode == "concurrent"
    assert seq.records == conc.records == again.records
    assert seq.shed_events == conc.shed_events == again.shed_events
    assert (seq.brownout_transitions == conc.brownout_transitions
            == again.brownout_transitions)
    assert seq.task_metrics == conc.task_metrics
    assert seq.slo == conc.slo


@pytest.mark.slow
def test_shed_and_brownout_events_round_trip_jsonl(tmp_path, task_rate):
    rep, _ = _run_trace(2.0, task_rate, guarded=True, n_target=300,
                        target_scale=1.2)
    assert rep.shed_events and rep.brownout_transitions
    path = tmp_path / "events.jsonl"
    events = rep.shed_events + rep.brownout_transitions
    dump_records(events, path)
    loaded = load_records(path)
    assert loaded == events
    assert all(isinstance(e, (ShedEvent, BrownoutTransition))
               for e in loaded)


# --------------------------------------------------------------------------
# brownout ladder: deepen under pressure, restore when it clears
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_brownout_deepens_under_sustained_breach(task_rate):
    # a target tight enough that full-quality p99 cannot meet it
    rep, _ = _run_trace(2.0, task_rate, guarded=True, n_target=400,
                        target_scale=1.2)
    deepens = [t for t in rep.brownout_transitions if t.direction == "deepen"]
    assert deepens and rep.brownout_rung > 0
    assert sum(rep.brownout_occupancy.values()) == len(rep.rounds)
    assert any(rung > 0 for rung in rep.brownout_occupancy)
    for tr in deepens:
        assert tr.rung_to == tr.rung_from + 1
        assert tr.observed > rep.slo["target_s"]


@pytest.mark.slow
def test_brownout_restores_after_burst_clears(task_rate):
    def bursty(lam):
        return BurstyRate(base_per_s=0.3 * task_rate,
                          burst_per_s=3.0 * task_rate,
                          period_s=900 / task_rate, duty=0.25)

    rep, _ = _run_trace(1.0, task_rate, guarded=True, n_target=900,
                        target_scale=1.2, rounds=80,
                        rate_fn=bursty(None))
    dirs = [t.direction for t in rep.brownout_transitions]
    assert "deepen" in dirs and "restore" in dirs
    # the ladder is reversible: every restore steps exactly one rung up
    for tr in rep.brownout_transitions:
        if tr.direction == "restore":
            assert tr.rung_to == tr.rung_from - 1


# --------------------------------------------------------------------------
# circuit-breaker recovery under sustained open-loop load
# --------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("mode", ["sequential", "concurrent"])
def test_breaker_recovery_arc_under_open_loop_load(task_rate, mode):
    def outage(scenario, horizon):
        scenario.outage("Rack", t=horizon * 0.2, end=horizon * 0.45)

    rep, _ = _run_trace(1.2, task_rate, guarded=True, mode=mode,
                        n_target=500, target_scale=6.0,
                        scenario_hook=outage)
    assert rep.recovered_platforms == ("Rack",)
    arc = [(t.frm, t.to) for t in rep.breaker_transitions
           if t.platform == "Rack"]
    assert (CLOSED, OPEN) in arc and (OPEN, HALF_OPEN) in arc
    assert (HALF_OPEN, CLOSED) in arc
    # arrivals keep flowing after the platform is re-admitted
    rec_round = max(t.round for t in rep.breaker_transitions
                    if t.platform == "Rack" and t.to == CLOSED)
    assert sum(r.arrivals for r in rep.rounds[rec_round:]) > 0


@pytest.mark.slow
def test_breaker_recovery_record_parity_across_modes(task_rate):
    def outage(scenario, horizon):
        scenario.outage("Rack", t=horizon * 0.2, end=horizon * 0.45)

    runs = {}
    for mode in ("sequential", "concurrent"):
        rep, _ = _run_trace(1.2, task_rate, guarded=True, mode=mode,
                            n_target=500, target_scale=6.0,
                            scenario_hook=outage)
        runs[mode] = rep
    seq, conc = runs["sequential"], runs["concurrent"]
    assert seq.records == conc.records
    assert seq.shed_events == conc.shed_events
    assert seq.breaker_transitions == conc.breaker_transitions
    assert seq.recovered_platforms == conc.recovered_platforms
