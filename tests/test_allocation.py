"""Allocation-solver tests (paper §3.2/§4.3/§6): invariants + quality."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    AllocationProblem,
    check_allocation,
    makespan,
    milp_allocation,
    ml_allocation,
    platform_latencies,
    proportional_allocation,
    synthetic,
)
from repro.core.annealing import lp_polish


def small_problem(seed=0, mu=4, tau=12, psi=1.0, case="Het-Inc"):
    return synthetic.generate_case(case, tau=tau, mu=mu, psi=psi, seed=seed)


# ---------------------------------------------------------------- invariants

@given(seed=st.integers(0, 10_000), psi=st.floats(0.0, 10.0),
       case=st.sampled_from(sorted(synthetic.TABLE3_CASES)))
@settings(max_examples=25, deadline=None)
def test_heuristic_constraints(seed, psi, case):
    p = small_problem(seed, psi=max(psi, 1e-6), case=case)
    a = proportional_allocation(p)
    check_allocation(a.A, p)
    assert a.makespan > 0


@given(seed=st.integers(0, 1000))
@settings(max_examples=5, deadline=None)
def test_milp_constraints_and_dominance(seed):
    p = small_problem(seed)
    h = proportional_allocation(p)
    m = milp_allocation(p, time_limit=20)
    check_allocation(m.A, p)
    # MILP never loses to the heuristic (it could fall back to it at worst)
    assert m.makespan <= h.makespan * (1 + 1e-6)


def test_ml_constraints_and_dominance():
    p = small_problem(3)
    h = proportional_allocation(p)
    m = ml_allocation(p, chains=8, steps=1500, rounds=1, seed=0)
    check_allocation(m.A, p)
    assert m.makespan <= h.makespan * (1 + 1e-6)


# ------------------------------------------------------------------- quality

def test_heuristic_optimal_rank1_no_constants():
    """Paper §4.3.2: with gamma=0 and task-independent platform speeds the
    proportional heuristic is optimal (all platforms finish together)."""
    rng = np.random.default_rng(0)
    speed = rng.uniform(1, 10, size=5)          # per-platform s/path
    work = rng.uniform(1, 100, size=9)          # per-task paths
    W = np.outer(speed, work)
    p = AllocationProblem.from_work(W, np.zeros_like(W))
    h = proportional_allocation(p)
    lat = platform_latencies(h.A, p)
    np.testing.assert_allclose(lat, lat[0], rtol=1e-9)   # equalised
    m = milp_allocation(p, time_limit=20)
    assert h.makespan == pytest.approx(m.makespan, rel=1e-4)


def test_milp_beats_heuristic_when_constants_dominate():
    """Paper §6.3: large psi (constants dominate) is where MILP shines."""
    p = small_problem(1, mu=6, tau=24, psi=10.0)
    h = proportional_allocation(p)
    m = milp_allocation(p, time_limit=30)
    assert m.makespan < h.makespan / 2   # at least 2x better


def test_milp_reports_certificate():
    p = small_problem(2)
    m = milp_allocation(p, time_limit=30)
    assert m.solver == "milp"
    assert m.meta["status"] in (0, 1, 3)
    if m.optimal:
        assert m.bound is not None
        assert m.bound <= m.makespan * (1 + 1e-3)


def test_lp_polish_improves_or_matches():
    p = small_problem(5)
    h = proportional_allocation(p)
    out = lp_polish(p, np.ones((p.mu, p.tau), dtype=bool))
    assert out is not None
    _, m = out
    assert m <= h.makespan * (1 + 1e-9)


def test_atomic_milp():
    p = small_problem(4, mu=3, tau=6)
    m = milp_allocation(p, time_limit=20, atomic=True)
    check_allocation(m.A, p)
    # atomic solution must be integral
    assert np.allclose(m.A, np.round(m.A), atol=1e-6)
    # relaxed (divisible) problem can only be better or equal
    r = milp_allocation(p, time_limit=20)
    assert r.makespan <= m.makespan * (1 + 1e-6)


# ------------------------------------------------------------------ makespan

@given(seed=st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_makespan_is_max_of_platform_latencies(seed):
    p = small_problem(seed)
    rng = np.random.default_rng(seed)
    A = rng.dirichlet(np.ones(p.mu), size=p.tau).T  # valid random allocation
    check_allocation(A, p)
    assert makespan(A, p) == pytest.approx(platform_latencies(A, p).max())


def test_makespan_monotone_in_accuracy():
    """Tighter accuracy (smaller c) => more paths => larger makespan."""
    base = small_problem(6)
    for solver in (proportional_allocation,):
        prev = None
        for c in (1.0, 0.5, 0.25):
            p = AllocationProblem(delta=base.delta, gamma=base.gamma,
                                  c=np.full(base.tau, c))
            m = solver(p).makespan
            if prev is not None:
                assert m >= prev
            prev = m


def test_synthetic_generator_properties():
    for name in synthetic.TABLE3_CASES:
        p = synthetic.generate_case(name, tau=16, mu=8, psi=1.0, seed=0)
        assert p.delta.shape == (8, 16)
        assert (p.delta >= 1).all()
        assert (p.gamma >= 0).all()
    # consistency: fully consistent case has sorted columns
    p = synthetic.generate_case("Het-Con", tau=16, mu=8, psi=1.0, seed=0)
    assert (np.diff(p.delta, axis=0) >= 0).all()
