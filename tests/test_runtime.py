"""Runtime-subsystem tests: Domain protocol, Scheduler, registry, and the
two shipped domains (pricing parity + LM serving end-to-end)."""
import types

import numpy as np
import pytest

from repro.core import (
    AllocationProblem,
    linear_work_reduction,
    mc_work_reduction,
)
from repro.runtime import (
    Scheduler,
    available_domains,
    make_domain,
    register_domain,
)


# ------------------------------------------------------------------ registry

def test_registry_lists_builtin_domains():
    names = available_domains()
    assert "pricing" in names and "lm_serving" in names


def test_registry_unknown_domain_raises():
    with pytest.raises(KeyError, match="unknown domain"):
        make_domain("definitely-not-a-domain")


def test_registry_custom_domain_roundtrip():
    from repro.runtime import registry

    marker = types.SimpleNamespace(calls=[])

    def factory(*args, **kw):
        marker.calls.append((args, kw))
        return marker

    register_domain("_test_domain", factory)
    try:
        assert "_test_domain" in available_domains()
        assert make_domain("_test_domain", 1, flag=True) is marker
        assert marker.calls == [((1,), {"flag": True})]
    finally:  # the registry is process-global; don't leak into other tests
        registry._REGISTRY.pop("_test_domain", None)


# ---------------------------------------------------------------- reductions

def test_work_reductions():
    delta = np.array([[2.0, 4.0], [1.0, 8.0]])
    c = np.array([0.5, 2.0])
    np.testing.assert_allclose(mc_work_reduction(delta, c),
                               [[8.0, 1.0], [4.0, 2.0]])
    np.testing.assert_allclose(linear_work_reduction(delta, c),
                               [[1.0, 8.0], [0.5, 16.0]])


def test_allocation_problem_uses_domain_reduction():
    delta = np.array([[2.0, 4.0]])
    gamma = np.zeros((1, 2))
    c = np.array([0.5, 2.0])
    mc = AllocationProblem(delta=delta, gamma=gamma, c=c)
    lin = AllocationProblem(delta=delta, gamma=gamma, c=c,
                            reduction=linear_work_reduction)
    np.testing.assert_allclose(mc.work, [[8.0, 1.0]])
    np.testing.assert_allclose(lin.work, [[1.0, 8.0]])


# ------------------------------------------------- pricing: pooled CI maths

def test_pricing_pooled_inverse_variance_ci():
    """execute's pooling: path-weighted mean + ci^2 = sum (n ci)^2 / N^2."""
    from repro.domains.pricing import PricingDomain
    from repro.pricing.platforms import RunRecord

    task = types.SimpleNamespace(task_id=7)
    domain = PricingDomain([task], platforms=[])
    problem = AllocationProblem(delta=np.ones((1, 1)), gamma=np.zeros((1, 1)),
                                c=np.array([0.05]))
    records = [
        RunRecord("a", 7, n_paths=100, price=1.0, ci95=0.4, latency=0.1),
        RunRecord("b", 7, n_paths=300, price=2.0, ci95=0.2, latency=0.1),
    ]
    out = domain.summarise(records, problem)
    assert out["prices"][7] == pytest.approx((100 * 1.0 + 300 * 2.0) / 400)
    expect_ci = np.sqrt((100 * 0.4) ** 2 + (300 * 0.2) ** 2) / 400
    assert out["measured_ci"][7] == pytest.approx(expect_ci)
    assert out["predicted_ci"][7] == pytest.approx(0.05)


def test_pricing_pooled_ci_single_shard_is_identity():
    """Pooling one shard must return its own estimate verbatim."""
    from repro.domains.pricing import PricingDomain
    from repro.pricing.platforms import RunRecord

    task = types.SimpleNamespace(task_id=0)
    domain = PricingDomain([task], platforms=[])
    problem = AllocationProblem(delta=np.ones((1, 1)), gamma=np.zeros((1, 1)),
                                c=np.array([0.1]))
    rec = RunRecord("a", 0, n_paths=1000, price=3.25, ci95=0.07, latency=0.1)
    out = domain.summarise([rec], problem)
    assert out["prices"][0] == pytest.approx(3.25)
    assert out["measured_ci"][0] == pytest.approx(0.07)


# ------------------------------------------- pricing: scheduler parity

def _pricing_fixture():
    from repro.pricing import SimulatedPlatform, TABLE2_SPECS, table1_workload
    from repro.pricing.platforms import _TaskMoments

    tasks = table1_workload(seed=12, n_steps=8,
                            categories=[("BS-A", 2), ("H-A", 2)])
    moments = _TaskMoments(calib_paths=4096)
    platforms = [SimulatedPlatform(TABLE2_SPECS[0], moments=moments),
                 SimulatedPlatform(TABLE2_SPECS[9], moments=moments)]
    return tasks, platforms


def test_scheduler_matches_legacy_characterise():
    """The generic Domain.characterise loop reproduces the pricing layer's
    batched characterisation exactly (same grouping, ladders, seeds)."""
    from repro.pricing.platforms import characterise as legacy_characterise

    tasks, platforms = _pricing_fixture()
    ladder = (512, 2048)
    sched = Scheduler(make_domain("pricing", tasks, platforms))
    sched.characterise(seed=1, path_ladder=ladder)
    legacy = legacy_characterise(platforms, tasks, ladder, seed=1, batched=True)
    assert set(sched.models) == set(legacy)
    for key, model in sched.models.items():
        assert model.latency.beta == pytest.approx(legacy[key].latency.beta)
        assert model.accuracy.alpha == pytest.approx(legacy[key].accuracy.alpha)


def test_scheduler_run_convenience_pricing():
    tasks, platforms = _pricing_fixture()
    sched = Scheduler(make_domain("pricing", tasks, platforms))
    rep = sched.run(quality=0.5, method="heuristic",
                    characterise_kw=dict(seed=1, path_ladder=(512, 2048)))
    assert rep.measured_makespan > 0
    assert set(rep.summary["prices"]) == {t.task_id for t in tasks}


def test_pricing_solver_wrapper_exposes_models():
    """Compatibility surface: .models, .tasks, .platforms, problem()."""
    from repro.pricing import PricingSolver

    tasks, platforms = _pricing_fixture()
    solver = PricingSolver(tasks, platforms)
    assert solver.models is None
    with pytest.raises(RuntimeError, match="characterise"):
        solver.problem(0.5)
    solver.characterise(path_ladder=(512, 2048), seed=1)
    assert len(solver.models) == len(platforms) * len(tasks)
    p = solver.problem(0.5)
    assert p.delta.shape == (len(platforms), len(tasks))
    assert p.reduction is mc_work_reduction


# --------------------------------------------------- LM serving end-to-end

@pytest.fixture(scope="module")
def lm_sched():
    from repro.domains.lm_serving import build_lm_fleet, smoke_requests

    reqs = smoke_requests(3, arch="qwen25_3b")
    fleet = build_lm_fleet(include_local=True)
    sched = Scheduler(make_domain("lm_serving", reqs, fleet))
    sched.characterise(seed=1, token_ladder=(2, 4, 8))
    return sched


def test_lm_serving_characterise_fits_eq7(lm_sched):
    """Every (platform, request) pair gets a sane latency model."""
    reqs, fleet = lm_sched.tasks, lm_sched.platforms
    assert len(lm_sched.models) == len(fleet) * len(reqs)
    for model in lm_sched.models.values():
        assert model.latency.beta > 0
        assert model.latency.gamma >= 0
    delta, gamma = lm_sched.model_matrices()
    assert (delta > 0).all() and (gamma >= 0).all()


def test_lm_serving_simulated_beta_matches_flops_model():
    """Online benchmarking recovers a simulated platform's true beta."""
    from repro.core.metrics import fit_latency_model
    from repro.domains.lm_serving import (
        LM_FLEET_SPECS,
        SimulatedLMPlatform,
        flops_per_token,
        smoke_requests,
    )

    (req,) = smoke_requests(1)
    spec = LM_FLEET_SPECS[0]  # Edge Accelerator: beta-dominated
    platform = SimulatedLMPlatform(spec, jitter=1e-4)
    recs = [platform.run(req, n, seed=i) for i, n in enumerate((4, 8, 16, 32))]
    lat = fit_latency_model([r.n_tokens for r in recs],
                            [r.latency for r in recs])
    beta_true = flops_per_token(req.config(), req.batch) / (spec.gflops * 1e9)
    assert lat.beta == pytest.approx(beta_true, rel=0.05)


@pytest.mark.parametrize("method,kw", [
    ("heuristic", {}),
    ("ml", dict(chains=8, steps=800, rounds=1, seed=0)),
    ("milp", dict(time_limit=20)),
])
def test_lm_serving_all_solvers_end_to_end(lm_sched, method, kw):
    """Acceptance: the smoke LM workload is allocated by every solver and
    executed with predicted-vs-measured makespan reported."""
    alloc = lm_sched.allocate(method=method, **kw)
    rep = lm_sched.execute(alloc)
    assert rep.predicted_makespan > 0
    assert rep.measured_makespan > 0
    assert np.isfinite(rep.makespan_error)
    # every request is fully served: tokens >= its generation target
    for req in lm_sched.tasks:
        assert rep.summary["tokens"][req.task_id] >= req.gen_tokens
    # per-platform latencies account for the measured makespan
    assert rep.measured_makespan == pytest.approx(
        max(rep.platform_latencies.values()))


def test_lm_serving_milp_beats_heuristic(lm_sched):
    """Constants (RTT/prefill) dominate at smoke scale — the regime where
    the optimising solvers win (paper §6.3), now in the second domain."""
    h = lm_sched.allocate(method="heuristic")
    m = lm_sched.allocate(method="milp", time_limit=20)
    assert m.makespan <= h.makespan * (1 + 1e-6)


def test_lm_serving_uses_linear_reduction(lm_sched):
    problem = lm_sched.problem()
    assert problem.reduction is linear_work_reduction
    # default quality comes from the requests' generation targets
    np.testing.assert_allclose(problem.c,
                               [r.gen_tokens for r in lm_sched.tasks])
    # W = beta o c: doubling requested tokens doubles work, not x4
    doubled = lm_sched.problem(problem.c * 2)
    np.testing.assert_allclose(doubled.work, problem.work * 2)


def test_lm_characterise_ladder_clamps_without_degenerating():
    """A small max_new_tokens must clamp the token ladder to *distinct*
    rungs — duplicate points would make the (beta, gamma) fit
    rank-deficient and misattribute the RTT constant to the slope."""
    from repro.domains.lm_serving import (
        LM_FLEET_SPECS,
        LMRequest,
        LMServingDomain,
        SimulatedLMPlatform,
    )

    req = LMRequest("qwen25_3b", prompt_len=8, gen_tokens=2, max_new_tokens=2,
                    task_id=0)
    platform = SimulatedLMPlatform(LM_FLEET_SPECS[2], jitter=1e-4)  # RTT-heavy
    domain = LMServingDomain([req], [platform])
    rungs = domain.characterise_batch(platform, [req], seed=1)
    ns = [rung[0].n_tokens for rung in rungs]
    assert len(set(ns)) == len(ns) >= 2
    model = domain.fit_models([rung[0] for rung in rungs])
    # the 60ms RTT must land in gamma, not beta
    assert model.latency.gamma == pytest.approx(
        LM_FLEET_SPECS[2].rtt_ms * 1e-3, rel=0.2)


def test_lm_request_validates_gen_tokens():
    from repro.domains.lm_serving import LMRequest

    with pytest.raises(ValueError, match="gen_tokens"):
        LMRequest("qwen25_3b", prompt_len=8, gen_tokens=100, max_new_tokens=64)
    with pytest.raises(ValueError, match="gen_tokens"):
        LMRequest("qwen25_3b", prompt_len=8, gen_tokens=0)


def test_lm_request_launch_key_groups_families():
    from repro.domains.lm_serving import LMRequest, LMServingDomain

    reqs = [LMRequest("qwen25_3b", 8, 16, batch=2, task_id=0),
            LMRequest("qwen25_3b", 8, 24, batch=2, task_id=1),
            LMRequest("qwen25_3b", 16, 16, batch=2, task_id=2)]
    domain = LMServingDomain(reqs, platforms=[])
    groups = domain.group_tasks(reqs)
    assert len(groups) == 2  # same (arch, batch, prompt) -> one compile unit
