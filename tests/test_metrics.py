"""Metric-model tests (paper §3.1/§4.2): fitting, prediction, properties."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    AccuracyModel,
    CombinedModel,
    LatencyModel,
    fit_accuracy_model,
    fit_latency_model,
    relative_error,
)


def test_latency_fit_exact_recovery():
    true = LatencyModel(beta=2.5e-6, gamma=0.125)
    n = np.array([1e3, 1e4, 1e5, 1e6])
    m = fit_latency_model(n, true(n))
    assert m.beta == pytest.approx(true.beta, rel=1e-6)
    assert m.gamma == pytest.approx(true.gamma, rel=1e-6)


def test_accuracy_fit_exact_recovery():
    true = AccuracyModel(alpha=42.0)
    n = np.array([1e2, 1e4, 1e6])
    m = fit_accuracy_model(n, true(n))
    assert m.alpha == pytest.approx(42.0, rel=1e-6)


def test_combined_model_eq9():
    lat = LatencyModel(beta=1e-6, gamma=0.5)
    acc = AccuracyModel(alpha=10.0)
    comb = CombinedModel.from_models(lat, acc)
    # delta = beta * alpha^2
    assert comb.delta == pytest.approx(1e-4)
    # consistency: latency to reach accuracy c == beta * paths_for(c) + gamma
    c = 0.05
    n = acc.paths_for_accuracy(c)
    assert comb(c) == pytest.approx(lat(n), rel=1e-9)


@given(
    beta=st.floats(1e-9, 1e-3), gamma=st.floats(0, 10.0),
    noise=st.floats(0, 0.02), seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_latency_fit_noise_robust(beta, gamma, noise, seed):
    """Incorporation property: with b>=3 noisy points the fit stays within
    a few x the noise floor in relative terms."""
    rng = np.random.default_rng(seed)
    n = np.logspace(3, 6, 8)
    t = (beta * n + gamma) * (1 + rng.normal(0, noise, n.shape))
    m = fit_latency_model(n, t)
    pred_err = relative_error(m(n), beta * n + gamma)
    assert pred_err.max() < max(10 * noise, 1e-6)


def test_extrapolation_property():
    """Extrapolation (paper §5): fit on small n, predict 100x larger."""
    true = LatencyModel(beta=3e-6, gamma=0.2)
    n_bench = np.array([1e3, 3e3, 1e4])
    rng = np.random.default_rng(0)
    m = fit_latency_model(n_bench, true(n_bench) * (1 + rng.normal(0, 0.01, 3)))
    err = relative_error(m(1e6), true(1e6))
    assert err < 0.1  # within 10% — the paper's headline number


def test_relative_error_eq13():
    assert relative_error(11.0, 10.0) == pytest.approx(0.1)
    assert relative_error(9.0, 10.0) == pytest.approx(0.1)
