"""Online re-allocation: drift detection, re-fit, warm-started re-solves,
outage recovery, streaming arrivals, mode parity, record persistence."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    Allocation,
    AllocationProblem,
    expand_allocation,
    makespan,
    milp_allocation,
    ml_allocation,
    platform_latencies,
    restrict_allocation,
    restrict_problem,
)
from repro.runtime import (
    DriftDetector,
    OnlineConfig,
    OnlineScheduler,
    Scenario,
    Scheduler,
    TailDriftDetector,
    dump_records,
    group_records,
    load_records,
    make_domain,
)

LADDER = (512, 2048, 8192)
ROWS = (0, 9, 14)  # Desktop, Local GPU 1, Local FPGA 1


def _tasks():
    from repro.pricing import table1_workload

    return table1_workload(seed=12, n_steps=8,
                           categories=[("BS-A", 3), ("H-A", 3)])


def _fresh(scenario=None, tasks=None):
    """A characterised scheduler on fresh simulated platforms.

    Fresh per call: online runs re-fit models in place and platforms carry
    virtual clocks, so legs of an A/B must not share state."""
    from repro.pricing import SimulatedPlatform, TABLE2_SPECS
    from repro.pricing.platforms import _TaskMoments

    moments = _TaskMoments(calib_paths=4096)
    platforms = [SimulatedPlatform(TABLE2_SPECS[i], moments=moments, seed=7)
                 for i in ROWS]
    sched = Scheduler(make_domain("pricing", list(tasks or _tasks()), platforms))
    sched.characterise(seed=1, path_ladder=LADDER)
    if scenario is not None:
        for p in platforms:
            p.attach_scenario(scenario)
    return sched, platforms


# ------------------------------------------------- core: restricted solves

def _problem():
    delta = np.array([[1.0, 2.0, 4.0], [2.0, 1.0, 1.0]])
    gamma = np.array([[0.1, 0.1, 0.1], [0.2, 0.2, 0.2]])
    return AllocationProblem(delta=delta, gamma=gamma, c=np.ones(3))


def test_restrict_problem_scales_remaining_work():
    p = _problem()
    sub = restrict_problem(p, platforms=[1], tasks=[0, 2], remaining=[0.5, 0.25])
    np.testing.assert_allclose(sub.work, [[1.0, 0.25]])  # delta scaled
    np.testing.assert_allclose(sub.gamma, [[0.2, 0.2]])  # constants whole
    np.testing.assert_allclose(sub.c, [1.0, 1.0])


def test_restrict_expand_allocation_roundtrip():
    A = np.array([[0.25, 1.0, 0.0], [0.75, 0.0, 1.0]])
    sub = restrict_allocation(A, platforms=[0, 1], tasks=[0, 2])
    np.testing.assert_allclose(sub.sum(axis=0), 1.0)
    full = expand_allocation(sub, 2, 3, [0, 1], [0, 2])
    np.testing.assert_allclose(full[:, 1], 0.0)  # dropped column stays zero
    np.testing.assert_allclose(full[:, 0], A[:, 0])


def test_restrict_allocation_orphan_column_uniform():
    # task 1's whole mass sits on platform 0; dropping that platform must
    # fall back to uniform shares, not a zero column
    A = np.array([[0.0, 1.0], [1.0, 0.0], [0.0, 0.0]])
    sub = restrict_allocation(A, platforms=[1, 2], tasks=[0, 1])
    np.testing.assert_allclose(sub[:, 1], [0.5, 0.5])
    np.testing.assert_allclose(sub.sum(axis=0), 1.0)


def test_problem_offsets_shift_latencies_and_solvers_honour_them():
    delta = np.array([[1.0, 1.0], [1.0, 1.0]])
    p0 = AllocationProblem(delta=delta, gamma=np.zeros((2, 2)), c=np.ones(2))
    # platform 0 already busy for 10s: everything must go to platform 1
    p = dataclasses.replace(p0, offsets=np.array([10.0, 0.0]))
    ones = np.ones((2, 2))
    np.testing.assert_allclose(
        platform_latencies(ones, p) - platform_latencies(ones, p0), [10.0, 0.0])
    m = milp_allocation(p, time_limit=10)
    assert m.A[1].sum() == pytest.approx(2.0, abs=1e-6)
    # the reported makespan is the projected finish: the busy platform's
    # committed 10s dominates the 2s of fresh work routed around it
    assert m.makespan == pytest.approx(10.0, rel=1e-3)


# ------------------------------------------------- solver warm starts

def test_warm_start_skips_when_incumbent_good():
    p = _problem()
    inc = milp_allocation(p, time_limit=10)
    again = milp_allocation(p, time_limit=10, incumbent=inc)
    assert again.meta["warm_start"] == "skipped"
    assert again.makespan == pytest.approx(inc.makespan, rel=1e-6)
    assert again.solve_time < inc.solve_time + 1.0  # no branch & bound pass


def test_warm_start_solves_when_problem_shifts():
    p = _problem()
    inc = milp_allocation(p, time_limit=10)
    shifted = dataclasses.replace(p, delta=p.delta * np.array([[10.0], [1.0]]))
    fresh = milp_allocation(shifted, time_limit=10, incumbent=inc)
    assert fresh.meta["warm_start"] == "solved"
    assert fresh.makespan < makespan(inc.A, shifted)


def test_ml_warm_start_skip_and_chain_seed():
    p = _problem()
    inc = milp_allocation(p, time_limit=10)
    skipped = ml_allocation(p, chains=4, steps=200, rounds=1, incumbent=inc)
    assert skipped.meta["warm_start"] == "skipped"
    shifted = dataclasses.replace(p, delta=p.delta * np.array([[25.0], [1.0]]))
    solved = ml_allocation(shifted, chains=4, steps=500, rounds=1,
                           incumbent=inc, warm_tol=1e-6)
    assert solved.meta["warm_start"] == "solved"
    # never worse than the incumbent it was seeded with
    assert solved.makespan <= makespan(inc.A, shifted) + 1e-9


def test_warm_start_solves_when_offsets_imbalanced():
    """A flat-optimal incumbent that ignores committed platform time must
    not be waved through: the offset-aware heuristic exposes it."""
    delta = np.ones((2, 2))
    flat = AllocationProblem(delta=delta, gamma=np.zeros((2, 2)), c=np.ones(2))
    inc = milp_allocation(flat, time_limit=10)  # balanced halves
    shifted = dataclasses.replace(flat, offsets=np.array([10.0, 0.0]))
    out = milp_allocation(shifted, time_limit=10, incumbent=inc)
    assert out.meta["warm_start"] == "solved"
    assert out.makespan < makespan(inc.A, shifted)


def test_warm_start_shape_mismatch_raises():
    p = _problem()
    bad = Allocation(A=np.ones((3, 3)) / 3, makespan=1.0, solver="x")
    with pytest.raises(ValueError, match="incumbent shape"):
        milp_allocation(p, incumbent=bad)


# ------------------------------------------------- drift detector

def test_drift_detector_fires_on_sustained_error():
    det = DriftDetector(window=4, threshold=0.5, min_records=3)
    for _ in range(4):
        det.observe("a", predicted=1.0, measured=1.02)
        det.observe("b", predicted=1.0, measured=4.0)
    assert det.drifted() == ("b",)
    assert det.median_ratio("b") == pytest.approx(4.0)
    det.reset()
    assert det.drifted() == ()


def test_drift_detector_needs_min_records():
    det = DriftDetector(window=8, threshold=0.5, min_records=3)
    det.observe("a", 1.0, 4.0)
    det.observe("a", 1.0, 4.0)
    assert det.drifted() == ()


@pytest.mark.parametrize("det_cls,ratio_of", [
    (DriftDetector, "median_ratio"),
    (TailDriftDetector, "tail_ratio"),
])
def test_detector_empty_window_is_neutral(det_cls, ratio_of):
    """Both the median and the p99 detector answer ratio 1.0 / error 0.0
    on a platform they have never observed — never nan, never a fire."""
    det = det_cls()
    assert getattr(det, ratio_of)("ghost") == 1.0
    assert det.error("ghost") == 0.0
    assert det.drifted() == ()


@pytest.mark.parametrize("det_cls,ratio_of", [
    (DriftDetector, "median_ratio"),
    (TailDriftDetector, "tail_ratio"),
])
def test_detector_single_record_window(det_cls, ratio_of):
    det = det_cls(window=8, threshold=0.5, min_records=3)
    det.observe("a", predicted=1.0, measured=3.0)
    assert getattr(det, ratio_of)("a") == pytest.approx(3.0)
    assert det.error("a") == pytest.approx(2.0)
    # one record is below min_records: no verdict yet
    assert det.drifted() == ()


def test_tail_detector_fires_on_spread_not_level():
    """A p99 blowup with a quiet median: the tail detector fires while
    the median detector stays silent — the overload signature."""
    med = DriftDetector(window=16, threshold=0.5, min_records=8)
    tail = TailDriftDetector(window=16, threshold=1.0, min_records=8)
    for i in range(16):
        measured = 5.0 if i % 8 == 7 else 1.0   # rare straggler
        med.observe("a", predicted=1.0, measured=measured)
        tail.observe("a", predicted=1.0, measured=measured)
    assert med.drifted() == ()
    assert tail.drifted() == ("a",)


# ------------------------------------------------- the online loop

def test_no_drift_solves_exactly_once():
    sched, _ = _fresh()
    rep = OnlineScheduler(sched, OnlineConfig(rounds=6)).run(
        0.05, method="milp", seed=3, time_limit=20)
    assert rep.n_solves == 1
    assert rep.n_resolves == 0 and rep.n_skipped == 0 and rep.n_refits == 0
    assert rep.measured_makespan > 0
    # quality met: every task's pooled CI at or near target
    for tid, ci in rep.summary["measured_ci"].items():
        assert ci <= 0.05 * 1.25


def test_drift_fires_and_shifts_work_off_slowed_platform():
    """Mid-run 4x slowdown on the most-loaded platform: the detector
    fires, models re-fit, and the re-solved allocation moves work away —
    measured by the platform's share of dispatched paths before vs after
    the re-solve."""
    base, base_platforms = _fresh()
    alloc = base.allocate(0.05, method="milp", time_limit=20)
    lat = platform_latencies(alloc.A, base.problem(0.05))
    hot = int(np.argmax(lat))
    slow = base_platforms[hot].spec.name
    sc = Scenario().slowdown(slow, t=float(lat[hot]) / 2, factor=4.0)
    sched, _ = _fresh(sc)
    beta0 = {tid: m.latency.beta for (pn, tid), m in sched.models.items()
             if pn == slow}
    rep = OnlineScheduler(sched, OnlineConfig(rounds=6)).run(
        0.05, method="milp", seed=3, time_limit=20)
    assert rep.n_resolves >= 1
    drift_round = next(r.round for r in rep.rounds if r.resolved)
    assert any(slow in r.drifted for r in rep.rounds)
    # re-fit moved the latency model substantially toward the 4x regime
    # (the first drift can fire while the window still straddles the
    # boundary, so the one-shot correction may land between 2x and 4x;
    # the allocation shift below is the functional contract)
    beta1 = {tid: m.latency.beta for (pn, tid), m in sched.models.items()
             if pn == slow}
    ratios = [beta1[tid] / beta0[tid] for tid in beta0]
    assert 2.0 <= np.median(ratios) <= 5.0
    # and the allocation shifted work off the slowed platform
    def gpu_share(rounds):
        units = {}
        for r in rounds:
            for pn, u in r.dispatched_units.items():
                units[pn] = units.get(pn, 0) + u
        return units.get(slow, 0) / max(sum(units.values()), 1)
    before = gpu_share([r for r in rep.rounds if r.round <= drift_round])
    after = gpu_share([r for r in rep.rounds if r.round > drift_round])
    assert after < before * 0.6


def test_adaptive_beats_static_under_midpoint_slowdown():
    """The acceptance scenario at test scale: slow the busiest platform 4x
    at the static plan's half-makespan; the adaptive run must win."""
    base, _ = _fresh()
    alloc = base.allocate(0.05, method="milp", time_limit=20)
    sc = Scenario().slowdown("Local GPU 1", alloc.makespan / 2, 4.0)

    s1, _ = _fresh(sc)
    static = s1.execute(s1.allocate(0.05, method="milp", time_limit=20),
                        0.05, seed=3)
    s2, _ = _fresh(sc)
    adaptive = OnlineScheduler(s2, OnlineConfig(rounds=6)).run(
        0.05, method="milp", seed=3, time_limit=20)
    assert adaptive.n_resolves >= 1
    assert adaptive.measured_makespan < static.measured_makespan


def test_outage_recovery_completes_all_tasks():
    dead = "Local GPU 1"
    sc = Scenario().outage(dead, t=0.02)
    sched, _ = _fresh(sc)
    rep = OnlineScheduler(sched, OnlineConfig(rounds=6)).run(
        0.05, method="milp", seed=3, time_limit=20)
    assert rep.dead_platforms == (dead,)
    assert rep.n_resolves >= 1
    # every task completed to quality on the survivors
    assert sorted(rep.summary["prices"]) == sorted(
        t.task_id for t in sched.tasks)
    for tid, ci in rep.summary["measured_ci"].items():
        assert ci <= 0.05 * 1.25
    # nothing dispatched to the dead platform after it was declared dead
    death_round = next(r.round for r in rep.rounds
                       if r.failed and r.resolved)
    for r in rep.rounds:
        if r.round > death_round:
            assert dead not in r.dispatched_units


def test_streaming_arrival_joins_and_is_served():
    extra = dataclasses.replace(_tasks()[0], task_id=100)
    sc = Scenario().arrive(t=0.05, task=extra)
    sched, _ = _fresh(sc)
    rep = OnlineScheduler(sched, OnlineConfig(rounds=6)).run(
        0.05, method="milp", seed=3, scenario=sc, time_limit=20)
    assert rep.arrivals == 1
    assert rep.n_solves >= 2  # the newcomer forces a placement solve
    assert 100 in rep.summary["prices"]
    assert rep.summary["measured_ci"][100] <= 0.05 * 1.25


def test_streaming_arrival_takes_patch_path():
    """A pure arrival (no drift, no outage) is placed by the O(k)
    incremental patch, the round log says so, and the per-solve telemetry
    rides along in ``solve_metas`` — while the accuracy target still holds."""
    extra = dataclasses.replace(_tasks()[0], task_id=100)
    sc = Scenario().arrive(t=0.05, task=extra)
    sched, _ = _fresh(sc)
    rep = OnlineScheduler(sched, OnlineConfig(rounds=6)).run(
        0.05, method="milp", seed=3, scenario=sc, time_limit=20)
    assert rep.n_patched == 1
    assert "patched" in [r.solve_outcome for r in rep.rounds]
    # telemetry satellite: the initial full solve carries phase timings,
    # the arrival solve is tagged as the incremental patch
    assert rep.solve_metas[0]["build_s"] >= 0
    assert rep.solve_metas[0]["solve_s"] >= 0
    assert any(m.get("incremental") == "patched" for m in rep.solve_metas)
    assert rep.summary["measured_ci"][100] <= 0.05 * 1.25


def test_streaming_arrival_patch_opt_out():
    """``patch_arrivals=False`` restores the pre-patch behaviour: the
    arrival is served through a full warm-started re-solve."""
    extra = dataclasses.replace(_tasks()[0], task_id=100)
    sc = Scenario().arrive(t=0.05, task=extra)
    sched, _ = _fresh(sc)
    rep = OnlineScheduler(
        sched, OnlineConfig(rounds=6, patch_arrivals=False)).run(
        0.05, method="milp", seed=3, scenario=sc, time_limit=20)
    assert rep.n_patched == 0
    assert not any(r.solve_outcome in ("patched", "patch-fallback")
                   for r in rep.rounds)
    assert 100 in rep.summary["prices"]


def test_arrival_after_platform_death_served_on_survivors():
    """A task arriving after a platform died must be characterised on the
    survivors only (benchmarking the dead platform would raise) and still
    complete; the dead pair gets an unreachable model placeholder."""
    dead = "Local GPU 1"
    extra = dataclasses.replace(_tasks()[0], task_id=100)
    sc = Scenario().outage(dead, t=0.002).arrive(t=0.01, task=extra)
    sched, _ = _fresh(sc)
    rep = OnlineScheduler(sched, OnlineConfig(rounds=6)).run(
        0.05, method="milp", seed=3, scenario=sc, time_limit=20)
    assert rep.dead_platforms == (dead,)
    assert rep.arrivals == 1
    assert 100 in rep.summary["prices"]
    assert not any(r.platform == dead and r.task_id == 100 for r in rep.records)


def test_arrival_scenario_replays_across_runs():
    """One scenario object must drive an A/B pair of runs: the arrival
    cursor is rewound per run, not consumed forever by the first."""
    extra = dataclasses.replace(_tasks()[0], task_id=100)
    sc = Scenario().arrive(t=0.05, task=extra)
    for _ in range(2):
        sched, _ = _fresh(sc)
        rep = OnlineScheduler(sched, OnlineConfig(rounds=4)).run(
            0.05, method="heuristic", seed=3, scenario=sc)
        assert rep.arrivals == 1
        assert 100 in rep.summary["prices"]


def test_arrival_rerun_same_scheduler_does_not_duplicate_task():
    """Re-running on the same scheduler replays the scenario, but a task
    that already joined the workload is admitted idempotently."""
    extra = dataclasses.replace(_tasks()[0], task_id=100)
    sc = Scenario().arrive(t=0.05, task=extra)
    sched, _ = _fresh(sc)
    online = OnlineScheduler(sched, OnlineConfig(rounds=4))
    first = online.run(0.05, method="heuristic", seed=3, scenario=sc)
    assert first.arrivals == 1
    n_tasks = len(sched.tasks)
    second = online.run(0.05, method="heuristic", seed=3, scenario=sc)
    assert second.arrivals == 0  # already part of the workload
    assert len(sched.tasks) == n_tasks
    assert 100 in second.summary["prices"]


def test_arrivals_reject_per_task_quality_vector():
    extra = dataclasses.replace(_tasks()[0], task_id=100)
    sc = Scenario().arrive(t=0.05, task=extra)
    sched, _ = _fresh(sc)
    with pytest.raises(ValueError, match="scalar quality"):
        OnlineScheduler(sched, OnlineConfig(rounds=4)).run(
            np.full(len(sched.tasks), 0.05), method="heuristic",
            scenario=sc)


def test_online_concurrent_sequential_bitwise_identical():
    """Drift, re-solves and all: records must not depend on the dispatch
    mode (round barriers + per-(platform, launch key, round) seeds)."""
    def run(mode):
        sc = Scenario().slowdown("Local GPU 1", 0.05, 4.0)
        sched, _ = _fresh(sc)
        return OnlineScheduler(sched, OnlineConfig(rounds=6)).run(
            0.05, method="milp", seed=3, mode=mode, time_limit=20)

    conc, seq = run("concurrent"), run("sequential")
    assert conc.n_resolves == seq.n_resolves
    assert conc.records == seq.records
    assert conc.mode == "concurrent" and seq.mode == "sequential"


def test_online_lm_serving_domain():
    """The loop is domain-agnostic: run it over the LM serving simulators
    with a mid-run slowdown of the big pod."""
    from repro.domains.lm_serving import (
        LM_FLEET_SPECS,
        SimulatedLMPlatform,
        smoke_requests,
    )

    reqs = smoke_requests(3, arch="qwen25_3b")
    sc = Scenario().slowdown("Cloud Pod", t=0.0, factor=50.0)
    fleet = [SimulatedLMPlatform(s) for s in LM_FLEET_SPECS]
    sched = Scheduler(make_domain("lm_serving", reqs, fleet))
    sched.characterise(seed=1, token_ladder=(2, 4, 8))
    for p in fleet:
        p.attach_scenario(sc)
    rep = OnlineScheduler(sched, OnlineConfig(rounds=4)).run(
        method="milp", seed=3, time_limit=20)
    for req in reqs:
        assert rep.summary["tokens"][req.task_id] >= req.gen_tokens


# ------------------------------------------------- record persistence

def test_records_jsonl_roundtrip_pricing(tmp_path):
    sched, _ = _fresh()
    rep = OnlineScheduler(sched, OnlineConfig(rounds=4)).run(
        0.05, method="heuristic", seed=3)
    path = tmp_path / "records.jsonl"
    n = dump_records(rep.records, path)
    assert n == len(rep.records)
    loaded = load_records(path)
    assert loaded == rep.records  # bitwise: json floats round-trip exactly


def test_records_jsonl_roundtrip_characterise_and_lm(tmp_path):
    from repro.domains.lm_serving import ServeRecord

    sched, _ = _fresh()
    char_records = [r for recs in sched.characterise_records.values()
                    for r in recs]
    mixed = char_records + [
        ServeRecord("Cloud Pod", 1, 16, 0.25, prefill_latency=0.01)]
    path = tmp_path / "mixed.jsonl"
    dump_records(mixed, path)
    loaded = load_records(path)
    assert loaded == mixed
    assert isinstance(loaded[-1], ServeRecord)


def test_load_records_tolerates_truncated_final_line(tmp_path):
    """A crash mid-append tears the last JSONL line; loading warns and
    returns the intact prefix instead of losing the whole file."""
    from repro.domains.lm_serving import ServeRecord

    records = [ServeRecord("Cloud Pod", i, 16, 0.25 + i,
                           prefill_latency=0.01) for i in range(4)]
    path = tmp_path / "torn.jsonl"
    dump_records(records, path)
    text = path.read_text()
    torn = text.rstrip("\n")[:-10]          # tear the final record mid-JSON
    path.write_text(torn)
    with pytest.warns(UserWarning, match="truncated final JSONL line"):
        loaded = load_records(path)
    assert loaded == records[:-1]
    # a torn line in the *middle* is real corruption and still raises
    lines = text.splitlines()
    path.write_text("\n".join([lines[0], lines[1][:-10]] + lines[2:]) + "\n")
    with pytest.raises(Exception):
        load_records(path)


def test_records_replay_refits_same_models(tmp_path):
    """An offline replay of dumped characterise records reproduces the
    fitted models — the record shape is the whole interface."""
    sched, _ = _fresh()
    flat = [r for recs in sched.characterise_records.values() for r in recs]
    path = tmp_path / "char.jsonl"
    dump_records(flat, path)
    regrouped = group_records(load_records(path))
    for key, recs in regrouped.items():
        refit = sched.domain.fit_models(recs)
        assert refit.latency.beta == pytest.approx(
            sched.models[key].latency.beta)


def test_load_records_unknown_kind_raises(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"kind": "NoSuchRecord", "platform": "x"}\n')
    with pytest.raises(KeyError, match="NoSuchRecord"):
        load_records(path)


# ------------------------------------------------- scenario layer

def test_outage_mid_batch_salvages_completed_records():
    """Records completed before an outage strikes mid-batch ride along on
    the exception and stay in the accounting — their virtual-clock time
    already ran."""
    from repro.pricing import SimulatedPlatform, TABLE2_SPECS
    from repro.pricing.platforms import _TaskMoments
    from repro.runtime import PlatformOutage

    tasks = _tasks()
    platform = SimulatedPlatform(TABLE2_SPECS[0],
                                 moments=_TaskMoments(calib_paths=2048))
    clean = platform.run_batch(tasks, 4096, seed=1)
    cut = clean[1].latency + clean[0].latency / 2  # outage mid-record-2...
    platform.attach_scenario(Scenario().outage(platform.spec.name, t=cut))
    with pytest.raises(PlatformOutage) as err:
        platform.run_batch(tasks, 4096, seed=1)
    assert 1 <= len(err.value.records) < len(tasks)
    assert all(r.platform == platform.spec.name for r in err.value.records)


def test_scenario_stretch_integrates_across_boundary():
    sc = Scenario().slowdown("p", t=1.0, factor=4.0)
    assert sc.stretch("p", 0.0, 0.5) == pytest.approx(0.5)   # fully before
    assert sc.stretch("p", 2.0, 0.5) == pytest.approx(2.0)   # fully after
    # straddling: 0.5 clean before the edge, 0.5 clean at 4x after
    assert sc.stretch("p", 0.5, 1.0) == pytest.approx(0.5 + 2.0)


def test_scenario_windows_and_arrivals():
    sc = (Scenario().slowdown("a", 1.0, 2.0, end=3.0)
          .outage("b", 2.0, end=4.0)
          .arrive(1.0, "t1").arrive(5.0, "t2"))
    assert sc.factor("a", 0.5) == 1.0
    assert sc.factor("a", 2.0) == 2.0
    assert sc.factor("a", 3.5) == 1.0
    assert not sc.in_outage("b", 1.0) and sc.in_outage("b", 3.0)
    assert sc.take_arrivals(0.5) == []
    assert sc.take_arrivals(1.5) == ["t1"]
    assert sc.pending_arrivals == 1
    assert sc.take_arrivals(0.0, force=True) == ["t2"]
    sc.reset()
    assert sc.pending_arrivals == 2
