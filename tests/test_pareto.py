"""Pareto surface helpers (core/pareto.py): the epsilon-constraint sweep,
the per-platform Fig 9 curves, and the non-dominated filter."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.heuristic import proportional_allocation
from repro.core.milp import milp_allocation
from repro.core.pareto import ParetoPoint, pareto_filter, platform_curves, sweep

DELTA = np.array([[2.0, 1.0], [8.0, 4.0]])
GAMMA = np.array([[0.1, 0.1], [0.0, 0.0]])


def test_sweep_one_point_per_accuracy_monotone_makespan():
    accuracies = [0.5, 0.2, 0.1]
    points = sweep(DELTA, GAMMA, accuracies, proportional_allocation)
    assert [p.accuracy for p in points] == accuracies
    for p in points:
        assert isinstance(p, ParetoPoint)
        assert p.solver == "heuristic"
        assert p.solve_time >= 0
        assert p.allocation.A.shape == DELTA.shape
        # columns of the allocation are task shares
        np.testing.assert_allclose(p.allocation.A.sum(axis=0), 1.0)
    # tighter accuracy (smaller c) means more work: makespan must not fall
    mks = [p.makespan for p in points]
    assert mks == sorted(mks)


def test_sweep_solver_is_pluggable():
    heur = sweep(DELTA, GAMMA, [0.2], proportional_allocation)[0]
    opt = sweep(DELTA, GAMMA, [0.2],
                lambda p: milp_allocation(p, time_limit=10))[0]
    assert opt.solver == "milp"
    # the optimiser can only improve on the proportional bound
    assert opt.makespan <= heur.makespan * (1 + 1e-6)


def test_platform_curves_analytic_values_and_crossover():
    acc = [1.0, 0.1]
    curves = platform_curves(DELTA, GAMMA, acc)
    assert curves.shape == (2, 2)
    # platform i at accuracy c: sum_j delta[i, j] / c^2 + sum_j gamma[i, j]
    np.testing.assert_allclose(curves[0], [3.0 / 1.0 + 0.2, 3.0 / 0.01 + 0.2])
    np.testing.assert_allclose(curves[1], [12.0, 1200.0])
    # the gamma-free slow platform wins at tight accuracy only in reverse:
    # compute dominates there, so the 4x-faster platform 0 pulls ahead
    assert curves[0, 1] < curves[1, 1]
    # at loose accuracy the constant term decides (here platform 0 still
    # wins; flip the gammas to check the geographic ordering regime)
    flipped = platform_curves(DELTA, np.array([[10.0, 10.0], [0.0, 0.0]]), [10.0])
    assert flipped[1, 0] < flipped[0, 0]


def test_pareto_filter_keeps_non_dominated_frontier():
    pts = [(0.1, 9.0), (0.2, 4.0), (0.2, 5.0), (0.3, 4.5), (0.4, 1.0)]
    out = pareto_filter(pts)
    assert out == [(0.1, 9.0), (0.2, 4.0), (0.4, 1.0)]
    # every input point is dominated by (or is) a frontier point
    for acc, mk in pts:
        assert any(a <= acc and m <= mk for a, m in out)


def test_pareto_filter_trivial_cases():
    assert pareto_filter([]) == []
    assert pareto_filter([(1.0, 1.0)]) == [(1.0, 1.0)]
    with pytest.raises(TypeError):
        pareto_filter(None)
