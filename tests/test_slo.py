"""SLO tail-metric layer: exact quantiles, the P-squared streaming
estimator, and the TTFT/TPOT/e2e tracker.

What is locked down:

- ``quantile`` matches numpy's linear interpolation and returns nan on
  empty input instead of raising.
- ``P2Quantile`` is exact below five observations, close to the exact
  quantile on heavy-tailed streams, and nan (not a crash) when empty —
  the estimator feeds live dashboards, so short windows must degrade
  gracefully.
- ``SLOTracker`` streams three metrics at once, snapshots p50/p95/p99,
  reports attainment against the configured target, and its recent
  window answers None (not a bogus number) until ``min_window``
  completions exist.
- ``SLOConfig`` validates its metric name and hysteresis ratios.
"""
import math

import numpy as np
import pytest

from repro.core.slo import P2Quantile, SLOConfig, SLOTracker, quantile


# --------------------------------------------------------------------------
# exact quantile helper
# --------------------------------------------------------------------------

def test_quantile_matches_numpy_linear_interpolation():
    rng = np.random.default_rng(0)
    vals = list(rng.lognormal(0.0, 1.0, size=101))
    for q in (0.0, 0.25, 0.5, 0.95, 0.99, 1.0):
        assert quantile(vals, q) == pytest.approx(
            float(np.quantile(np.asarray(vals), q)))


def test_quantile_empty_is_nan_and_singleton_is_identity():
    assert math.isnan(quantile([], 0.99))
    assert quantile([3.5], 0.5) == 3.5
    assert quantile([3.5], 0.99) == 3.5


# --------------------------------------------------------------------------
# P-squared streaming estimator
# --------------------------------------------------------------------------

def test_p2_empty_is_nan_and_short_windows_are_exact():
    est = P2Quantile(0.99)
    assert math.isnan(est.value())
    seen: list[float] = []
    for v in (5.0, 1.0, 3.0, 2.0):
        est.observe(v)
        seen.append(v)
        assert est.value() == pytest.approx(quantile(seen, 0.99))


def test_p2_tracks_heavy_tailed_stream_within_a_few_percent():
    rng = np.random.default_rng(7)
    vals = rng.lognormal(0.0, 1.0, size=5000)
    for q in (0.5, 0.95, 0.99):
        est = P2Quantile(q)
        for v in vals:
            est.observe(float(v))
        exact = float(np.quantile(vals, q))
        assert est.value() == pytest.approx(exact, rel=0.05)


def test_p2_is_deterministic_and_order_sensitive_only_in_estimate():
    # same stream -> bitwise same estimate (no hidden randomness)
    vals = [float(v) for v in np.random.default_rng(1).exponential(1.0, 200)]
    a, b = P2Quantile(0.95), P2Quantile(0.95)
    for v in vals:
        a.observe(v)
        b.observe(v)
    assert a.value() == b.value()


# --------------------------------------------------------------------------
# SLOConfig validation
# --------------------------------------------------------------------------

def test_slo_config_validates_metric_and_ratios():
    with pytest.raises(ValueError):
        SLOConfig(target_s=1.0, metric="latency")
    with pytest.raises(ValueError):
        SLOConfig(target_s=1.0, exit_ratio=1.5)      # exit above enter
    with pytest.raises(ValueError):
        SLOConfig(target_s=0.0)
    cfg = SLOConfig(target_s=2.0, metric="ttft", exit_ratio=0.5)
    assert cfg.quantile == 0.99


# --------------------------------------------------------------------------
# SLOTracker
# --------------------------------------------------------------------------

def test_tracker_snapshot_streams_three_metrics():
    tr = SLOTracker(SLOConfig(target_s=1.0, metric="e2e"))
    rng = np.random.default_rng(3)
    e2es = []
    for _ in range(300):
        ttft = float(rng.uniform(0.01, 0.1))
        tpot = float(rng.uniform(0.001, 0.01))
        e2e = ttft + 50 * tpot
        tr.observe(ttft, tpot, e2e)
        e2es.append(e2e)
    snap = tr.snapshot()
    assert snap["count"] == 300
    assert set(snap["metrics"]) == {"ttft", "tpot", "e2e"}
    m = snap["metrics"]["e2e"]
    assert m["p50"] == pytest.approx(float(np.quantile(e2es, 0.5)), rel=0.05)
    assert m["p99"] == pytest.approx(float(np.quantile(e2es, 0.99)), rel=0.05)
    assert m["max"] == pytest.approx(max(e2es))
    # every e2e here is below the 1 s target
    assert snap["attainment"] == 1.0


def test_tracker_attainment_counts_guardrail_metric_only():
    tr = SLOTracker(SLOConfig(target_s=0.5, metric="ttft"))
    tr.observe(ttft=0.4, tpot=9.9, e2e=9.9)   # ttft ok, rest terrible
    tr.observe(ttft=0.6, tpot=0.0, e2e=0.1)   # ttft breaches
    assert tr.attainment() == pytest.approx(0.5)


def test_tracker_recent_quantile_needs_min_window():
    tr = SLOTracker(SLOConfig(target_s=1.0, window=8, min_window=4))
    assert tr.recent_quantile() is None          # empty
    tr.observe(0.1, 0.01, 0.2)
    assert tr.recent_quantile() is None          # single record
    for _ in range(3):
        tr.observe(0.1, 0.01, 0.2)
    assert tr.recent_quantile() == pytest.approx(0.2)


def test_tracker_recent_quantile_slides_with_the_window():
    tr = SLOTracker(SLOConfig(target_s=1.0, window=4, min_window=4))
    for _ in range(4):
        tr.observe(0.1, 0.01, 5.0)               # slow era
    assert tr.recent_quantile() > 1.0
    for _ in range(4):
        tr.observe(0.1, 0.01, 0.2)               # fast era displaces it
    assert tr.recent_quantile() == pytest.approx(0.2)


def test_tracker_empty_snapshot_is_well_formed():
    tr = SLOTracker(SLOConfig(target_s=1.0))
    snap = tr.snapshot()
    assert snap["count"] == 0
    # nan, not a vacuous 1.0 — an all-shedding system has no attainment
    assert math.isnan(snap["attainment"])
    assert math.isnan(snap["metrics"]["e2e"]["p99"])
