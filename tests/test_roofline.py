"""Roofline/HLO-parsing + fleet-allocation unit tests (artifact-optional)."""
import glob
import json
import os

import pytest

from repro.roofline.analysis import HW, analyze
from repro.roofline.hlo import collective_bytes, parse_collectives

HLO_SAMPLE = """
ENTRY %main {
  %p0 = f32[256,1024]{1,0} parameter(0)
  %ag = f32[4096,1024]{1,0} all-gather(f32[256,1024]{1,0} %p0), replica_groups={}
  %ar = bf16[512]{0} all-reduce(bf16[512]{0} %x), to_apply=%add
  %rs = f32[64,128]{1,0} reduce-scatter(f32[1024,128]{1,0} %y), dimensions={0}
  %done = f32[8]{0} all-gather-done(f32[8]{0} %t)
  %cp = u32[16]{0} collective-permute(u32[16]{0} %z), source_target_pairs={{0,1}}
}
"""


def test_parse_collectives_kinds_and_bytes():
    got = parse_collectives(HLO_SAMPLE)
    assert set(got) == {"all-gather", "all-reduce", "reduce-scatter",
                        "collective-permute"}
    # all-gather payload = max(result, operand) = 4096*1024*4
    assert got["all-gather"] == [4096 * 1024 * 4]
    assert got["all-reduce"] == [512 * 2]
    # reduce-scatter: operand is the big end
    assert got["reduce-scatter"] == [1024 * 128 * 4]
    assert collective_bytes(HLO_SAMPLE) == (4096 * 1024 * 4 + 512 * 2 +
                                            1024 * 128 * 4 + 16 * 4)


def test_analyze_terms_math():
    stats = {"arch": "a", "shape": "s", "mesh": "16x16", "kind": "train",
             "ok": True, "microbatches": 2, "model_flops": 1e15,
             "full_collective_bytes": 50e9,
             "probes": {"block": {"flops": 197e12, "bytes": 819e9,
                                  "coll_bytes": 0.0, "multiplier": 1.0}}}
    r = analyze(stats, chips=256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)
    assert r.step_time_s == pytest.approx(1.0)
    assert r.mfu == pytest.approx(1e15 / (256 * 197e12))


ARTIFACTS = sorted(glob.glob("artifacts/dryrun/16x16/*__*.json"))


@pytest.mark.skipif(not ARTIFACTS, reason="run repro.launch.dryrun first")
def test_dryrun_artifacts_are_coherent():
    ok = 0
    for path in ARTIFACTS:
        if os.path.basename(path).count("__") > 1:
            continue
        d = json.load(open(path))
        assert d["mesh"] == "16x16"
        if not d["ok"]:
            continue
        ok += 1
        r = analyze(d, chips=256)
        assert r.compute_s >= 0 and r.memory_s >= 0 and r.collective_s >= 0
        assert r.bottleneck in ("compute", "memory", "collective")
        if d["kind"] == "train":
            assert d["model_flops"] > 0
            assert 0 < r.useful_flops_ratio <= 1.5
    assert ok >= 30  # 32 cells expected


@pytest.mark.skipif(not ARTIFACTS, reason="run repro.launch.dryrun first")
def test_fleet_allocation_from_artifacts():
    from repro.launch.allocate import FLEET, cell_matrices, load_cells
    from repro.core import AllocationProblem, proportional_allocation, \
        milp_allocation, check_allocation
    cells = load_cells("artifacts/dryrun/16x16")
    assert len(cells) >= 30
    delta, gamma = cell_matrices(cells[:12], FLEET, budget_steps=10)
    prob = AllocationProblem.from_work(delta, gamma)
    h = proportional_allocation(prob)
    m = milp_allocation(prob, time_limit=20)
    check_allocation(m.A, prob)
    assert m.makespan <= h.makespan * (1 + 1e-6)
