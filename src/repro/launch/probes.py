"""Single-layer probe compilation for exact roofline accounting.

XLA's cost analysis counts a while-loop (lax.scan) body ONCE, so the
full-model numbers undercount by the layer / microbatch / chunk trip
counts. Probes compile the *body* functions directly under the production
mesh and shardings; the roofline multiplies by the known static trip
counts:

    train  : fwd+bwd(block) x L x microbatches  +  head(fwd+bwd)  +  opt
    prefill: fwd(block) x L                      +  head(fwd, last pos)
    decode : decode(block) x L                   +  head(fwd, 1 token)

Per-family notes:
  * rwkv    — the block probe covers ONE chunk; multiplier x= S/CHUNK
  * hybrid  — the probe is one (rec, rec, attn) GROUP; multiplier is
              n_groups + n_tail/len(pattern) (tail is rec-only, noted)
  * encdec  — decoder block probe x L + encoder block probe x enc_layers
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import rules_for
from repro.models.config import ModelConfig, Shape
from repro.models.layers import cross_entropy
from repro.models.rwkv import CHUNK as RWKV_CHUNK
from repro.optim.adamw import AdamW

__all__ = ["cell_probes"]


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _grad(fn, remat: bool = True):
    """fwd+bwd probe of fn (cotangents of ones).

    remat=True wraps fn in the same nothing-saveable checkpoint policy the
    full training step uses, so the probe's FLOPs include the backward
    recompute the real step pays (the useful-FLOPs ratio then honestly
    reflects remat waste)."""
    if remat:
        from repro.models.common import remat_policy
        fn = jax.checkpoint(fn, policy=remat_policy())

    def probe(*args):
        out, vjp = jax.vjp(fn, *args)
        cot = jax.tree.map(jnp.ones_like, out)
        return vjp(cot)

    return probe


def _compile_stats(fn, args, mesh, multiplier):
    from repro.launch.lowering import _compile, _cost_dict
    from repro.roofline.hlo import collective_bytes
    _, compiled = _compile(fn, args, mesh)
    c = _cost_dict(compiled)
    return {"flops": c.get("flops", 0.0), "bytes": c.get("bytes accessed", 0.0),
            "coll_bytes": collective_bytes(compiled.as_text()),
            "multiplier": float(multiplier)}


def _x_struct(cfg, b, s, mesh, act_spec, rules=None):
    """Activation struct with a divisibility-sanitised version of act_spec
    (decode cells have batch=1 / seq=1 dims that cannot be sharded)."""
    if rules is not None:
        entries = list(act_spec) + [None] * (3 - len(act_spec))
        dims = []
        for size, ax in zip((b, s, cfg.d_model), entries):
            if ax is None:
                dims.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            prod = 1
            for a in axes:
                prod *= rules.axis_sizes.get(a, 1)
            dims.append(ax if size % prod == 0 else None)
        act_spec = P(*dims)
    return _sds((b, s, cfg.d_model), cfg.cdtype, mesh, act_spec)


def _head_fn(model, cfg, labels_needed=True):
    """embed + final norm + chunked CE (the non-layer compute)."""

    def fn(embed, unembed, lnf, tokens):
        from repro.models.layers import rmsnorm
        x = embed[tokens].astype(cfg.cdtype)
        x = rmsnorm(x, lnf, cfg.eps)
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        return cross_entropy(lambda l: l, x, unembed, labels, chunk=512)

    return fn


def cell_probes(model, cfg: ModelConfig, shape: Shape, mesh: Mesh, *,
                microbatches: int = 1, q_chunk=None) -> dict:
    from repro.launch.lowering import _layer_param_structs
    rules = rules_for(mesh)
    out: dict = {}
    b_mb = shape.global_batch // microbatches if shape.kind == "train" \
        else shape.global_batch
    dp = rules.maybe(b_mb, "pod", "data")
    s = shape.seq_len
    act_spec = model.act_spec

    # ---------------- block probes -----------------------------------
    if cfg.family == "rwkv":
        layer_structs, _ = _layer_param_structs(model._build_block(), mesh)
        h, hd = model.n_heads, model.hd
        mdl = rules.maybe(h, "model")
        if shape.kind == "decode":
            xc = _x_struct(cfg, b_mb, 1, mesh, act_spec, rules)
            mult = cfg.n_layers
        else:
            xc = _x_struct(cfg, b_mb, RWKV_CHUNK, mesh, act_spec, rules)
            mult = cfg.n_layers * (s // RWKV_CHUNK)
        tprev = _sds((b_mb, cfg.d_model), cfg.cdtype, mesh, P(dp, None))
        state = _sds((b_mb, h, hd, hd), jnp.float32, mesh, P(dp, mdl, None, None))
        fn, _ = model.probe_block()
        args = (layer_structs, xc, tprev, tprev, state)
        if shape.kind == "train":
            out["block"] = _compile_stats(_grad(fn), args, mesh,
                                          mult * microbatches)
        else:
            out["block"] = _compile_stats(fn, args, mesh, mult)
    elif cfg.family == "hybrid":
        group_structs, _ = _layer_param_structs(model._build_group(), mesh)
        pat = len(model.pattern)
        mult = model.n_groups + model.n_tail / pat
        if shape.kind == "decode":
            cache_sh = jax.eval_shape(lambda: model._zero_group_cache(b_mb))
            cache = jax.tree.map(
                lambda v: _sds(v.shape, v.dtype, mesh,
                               P(*( [dp] + [None] * (len(v.shape) - 1) ))
                               if len(v.shape) > 1 else P(None)),
                cache_sh, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            pos = _sds((), jnp.int32, mesh, P())
            fn, _ = model.probe_block_decode()
            x1 = _x_struct(cfg, b_mb, 1, mesh, act_spec, rules)
            out["block"] = _compile_stats(fn, (group_structs, x1, cache, pos),
                                          mesh, mult)
        else:
            fn, _ = model.probe_block(q_chunk=q_chunk)
            x = _x_struct(cfg, b_mb, s, mesh, act_spec, rules)
            if shape.kind == "train":
                out["block"] = _compile_stats(_grad(fn), (group_structs, x),
                                              mesh, mult * microbatches)
            else:
                out["block"] = _compile_stats(fn, (group_structs, x), mesh, mult)
    elif cfg.family == "encdec":
        dec_structs, _ = _layer_param_structs(model._build_dec_block(), mesh)
        enc_structs, _ = _layer_param_structs(model._build_enc_block(), mesh)
        kvh = cfg.n_kv_heads
        kv_sh = rules.maybe(kvh, "model")
        enc_kv = _sds((b_mb, cfg.frontend_len, kvh, cfg.hd), cfg.cdtype, mesh,
                      P(dp, None, kv_sh, None))
        if shape.kind == "decode":
            fn, mult = model.probe_block_decode()
            x1 = _x_struct(cfg, b_mb, 1, mesh, act_spec, rules)
            kv = _sds((b_mb, s, kvh, cfg.hd), cfg.pdtype, mesh,
                      P(dp, None, kv_sh, None))
            pos = _sds((), jnp.int32, mesh, P())
            out["block"] = _compile_stats(
                fn, (dec_structs, x1, kv, kv, enc_kv, enc_kv, pos), mesh, mult)
        else:
            fn, mult = model.probe_block()
            x = _x_struct(cfg, b_mb, s, mesh, act_spec, rules)
            args = (dec_structs, x, enc_kv, enc_kv)
            if shape.kind == "train":
                out["block"] = _compile_stats(_grad(fn), args, mesh,
                                              mult * microbatches)
            else:
                out["block"] = _compile_stats(fn, args, mesh, mult)
            # encoder side
            def enc_fn(layer_p, h):
                from repro.models.layers import apply_attn, mlp, rmsnorm
                hn = rmsnorm(h, layer_p["ln1"], cfg.eps)
                k = jnp.einsum("bsd,dhk->bshk", hn, layer_p["attn/wk"])
                v = jnp.einsum("bsd,dhk->bshk", hn, layer_p["attn/wv"])
                a, _ = apply_attn(layer_p, cfg, hn,
                                  positions=jnp.arange(h.shape[1]),
                                  kv_override=(k, v), use_rope=False)
                h = h + a
                return h + mlp(layer_p, cfg, rmsnorm(h, layer_p["ln2"], cfg.eps))
            xe = _x_struct(cfg, b_mb, cfg.frontend_len, mesh, act_spec, rules)
            emult = cfg.encoder_layers * (microbatches if shape.kind == "train" else 1)
            out["enc_block"] = _compile_stats(
                _grad(enc_fn) if shape.kind == "train" else enc_fn,
                (enc_structs, xe), mesh, emult)
    else:  # dense / vlm / moe
        layer_structs, _ = _layer_param_structs(model._build_block(), mesh)
        if shape.kind == "decode":
            fn, mult = model.probe_block_decode()
            x1 = _x_struct(cfg, b_mb, 1, mesh, act_spec, rules)
            kvh = cfg.n_kv_heads
            kv_sh = rules.maybe(kvh, "model")
            seq_sh = rules.maybe(s, "model") if kv_sh is None else None
            kv = _sds((b_mb, s, kvh, cfg.hd), cfg.pdtype, mesh,
                      P(dp, seq_sh, kv_sh, None))
            pos = _sds((), jnp.int32, mesh, P())
            out["block"] = _compile_stats(fn, (layer_structs, x1, kv, kv, pos),
                                          mesh, mult)
        else:
            fn, mult = model.probe_block()
            # vlm: frontend tokens + text tokens together span seq_len
            x = _x_struct(cfg, b_mb, s, mesh, act_spec, rules)
            if shape.kind == "train":
                out["block"] = _compile_stats(_grad(fn), (layer_structs, x),
                                              mesh, mult * microbatches)
            else:
                out["block"] = _compile_stats(fn, (layer_structs, x), mesh, mult)

    # ---------------- head probe (embed + unembed + CE) ---------------
    vs = rules.maybe(cfg.vocab, "model")
    ds = rules.maybe(cfg.d_model, "data")
    embed = _sds((cfg.vocab, cfg.d_model), cfg.pdtype, mesh, P(vs, ds))
    unembed = _sds((cfg.d_model, cfg.vocab), cfg.pdtype, mesh, P(ds, vs))
    lnf = _sds((cfg.d_model,), cfg.pdtype, mesh, P(None))
    if shape.kind == "train":
        text = s - cfg.frontend_len if cfg.family == "vlm" else s
        toks = _sds((b_mb, text), jnp.int32, mesh, P(dp, None))
        out["head"] = _compile_stats(_grad(_head_fn(model, cfg)),
                                     (embed, unembed, lnf, toks), mesh,
                                     microbatches)
    else:
        def head_inf(embed, unembed, lnf, x_last):
            from repro.models.layers import rmsnorm
            return (rmsnorm(x_last, lnf, cfg.eps) @ unembed).astype(jnp.float32)

        x_last = _x_struct(cfg, b_mb, 1, mesh, act_spec, rules)
        out["head"] = _compile_stats(head_inf, (embed, unembed, lnf, x_last),
                                     mesh, 1)

    # ---------------- optimizer probe ---------------------------------
    if shape.kind == "train":
        opt = AdamW()
        params_structs, _ = _abstract(model, mesh)
        opt_structs = jax.eval_shape(opt.init, params_structs)
        opt_specs = opt.state_specs(_abstract(model, mesh)[1])
        opt_structs = jax.tree.map(
            lambda v, sp: _sds(v.shape, v.dtype, mesh, sp),
            opt_structs, opt_specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        grads = jax.tree.map(lambda v: _sds(v.shape, jnp.float32, mesh,
                                            v.sharding.spec), params_structs)

        def opt_fn(g, st, p):
            return opt.update(g, st, p)

        out["opt"] = _compile_stats(opt_fn, (grads, opt_structs,
                                             params_structs), mesh, 1)
    return out


def _abstract(model, mesh):
    shapes, specs = model.abstract()
    return ({k: _sds(v.shape, v.dtype, mesh, specs[k])
             for k, v in shapes.items()}, specs)
