import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, record memory/cost/collective artifacts.

MUST be run as its own process (the first two lines above force 512 host
placeholder devices before jax initialises — never set this in conftest
or package __init__: smoke tests and benches should see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --no-probes
    ... --seq-shard/--no-seq-shard --microbatches N   (hillclimb levers)

Artifacts: artifacts/dryrun/<mesh>/<arch>__<shape>.json
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one architecture id")
    ap.add_argument("--shape", default=None, help="one shape name")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the (2,16,16) pod mesh instead of (16,16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-probes", dest="probes", action="store_false")
    ap.add_argument("--seq-shard", dest="seq_shard", action="store_true",
                    default=True)
    ap.add_argument("--no-seq-shard", dest="seq_shard", action="store_false")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="", help="suffix for artifact files")
    ap.add_argument("--moe-dispatch", default=None, choices=["scatter", "a2a", "a2a_sp"],
                    help="MoE dispatch strategy (hillclimb lever)")
    ap.add_argument("--remat", default=None, choices=["nothing", "dots"],
                    help="activation checkpoint policy (hillclimb lever)")
    ap.add_argument("--pad-kv-heads", type=int, default=None,
                    help="pad n_kv_heads (e.g. to the model-axis size) so "
                         "the KV cache shards by head instead of sequence")
    args = ap.parse_args(argv)

    import jax  # noqa: E402 — after XLA_FLAGS
    assert jax.device_count() == 512, \
        f"expected 512 placeholder devices, got {jax.device_count()}"

    from repro.configs import ARCHS, cells_for, get_config
    from repro.launch.lowering import lower_cell
    from repro.launch.mesh import make_production_mesh
    if args.moe_dispatch:
        from repro.models import moe
        moe.DISPATCH_MODE = args.moe_dispatch
    if args.remat:
        from repro.models import common
        common.REMAT_POLICY = args.remat

    meshes = []
    if args.both_meshes:
        meshes = [(False, make_production_mesh()),
                  (True, make_production_mesh(multi_pod=True))]
    else:
        meshes = [(args.multi_pod, make_production_mesh(multi_pod=args.multi_pod))]

    archs = [args.arch] if args.arch else list(ARCHS)
    failures = 0
    for multi_pod, mesh in meshes:
        mesh_name = "x".join(str(s) for s in mesh.devices.shape)
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        for arch in archs:
            cfg = get_config(arch)
            if args.pad_kv_heads:
                import dataclasses as _dc
                cfg = _dc.replace(cfg, n_kv_heads=args.pad_kv_heads)
            for shape in cells_for(cfg):
                if args.shape and shape.name != args.shape:
                    continue
                t0 = time.time()
                try:
                    stats = lower_cell(arch, cfg, shape, mesh,
                                       seq_shard=args.seq_shard,
                                       with_probes=args.probes,
                                       microbatches=args.microbatches,
                                       q_chunk=args.q_chunk)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    from repro.launch.lowering import CellStats
                    stats = CellStats(arch=arch, shape=shape.name,
                                      mesh=mesh_name, kind=shape.kind,
                                      ok=False,
                                      error=f"{type(e).__name__}: {e}"[:2000])
                dt = time.time() - t0
                status = "OK " if stats.ok else "FAIL"
                mem = stats.memory.get("temp_size_in_bytes", 0) / 2**30
                arg = stats.memory.get("argument_size_in_bytes", 0) / 2**30
                print(f"[{status}] {mesh_name:9s} {arch:22s} {shape.name:12s} "
                      f"args={arg:7.2f}GiB temp={mem:7.2f}GiB "
                      f"coll={stats.full_collective_bytes/2**20:9.1f}MiB "
                      f"mb={stats.microbatches} {dt:6.1f}s "
                      f"{stats.error[:120]}", flush=True)
                failures += 0 if stats.ok else 1
                tag = f"__{args.tag}" if args.tag else ""
                path = os.path.join(outdir, f"{arch}__{shape.name}{tag}.json")
                with open(path, "w") as f:
                    json.dump(stats.to_json(), f, indent=1)
    print(f"dry-run complete: {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
