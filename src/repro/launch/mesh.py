"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module touches no jax device state — required because the dry-run must
set XLA_FLAGS before the first jax initialisation.

Mesh logical axes:
    pod    — data parallelism across pods (slow DCN links; gradient
             compression applies here)
    data   — within-pod data parallelism + FSDP weight sharding
    model  — tensor / expert / sequence parallelism (fast ICI)
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models.common import Rules

__all__ = ["make_production_mesh", "make_host_mesh", "rules_for",
           "HostMeshError", "SINGLE_POD_SHAPE", "MULTI_POD_SHAPE"]


class HostMeshError(ValueError):
    """A host mesh request that the local device set cannot satisfy."""

SINGLE_POD_SHAPE = (16, 16)            # 256 chips (one v5e pod in this study)
MULTI_POD_SHAPE = (2, 16, 16)          # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1) -> Mesh:
    """Small (data, model) mesh over local devices (tests / examples).

    ``data=None`` uses every local device not claimed by the model axis.
    Raises :class:`HostMeshError` (never a bare numpy reshape error) when
    the request exceeds the local device count, naming what is available
    and the XLA flag that fakes more.
    """
    devs = jax.devices()
    avail = len(devs)
    if model < 1:
        raise HostMeshError(f"model axis size must be >= 1, got {model}")
    if data is None:
        if avail % model:
            raise HostMeshError(
                f"model axis {model} does not divide the {avail} available "
                f"devices; pass data= explicitly")
        data = avail // model
    if data < 1:
        raise HostMeshError(f"data axis size must be >= 1, got {data}")
    need = data * model
    if need > avail:
        raise HostMeshError(
            f"host mesh ({data}, {model}) needs {need} devices but only "
            f"{avail} are available; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before the "
            f"first jax import to fake more")
    return Mesh(np.array(devs[:need]).reshape(data, model), ("data", "model"))


def rules_for(mesh: Mesh) -> Rules:
    return Rules({name: size for name, size in mesh.shape.items()})
