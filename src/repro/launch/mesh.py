"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module touches no jax device state — required because the dry-run must
set XLA_FLAGS before the first jax initialisation.

Mesh logical axes:
    pod    — data parallelism across pods (slow DCN links; gradient
             compression applies here)
    data   — within-pod data parallelism + FSDP weight sharding
    model  — tensor / expert / sequence parallelism (fast ICI)
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models.common import Rules

__all__ = ["make_production_mesh", "make_host_mesh", "rules_for",
           "SINGLE_POD_SHAPE", "MULTI_POD_SHAPE"]

SINGLE_POD_SHAPE = (16, 16)            # 256 chips (one v5e pod in this study)
MULTI_POD_SHAPE = (2, 16, 16)          # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None) -> Mesh:
    """Small mesh over whatever local devices exist (tests / examples)."""
    devs = np.array(jax.devices())
    n = data or len(devs)
    return Mesh(devs[:n].reshape(n, 1), ("data", "model"))


def rules_for(mesh: Mesh) -> Rules:
    return Rules({name: size for name, size in mesh.shape.items()})
