"""Batched serving engine + CLI driver: prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen25_3b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Serving latency decomposes exactly like the paper's eq. 7: a constant
prefill cost (gamma) plus a per-token decode cost (beta x tokens). The
reusable :class:`ServeEngine` is what the LM-serving domain
(:mod:`repro.domains.lm_serving`) drives as its local execution platform;
the CLI fits the latency model online from its own measurements and logs
the coefficients, which is what the fleet allocator consumes.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any

from repro.obs.log import get_logger

log = get_logger("launch.serve")


@dataclasses.dataclass
class GenerationResult:
    """One batched generation: wall-clock split + greedy tokens."""

    prefill_latency: float          # seconds, one prefill of the whole batch
    decode_latencies: list[float]   # seconds per decode step (len == gen)
    tokens: Any                     # (batch, gen + 1) int32 greedy samples

    @property
    def total_latency(self) -> float:
        return self.prefill_latency + sum(self.decode_latencies)


class ServeEngine:
    """Prefill + KV-cache decode engine for one model configuration.

    Owns the params and the jitted prefill/decode executables. ``max_seq``
    is fixed at construction so every ``generate`` call with
    ``prompt_len + gen <= max_seq`` reuses the same two executables —
    the engine analogue of the pricing engine's runtime-parameter batching
    (the compile unit is the (config, batch, max_seq) family, not the
    individual request).
    """

    def __init__(self, cfg, batch: int, prompt_len: int, max_seq: int | None = None,
                 seed: int = 0, mesh=None):
        import jax

        from repro.models import build_model

        if not cfg.has_decoder:
            raise ValueError(f"{cfg.name} has no decoder; nothing to serve")
        self.cfg = cfg
        self.batch = batch
        self.prompt_len = prompt_len
        self.max_seq = max_seq or (prompt_len + 64)
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.mesh = mesh
        tp = mesh.shape.get("model", 1) if mesh is not None else 1
        if mesh is not None and mesh.shape.get("data", 1) > 1:
            # a data axis > 1 would reach XLA with mixed manual/auto
            # shardings and abort the process inside the SPMD partitioner
            # — refuse it here with a catchable error instead
            raise ValueError(
                f"ServeEngine only shards the model axis; got a mesh with "
                f"data axis {mesh.shape['data']} — batch-parallel serving "
                f"is not supported yet, pass make_host_mesh(data=1, "
                f"model={tp})")
        if tp > 1:
            # tensor-parallel step functions; logits stay bitwise-equal
            # to the single-device path (see repro.launch.tp)
            from repro.launch.tp import build_tp_step_fns

            prefill, decode = build_tp_step_fns(self.model, self.params,
                                                mesh, self.max_seq)
            self._prefill = jax.jit(prefill)
            self._decode = jax.jit(decode)
        else:
            self._prefill = jax.jit(
                lambda p, b: self.model.prefill(p, b, self.max_seq))
            self._decode = jax.jit(self.model.decode_step)
        self._warm = False

    def probe_logits(self, seed: int = 0):
        """(prefill logits, one greedy decode step's logits) as numpy —
        the parity probe used to assert the sharded path is bitwise."""
        import jax.numpy as jnp
        import numpy as np

        self.warm(seed)
        batch = self._batch_inputs(seed)
        cache, logits = self._prefill(self.params, batch)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        _, dlogits = self._decode(self.params, cache, toks)
        return np.asarray(logits), np.asarray(dlogits)

    def _batch_inputs(self, seed: int):
        from repro.data.pipeline import batch_for

        return batch_for(self.cfg, self.batch, self.prompt_len, seed=seed)

    def warm(self, seed: int = 0) -> None:
        """Compile prefill + decode outside any timed region (the paper's
        gamma measures dispatch, not code generation)."""
        if self._warm:
            return
        import jax.numpy as jnp

        batch = self._batch_inputs(seed)
        cache, logits = self._prefill(self.params, batch)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        _, logits = self._decode(self.params, cache, toks)
        logits.block_until_ready()
        self._warm = True

    def generate(self, gen: int, seed: int = 0) -> GenerationResult:
        """Greedy-decode ``gen`` tokens for one synthetic batch."""
        import jax.numpy as jnp
        import numpy as np

        if self.prompt_len + gen > self.max_seq:
            raise ValueError(
                f"prompt {self.prompt_len} + gen {gen} exceeds max_seq {self.max_seq}")
        self.warm(seed)
        batch = self._batch_inputs(seed)

        t0 = time.perf_counter()
        cache, logits = self._prefill(self.params, batch)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated = [np.asarray(toks)]
        lat: list[float] = []
        for _ in range(gen):
            t0 = time.perf_counter()
            cache, logits = self._decode(self.params, cache, toks)
            toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            toks.block_until_ready()
            lat.append(time.perf_counter() - t0)
            generated.append(np.asarray(toks))
        return GenerationResult(
            prefill_latency=t_prefill,
            decode_latencies=lat,
            tokens=np.concatenate(generated, axis=1),
        )

    def generate_many(self, gens: list[int], seed: int = 0) -> list[GenerationResult]:
        """Continuous batching: ``len(gens)`` streams share one running
        decode loop and leave it individually.

        All streams join at one joint prefill (its wall clock split
        evenly); the loop then decodes until the *longest* stream's target,
        and each measured step is attributed in equal shares to the streams
        still active at that step — a stream "leaves the batch" the moment
        its own target is reached, so late steps get cheaper per resident
        exactly as on a continuous-batching server. Per-stream sums
        therefore add up to the engine's true busy time, which is what the
        allocator's records must reflect.
        """
        import jax.numpy as jnp
        import numpy as np

        gens = [int(g) for g in gens]
        if not gens:
            return []
        if min(gens) < 1:
            raise ValueError(f"every stream must decode >= 1 token: {gens}")
        if self.prompt_len + max(gens) > self.max_seq:
            raise ValueError(
                f"prompt {self.prompt_len} + gen {max(gens)} exceeds "
                f"max_seq {self.max_seq}")
        self.warm(seed)
        batch = self._batch_inputs(seed)

        t0 = time.perf_counter()
        cache, logits = self._prefill(self.params, batch)
        logits.block_until_ready()
        t_prefill = (time.perf_counter() - t0) / len(gens)

        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated = [np.asarray(toks)]
        per_stream: list[list[float]] = [[] for _ in gens]
        for step in range(max(gens)):
            t0 = time.perf_counter()
            cache, logits = self._decode(self.params, cache, toks)
            toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            toks.block_until_ready()
            step_lat = time.perf_counter() - t0
            generated.append(np.asarray(toks))
            active = [i for i, g in enumerate(gens) if g > step]
            for i in active:
                per_stream[i].append(step_lat / len(active))
        tokens = np.concatenate(generated, axis=1)
        return [GenerationResult(prefill_latency=t_prefill,
                                 decode_latencies=per_stream[i],
                                 tokens=tokens[:, :g + 1])
                for i, g in enumerate(gens)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen25_3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--queue", default="",
                    help="comma-separated per-stream token targets served "
                         "with continuous batching (e.g. 4,16,8); streams "
                         "share one decode loop and leave at their target")
    args = ap.parse_args(argv)

    import numpy as np
    from repro.configs import get_config
    from repro.core.metrics import fit_latency_model

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if not cfg.has_decoder:
        log.info(f"{args.arch} has no decoder; nothing to serve")
        return 0

    gens = [int(g) for g in args.queue.split(",") if g] if args.queue else []
    engine = ServeEngine(cfg, batch=args.batch, prompt_len=args.prompt_len,
                         max_seq=args.max_seq or
                         (args.prompt_len + max([args.gen, *gens]) + 8),
                         seed=args.seed)
    if gens:
        results = engine.generate_many(gens, seed=args.seed)
        busy = sum(r.total_latency for r in results)
        for i, (g, r) in enumerate(zip(gens, results)):
            log.info(f"stream {i}: {g} tokens in {r.total_latency*1e3:.1f} ms "
                     f"(attributed share of the running batch)")
        # solo baseline: every stream paying its own prefill + decode pass
        step = busy / max(sum(gens), 1)
        solo = sum(results[0].prefill_latency * len(gens) + step * g for g in gens)
        log.info(f"continuous batch: {sum(gens)} tokens, engine busy "
                 f"{busy*1e3:.1f} ms (solo serves ~{solo*1e3:.1f} ms)")
        return 0
    result = engine.generate(args.gen, seed=args.seed)

    n = np.arange(1, len(result.decode_latencies) + 1)
    cum = np.cumsum(result.decode_latencies)
    lm = fit_latency_model(n, cum)
    log.info(f"prefill: {result.prefill_latency*1e3:.1f} ms "
             f"for {args.batch}x{args.prompt_len}")
    log.info(f"decode:  beta={lm.beta*1e3:.3f} ms/token-step, gamma={lm.gamma*1e3:.3f} ms")
    log.info(f"sample output tokens[0]: {list(map(int, result.tokens[0, :8]))}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
