"""Batched serving driver: prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen25_3b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Serving latency decomposes exactly like the paper's eq. 7: a constant
prefill cost (gamma) plus a per-token decode cost (beta x tokens); the
driver fits the model online from its own measurements and prints the
coefficients, which is what the fleet allocator consumes.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen25_3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.core.metrics import fit_latency_model
    from repro.data.pipeline import batch_for
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if not cfg.has_decoder:
        print(f"{args.arch} has no decoder; nothing to serve")
        return 0
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_seq = args.max_seq or (args.prompt_len + args.gen + 8)

    batch = batch_for(cfg, args.batch, args.prompt_len, seed=args.seed)
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq))
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    cache, logits = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [np.asarray(toks)]
    lat = []
    for i in range(args.gen):
        t0 = time.perf_counter()
        cache, logits = decode(params, cache, toks)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        toks.block_until_ready()
        lat.append(time.perf_counter() - t0)
        generated.append(np.asarray(toks))

    n = np.arange(1, len(lat) + 1)
    cum = np.cumsum(lat)
    lm = fit_latency_model(n[1:], cum[1:])  # drop the compile step
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len}")
    print(f"decode:  beta={lm.beta*1e3:.3f} ms/token-step, gamma={lm.gamma*1e3:.3f} ms")
    print(f"sample output tokens[0]: {[int(g[0,0]) for g in generated[:8]]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
