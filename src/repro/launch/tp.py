"""Tensor-parallel serving step functions (gather-based, bitwise-exact).

The sharded path must produce *bitwise* the logits of the single-device
engine — it is the same platform quoted at a different mesh shape, and
the allocator's accountability story dies the moment "same work, wider
mesh" changes the answer. psum-based (Megatron-style row-parallel)
output projections reassociate the contraction across devices and are
NOT bitwise; this module therefore shards only *column-parallel* weights
(q/k/v heads, MLP hidden, unembed vocab) and **all-gathers activations**
back to full width before every contraction-sharded matmul, which then
runs replicated. ``all_gather(tiled=True)`` concatenates shards in axis
order, so gathered tensors are elementwise identical to their dense
layout and every remaining op is the exact computation the dense path
runs.

The KV cache shards on the kv-head axis — the genuine pooled-KV win —
which requires ``n_kv_heads % tp == 0``; GQA head groups then stay
contiguous per device (device ``p`` holds q heads ``[p*h/tp, ...)`` and
exactly their kv heads). Other widths raise :class:`TPShardingError`
(kv-head *replication* for tp > n_kv_heads drifts by ~1 ulp in decode
and is deliberately not offered as an "exact" path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.layers import attention, rmsnorm, rope

__all__ = ["TPShardingError", "tp_param_specs", "tp_cache_specs",
           "build_tp_step_fns", "validate_tp"]

MODEL = "model"


class TPShardingError(ValueError):
    """The model's shapes cannot be tensor-parallelised at this width."""


def validate_tp(cfg, tp: int) -> None:
    if tp < 2:
        raise TPShardingError(f"tensor-parallel width must be >= 2, got {tp}")
    if cfg.family != "dense":
        raise TPShardingError(
            f"tensor-parallel serving supports the dense family only, "
            f"got {cfg.family!r} ({cfg.name})")
    bad = {ax: dim for ax, dim in
           (("n_heads", cfg.n_heads), ("n_kv_heads", cfg.n_kv_heads),
            ("d_ff", cfg.d_ff), ("vocab", cfg.vocab))
           if dim % tp}
    if bad:
        raise TPShardingError(
            f"{cfg.name}: tp={tp} must divide every sharded axis; "
            f"indivisible: {bad} (kv-head replication is not offered — "
            f"it is not bitwise-exact)")


def tp_param_specs(params: dict, block_key: str = "blocks") -> dict:
    """PartitionSpec per param: column-parallel shards on the model axis,
    everything contraction-sharded in Megatron stays replicated here."""
    specs = {}
    for k, v in params.items():
        stacked = k.startswith(block_key + "/")
        lead = (None,) if stacked else ()
        if k.endswith("attn/wq"):
            specs[k] = P(*lead, None, MODEL, None)
        elif k.endswith(("attn/wk", "attn/wv")):
            specs[k] = P(*lead, None, MODEL, None)
        elif k.endswith(("attn/bq", "attn/bk", "attn/bv")):
            specs[k] = P(*lead, MODEL, None)
        elif k.endswith(("mlp/w_in", "mlp/w_gate")):
            specs[k] = P(*lead, None, MODEL)
        elif k == "unembed":
            specs[k] = P(None, MODEL)
        else:  # norms, embed, wo, w_out: replicated (wo/w_out consume
            #    gathered full-width activations)
            specs[k] = P(*([None] * v.ndim))
    return specs


def tp_cache_specs() -> dict:
    """KV cache [L, B, S, KVH, D] shards on the kv-head axis."""
    kv = P(None, None, None, MODEL, None)
    return {"k": kv, "v": kv, "pos": P()}


def _tp_forward(cfg, block_key: str):
    """Per-device worker: the DenseModel forward with gathers at the two
    contraction-sharded matmuls (attention out-proj, MLP down-proj) and
    at the logits. Mirrors transformer.apply_block exactly elsewhere."""
    eps, theta = cfg.eps, cfg.rope_theta

    def fwd(p, cache, tokens, last_only):
        x = p["embed"][tokens].astype(cfg.cdtype)
        pos0 = cache["pos"]
        positions = pos0 + jnp.arange(x.shape[1])
        pre = block_key + "/"
        blocks = {k[len(pre):]: v for k, v in p.items() if k.startswith(pre)}

        def body(h, xs):
            lp, k_l, v_l = xs
            xn = rmsnorm(h, lp["ln1"], eps)
            q = jnp.einsum("bsd,dhk->bshk", xn, lp["attn/wq"])
            k = jnp.einsum("bsd,dhk->bshk", xn, lp["attn/wk"])
            v = jnp.einsum("bsd,dhk->bshk", xn, lp["attn/wv"])
            if "attn/bq" in lp:
                q = q + lp["attn/bq"]
                k = k + lp["attn/bk"]
                v = v + lp["attn/bv"]
            q = rope(q, positions, theta)
            k = rope(k, positions, theta)
            kc = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype),
                                              (0, pos0, 0, 0))
            vc = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype),
                                              (0, pos0, 0, 0))
            out = attention(q, kc, vc, causal=True, q_offset=pos0)
            out = jax.lax.all_gather(out, MODEL, axis=2, tiled=True)
            h = h + jnp.einsum("bshk,hkd->bsd", out, lp["attn/wo"])
            xn = rmsnorm(h, lp["ln2"], eps)
            hid = xn @ lp["mlp/w_in"]
            if cfg.mlp_variant == "swiglu":
                hid = jax.nn.silu(xn @ lp["mlp/w_gate"]) * hid
            elif cfg.mlp_variant == "geglu":
                hid = jax.nn.gelu(xn @ lp["mlp/w_gate"]) * hid
            else:
                hid = jax.nn.gelu(hid)
            hid = jax.lax.all_gather(hid, MODEL, axis=2, tiled=True)
            h = h + hid @ lp["mlp/w_out"]
            return h, (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (blocks, cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "pos": pos0 + tokens.shape[1]}
        if last_only:
            x = x[:, -1:]
        x = rmsnorm(x, p["ln_f"], eps)
        logits = x @ p["unembed"]
        logits = jax.lax.all_gather(logits, MODEL, axis=2, tiled=True)
        return new_cache, logits.astype(jnp.float32)

    return fwd


def build_tp_step_fns(model, params: dict, mesh, max_seq: int):
    """(prefill, decode) callables matching ``DenseModel.prefill`` /
    ``decode_step`` signatures, tensor-parallel over ``mesh``'s model
    axis. Raises :class:`TPShardingError` for unshardable shapes."""
    cfg = model.cfg
    tp = mesh.shape[MODEL]
    validate_tp(cfg, tp)
    block_key = model.block_key
    fwd = _tp_forward(cfg, block_key)
    pspecs = tp_param_specs(params, block_key)
    cache_spec = tp_cache_specs()
    out_specs = (cache_spec, P(None, None, None))
    kvh_local = cfg.n_kv_heads // tp

    def prefill_worker(p, tokens):
        b = tokens.shape[0]
        shape = (cfg.n_layers, b, max_seq, kvh_local, cfg.hd)
        cache = {"k": jnp.zeros(shape, cfg.pdtype),
                 "v": jnp.zeros(shape, cfg.pdtype),
                 "pos": jnp.asarray(0, jnp.int32)}
        return fwd(p, cache, tokens, True)

    sm_prefill = shard_map(prefill_worker, mesh,
                           in_specs=(pspecs, P(None, None)),
                           out_specs=out_specs, axis_names={MODEL})
    sm_decode = shard_map(lambda p, c, t: fwd(p, c, t, False), mesh,
                          in_specs=(pspecs, cache_spec, P(None, None)),
                          out_specs=out_specs, axis_names={MODEL})

    def prefill(params, batch):
        return sm_prefill(params, batch["tokens"])

    return prefill, sm_decode
