"""Cell lowering: (arch x shape x mesh) -> compiled artifacts + stats.

This is the engine behind the multi-pod dry-run (launch/dryrun.py) and the
roofline analysis. Everything is ShapeDtypeStruct-based: no arrays are
ever materialised for the production configs.

Per cell we compile
  1. the FULL step (train_step / prefill / decode_step) under the target
     mesh: proves shardings are coherent, gives memory_analysis (fits?) and
     the post-SPMD HLO for the outside-the-scan collectives;
  2. PROBES — single-layer (or single-chunk) functions under the same mesh
     and shardings: exact per-layer FLOPs / bytes / collective bytes that
     the roofline scales by the known multipliers (XLA cost analysis counts
     a while-loop body once, so full-model numbers are NOT usable directly;
     see repro.roofline).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import rules_for
from repro.models import build_model
from repro.models.config import ModelConfig, Shape
from repro.models.rwkv import CHUNK as RWKV_CHUNK
from repro.optim.adamw import AdamW
from repro.roofline.hlo import collective_bytes
from repro.train.train_step import make_train_step

__all__ = ["CellStats", "lower_cell", "pick_microbatches", "batch_structs"]

ACT_BUDGET_BYTES = 2 << 30  # per-device saved-activation budget for grad-accum


# --------------------------------------------------------------------------
# input specs
# --------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_structs(cfg: ModelConfig, batch: int, seq: int, mesh: Mesh):
    """ShapeDtypeStructs for one global batch (frontend stubs included)."""
    rules = rules_for(mesh)
    dp = rules.maybe(batch, "pod", "data")
    out = {}
    text = seq - cfg.frontend_len if cfg.family == "vlm" else seq
    out["tokens"] = _sds((batch, text), jnp.int32, mesh, P(dp, None))
    if cfg.family == "vlm":
        out["vision"] = _sds((batch, cfg.frontend_len, cfg.d_model),
                             jnp.float32, mesh, P(dp, None, None))
    if cfg.family == "encdec":
        out["audio"] = _sds((batch, cfg.frontend_len, cfg.d_model),
                            jnp.float32, mesh, P(dp, None, None))
    return out


def pick_microbatches(cfg: ModelConfig, shape: Shape, rules) -> int:
    """Grad-accum factor: keep saved layer-boundary activations under the
    per-device budget. Saved state per microbatch ~= L x B_mb x S x D x 2B
    sharded over dp (and model, with sequence parallelism)."""
    dp = math.prod(rules.axis_sizes.get(a, 1) for a in ("pod", "data"))
    sp = rules.axis_sizes.get("model", 1)
    n_layers = cfg.n_layers + cfg.encoder_layers
    per_mb = 2 * shape.global_batch * shape.seq_len * cfg.d_model * n_layers
    per_mb /= dp * sp
    mb = 1
    while per_mb / mb > ACT_BUDGET_BYTES and mb < shape.global_batch:
        mb *= 2
    while shape.global_batch % mb or (shape.global_batch // mb) % dp:
        mb //= 2  # keep microbatches divisible over the DP axes
    return max(mb, 1)


# --------------------------------------------------------------------------
# per-cell lowering
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CellStats:
    arch: str
    shape: str
    mesh: str
    kind: str
    ok: bool
    error: str = ""
    microbatches: int = 1
    # full-step artifacts (per device)
    memory: dict = dataclasses.field(default_factory=dict)
    cost: dict = dataclasses.field(default_factory=dict)
    full_collective_bytes: int = 0
    # probe artifacts: name -> {flops, bytes, coll_bytes, multiplier}
    probes: dict = dataclasses.field(default_factory=dict)
    # analytic
    model_flops: float = 0.0
    params_total: int = 0
    params_active: int = 0

    def to_json(self):
        return dataclasses.asdict(self)


def _mem_dict(compiled) -> dict:
    m = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_dict(compiled) -> dict:
    try:
        c = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(c, (list, tuple)):
        c = c[0]
    return {k: float(v) for k, v in c.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")}


def _compile(fn, args, mesh, static_argnums=(), donate_argnums=()):
    with mesh:
        lowered = jax.jit(fn, static_argnums=static_argnums,
                          donate_argnums=donate_argnums).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def _probe_stats(fn, args, mesh, multiplier: float) -> dict:
    _, compiled = _compile(fn, args, mesh)
    return {
        "flops": _cost_dict(compiled).get("flops", 0.0),
        "bytes": _cost_dict(compiled).get("bytes accessed", 0.0),
        "coll_bytes": collective_bytes(compiled.as_text()),
        "multiplier": float(multiplier),
    }


def _abstract_params(model, mesh):
    shapes, specs = model.abstract()
    return {k: _sds(v.shape, v.dtype, mesh, specs[k])
            for k, v in shapes.items()}, specs


def _layer_param_structs(build_fn, mesh):
    """Abstract single-layer params (no leading stack dim) + shardings.

    The spec dict is a side channel of the builder, captured while
    eval_shape traces the (allocation-free) init."""
    captured: dict = {}

    def capture():
        params, specs = build_fn(jax.random.PRNGKey(0))
        captured.update(specs)
        return params

    shapes = jax.eval_shape(capture)
    return ({k: _sds(v.shape, v.dtype, mesh, captured[k])
             for k, v in shapes.items()}, captured)


def lower_cell(arch: str, cfg: ModelConfig, shape: Shape, mesh: Mesh, *,
               seq_shard: bool = True, with_probes: bool = True,
               microbatches: int | None = None,
               q_chunk: int | None = None,
               opt: AdamW | None = None,
               collect_hlo: bool = False) -> CellStats:
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    stats = CellStats(arch=arch, shape=shape.name, mesh=mesh_name,
                      kind=shape.kind, ok=False)
    rules = rules_for(mesh)
    model = build_model(cfg, rules=rules, seq_shard=seq_shard)
    total, active = cfg.param_count()
    stats.params_total, stats.params_active = total, active

    if q_chunk is None:
        q_chunk = 1024 if shape.seq_len > 8192 else None

    try:
        params_structs, specs = _abstract_params(model, mesh)
        if shape.kind == "train":
            mb = microbatches or pick_microbatches(cfg, shape, rules)
            stats.microbatches = mb
            opt = opt or AdamW()
            opt_structs = jax.eval_shape(opt.init, params_structs)
            opt_specs = opt.state_specs(specs)
            opt_structs = jax.tree.map(
                lambda v, s: _sds(v.shape, v.dtype, mesh, s),
                opt_structs, opt_specs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            batch = batch_structs(cfg, shape.global_batch, shape.seq_len, mesh)
            step = make_train_step(model, opt, microbatches=mb,
                                   loss_kwargs={"q_chunk": q_chunk})
            # donate params + opt state: in-place update, as in production
            lowered, compiled = _compile(step, (params_structs, opt_structs,
                                                batch), mesh,
                                         donate_argnums=(0, 1))
            # MODEL_FLOPS = 6 * N_active * D_tokens
            stats.model_flops = 6.0 * active * shape.tokens
        elif shape.kind == "prefill":
            batch = batch_structs(cfg, shape.global_batch, shape.seq_len, mesh)
            fn = lambda p, b: model.prefill(p, b, shape.seq_len)
            lowered, compiled = _compile(fn, (params_structs, batch), mesh)
            stats.model_flops = 2.0 * active * shape.tokens
        else:  # decode
            cache_structs = _cache_structs(model, shape, mesh)
            tok = _sds((shape.global_batch, 1), jnp.int32, mesh,
                       P(rules.maybe(shape.global_batch, "pod", "data"), None))
            # donate the KV/state cache: decode updates it in place
            lowered, compiled = _compile(model.decode_step,
                                         (params_structs, cache_structs, tok),
                                         mesh, donate_argnums=(1,))
            stats.model_flops = 2.0 * active * shape.global_batch
        stats.memory = _mem_dict(compiled)
        stats.cost = _cost_dict(compiled)
        hlo = compiled.as_text()
        stats.full_collective_bytes = collective_bytes(hlo)
        if collect_hlo:
            stats.memory["hlo_text"] = hlo[:0]  # placeholder (large)
        stats.ok = True
    except Exception as e:  # noqa: BLE001 — dry-run failures are findings
        stats.error = f"{type(e).__name__}: {e}"[:2000]
        return stats

    if with_probes:
        try:
            from repro.launch.probes import cell_probes
            stats.probes = cell_probes(model, cfg, shape, mesh,
                                       microbatches=stats.microbatches,
                                       q_chunk=q_chunk)
        except Exception as e:  # noqa: BLE001
            stats.probes = {"error": f"{type(e).__name__}: {e}"[:2000]}
    return stats


def _cache_structs(model, shape: Shape, mesh: Mesh):
    # NB: close over the (static) sizes — eval_shape would trace them.
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
    return jax.tree.map(
        lambda v, s: _sds(v.shape, v.dtype, mesh, s),
        cache_shapes, cache_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
