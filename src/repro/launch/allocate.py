"""Fleet allocation: the paper's technique applied to THIS framework.

Tasks     = the (arch x shape) dry-run cells (divisible by tokens/steps).
Platforms = heterogeneous pod slices (mesh shape x chip generation).
beta      = seconds per unit work, derived from each cell's dominant
            roofline term on that slice (compute / memory / collective).
gamma     = dispatch + cross-slice setup, from the collective residue +
            a per-slice control-plane constant (the "network RTT" of 2026).

With (delta, gamma) matrices in hand, scheduling the fleet is literally
eq. 10: the same heuristic / SA / MILP solvers from repro.core produce
the assignment and its certified makespan. Straggler mitigation and
elastic re-scaling are re-solves with re-fitted coefficients (the paper's
online-benchmarking loop as a fault-tolerance policy).

    PYTHONPATH=src python -m repro.launch.allocate \
        --artifacts artifacts/dryrun/16x16 --budget-steps 100
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os

import numpy as np


@dataclasses.dataclass(frozen=True)
class PodSlice:
    """A heterogeneous TPU platform: relative speed + control-plane RTT."""
    name: str
    chips: int
    rel_flops: float      # vs v5e baseline
    rel_bw: float
    dispatch_s: float     # per-job constant (gamma seed)


#: A plausible 2026 heterogeneous fleet (per-chip ratios vs v5e).
FLEET: list[PodSlice] = [
    PodSlice("v5e-256-a", 256, 1.00, 1.00, 0.8),
    PodSlice("v5e-256-b", 256, 1.00, 1.00, 0.8),
    PodSlice("v5p-128", 128, 2.32, 3.35, 1.1),     # 459 TF, 2765 GB/s
    PodSlice("v4-128", 128, 1.39, 1.47, 1.5),      # 275 TF, 1200 GB/s
    PodSlice("v5e-64-edge", 64, 1.00, 1.00, 4.0),  # remote slice, slow control
    PodSlice("trn2-64", 64, 3.30, 3.54, 2.2),      # 650 TF dense, 2.9 TB/s
]


def load_cells(artifact_dir: str) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        name = os.path.basename(path)
        if "__" not in name or name.count("__") > 1:
            continue  # only untagged baseline cells
        with open(path) as f:
            d = json.load(f)
        if d.get("ok"):
            cells.append(d)
    return cells


def cell_matrices(cells: list[dict], fleet: list[PodSlice],
                  budget_steps: int = 100):
    """(delta, gamma) for eq. 10. Work unit = one step of the cell; the
    accuracy knob c plays the 'how many steps' role (c=1 => budget_steps),
    mirroring delta/c^2; here we use delta directly as steps x step-time."""
    from repro.roofline.analysis import HW, analyze
    mu, tau = len(fleet), len(cells)
    delta = np.zeros((mu, tau))
    gamma = np.zeros((mu, tau))
    for j, cell in enumerate(cells):
        base = analyze(cell, chips=256)
        for i, p in enumerate(fleet):
            # re-scale the three terms to this slice's hardware
            comp = base.compute_s / p.rel_flops * (256 / p.chips)
            mem = base.memory_s / p.rel_bw * (256 / p.chips)
            coll = base.collective_s * (256 / p.chips) ** 0.5
            step_time = max(comp, mem, coll)
            delta[i, j] = budget_steps * step_time
            gamma[i, j] = p.dispatch_s + 0.1 * coll * budget_steps ** 0.5
    return delta, gamma


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun/16x16")
    ap.add_argument("--budget-steps", type=int, default=100)
    ap.add_argument("--solvers", default="heuristic,ml,milp")
    ap.add_argument("--time-limit", type=float, default=120.0)
    args = ap.parse_args(argv)

    from repro.core import (AllocationProblem, milp_allocation, ml_allocation,
                            proportional_allocation)

    cells = load_cells(args.artifacts)
    if not cells:
        print(f"no dry-run artifacts under {args.artifacts} — run "
              "repro.launch.dryrun first")
        return 1
    delta, gamma = cell_matrices(cells, FLEET, args.budget_steps)
    problem = AllocationProblem.from_work(delta, gamma)
    print(f"fleet scheduling: {len(cells)} cells x {len(FLEET)} slices")

    results = {}
    for name in args.solvers.split(","):
        if name == "heuristic":
            a = proportional_allocation(problem)
        elif name == "ml":
            a = ml_allocation(problem, time_limit=args.time_limit)
        else:
            a = milp_allocation(problem, time_limit=args.time_limit)
        results[name] = a
        print(f"  {name:10s} makespan={a.makespan:10.1f}s "
              f"solve={a.solve_time:6.1f}s optimal={a.optimal}")
    if "heuristic" in results:
        h = results["heuristic"].makespan
        for name, a in results.items():
            if name != "heuristic":
                print(f"  {name} improvement over heuristic: {h/a.makespan:.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
