"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen25_3b --smoke \
        --steps 300 --batch 8 --seq 128

Fault tolerance in the loop:
  * async sharded checkpoints every --ckpt-every steps (atomic publish);
  * on start, resumes from the newest complete checkpoint — the data
    pipeline is a pure function of step, so restart is exact;
  * --preempt-at N simulates a hard kill at step N (exercised in tests);
  * per-step wall-clock is fed to an online latency model (the paper's
    eq. 7 populated live) whose drift is the straggler alarm: a step
    slower than model + 6 sigma re-fits and reports.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def main(argv=None, cfg=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen25_3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--preempt-at", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    import jax
    from repro.checkpoint.checkpoint import Checkpointer
    from repro.configs import get_config
    from repro.core.metrics import fit_latency_model
    from repro.data.pipeline import batch_for
    from repro.models import build_model
    from repro.optim.adamw import AdamW, cosine_schedule
    from repro.train.train_step import make_train_step

    if cfg is None:
        cfg = get_config(args.arch)
        if args.smoke:
            cfg = cfg.smoke()
    model = build_model(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps))
    step_fn = jax.jit(make_train_step(model, opt,
                                      microbatches=args.microbatches))

    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    start_step = 0

    ckpt = None
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir)
        latest = ckpt.latest_step()
        if latest is not None:
            restored = ckpt.restore(latest, {"params": params, "opt": opt_state})
            params, opt_state = restored["params"], restored["opt"]
            start_step = latest
            print(f"resumed from checkpoint step {latest}")

    times: list[tuple[int, float]] = []
    for step in range(start_step, args.steps):
        if args.preempt_at and step == args.preempt_at:
            print(f"simulated preemption at step {step}")
            if ckpt:
                ckpt.wait()
            os._exit(42)  # hard kill: no cleanup, like a real preemption
        batch = batch_for(cfg, args.batch, args.seq, step=step, seed=args.seed)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        metrics["loss"].block_until_ready()
        dt = time.perf_counter() - t0
        times.append((args.batch * args.seq, dt))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:7.1f} ms")
        # online latency model (paper eq. 7) as a straggler detector
        if len(times) >= 8 and len(times) % 16 == 0:
            n, t = np.array(times[2:]).T  # drop compile steps
            lm = fit_latency_model(n, t)
            resid = t - lm(n)
            if resid[-1] > 6 * (resid.std() + 1e-9):
                print(f"straggler alarm: step latency {t[-1]*1e3:.1f} ms vs "
                      f"model {lm(n[-1])*1e3:.1f} ms — refit & rebalance")
        # label = the NEXT step to run: params here are post-`step`,
        # so a resume must not re-execute this step's batch
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state},
                  blocking=True)
    print("training complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
