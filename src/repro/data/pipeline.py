"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step) via counter-based Philox —
the same property the MC engine's RNG gives paths: restart/resume at any
step reproduces the exact stream with no state files, and any host can
materialise any shard (elastic re-sharding needs no data re-shuffle).

For real deployments this module is the seam where a tokenised corpus
reader would plug in; the interface (get_batch(step) -> global arrays) is
what the train loop and checkpoint/restore contract on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig

__all__ = ["SyntheticTokens", "batch_for"]


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def get_batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=(self.seed << 32) + step))
        # skewed zipf-ish marginal so losses move like text, not uniform noise
        z = rng.zipf(1.3, size=(self.batch, self.seq))
        return {"tokens": np.minimum(z - 1, self.vocab - 1).astype(np.int32)}


def batch_for(cfg: ModelConfig, batch: int, seq: int, step: int = 0,
              seed: int = 0) -> dict[str, np.ndarray]:
    """A full input batch for any architecture (frontend stubs included)."""
    out = dict(SyntheticTokens(cfg.vocab, batch, seq, seed).get_batch(step))
    rng = np.random.Generator(np.random.Philox(key=((seed + 1) << 32) + step))
    if cfg.family == "vlm":
        # seq budget = frontend tokens + text tokens
        text = seq - cfg.frontend_len
        out["tokens"] = out["tokens"][:, :text]
        out["vision"] = rng.normal(0, 1, (batch, cfg.frontend_len,
                                          cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        out["audio"] = rng.normal(0, 1, (batch, cfg.frontend_len,
                                         cfg.d_model)).astype(np.float32)
    return out
