"""repro.runtime — the domain-agnostic allocation runtime.

The characterise -> allocate -> execute workflow (paper Fig. 1) factored
out of the pricing front-end: a :class:`Domain` protocol for workloads, a
generic :class:`Scheduler` that drives the loop over the shared
:mod:`repro.core` solvers, and a registry so new domains plug in by name.

    from repro.runtime import Scheduler, make_domain
    sched = Scheduler(make_domain("lm_serving", requests, fleet))
    report = sched.run(method="milp")
"""
from .admission import (  # noqa: F401
    AdmissionConfig,
    AdmissionController,
    BrownoutTransition,
    RejectedTask,
    ShedEvent,
    predicted_unit_rates,
)
from .domain import Domain, PlatformSpec, RunRecordLike, seed_for  # noqa: F401
from .executor import Executor, TimedResult  # noqa: F401
from .faults import (  # noqa: F401
    BreakerTransition,
    CircuitBreaker,
    CorruptResult,
    DegradationEvent,
    DispatchFault,
    DispatchTimeout,
    FaultEvent,
    JobCancelled,
    RetryPolicy,
    TransientFault,
    check_records,
)
from .loadgen import (  # noqa: F401
    BurstyRate,
    ConstantRate,
    DiurnalRate,
    LoadGenerator,
    lm_request_factory,
)
from .online import (  # noqa: F401
    DriftDetector,
    OnlineConfig,
    OnlineReport,
    OnlineScheduler,
    TailDriftDetector,
)
from .records import dump_records, group_records, load_records  # noqa: F401
from .registry import (  # noqa: F401
    available_domains,
    domain_factory,
    make_domain,
    register_domain,
)
from .scenario import PlatformOutage, Scenario  # noqa: F401
from .scheduler import SOLVERS, DispatchResult, RuntimeReport, Scheduler  # noqa: F401
