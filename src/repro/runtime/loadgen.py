"""Deterministic open-loop load generation for trace-driven serving.

The scenario layer (PR 4) takes hand-scheduled arrivals; the north-star
regime is *open-loop* traffic — requests arrive on their own schedule
regardless of whether the fleet keeps up, which is exactly when offered
load can exceed capacity and the admission layer
(:mod:`repro.runtime.admission`) earns its keep.

A :class:`LoadGenerator` samples a non-homogeneous Poisson process by
Lewis–Shedler thinning from a :class:`RateProcess` (constant, diurnal
sinusoid, or square-wave bursts), draws each request from a task factory
(for LM serving: Zipf-weighted request families with bounded-Pareto
heavy-tailed output lengths — the shape of production serving traces),
and emits the result as timestamped :meth:`Scenario.arrive` entries.
Everything downstream is the *existing* admission path, so a trace
replays bit-for-bit: one seeded generator, one sequential RNG, no wall
clocks.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import numpy as np

from repro.runtime.scenario import Scenario

__all__ = ["ConstantRate", "DiurnalRate", "BurstyRate", "LoadGenerator",
           "lm_request_factory"]


# --------------------------------------------------------------------------
# Rate processes
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConstantRate:
    """Homogeneous Poisson arrivals at ``rate_per_s``."""

    rate_per_s: float

    def rate(self, t: float) -> float:
        return self.rate_per_s

    @property
    def peak(self) -> float:
        return self.rate_per_s


@dataclasses.dataclass(frozen=True)
class DiurnalRate:
    """Sinusoidal day/night load curve around ``base_per_s``.

    ``rate(t) = base * (1 + amplitude * sin(2*pi*(t + phase)/period))``;
    amplitude in [0, 1) keeps the intensity positive.
    """

    base_per_s: float
    amplitude: float = 0.5
    period_s: float = 60.0
    phase: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1)")

    def rate(self, t: float) -> float:
        return self.base_per_s * (
            1.0 + self.amplitude
            * math.sin(2.0 * math.pi * (t + self.phase) / self.period_s))

    @property
    def peak(self) -> float:
        return self.base_per_s * (1.0 + self.amplitude)


@dataclasses.dataclass(frozen=True)
class BurstyRate:
    """Square-wave bursts: ``burst_per_s`` for the first ``duty`` fraction
    of every period, ``base_per_s`` otherwise."""

    base_per_s: float
    burst_per_s: float
    period_s: float = 30.0
    duty: float = 0.2

    def __post_init__(self):
        if not 0.0 < self.duty < 1.0:
            raise ValueError("duty must be in (0, 1)")

    def rate(self, t: float) -> float:
        frac = (t % self.period_s) / self.period_s
        return self.burst_per_s if frac < self.duty else self.base_per_s

    @property
    def peak(self) -> float:
        return max(self.base_per_s, self.burst_per_s)


# --------------------------------------------------------------------------
# The generator
# --------------------------------------------------------------------------

class LoadGenerator:
    """Seeded open-loop arrival trace over a rate process.

    Lewis–Shedler thinning: candidate inter-arrivals are exponential at
    the process's peak rate; each candidate survives with probability
    ``rate(t) / peak``. One sequential RNG drives both the thinning and
    the task factory, so the trace is a pure function of (seed, rate
    process, factory) — replays are bit-for-bit, and two generators with
    different seeds are independent.
    """

    def __init__(self, rate: Any, make_task: Callable[[np.random.Generator, int], Any],
                 seed: int = 0, start_id: int = 1000):
        if rate.peak <= 0:
            raise ValueError("rate process must have a positive peak rate")
        self.rate_process = rate
        self.make_task = make_task
        self.seed = seed
        self.start_id = start_id

    def arrivals(self, horizon_s: float) -> list[tuple[float, Any]]:
        """Sample the timestamped trace over ``[0, horizon_s)``."""
        rng = np.random.default_rng(self.seed)
        peak = self.rate_process.peak
        out: list[tuple[float, Any]] = []
        t = 0.0
        tid = self.start_id
        while True:
            t += float(rng.exponential(1.0 / peak))
            if t >= horizon_s:
                break
            if float(rng.random()) * peak <= self.rate_process.rate(t):
                out.append((t, self.make_task(rng, tid)))
                tid += 1
        return out

    def scenario(self, horizon_s: float,
                 base: Scenario | None = None) -> Scenario:
        """Emit the trace into a :class:`Scenario` (a fresh one by
        default) through the existing ``arrive`` admission path."""
        sc = base if base is not None else Scenario()
        for t, task in self.arrivals(horizon_s):
            sc.arrive(t, task)
        return sc


# --------------------------------------------------------------------------
# LM request factory: heavy-tailed lengths over request families
# --------------------------------------------------------------------------

def _bounded_pareto(u: float, lo: int, hi: int, alpha: float) -> int:
    """Inverse-CDF sample from a Pareto truncated to the integers [lo, hi].

    The continuous sample lives on [lo, hi + 1) and is floored, so every
    integer bucket — including ``hi`` itself — gets the Pareto mass of its
    unit interval. (Truncating a sample bounded at ``hi`` instead makes
    the top bucket reachable only at exactly u == 1, which systematically
    underweights the very tail the p99 guardrails are meant to see.)
    """
    if alpha <= 0:
        raise ValueError(f"tail index alpha must be > 0, got {alpha}")
    if not 1 <= lo <= hi:
        raise ValueError(f"need 1 <= lo <= hi, got lo={lo}, hi={hi}")
    la, ha = lo ** -alpha, (hi + 1) ** -alpha
    # the clamp also absorbs float roundoff at the endpoints (e.g. at
    # u == 0 the power can come out a hair under lo and floor below it)
    return max(lo, min(int((la - u * (la - ha)) ** (-1.0 / alpha)), hi))


def lm_request_factory(archs: Sequence[str] = ("qwen25_3b",),
                       prompt_buckets: Sequence[int] = (8, 16),
                       batch: int = 1, max_new_tokens: int = 64,
                       tail_alpha: float = 1.5, min_tokens: int = 4,
                       family_zipf: float = 1.2) -> Callable:
    """Task factory drawing LM requests with production-trace shape.

    Request *families* (arch x prompt bucket — the compile units) are
    Zipf-weighted (rank ``r`` has weight ``r**-family_zipf``): a few hot
    families dominate, a long tail of cold ones trickles.  Output
    lengths are bounded-Pareto with index ``tail_alpha`` — heavy-tailed
    generation lengths are what make tail latency diverge from the
    median and give the p99 guardrail something real to guard.
    """
    families = [(arch, p) for arch in archs for p in prompt_buckets]
    weights = np.array([(r + 1) ** -family_zipf
                        for r in range(len(families))])
    weights /= weights.sum()

    def make(rng: np.random.Generator, task_id: int):
        from repro.domains.lm_serving import LMRequest

        fam = families[int(rng.choice(len(families), p=weights))]
        gen = _bounded_pareto(float(rng.random()), min_tokens,
                              max_new_tokens, tail_alpha)
        return LMRequest(arch=fam[0], prompt_len=fam[1], gen_tokens=gen,
                         batch=batch, max_new_tokens=max_new_tokens,
                         task_id=task_id)

    return make
