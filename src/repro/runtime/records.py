"""JSONL persistence for execution records.

Execute-time records are the same RunRecord shape characterisation
consumes (paper §2, Fig. 1), which makes a recorded run *replayable*: dump
an online run's records (``RuntimeReport.records`` /
``OnlineReport.records`` / ``Scheduler.characterise_records``) to JSONL,
load them back offline, and re-fit models or re-score allocations without
touching a platform.

One JSON object per line, ``{"kind": <record class name>, ...fields}``.
Known record kinds resolve lazily (loading pricing records must not import
the LM model zoo and vice versa); third-party domains register theirs with
:func:`register_record_type`. Floats survive the round trip exactly —
``json`` emits shortest-repr floats — so loaded records compare equal to
the originals, which the replay tests rely on.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import os
import warnings
from typing import Any, Iterable, Sequence

__all__ = ["dump_records", "load_records", "group_records",
           "register_record_type"]

#: kind -> "module.path:ClassName" for the record types shipped in-repo.
_BUILTIN: dict[str, str] = {
    "RunRecord": "repro.pricing.platforms:RunRecord",
    "ServeRecord": "repro.domains.lm_serving:ServeRecord",
    # fault-layer audit trails: a run's fault history persists next to
    # its execution records
    "FaultEvent": "repro.runtime.faults:FaultEvent",
    "DegradationEvent": "repro.runtime.faults:DegradationEvent",
    "BreakerTransition": "repro.runtime.faults:BreakerTransition",
    # overload-control audit trails (shedding / brownout guardrail)
    "ShedEvent": "repro.runtime.admission:ShedEvent",
    "BrownoutTransition": "repro.runtime.admission:BrownoutTransition",
    # observability: metric snapshots and prediction-ledger entries
    # persist on the same stream as the run they describe
    "MetricSnapshot": "repro.obs.metrics:MetricSnapshot",
    "LedgerEntry": "repro.obs.ledger:LedgerEntry",
}

_REGISTRY: dict[str, type] = {}


def register_record_type(cls: type, name: str | None = None) -> None:
    """Register a record dataclass so :func:`load_records` can revive it."""
    _REGISTRY[name or cls.__name__] = cls


def _resolve(kind: str) -> type:
    if kind in _REGISTRY:
        return _REGISTRY[kind]
    path = _BUILTIN.get(kind)
    if path is None:
        raise KeyError(
            f"unknown record kind {kind!r}; register it with "
            f"register_record_type")
    mod_name, _, attr = path.partition(":")
    cls = getattr(importlib.import_module(mod_name), attr)
    _REGISTRY[kind] = cls
    return cls


def dump_records(records: Iterable[Any], path: str | os.PathLike) -> int:
    """Write records to ``path`` as JSONL; returns the number written."""
    n = 0
    with open(path, "w") as fh:
        for rec in records:
            if not dataclasses.is_dataclass(rec):
                raise TypeError(
                    f"records must be dataclasses, got {type(rec).__name__}")
            fields = dataclasses.asdict(rec)
            if "kind" in fields:
                # the envelope key is reserved for the class name; a field
                # named "kind" would silently shadow it and break load
                raise TypeError(
                    f"{type(rec).__name__} has a field named 'kind', which "
                    f"the JSONL envelope reserves for the record class")
            row = {"kind": type(rec).__name__, **fields}
            fh.write(json.dumps(row) + "\n")
            n += 1
    return n


def load_records(path: str | os.PathLike) -> list[Any]:
    """Load a JSONL record dump back into typed record objects.

    A truncated *final* line — the signature of a crash or overload kill
    mid-``dump_records`` — is tolerated: the intact prefix is returned
    with a :class:`UserWarning` so post-crash replay always works.
    Malformed lines anywhere else still raise, because those indicate
    corruption rather than a torn tail.
    """
    with open(path) as fh:
        lines = fh.readlines()
    last = len(lines) - 1
    while last >= 0 and not lines[last].strip():
        last -= 1
    out: list[Any] = []
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            row = json.loads(line)
        except json.JSONDecodeError:
            if i == last:
                warnings.warn(
                    f"{os.fspath(path)}: discarding truncated final JSONL "
                    f"line ({len(line)} bytes); returning the "
                    f"{len(out)}-record intact prefix", stacklevel=2)
                break
            raise
        cls = _resolve(row.pop("kind"))
        out.append(cls(**row))
    return out


def group_records(records: Sequence[Any]) -> dict[tuple[str, int], list[Any]]:
    """Group a flat record list per (platform, task_id) — the window shape
    ``Scheduler.refit`` and ``Domain.fit_models`` consume when replaying a
    dumped run offline."""
    out: dict[tuple[str, int], list[Any]] = {}
    for rec in records:
        out.setdefault((rec.platform, rec.task_id), []).append(rec)
    return out
