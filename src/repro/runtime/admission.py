"""Bounded admission control: backpressure, load shedding, brownout events.

The online loop (PR 4) admits every scenario arrival unconditionally,
which is correct for finite closed workloads but catastrophic under
sustained open-loop load: when offered load exceeds fleet capacity the
pending queue — and with it every latency percentile — grows without
bound.  The only robust saturation behaviours are *bounded* queues,
*backpressure* (admit less when utilisation is high), and *shedding*
(reject excess with a typed, logged outcome the client can see).

:class:`AdmissionController` implements all three as pure round-barrier
arithmetic: the queue bound derives from predicted per-platform service
rates and remaining KV capacity, the backpressure signal is an EWMA of
fleet utilisation, and every rejected task becomes a :class:`ShedEvent`
that persists through :mod:`repro.runtime.records` JSONL like any other
execution record.  No wall clocks, no randomness — identical seeds
reproduce identical shed streams in concurrent and sequential modes.

:class:`BrownoutTransition` lives here too: the SLO guardrail in
:mod:`repro.runtime.online` walks the PR 6 ``degrade_quality`` rungs
when the recent tail quantile breaches the SLO and restores quality
when pressure clears; each rung move is one typed, persistable event.
"""

from __future__ import annotations

import dataclasses
import statistics
from collections import deque
from typing import Any

__all__ = ["ShedEvent", "RejectedTask", "BrownoutTransition",
           "AdmissionConfig", "AdmissionController", "predicted_unit_rates"]


@dataclasses.dataclass(frozen=True)
class ShedEvent:
    """One task rejected by admission control (persisted via records.py).

    ``reason`` is one of ``"queue-full"`` (bounded queue at its computed
    limit), ``"capacity"`` (no alive platform can ever hold the task's
    KV footprint), or ``"timeout"`` (queued longer than the configured
    max wait — the client would have given up).
    """

    task_id: int
    t: float
    reason: str
    queue_depth: int
    utilisation: float
    round: int = -1


@dataclasses.dataclass(frozen=True)
class RejectedTask:
    """A shed task paired with its event — what ``offer`` hands back."""

    task: Any
    event: ShedEvent


@dataclasses.dataclass(frozen=True)
class BrownoutTransition:
    """One rung move of the SLO brownout ladder (persisted via records.py).

    ``direction`` is ``"deepen"`` (tail breached the SLO, quality drops
    one rung) or ``"restore"`` (pressure cleared, quality returns one
    rung).  ``observed`` is the recent guardrail quantile that triggered
    the move, against ``target_s``.
    """

    round: int
    at: float
    rung_from: int
    rung_to: int
    direction: str
    observed: float
    target_s: float


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Tuning for :class:`AdmissionController`.

    ``queue_s`` is the backlog budget in *seconds of predicted fleet
    work*: both the queue-depth bound (how many tasks may wait) and the
    per-round admission budget derive from it.  ``max_queue`` optionally
    caps the computed depth bound.  When EWMA utilisation exceeds
    ``util_high`` the admission budget shrinks by
    ``backpressure_factor`` — backpressure engages *before* the queue
    overflows.  ``max_wait_s`` sheds tasks that have queued longer than
    a client would plausibly wait (None disables timeout shedding).
    """

    queue_s: float = 2.0
    max_queue: int | None = None
    util_high: float = 0.9
    ewma_alpha: float = 0.4
    backpressure_factor: float = 0.5
    max_wait_s: float | None = None

    def __post_init__(self):
        if self.queue_s <= 0:
            raise ValueError("queue_s must be positive")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if not 0.0 < self.backpressure_factor <= 1.0:
            raise ValueError("backpressure_factor must be in (0, 1]")


def predicted_unit_rates(models: dict, alive=None,
                         typical_units: float = 8.0) -> dict[str, float]:
    """Predicted work-units/second per platform from fitted latency models.

    Eq. 7 prices a ``typical_units``-sized dispatch at
    ``beta * units + gamma`` seconds, so the sustained service rate is
    ``units / (median(beta) * units + median(gamma))`` — the gamma term
    matters: an RTT-dominated platform (tiny beta, large constant) has a
    *finite* dispatch rate, and a pure ``1/beta`` estimate would credit
    it near-infinite headroom.  Medians over the platform's fitted task
    models keep the estimate robust to one weird family.  Placeholder
    models for unreachable pairs carry 1e9-scale sentinels and are
    excluded; platforms with no usable model get rate 0 (they cannot
    serve, so they add no queue headroom).
    """
    per: dict[str, tuple[list[float], list[float]]] = {}
    for (pname, _tid), model in models.items():
        if alive is not None and pname not in alive:
            continue
        beta = float(model.latency.beta)
        gamma = float(model.latency.gamma)
        if 0.0 <= beta < 1e8 and 0.0 <= gamma < 1e8 and beta + gamma > 0:
            betas, gammas = per.setdefault(pname, ([], []))
            betas.append(beta)
            gammas.append(gamma)
    u = max(typical_units, 1e-9)
    out: dict[str, float] = {}
    for pname, (betas, gammas) in per.items():
        cost = statistics.median(betas) * u + statistics.median(gammas)
        out[pname] = u / max(cost, 1e-12)
    if alive is not None:
        for pname in alive:
            out.setdefault(pname, 0.0)
    return out


class AdmissionController:
    """Bounded queue + EWMA backpressure between a trace and the scheduler.

    Lifecycle per online round (all quantities round-barrier, so
    executor modes agree bitwise):

    1. ``update_fleet`` — recompute the queue bound from predicted
       service rates and remaining per-platform capacity.
    2. ``observe_utilisation`` — fold this round's busy fraction into
       the EWMA backpressure signal.
    3. ``offer`` each new arrival — queue it, or shed it with a typed
       reason when the queue is at bound / the task can never fit.
    4. ``admit`` — release queued tasks (FIFO) while the scheduler's
       backlog stays inside the (possibly backpressured) budget, and
       time out tasks that waited too long.
    """

    def __init__(self, config: AdmissionConfig | None = None, tracer=None):
        self.config = config or AdmissionConfig()
        #: optional repro.obs.Tracer; every shed becomes an instant event
        #: on the admission track
        self.tracer = tracer
        self.pending: deque[tuple[float, Any, float]] = deque()
        self.util = 0.0
        self.n_offered = 0
        self.n_admitted = 0
        self.n_shed = 0
        self._queue_limit = 1
        self._fleet_rate = 0.0

    # -- round-barrier signal updates --------------------------------------

    def update_fleet(self, unit_rates: dict[str, float],
                     capacity_rem: dict[str, float],
                     task_units: float, task_resource: float) -> None:
        """Size the queue bound from service rate and remaining capacity.

        Per platform the headroom is the *smaller* of (a) how many
        typical tasks it can serve inside the ``queue_s`` budget at its
        predicted rate and (b) how many typical KV footprints still fit
        in its remaining capacity; the fleet bound is the sum.  A fleet
        that is both fast and full sheds; one that is slow but empty
        sheds too — capacity and rate are separate ceilings.
        """
        cfg = self.config
        task_units = max(task_units, 1e-9)
        total = 0.0
        for pname, rate in unit_rates.items():
            by_rate = rate * cfg.queue_s / task_units
            cap = capacity_rem.get(pname)
            if cap is not None and task_resource > 0:
                by_cap = max(cap, 0.0) / task_resource
                total += max(min(by_rate, by_cap), 0.0)
            else:
                total += max(by_rate, 0.0)
        limit = max(int(total), 1)
        if cfg.max_queue is not None:
            limit = min(limit, cfg.max_queue)
        self._queue_limit = limit
        self._fleet_rate = sum(max(r, 0.0) for r in unit_rates.values())

    def observe_utilisation(self, busy_s: float, span_s: float,
                            n_platforms: int) -> None:
        """Fold one round's busy fraction into the EWMA signal."""
        denom = span_s * max(n_platforms, 1)
        sample = min(busy_s / denom, 1.0) if denom > 1e-12 else 0.0
        a = self.config.ewma_alpha
        self.util = a * sample + (1.0 - a) * self.util

    # -- admission decisions -----------------------------------------------

    @property
    def queue_limit(self) -> int:
        return self._queue_limit

    @property
    def fleet_rate(self) -> float:
        return self._fleet_rate

    @property
    def queue_depth(self) -> int:
        return len(self.pending)

    def cost_s(self, units: float) -> float:
        """Predicted fleet-seconds to serve ``units`` of work."""
        return units / self._fleet_rate if self._fleet_rate > 0 else 0.0

    def offer(self, task: Any, t: float, round_idx: int, *,
              cost_s: float, fits: bool) -> RejectedTask | None:
        """Offer one arrival; returns a :class:`RejectedTask` when shed,
        None when queued."""
        self.n_offered += 1
        if not fits:
            return self._shed(task, t, "capacity", round_idx)
        if len(self.pending) >= self._queue_limit:
            return self._shed(task, t, "queue-full", round_idx)
        self.pending.append((t, task, cost_s))
        return None

    def admit(self, now: float, round_idx: int,
              backlog_s: float) -> tuple[list[tuple[float, Any]],
                                         list[RejectedTask]]:
        """Release queued tasks while backlog stays inside the budget.

        ``backlog_s`` is the scheduler's currently-planned work in
        predicted fleet-seconds; each admitted task adds its own cost.
        Under high utilisation the budget shrinks by
        ``backpressure_factor`` so the queue drains before refilling.
        Tasks older than ``max_wait_s`` shed with reason ``timeout``.
        """
        cfg = self.config
        timed_out: list[RejectedTask] = []
        if cfg.max_wait_s is not None:
            keep: deque[tuple[float, Any, float]] = deque()
            for arr_t, task, cost in self.pending:
                if now - arr_t > cfg.max_wait_s:
                    timed_out.append(
                        self._shed(task, arr_t, "timeout", round_idx))
                else:
                    keep.append((arr_t, task, cost))
            self.pending = keep
        budget = cfg.queue_s
        if self.util > cfg.util_high:
            budget *= cfg.backpressure_factor
        admitted: list[tuple[float, Any]] = []
        while self.pending and backlog_s < budget:
            arr_t, task, cost = self.pending.popleft()
            admitted.append((arr_t, task))
            backlog_s += cost
            self.n_admitted += 1
        return admitted, timed_out

    def _shed(self, task: Any, t: float, reason: str,
              round_idx: int) -> RejectedTask:
        self.n_shed += 1
        event = ShedEvent(
            task_id=int(getattr(task, "task_id", -1)), t=t, reason=reason,
            queue_depth=len(self.pending),
            utilisation=round(self.util, 12), round=round_idx)
        if self.tracer is not None:
            self.tracer.instant(f"shed:{reason}", track="admission",
                                cat="admission", task_id=event.task_id,
                                queue_depth=event.queue_depth,
                                round=round_idx)
        return RejectedTask(task=task, event=event)
