"""Deterministic perturbation scenarios for simulated platforms.

The online re-allocation loop (:mod:`repro.runtime.online`) only earns its
keep when system behaviour *shifts* mid-workload — the measurement-driven
re-optimisation regime of Memeti & Pllana (arXiv:1606.05134). Real drift
needs real hardware misbehaving on cue; a :class:`Scenario` replays it on
the simulated platforms instead, as a seed-stable schedule keyed on each
platform's own **virtual clock** (the cumulative latency of everything it
has executed so far):

    sc = (Scenario()
          .slowdown("Local GPU 1", t=1.6, factor=4.0)   # degrade from t on
          .outage("AWS Server EC1", t=2.0)              # dispatches fail
          .arrive(t=0.8, task=extra_task))              # joins mid-workload

Keying on virtual (not host) time makes a scenario a pure function of what
was dispatched: concurrent and sequential runs see identical perturbations,
so the online loop's bitwise mode parity survives drift injection. An
outage makes ``run`` raise :class:`PlatformOutage` — the simulator advances
the platform's clock by a retry cost per failed attempt so finite outage
windows end after finitely many retries.

Slowdowns and outages are consumed by the platforms
(:class:`repro.pricing.platforms.SimulatedPlatform`,
:class:`repro.domains.lm_serving.SimulatedLMPlatform` — see their
``attach_scenario``); arrivals are consumed by the
:class:`~repro.runtime.online.OnlineScheduler`, which admits queued tasks
once the workload's elapsed virtual makespan passes their arrival time.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

__all__ = ["Scenario", "PlatformOutage", "apply_scenario", "salvage_runs"]


class PlatformOutage(RuntimeError):
    """A dispatch hit a platform inside one of its scenario outage windows.

    ``records`` carries whatever the failing batch completed before the
    outage struck — the platform's virtual clock already advanced for that
    work, so dispatchers salvage it instead of re-executing it."""

    def __init__(self, *args):
        super().__init__(*args)
        self.records: list[Any] = []


def apply_scenario(platform, latency: float) -> float:
    """One simulated run's scenario bookkeeping, shared by every simulator.

    Consults ``platform.scenario`` at ``platform.clock``: inside an outage
    window the attempt raises :class:`PlatformOutage` after advancing the
    clock by a retry cost (a failed attempt still costs a round trip, so
    finite windows end after finitely many retries); otherwise the clean
    ``latency`` is stretched through the piecewise slowdown schedule and
    the clock advanced by the result. With no scenario attached the
    latency passes through untouched and no clock is tracked.
    """
    scenario = platform.scenario
    if scenario is None:
        return latency
    name = platform.spec.name
    if scenario.in_outage(name, platform.clock):
        platform.clock += max(platform.spec.rtt_ms * 1e-3, 1e-3)
        raise PlatformOutage(f"{name} is down at t={platform.clock:.3f}s")
    latency = scenario.stretch(name, platform.clock, latency)
    platform.clock += latency
    return latency


def salvage_runs(run_one, items) -> list:
    """Map ``run_one`` over ``items``, salvaging partial output on outage.

    When a :class:`PlatformOutage` interrupts the sweep the results
    completed so far are attached to the exception (``.records``) before
    it propagates — the platform's virtual clock already ran that work, so
    dispatchers keep it in the accounting instead of re-executing it. The
    batched ``run_batch`` loops of both simulators share this one copy.
    """
    out = []
    for item in items:
        try:
            out.append(run_one(item))
        except PlatformOutage as exc:
            exc.records = out + exc.records
            raise
    return out


@dataclasses.dataclass(frozen=True)
class _Window:
    platform: str
    start: float
    end: float
    factor: float = 1.0


@dataclasses.dataclass(frozen=True)
class _Arrival:
    time: float
    task: Any


class Scenario:
    """A deterministic schedule of platform perturbations and task arrivals.

    Builder methods chain and return ``self``; the object is then shared by
    every platform of a run (each queries only its own name) and by the
    online scheduler (arrivals). ``reset()`` rewinds the arrival cursor so
    the same scenario can drive an A/B pair of runs.
    """

    def __init__(self):
        self._slowdowns: list[_Window] = []
        self._outages: list[_Window] = []
        self._arrivals: list[_Arrival] = []
        self._admitted = 0

    # -- builders ----------------------------------------------------------

    def slowdown(self, platform: str, t: float, factor: float,
                 end: float = math.inf) -> "Scenario":
        """From virtual time ``t`` (to ``end``), scale the platform's
        latencies by ``factor`` (> 1 degrades, < 1 speeds up)."""
        if factor <= 0:
            raise ValueError(f"slowdown factor must be > 0, got {factor}")
        self._slowdowns.append(_Window(platform, t, end, factor))
        return self

    def outage(self, platform: str, t: float, end: float = math.inf) -> "Scenario":
        """From virtual time ``t`` (to ``end``), dispatches to the platform
        raise :class:`PlatformOutage` instead of running."""
        self._outages.append(_Window(platform, t, end))
        return self

    def arrive(self, t: float, task: Any) -> "Scenario":
        """Queue a task to join the workload once its elapsed virtual
        makespan reaches ``t``."""
        self._arrivals.append(_Arrival(t, task))
        self._arrivals.sort(key=lambda a: a.time)
        return self

    # -- platform-side queries ---------------------------------------------

    def factor(self, platform: str, t: float) -> float:
        """Combined slowdown factor for a platform at virtual time ``t``."""
        f = 1.0
        for w in self._slowdowns:
            if w.platform == platform and w.start <= t < w.end:
                f *= w.factor
        return f

    def in_outage(self, platform: str, t: float) -> bool:
        return any(w.platform == platform and w.start <= t < w.end
                   for w in self._outages)

    def stretch(self, platform: str, t0: float, clean: float) -> float:
        """Wall-clock duration of ``clean`` seconds of unit-factor work
        started at virtual time ``t0``.

        The slowdown factor is piecewise-constant in virtual time, and a
        run may straddle a boundary — a record half-executed when a 4x
        slowdown lands costs half its clean time plus 4x the other half.
        Integrating instead of sampling the factor at dispatch start keeps
        coarse-grained runs (a one-shot execute's big shards) and
        fine-grained ones (online tranches) on the same physics.
        """
        t, w = float(t0), float(clean)
        while w > 1e-15:
            f = self.factor(platform, t)
            boundary = min(
                (edge for win in self._slowdowns if win.platform == platform
                 for edge in (win.start, win.end)
                 if t < edge < math.inf),
                default=None)
            if boundary is None or t + w * f <= boundary:
                t += w * f
                break
            w -= (boundary - t) / f  # clean work absorbed up to the edge
            t = boundary
        return t - t0

    # -- scheduler-side queries --------------------------------------------

    def take_arrivals(self, t: float, force: bool = False) -> list[Any]:
        """Pop every queued task whose arrival time has passed.

        ``force=True`` pops the whole queue regardless of ``t`` — used when
        the workload drains before the clock reaches the stragglers (there
        is no more work to advance virtual time, so they join immediately).
        """
        out = []
        while self._admitted < len(self._arrivals):
            nxt = self._arrivals[self._admitted]
            if not force and nxt.time > t:
                break
            out.append(nxt.task)
            self._admitted += 1
        return out

    @property
    def pending_arrivals(self) -> int:
        return len(self._arrivals) - self._admitted

    def reset(self) -> "Scenario":
        """Rewind the arrival cursor (for replaying the scenario)."""
        self._admitted = 0
        return self
