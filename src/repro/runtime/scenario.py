"""Deterministic perturbation scenarios for simulated platforms.

The online re-allocation loop (:mod:`repro.runtime.online`) only earns its
keep when system behaviour *shifts* mid-workload — the measurement-driven
re-optimisation regime of Memeti & Pllana (arXiv:1606.05134). Real drift
needs real hardware misbehaving on cue; a :class:`Scenario` replays it on
the simulated platforms instead, as a seed-stable schedule keyed on each
platform's own **virtual clock** (the cumulative latency of everything it
has executed so far):

    sc = (Scenario()
          .slowdown("Local GPU 1", t=1.6, factor=4.0)   # degrade from t on
          .outage("AWS Server EC1", t=2.0)              # dispatches fail
          .flaky("Desktop", p=0.2, t=0.5, end=2.0)      # transient blips
          .corrupt("Local FPGA 1", t=1.0, end=1.2)      # bad records back
          .arrive(t=0.8, task=extra_task))              # joins mid-workload

Keying on virtual (not host) time makes a scenario a pure function of what
was dispatched: concurrent and sequential runs see identical perturbations,
so the online loop's bitwise mode parity survives drift injection. An
outage makes ``run`` raise :class:`PlatformOutage`, a flaky window makes it
raise :class:`TransientFault` with seeded probability — in both cases the
simulator advances the platform's clock by a retry cost per failed attempt
so finite fault windows end after finitely many retries. A corrupt window
poisons the run instead of failing it: the dispatch *returns*, the clock
advances by the true latency (the work was done — and wasted), but the
reported latency comes back negated, which the dispatcher's record sanity
checks (:func:`repro.runtime.faults.check_records`) flag as a
:class:`CorruptResult`.

Slowdowns, outages, flaky and corrupt windows are consumed by the
platforms (:class:`repro.pricing.platforms.SimulatedPlatform`,
:class:`repro.domains.lm_serving.SimulatedLMPlatform` — see their
``attach_scenario``); arrivals are consumed by the
:class:`~repro.runtime.online.OnlineScheduler`, which admits queued tasks
once the workload's elapsed virtual makespan passes their arrival time.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any

from repro.runtime.faults import DispatchFault, PlatformOutage, TransientFault

__all__ = ["Scenario", "PlatformOutage", "TransientFault", "apply_scenario",
           "salvage_runs"]


def _retry_cost(platform) -> float:
    """Virtual time a failed attempt burns (one round trip, floored)."""
    return max(platform.spec.rtt_ms * 1e-3, 1e-3)


def apply_scenario(platform, latency: float) -> float:
    """One simulated run's scenario bookkeeping, shared by every simulator.

    Consults ``platform.scenario`` at ``platform.clock``: inside an outage
    window the attempt raises :class:`PlatformOutage` after advancing the
    clock by a retry cost (a failed attempt still costs a round trip, so
    finite windows end after finitely many retries); a flaky window rolls a
    seeded coin keyed on the clock and raises :class:`TransientFault` the
    same way. Otherwise the clean ``latency`` is stretched through the
    piecewise slowdown schedule and the clock advanced by the result —
    negated on return if the run started inside a corrupt window (the work
    happened and cost its true time, but the record it produces is bad).
    With no scenario attached the latency passes through untouched and no
    clock is tracked.
    """
    scenario = platform.scenario
    if scenario is None:
        return latency
    name = platform.spec.name
    start = platform.clock
    if scenario.in_outage(name, start):
        platform.clock += _retry_cost(platform)
        raise PlatformOutage(f"{name} is down at t={platform.clock:.3f}s")
    if scenario.flaky_failure(name, start):
        platform.clock += _retry_cost(platform)
        raise TransientFault(
            f"{name} dropped a dispatch at t={platform.clock:.3f}s")
    latency = scenario.stretch(name, start, latency)
    platform.clock += latency
    if scenario.in_corrupt(name, start):
        return -latency
    return latency


def salvage_runs(run_one, items) -> list:
    """Map ``run_one`` over ``items``, salvaging partial output on faults.

    When a :class:`~repro.runtime.faults.DispatchFault` (outage *or*
    transient blip) interrupts the sweep the results completed so far are
    attached to the exception (``.records``) before it propagates — the
    platform's virtual clock already ran that work, so dispatchers keep it
    in the accounting instead of re-executing it. The batched ``run_batch``
    loops of both simulators share this one copy.
    """
    out = []
    for item in items:
        try:
            out.append(run_one(item))
        except DispatchFault as exc:
            exc.records = out + exc.records
            raise
    return out


@dataclasses.dataclass(frozen=True)
class _Window:
    platform: str
    start: float
    end: float
    factor: float = 1.0


@dataclasses.dataclass(frozen=True)
class _FlakyWindow:
    platform: str
    start: float
    end: float
    p: float
    seed: int


@dataclasses.dataclass(frozen=True)
class _Arrival:
    time: float
    task: Any


class Scenario:
    """A deterministic schedule of platform perturbations and task arrivals.

    Builder methods chain and return ``self``; the object is then shared by
    every platform of a run (each queries only its own name) and by the
    online scheduler (arrivals). ``reset()`` rewinds the arrival cursor so
    the same scenario can drive an A/B pair of runs.
    """

    def __init__(self):
        self._slowdowns: list[_Window] = []
        self._outages: list[_Window] = []
        self._flaky: list[_FlakyWindow] = []
        self._corrupt: list[_Window] = []
        self._arrivals: list[_Arrival] = []
        self._admitted = 0

    # -- builders ----------------------------------------------------------

    def slowdown(self, platform: str, t: float, factor: float,
                 end: float = math.inf) -> "Scenario":
        """From virtual time ``t`` (to ``end``), scale the platform's
        latencies by ``factor`` (> 1 degrades, < 1 speeds up)."""
        if factor <= 0:
            raise ValueError(f"slowdown factor must be > 0, got {factor}")
        self._slowdowns.append(_Window(platform, t, end, factor))
        return self

    def outage(self, platform: str, t: float, end: float = math.inf) -> "Scenario":
        """From virtual time ``t`` (to ``end``), dispatches to the platform
        raise :class:`PlatformOutage` instead of running."""
        self._outages.append(_Window(platform, t, end))
        return self

    def flaky(self, platform: str, p: float, seed: int = 0, t: float = 0.0,
              end: float = math.inf) -> "Scenario":
        """From virtual time ``t`` (to ``end``), each dispatch attempt on
        the platform fails with probability ``p`` as a retryable
        :class:`TransientFault`.

        The coin is a pure function of (seed, platform, virtual clock) —
        no mutable RNG state — so concurrent and sequential runs see the
        same blips, and because each failed attempt advances the clock by
        a retry cost, consecutive retries draw fresh coins and a finite
        window's storm always ends."""
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"flaky probability must be in [0, 1], got {p}")
        self._flaky.append(_FlakyWindow(platform, t, end, p, seed))
        return self

    def corrupt(self, platform: str, t: float, end: float = math.inf) -> "Scenario":
        """From virtual time ``t`` (to ``end``), dispatches on the platform
        *return* but their records are poisoned (negated latency) — caught
        downstream by record sanity checks as a
        :class:`~repro.runtime.faults.CorruptResult`. The work still costs
        its true virtual time: corruption wastes the run, unlike an outage
        which prevents it."""
        self._corrupt.append(_Window(platform, t, end))
        return self

    def arrive(self, t: float, task: Any) -> "Scenario":
        """Queue a task to join the workload once its elapsed virtual
        makespan reaches ``t``."""
        self._arrivals.append(_Arrival(t, task))
        self._arrivals.sort(key=lambda a: a.time)
        return self

    # -- platform-side queries ---------------------------------------------

    def factor(self, platform: str, t: float) -> float:
        """Combined slowdown factor for a platform at virtual time ``t``."""
        f = 1.0
        for w in self._slowdowns:
            if w.platform == platform and w.start <= t < w.end:
                f *= w.factor
        return f

    def in_outage(self, platform: str, t: float) -> bool:
        return any(w.platform == platform and w.start <= t < w.end
                   for w in self._outages)

    def flaky_failure(self, platform: str, t: float) -> bool:
        """Seeded coin flip: does a dispatch starting at virtual time ``t``
        hit a transient fault? Pure in (seed, platform, t) — ``repr(t)``
        round-trips the float exactly, so the draw is bit-stable across
        modes and replays."""
        for w in self._flaky:
            if w.platform == platform and w.start <= t < w.end:
                key = f"flaky|{w.seed}|{platform}|{t!r}"
                u = (zlib.crc32(key.encode()) & 0xFFFFFFFF) / 2**32
                if u < w.p:
                    return True
        return False

    def in_corrupt(self, platform: str, t: float) -> bool:
        return any(w.platform == platform and w.start <= t < w.end
                   for w in self._corrupt)

    def stretch(self, platform: str, t0: float, clean: float) -> float:
        """Wall-clock duration of ``clean`` seconds of unit-factor work
        started at virtual time ``t0``.

        The slowdown factor is piecewise-constant in virtual time, and a
        run may straddle a boundary — a record half-executed when a 4x
        slowdown lands costs half its clean time plus 4x the other half.
        Integrating instead of sampling the factor at dispatch start keeps
        coarse-grained runs (a one-shot execute's big shards) and
        fine-grained ones (online tranches) on the same physics.
        """
        t, w = float(t0), float(clean)
        while w > 1e-15:
            f = self.factor(platform, t)
            boundary = min(
                (edge for win in self._slowdowns if win.platform == platform
                 for edge in (win.start, win.end)
                 if t < edge < math.inf),
                default=None)
            if boundary is None or t + w * f <= boundary:
                t += w * f
                break
            w -= (boundary - t) / f  # clean work absorbed up to the edge
            t = boundary
        return t - t0

    # -- scheduler-side queries --------------------------------------------

    def take_arrivals(self, t: float, force: bool = False) -> list[Any]:
        """Pop every queued task whose arrival time has passed.

        ``force=True`` pops the whole queue regardless of ``t`` — used when
        the workload drains before the clock reaches the stragglers (there
        is no more work to advance virtual time, so they join immediately).
        """
        return [task for _, task in self.take_arrivals_timed(t, force)]

    def take_arrivals_timed(self, t: float,
                            force: bool = False) -> list[tuple[float, Any]]:
        """Like :meth:`take_arrivals`, keeping each task's nominal arrival
        time — admission control and SLO accounting (TTFT, queueing delay)
        need when the request *arrived*, not when the loop noticed it."""
        out = []
        while self._admitted < len(self._arrivals):
            nxt = self._arrivals[self._admitted]
            if not force and nxt.time > t:
                break
            out.append((nxt.time, nxt.task))
            self._admitted += 1
        return out

    @property
    def next_arrival_time(self) -> float | None:
        """Arrival time of the next still-queued task (None when drained).

        Open-loop runs idle-advance their clock floor to this instant when
        all admitted work is done but the trace has more to offer.
        """
        if self._admitted < len(self._arrivals):
            return self._arrivals[self._admitted].time
        return None

    @property
    def pending_arrivals(self) -> int:
        return len(self._arrivals) - self._admitted

    def reset(self) -> "Scenario":
        """Rewind the arrival cursor (for replaying the scenario)."""
        self._admitted = 0
        return self
