"""The domain-agnostic scheduler: one back-end for every metric-modelled
domain (paper Fig. 1; companion work arXiv:1408.4965).

    domain = PricingDomain(tasks, platforms)        # or LMServingDomain(...)
    sched = Scheduler(domain)
    sched.characterise()                            # online benchmarking, (2)
    alloc = sched.allocate(quality, method="milp")  # trade-off selection, (3-4)
    report = sched.execute(alloc, quality)          # evaluation, (5)

The scheduler owns everything that is *not* domain knowledge: building the
(delta, gamma) model matrices, the :class:`AllocationProblem`, solver
dispatch (heuristic / ML / MILP from :mod:`repro.core`, reused unchanged),
converting allocation shares back into per-platform work via the domain's
quality->work inversion, batched dispatch per launch group — overlapped
across platforms by the :class:`repro.runtime.Executor` so the measured
makespan is the max over concurrently running platforms, not a serial
sum — and the predicted-vs-measured makespan report (the paper's
Figs 8 & 10 quantities).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable

import numpy as np

from repro.core import (
    Allocation,
    AllocationProblem,
    SUPPORT_ATOL,
    makespan,
    milp_allocation,
    ml_allocation,
    proportional_allocation,
)
from .domain import Domain, RunRecordLike
from .executor import Executor

__all__ = ["Scheduler", "RuntimeReport", "SOLVERS"]

#: The three allocation approaches of §4.3, shared by every domain.
SOLVERS: dict[str, Callable[..., Allocation]] = {
    "heuristic": lambda p, **kw: proportional_allocation(p),
    "ml": lambda p, **kw: ml_allocation(p, **kw),
    "milp": lambda p, **kw: milp_allocation(p, **kw),
}


@dataclasses.dataclass
class RuntimeReport:
    """Outcome of one execute pass: makespans + domain summary.

    ``platform_latencies`` sums each platform's per-record latencies (real
    wall clock for local platforms, replayed latency for simulated ones);
    ``platform_wall_s`` is each platform's own host wall clock around its
    dispatches, and ``wall_s`` the whole pass — under concurrent dispatch
    ``wall_s`` tracks ``max`` of the per-platform clocks rather than their
    sum, which is the paper's makespan semantics.
    """

    allocation: Allocation
    predicted_makespan: float
    measured_makespan: float
    platform_latencies: dict[str, float]
    records: list[RunRecordLike]
    summary: dict = dataclasses.field(default_factory=dict)
    platform_wall_s: dict[str, float] = dataclasses.field(default_factory=dict)
    wall_s: float = 0.0
    mode: str = "sequential"

    @property
    def makespan_error(self) -> float:
        if self.measured_makespan == 0:
            # an allocation that dispatched no work has no measurable
            # makespan; inf (not ZeroDivisionError) marks the model as
            # unassessable
            return math.inf
        return abs(self.predicted_makespan - self.measured_makespan) / self.measured_makespan


class Scheduler:
    """Runs one domain's workload through the shared allocation back-end.

    ``mode`` selects the dispatch strategy for characterise *and* execute:
    ``"concurrent"`` (default) overlaps platforms on an :class:`Executor`
    thread pool so measured makespan reflects true concurrency;
    ``"sequential"`` replays the legacy serial loop for A/B comparisons.
    Both produce identical records for deterministic platforms. Every
    entry point also takes a per-call ``mode`` override.
    """

    def __init__(self, domain: Domain, mode: str = "concurrent",
                 max_workers: int | None = None):
        self.domain = domain
        self.executor = Executor(mode=mode, max_workers=max_workers)
        self.models: dict[tuple[str, int], Any] | None = None
        self._delta: np.ndarray | None = None
        self._gamma: np.ndarray | None = None

    @property
    def mode(self) -> str:
        return self.executor.mode

    def _executor(self, mode: str | None) -> Executor:
        if mode is None:
            return self.executor
        return Executor(mode=mode, max_workers=self.executor.max_workers)

    @property
    def tasks(self) -> list:
        return self.domain.tasks

    @property
    def platforms(self) -> list:
        return self.domain.platforms

    # -- step 2: characterisation ------------------------------------------

    def characterise(self, seed: int = 1, mode: str | None = None, **kw) -> None:
        self.models = self.domain.characterise(
            seed=seed, executor=self._executor(mode), **kw)
        self._delta, self._gamma = self.model_matrices()

    def model_matrices(self) -> tuple[np.ndarray, np.ndarray]:
        """(delta, gamma) matrices ordered [platform, task]."""
        assert self.models is not None, "characterise() first"
        mu, tau = len(self.platforms), len(self.tasks)
        delta = np.zeros((mu, tau))
        gamma = np.zeros((mu, tau))
        for i, p in enumerate(self.platforms):
            pname = self.domain.platform_name(p)
            for j, t in enumerate(self.tasks):
                d, g = self.domain.model_coefficients(self.models[(pname, t.task_id)])
                delta[i, j] = d
                gamma[i, j] = g
        return delta, gamma

    # -- steps 3-4: allocation ---------------------------------------------

    def quality_vector(self, quality=None) -> np.ndarray:
        if quality is None:
            quality = self.domain.default_quality()
            if quality is None:
                raise ValueError(
                    f"domain {self.domain.name!r} has no default quality; "
                    "pass one explicitly")
        return np.broadcast_to(np.asarray(quality, dtype=np.float64),
                               (len(self.tasks),)).copy()

    def problem(self, quality=None) -> AllocationProblem:
        if self._delta is None:
            raise RuntimeError("characterise() first")
        return AllocationProblem(delta=self._delta, gamma=self._gamma,
                                 c=self.quality_vector(quality),
                                 reduction=self.domain.reduction)

    def allocate(self, quality=None, method: str = "milp", **solver_kw) -> Allocation:
        return SOLVERS[method](self.problem(quality), **solver_kw)

    # -- step 5: execution --------------------------------------------------

    def shards(self, allocation: Allocation,
               problem: AllocationProblem) -> list[tuple[Any, list[tuple[Any, int]]]]:
        """Turn allocation shares into per-platform (task, units) launch
        groups via the domain's quality->work inversion."""
        assert self.models is not None
        A = allocation.A
        out = []
        for i, p in enumerate(self.platforms):
            pname = self.domain.platform_name(p)
            groups: dict = {}
            for j, t in enumerate(self.tasks):
                share = A[i, j]
                if share <= SUPPORT_ATOL:
                    continue
                model = self.models[(pname, t.task_id)]
                total = self.domain.work_units(model, float(problem.c[j]))
                units = max(int(np.ceil(share * total)), self.domain.min_chunk)
                groups.setdefault(self.domain.launch_key(t), []).append((t, units))
            out.append((p, list(groups.values())))
        return out

    def execute(self, allocation: Allocation, quality=None, seed: int = 3,
                mode: str | None = None) -> RuntimeReport:
        """Dispatch each platform's launch groups; concurrent by default.

        One job per platform: its groups run back-to-back on one thread
        (they contend for the same device anyway) while distinct platforms
        overlap, each timed by its own wall clock. Records are collected
        in platform-major order — identical to the sequential loop's."""
        problem = self.problem(quality)
        executor = self._executor(mode)
        shards = self.shards(allocation, problem)

        def run_platform(shard) -> list[RunRecordLike]:
            p, groups = shard
            recs: list[RunRecordLike] = []
            for group in groups:
                gtasks = [t for t, _ in group]
                g_units = [u for _, u in group]
                recs.extend(self.domain.dispatch_batch(p, gtasks, g_units,
                                                       seed=seed))
            return recs

        t0 = time.perf_counter()
        timed = executor.map_timed(run_platform, shards)
        wall_s = time.perf_counter() - t0

        records: list[RunRecordLike] = []
        plat_lat = {self.domain.platform_name(p): 0.0 for p in self.platforms}
        plat_wall: dict[str, float] = {}
        for (p, _groups), result in zip(shards, timed):
            pname = self.domain.platform_name(p)
            plat_wall[pname] = result.wall_s
            for rec in result.value:
                records.append(rec)
                plat_lat[pname] += rec.latency
        return RuntimeReport(
            allocation=allocation,
            predicted_makespan=makespan(allocation.A, problem),
            measured_makespan=max(plat_lat.values(), default=0.0),
            platform_latencies=plat_lat,
            records=records,
            summary=self.domain.summarise(records, problem),
            platform_wall_s=plat_wall,
            wall_s=wall_s,
            mode=executor.mode,
        )

    # -- convenience: the whole Fig. 1 flow --------------------------------

    def run(self, quality=None, method: str = "milp", seed: int = 3,
            characterise_kw: dict | None = None, mode: str | None = None,
            **solver_kw) -> RuntimeReport:
        """characterise (if needed) -> allocate -> execute in one call."""
        if self.models is None:
            self.characterise(mode=mode, **(characterise_kw or {}))
        alloc = self.allocate(quality, method=method, **solver_kw)
        return self.execute(alloc, quality, seed=seed, mode=mode)
