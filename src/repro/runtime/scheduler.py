"""The domain-agnostic scheduler: one back-end for every metric-modelled
domain (paper Fig. 1; companion work arXiv:1408.4965).

    domain = PricingDomain(tasks, platforms)        # or LMServingDomain(...)
    sched = Scheduler(domain)
    sched.characterise()                            # online benchmarking, (2)
    alloc = sched.allocate(quality, method="milp")  # trade-off selection, (3-4)
    report = sched.execute(alloc, quality)          # evaluation, (5)

The scheduler owns everything that is *not* domain knowledge: building the
(delta, gamma) model matrices, the :class:`AllocationProblem`, solver
dispatch (heuristic / ML / MILP from :mod:`repro.core`, reused unchanged),
converting allocation shares back into per-platform work via the domain's
quality->work inversion, batched dispatch per launch group — overlapped
across platforms by the :class:`repro.runtime.Executor` so the measured
makespan is the max over concurrently running platforms, not a serial
sum — and the predicted-vs-measured makespan report (the paper's
Figs 8 & 10 quantities).
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Any, Callable, Hashable, Sequence

import numpy as np

from repro.core import (
    Allocation,
    AllocationProblem,
    SUPPORT_ATOL,
    clustered_allocation,
    makespan,
    milp_allocation,
    ml_allocation,
    proportional_allocation,
)
from repro.obs import (
    PredictionLedger,
    lift_solver_phases,
    metrics as obs_metrics,
    resolve_tracer,
)
from .domain import Domain, RunRecordLike
from .executor import Executor
from .faults import (
    DispatchFault,
    FaultEvent,
    JobCancelled,
    RetryPolicy,
    check_records,
    fault_kind,
)

__all__ = ["Scheduler", "RuntimeReport", "DispatchResult", "SOLVERS"]

#: The three allocation approaches of §4.3, shared by every domain.
SOLVERS: dict[str, Callable[..., Allocation]] = {
    "heuristic": lambda p, **kw: proportional_allocation(p),
    "ml": lambda p, **kw: ml_allocation(p, **kw),
    "milp": lambda p, **kw: milp_allocation(p, **kw),
}


@dataclasses.dataclass(frozen=True)
class DispatchResult:
    """One platform's slice of a dispatch plan: the records it produced,
    its own wall clock, and — when the caller opted into partial dispatch
    via ``catch`` — the exception that cut it short (records up to the
    failure are kept, so remaining-work accounting stays exact)."""

    records: list
    wall_s: float
    error: BaseException | None = None
    #: fault-layer audit trail: one event per fault the retry loop handled
    faults: tuple[FaultEvent, ...] = ()


@dataclasses.dataclass
class RuntimeReport:
    """Outcome of one execute pass: makespans + domain summary.

    ``platform_latencies`` sums each platform's per-record latencies (real
    wall clock for local platforms, replayed latency for simulated ones);
    ``platform_wall_s`` is each platform's own host wall clock around its
    dispatches, and ``wall_s`` the whole pass — under concurrent dispatch
    ``wall_s`` tracks ``max`` of the per-platform clocks rather than their
    sum, which is the paper's makespan semantics.
    """

    allocation: Allocation
    predicted_makespan: float
    measured_makespan: float
    platform_latencies: dict[str, float]
    records: list[RunRecordLike]
    summary: dict = dataclasses.field(default_factory=dict)
    platform_wall_s: dict[str, float] = dataclasses.field(default_factory=dict)
    wall_s: float = 0.0
    mode: str = "sequential"
    #: fault-layer audit trails (see repro.runtime.faults): every fault the
    #: retry loop handled, and every quality-target relaxation the online
    #: loop's graceful degradation applied.
    fault_events: list = dataclasses.field(default_factory=list)
    degradations: list = dataclasses.field(default_factory=list)

    @property
    def solver_meta(self) -> dict:
        """Per-phase solver telemetry: build_s / solve_s (/ polish_s),
        n_vars / n_constraints, and — for clustered solves — how many
        super-tasks the solver actually saw (clustered_from / n_clusters)."""
        return dict(self.allocation.meta)

    @property
    def makespan_error(self) -> float:
        if self.measured_makespan == 0:
            # an allocation that dispatched no work has no measurable
            # makespan; inf (not ZeroDivisionError) marks the model as
            # unassessable
            return math.inf
        return abs(self.predicted_makespan - self.measured_makespan) / self.measured_makespan


class Scheduler:
    """Runs one domain's workload through the shared allocation back-end.

    ``mode`` selects the dispatch strategy for characterise *and* execute:
    ``"concurrent"`` (default) overlaps platforms on an :class:`Executor`
    thread pool so measured makespan reflects true concurrency;
    ``"sequential"`` replays the legacy serial loop for A/B comparisons.
    Both produce identical records for deterministic platforms. Every
    entry point also takes a per-call ``mode`` override.
    """

    def __init__(self, domain: Domain, mode: str = "concurrent",
                 max_workers: int | None = None, trace=None):
        self.domain = domain
        #: span tracer (repro.obs): ``trace`` may be a Tracer, True/False,
        #: or None to follow the process default (``REPRO_TRACE=1``). A
        #: disabled tracer makes every instrumentation site a no-op, which
        #: is what keeps instrumented overhead off the hot path by default.
        self.tracer = resolve_tracer(trace)
        #: prediction-accountability ledger (repro.obs): populated when
        #: tracing is enabled — each execute pairs predicted vs measured
        #: latency/makespan/accuracy per (platform, task family, round).
        self.ledger = PredictionLedger()
        self.executor = Executor(mode=mode, max_workers=max_workers,
                                 tracer=self.tracer)
        self.models: dict[tuple[str, int], Any] | None = None
        #: raw benchmark records per (platform, task_id) from the last
        #: characterise pass — the online loop's re-fit windows start from
        #: these, and runtime.records can persist them to JSONL.
        self.characterise_records: dict[tuple[str, int], list[RunRecordLike]] = {}
        #: bumped whenever the fitted models (and hence the matrices)
        #: change — characterise, incremental characterise, refit. Lets
        #: callers cache anything derived from the models (the online
        #: loop's per-pair work totals) and invalidate exactly on change.
        self.models_version: int = 0
        self._delta: np.ndarray | None = None
        self._gamma: np.ndarray | None = None

    @property
    def mode(self) -> str:
        return self.executor.mode

    def _executor(self, mode: str | None) -> Executor:
        if mode is None:
            return self.executor
        return Executor(mode=mode, max_workers=self.executor.max_workers,
                        tracer=self.tracer)

    @property
    def tasks(self) -> list:
        return self.domain.tasks

    @property
    def platforms(self) -> list:
        return self.domain.platforms

    # -- step 2: characterisation ------------------------------------------

    def characterise(self, seed: int = 1, mode: str | None = None, **kw) -> None:
        sink: dict[tuple[str, int], list[RunRecordLike]] = {}
        with self.tracer.span("characterise", track="scheduler",
                              cat="characterise",
                              n_platforms=len(self.platforms),
                              n_tasks=len(self.tasks)):
            self.models = self.domain.characterise(
                seed=seed, executor=self._executor(mode), record_sink=sink,
                **kw)
        self.characterise_records = sink
        self.models_version += 1
        self._delta, self._gamma = self.model_matrices()

    def characterise_tasks(self, tasks: Sequence[Any], seed: int = 1,
                           mode: str | None = None,
                           platforms: Sequence[Any] | None = None,
                           **kw) -> None:
        """Incrementally characterise tasks that joined mid-workload.

        The tasks must already be in ``domain.tasks``; only the new
        (platform, task) pairs are benchmarked — restricted to
        ``platforms`` when given (the online loop skips platforms it has
        declared dead) — their models and records merged into the existing
        ones, and the matrices rebuilt. The caller is responsible for
        filling models of any skipped (platform, task) pairs before the
        matrices are consumed."""
        assert self.models is not None, "characterise() first"
        sink: dict[tuple[str, int], list[RunRecordLike]] = {}
        fitted = self.domain.characterise(
            seed=seed, executor=self._executor(mode), tasks=tasks,
            platforms=platforms, record_sink=sink, skip_unavailable=True,
            **kw)
        self.models.update(fitted)
        self.characterise_records.update(sink)
        self.models_version += 1
        if platforms is None:
            self._delta, self._gamma = self.model_matrices()

    def adopt_models(self, tasks: Sequence[Any],
                     platforms: Sequence[Any] | None = None) -> list:
        """Adopt fitted models for arrivals from same-family incumbents.

        Open-loop traces deliver hundreds of arrivals from a handful of
        request families; benchmarking every one from scratch
        (``characterise_tasks``) would cost more than serving it.  A task
        whose launch key matches an already-characterised *donor* task
        shares the donor's per-platform metric models (the launch key is
        the compile unit — same family, same eq. 7 coefficients) and gets
        the donor's characterise records re-tagged under its own id so
        offline replay still fits the same models.  Returns the orphans —
        tasks with no same-family donor — which the caller must
        characterise for real.  Matrices are *not* rebuilt here; callers
        batch that with their placeholder fill (same contract as
        ``characterise_tasks(platforms=...)``).
        """
        assert self.models is not None, "characterise() first"
        sweep = self.platforms if platforms is None else list(platforms)
        donors: dict[Hashable, int] = {}
        new_ids = {t.task_id for t in tasks}
        for t in self.tasks:
            if t.task_id not in new_ids:
                donors.setdefault(self.domain.launch_key(t), t.task_id)
        orphans: list = []
        adopted = False
        for t in tasks:
            donor_id = donors.get(self.domain.launch_key(t))
            if donor_id is None:
                orphans.append(t)
                continue
            for p in sweep:
                pname = self.domain.platform_name(p)
                model = self.models.get((pname, donor_id))
                if model is None:
                    continue
                self.models[(pname, t.task_id)] = model
                recs = self.characterise_records.get((pname, donor_id), [])
                self.characterise_records[(pname, t.task_id)] = [
                    dataclasses.replace(r, task_id=t.task_id) for r in recs]
            adopted = True
        if adopted:
            self.models_version += 1
        return orphans

    def refit(self, windows: dict[tuple[str, int], Sequence[RunRecordLike]]) -> None:
        """Fold execute-time records back into the metric models.

        Execute records are the same shape characterisation consumes (the
        paper's premise, §2 Fig. 1), so re-fitting is just
        ``Domain.fit_models`` over each pair's accumulated window; the
        (delta, gamma) matrices are rebuilt so the next ``problem()`` sees
        the drifted coefficients."""
        assert self.models is not None, "characterise() first"
        for key, recs in windows.items():
            if recs:
                self.models[key] = self.domain.fit_models(list(recs))
        self.models_version += 1
        self._delta, self._gamma = self.model_matrices()

    def model_matrices(self) -> tuple[np.ndarray, np.ndarray]:
        """(delta, gamma) matrices ordered [platform, task]."""
        assert self.models is not None, "characterise() first"
        mu, tau = len(self.platforms), len(self.tasks)
        delta = np.zeros((mu, tau))
        gamma = np.zeros((mu, tau))
        for i, p in enumerate(self.platforms):
            pname = self.domain.platform_name(p)
            for j, t in enumerate(self.tasks):
                d, g = self.domain.model_coefficients(self.models[(pname, t.task_id)])
                delta[i, j] = d
                gamma[i, j] = g
        return delta, gamma

    # -- steps 3-4: allocation ---------------------------------------------

    def quality_vector(self, quality=None) -> np.ndarray:
        if quality is None:
            quality = self.domain.default_quality()
            if quality is None:
                raise ValueError(
                    f"domain {self.domain.name!r} has no default quality; "
                    "pass one explicitly")
        return np.broadcast_to(np.asarray(quality, dtype=np.float64),
                               (len(self.tasks),)).copy()

    def capacity_matrices(self, quality=None) -> tuple[np.ndarray, np.ndarray] | None:
        """(resource, capacity) for the domain's second constraint
        dimension, or None when the domain declares none.

        ``resource[i, j]`` is what platform i holds while serving *all* of
        task j at the requested quality: the domain's per-work-unit
        resource times its quality->work inversion under platform i's own
        fitted model (KV bytes/token x tokens, bytes/path x paths, ...).
        """
        assert self.models is not None, "characterise() first"
        c = self.quality_vector(quality)
        mu, tau = len(self.platforms), len(self.tasks)
        resource = np.zeros((mu, tau))
        capacity = np.zeros(mu)
        for i, p in enumerate(self.platforms):
            pname = self.domain.platform_name(p)
            capacity[i] = self.domain.platform_capacity(p)
            for j, t in enumerate(self.tasks):
                per_unit = self.domain.resource_per_unit(p, t)
                if per_unit:
                    model = self.models[(pname, t.task_id)]
                    resource[i, j] = per_unit * self.domain.work_units(
                        model, float(c[j]))
        if not resource.any() or not np.isfinite(capacity).any():
            return None  # dimension inert: keep the problem capacity-free
        return resource, capacity

    def problem(self, quality=None) -> AllocationProblem:
        if self._delta is None:
            raise RuntimeError("characterise() first")
        cap = self.capacity_matrices(quality)
        return AllocationProblem(delta=self._delta, gamma=self._gamma,
                                 c=self.quality_vector(quality),
                                 reduction=self.domain.reduction,
                                 resource=None if cap is None else cap[0],
                                 capacity=None if cap is None else cap[1])

    def allocate(self, quality=None, method: str = "milp", *,
                 cluster: bool = False, cluster_rtol: float = 0.0,
                 **solver_kw) -> Allocation:
        """Solve the allocation; ``cluster=True`` routes through task-family
        clustering (:func:`repro.core.clustered_allocation`) so fleets with
        many structurally identical tasks solve at family count, not task
        count. ``cluster_rtol`` merges near-identical families at bounded
        relative error."""
        problem = self.problem(quality)
        with self.tracer.span("allocate", track="scheduler", cat="solve",
                              method=method, cluster=cluster) as sp:
            if cluster:
                alloc = clustered_allocation(problem, method,
                                             rtol=cluster_rtol, **solver_kw)
            else:
                alloc = SOLVERS[method](problem, **solver_kw)
        if self.tracer.enabled:
            # lift the solver's per-phase meta timings (PR 7) into real
            # spans on the solver track, ending where allocate ended
            lift_solver_phases(self.tracer, alloc.meta, sp.t1,
                               label=f"{alloc.solver or method}")
            solve_s = alloc.meta.get("solve_s")
            if solve_s:
                obs_metrics.histogram("solver.solve_s").observe(solve_s)
        return alloc

    # -- step 5: execution --------------------------------------------------

    def shards(self, allocation: Allocation,
               problem: AllocationProblem) -> list[tuple[Any, list[tuple[Any, int]]]]:
        """Turn allocation shares into per-platform (task, units) launch
        groups via the domain's quality->work inversion."""
        assert self.models is not None
        A = allocation.A
        out = []
        for i, p in enumerate(self.platforms):
            pname = self.domain.platform_name(p)
            groups: dict = {}
            for j, t in enumerate(self.tasks):
                share = A[i, j]
                if share <= SUPPORT_ATOL:
                    continue
                model = self.models[(pname, t.task_id)]
                total = self.domain.work_units(model, float(problem.c[j]))
                units = max(int(np.ceil(share * total)), self.domain.min_chunk)
                groups.setdefault(self.domain.launch_key(t), []).append((t, units))
            out.append((p, list(groups.values())))
        return out

    def dispatch_plan(
        self,
        plan: Sequence[tuple[Any, list[list[tuple[Any, int]]]]],
        seed: int | Callable[[str, Hashable], int] = 3,
        mode: str | None = None,
        catch: tuple[type[BaseException], ...] = (),
        retry: RetryPolicy | None = None,
        round_idx: int = 0,
        cancel: threading.Event | None = None,
    ) -> tuple[list[DispatchResult], float]:
        """Dispatch an explicit per-platform plan; the partial-dispatch hook.

        ``plan`` is a list of (platform, launch groups) where each group is
        a list of (task, units) — the shape :meth:`shards` produces, but
        callers (the online loop) may hand any tranche of the workload.
        One job per platform: its groups run back-to-back on one thread
        (they contend for the same device anyway) while distinct platforms
        overlap, each timed by its own wall clock.

        ``seed`` is either one int for every launch (the execute path) or a
        callable ``(platform_name, launch_key) -> int`` so round-based
        callers can derive per-(platform, group, round) seeds via
        :func:`repro.runtime.domain.seed_for` — what keeps concurrent and
        sequential online runs bitwise-identical.

        ``retry`` arms the fault layer: retryable faults (transient blips,
        corrupt results — see :class:`~repro.runtime.faults.RetryPolicy`)
        re-dispatch the unsalvaged remainder of the failing group with
        deterministic backoff, bounded per dispatch by ``max_attempts`` and
        per (platform, round) by ``budget``; returned records are
        sanity-checked (:func:`~repro.runtime.faults.check_records`, bad
        records discarded and their tasks re-dispatched); every handled
        fault is logged into :attr:`DispatchResult.faults` with the virtual
        time it burned (platform clock delta minus salvaged record
        latencies) so makespan accounting charges storms honestly.

        Exception types in ``catch`` (e.g. ``PlatformOutage``) — and
        retry-exhausted retryable faults when they match — are captured per
        platform into :attr:`DispatchResult.error` with the records
        produced before the failure kept; anything else propagates.
        ``cancel``, when set mid-round, skips the platform's not-yet-started
        launch groups (:class:`~repro.runtime.faults.JobCancelled`).
        """
        executor = self._executor(mode)
        catchable = (DispatchFault,) + tuple(catch)
        tracer = self.tracer

        def run_platform(shard) -> DispatchResult:
            p, groups = shard
            pname = self.domain.platform_name(p)
            # the executor opened this platform's "dispatch" span on the
            # current thread (span_of below); annotate it with the round,
            # the parity-safe virtual clock endpoints, and the counts
            dsp = tracer.current()
            dsp.args["round"] = round_idx
            v_start = getattr(p, "clock", None)
            recs: list[RunRecordLike] = []
            faults: list[FaultEvent] = []
            error: BaseException | None = None
            budget = retry.budget if retry is not None else 0
            for group in groups:
                if cancel is not None and cancel.is_set():
                    error = JobCancelled(
                        f"{pname}: remaining launch groups cancelled")
                    break
                gtasks = [t for t, _ in group]
                group_seed = (seed(pname, self.domain.launch_key(gtasks[0]))
                              if callable(seed) else seed)
                with tracer.span("launch", track=pname, cat="dispatch",
                                 tasks=len(group),
                                 units=sum(u for _, u in group)) as lsp:
                    gv0 = getattr(p, "clock", None)
                    pending = list(group)
                    attempt = 1
                    while pending:
                        clock0 = getattr(p, "clock", None)
                        try:
                            new = self.domain.dispatch_batch(
                                p, [t for t, _ in pending],
                                [u for _, u in pending], seed=group_seed)
                            if retry is not None:
                                check_records(new)
                            recs.extend(new)
                            break
                        except catchable as exc:
                            # a batch failing mid-way may carry the records
                            # it completed first (DispatchFault.records) —
                            # that work already ran, so keep it in the
                            # accounting
                            salvaged = list(getattr(exc, "records", []))
                            recs.extend(salvaged)
                            burned = 0.0
                            if clock0 is not None:
                                burned = max(
                                    getattr(p, "clock", clock0) - clock0
                                    - sum(r.latency for r in salvaged), 0.0)
                            kind = fault_kind(exc)
                            if (retry is not None and retry.retryable(exc)
                                    and attempt < retry.max_attempts
                                    and budget > 0):
                                budget -= 1
                                faults.append(FaultEvent(
                                    pname, -1, round_idx, kind, "retried",
                                    attempt, burned))
                                tracer.instant(
                                    f"fault:{kind}", track=pname,
                                    cat="fault", action="retried",
                                    attempt=attempt, round=round_idx,
                                    burned=burned)
                                done = {r.task_id for r in salvaged}
                                pending = [(t, u) for t, u in pending
                                           if t.task_id not in done]
                                pause = retry.delay(
                                    0 if callable(seed) else seed,
                                    pname, round_idx, attempt)
                                if pause > 0.0:
                                    time.sleep(pause)
                                attempt += 1
                                continue
                            faults.append(FaultEvent(
                                pname, -1, round_idx, kind, "exhausted",
                                attempt, burned))
                            tracer.instant(
                                f"fault:{kind}", track=pname, cat="fault",
                                action="exhausted", attempt=attempt,
                                round=round_idx, burned=burned)
                            if isinstance(exc, catch):
                                error = exc
                                break
                            raise
                    if gv0 is not None:
                        lsp.set_virtual(gv0, getattr(p, "clock", gv0))
                if error is not None:
                    break
            if v_start is not None:
                dsp.set_virtual(v_start, getattr(p, "clock", v_start))
            dsp.args["n_records"] = len(recs)
            dsp.args["n_faults"] = len(faults)
            return DispatchResult(records=recs, wall_s=0.0, error=error,
                                  faults=tuple(faults))

        t0 = time.perf_counter()
        timed = executor.map_timed(
            run_platform, plan,
            span_of=lambda shard: ("dispatch",
                                   self.domain.platform_name(shard[0])))
        wall_s = time.perf_counter() - t0
        results = [dataclasses.replace(t.value, wall_s=t.wall_s) for t in timed]
        return results, wall_s

    def execute(self, allocation: Allocation, quality=None, seed: int = 3,
                mode: str | None = None,
                retry: RetryPolicy | None = None) -> RuntimeReport:
        """Dispatch each platform's launch groups; concurrent by default.

        Records are collected in platform-major order — identical to the
        sequential loop's (see :meth:`dispatch_plan`). ``retry`` arms the
        fault layer: handled faults land in ``report.fault_events`` and
        their burned virtual time inflates the faulty platform's latency
        (a storm honestly costs makespan)."""
        problem = self.problem(quality)
        shards = self.shards(allocation, problem)
        with self.tracer.span("execute", track="scheduler", cat="execute",
                              n_platforms=len(shards)):
            results, wall_s = self.dispatch_plan(shards, seed=seed,
                                                 mode=mode, retry=retry)

        records: list[RunRecordLike] = []
        fault_events: list[FaultEvent] = []
        plat_lat = {self.domain.platform_name(p): 0.0 for p in self.platforms}
        plat_wall: dict[str, float] = {}
        for (p, _groups), result in zip(shards, results):
            pname = self.domain.platform_name(p)
            plat_wall[pname] = result.wall_s
            for rec in result.records:
                records.append(rec)
                plat_lat[pname] += rec.latency
            for ev in result.faults:
                fault_events.append(ev)
                plat_lat[pname] += ev.latency
        report = RuntimeReport(
            allocation=allocation,
            predicted_makespan=makespan(allocation.A, problem),
            measured_makespan=max(plat_lat.values(), default=0.0),
            platform_latencies=plat_lat,
            records=records,
            summary=self.domain.summarise(records, problem),
            platform_wall_s=plat_wall,
            wall_s=wall_s,
            mode=self._executor(mode).mode,
            fault_events=fault_events,
        )
        if self.tracer.enabled:
            self._account(report, problem)
        return report

    def _account(self, report: RuntimeReport,
                 problem: AllocationProblem) -> None:
        """Pair this execute's predictions with their measurements in the
        ledger (and bump the process metrics) — only on instrumented runs,
        so the uninstrumented hot path never pays for it."""
        family = {t.task_id: str(self.domain.launch_key(t))
                  for t in self.tasks}
        # the ledger's makespan entry uses the same zero-measured -> inf
        # convention as RuntimeReport.makespan_error
        self.ledger.observe("makespan", "*", "-", -1,
                            report.predicted_makespan,
                            report.measured_makespan)
        lat_hist = obs_metrics.histogram("runtime.record_latency_s")
        for rec in report.records:
            model = self.models.get((rec.platform, rec.task_id))
            if model is not None:
                predicted = self.domain.predicted_latency(
                    model, self.domain.record_units(rec))
                self.ledger.observe("latency", rec.platform,
                                    family.get(rec.task_id, "?"), -1,
                                    predicted, rec.latency)
            lat_hist.observe(rec.latency)
        measured_ci = (report.summary or {}).get("measured_ci")
        if isinstance(measured_ci, dict):
            for j, t in enumerate(self.tasks):
                m = measured_ci.get(t.task_id)
                if m is not None:
                    self.ledger.observe("accuracy", "*",
                                        family.get(t.task_id, "?"), -1,
                                        float(problem.c[j]), float(m))
        obs_metrics.counter("runtime.records").inc(len(report.records))
        obs_metrics.counter("runtime.faults").inc(len(report.fault_events))

    # -- convenience: the whole Fig. 1 flow --------------------------------

    def run(self, quality=None, method: str = "milp", seed: int = 3,
            characterise_kw: dict | None = None, mode: str | None = None,
            **solver_kw) -> RuntimeReport:
        """characterise (if needed) -> allocate -> execute in one call."""
        if self.models is None:
            self.characterise(mode=mode, **(characterise_kw or {}))
        alloc = self.allocate(quality, method=method, **solver_kw)
        return self.execute(alloc, quality, seed=seed, mode=mode)
