"""Domain registry: name -> Domain factory.

Built-in domains are registered lazily by import path so that importing
:mod:`repro.runtime` stays cheap (the LM domain pulls in the model zoo;
the pricing domain pulls in the MC engine).

    from repro.runtime import make_domain
    domain = make_domain("pricing", tasks, platforms)
"""
from __future__ import annotations

import importlib
from typing import Callable

from .domain import Domain

__all__ = ["register_domain", "domain_factory", "make_domain", "available_domains"]

#: name -> "module.path:ClassName" for domains shipped with the repo.
_BUILTIN: dict[str, str] = {
    "pricing": "repro.domains.pricing:PricingDomain",
    "lm_serving": "repro.domains.lm_serving:LMServingDomain",
}

_REGISTRY: dict[str, Callable[..., Domain]] = {}


def register_domain(name: str, factory: Callable[..., Domain]) -> None:
    """Register a domain factory (usually the Domain subclass itself)."""
    _REGISTRY[name] = factory


def domain_factory(name: str) -> Callable[..., Domain]:
    if name in _REGISTRY:
        return _REGISTRY[name]
    path = _BUILTIN.get(name)
    if path is None:
        raise KeyError(
            f"unknown domain {name!r}; available: {sorted(available_domains())}")
    mod_name, _, attr = path.partition(":")
    factory = getattr(importlib.import_module(mod_name), attr)
    _REGISTRY[name] = factory
    return factory


def make_domain(name: str, *args, **kw) -> Domain:
    """Instantiate a registered domain by name."""
    return domain_factory(name)(*args, **kw)


def available_domains() -> list[str]:
    return sorted(set(_BUILTIN) | set(_REGISTRY))
