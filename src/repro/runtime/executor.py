"""Concurrent cross-platform dispatch (the paper's implicit execution model).

The paper's makespan (§3, Figs 8 & 10) is the wall-clock time until the
*last* platform finishes its share — platforms run their shards
simultaneously and the system is judged by the slowest one (same model as
the companion work, arXiv:1408.4965, and Memeti & Pllana's distributed
measurements, arXiv:1606.05134). A sequential per-platform loop therefore
measures the wrong thing: its wall clock is the *sum* of per-platform
latencies, not the max of concurrent ones.

:class:`Executor` is the one primitive the runtime needs to close that
gap: fan a function out over independent per-platform jobs on a thread
pool and time each job with its own wall clock. Host threads are the
right tool here — JAX dispatch is asynchronous (a host thread issuing
work to one platform sleeps in ``block_until_ready`` while another
platform's thread runs), and simulated platforms overlap trivially. A
``mode="sequential"`` escape hatch preserves the legacy serial order for
A/B comparisons; results must be identical in both modes, which is why
characterisation seeds are derived per (platform, launch group, rung)
(:func:`repro.runtime.domain.seed_for`) rather than from dispatch order.
"""
from __future__ import annotations

import dataclasses
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Iterable, TypeVar

__all__ = ["Executor", "TimedResult", "MODES"]

T = TypeVar("T")

#: The two dispatch modes; "concurrent" is the default everywhere.
MODES: tuple[str, ...] = ("concurrent", "sequential")


@dataclasses.dataclass(frozen=True)
class TimedResult:
    """One job's return value plus its own wall-clock time."""

    value: Any
    wall_s: float


class Executor:
    """Maps a function over independent jobs, concurrently or serially.

    Results are always returned in input order and exceptions from any
    job propagate to the caller, so swapping modes never changes
    semantics — only wall-clock overlap.
    """

    def __init__(self, mode: str = "concurrent", max_workers: int | None = None):
        if mode not in MODES:
            raise ValueError(f"unknown executor mode {mode!r}; expected one of {MODES}")
        self.mode = mode
        self.max_workers = max_workers

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"Executor(mode={self.mode!r}, max_workers={self.max_workers})"

    def map_timed(self, fn: Callable[[T], Any], items: Iterable[T]) -> list[TimedResult]:
        """``[fn(item) for item in items]`` with a per-item wall clock.

        Concurrent mode runs every item on its own pool thread; each
        item's ``wall_s`` spans only that item's call, so per-platform
        wall times remain meaningful under overlap.
        """
        jobs = list(items)

        def timed(item: T) -> TimedResult:
            t0 = time.perf_counter()
            value = fn(item)
            return TimedResult(value=value, wall_s=time.perf_counter() - t0)

        if self.mode == "sequential" or len(jobs) <= 1:
            return [timed(item) for item in jobs]
        workers = min(len(jobs),
                      self.max_workers or max(4, (os.cpu_count() or 4) * 2))
        with ThreadPoolExecutor(max_workers=workers,
                                thread_name_prefix="repro-exec") as pool:
            return list(pool.map(timed, jobs))

    def map(self, fn: Callable[[T], Any], items: Iterable[T]) -> list[Any]:
        """Like :meth:`map_timed` but returning bare values."""
        return [r.value for r in self.map_timed(fn, items)]
