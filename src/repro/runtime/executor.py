"""Concurrent cross-platform dispatch (the paper's implicit execution model).

The paper's makespan (§3, Figs 8 & 10) is the wall-clock time until the
*last* platform finishes its share — platforms run their shards
simultaneously and the system is judged by the slowest one (same model as
the companion work, arXiv:1408.4965, and Memeti & Pllana's distributed
measurements, arXiv:1606.05134). A sequential per-platform loop therefore
measures the wrong thing: its wall clock is the *sum* of per-platform
latencies, not the max of concurrent ones.

:class:`Executor` is the one primitive the runtime needs to close that
gap: fan a function out over independent per-platform jobs on a thread
pool and time each job with its own wall clock. Host threads are the
right tool here — JAX dispatch is asynchronous (a host thread issuing
work to one platform sleeps in ``block_until_ready`` while another
platform's thread runs), and simulated platforms overlap trivially. A
``mode="sequential"`` escape hatch preserves the legacy serial order for
A/B comparisons; results must be identical in both modes, which is why
characterisation seeds are derived per (platform, launch group, rung)
(:func:`repro.runtime.domain.seed_for`) rather than from dispatch order.

Failure isolation: one job blowing up must not discard its siblings'
results and wall clocks — the fault-tolerant scheduler needs *every*
per-platform outcome to account a round (a platform that failed mid-round
still ran real work its virtual clock charged for). ``map_timed`` with
``raise_errors=False`` therefore returns a :class:`TimedResult` per job,
carrying either the value or the typed exception; the default
``raise_errors=True`` still raises (after every job has run to
completion) so legacy callers keep their semantics without losing
siblings silently. An optional ``timeout_s`` bounds each job's wall clock
(:class:`~repro.runtime.faults.DispatchTimeout` — a health signal for the
circuit breaker, not a preemption: host threads cannot be killed, so a
blown job's thread is abandoned and its eventual value dropped), and an
optional ``cancel`` event skips jobs that have not started yet
(:class:`~repro.runtime.faults.JobCancelled`) — mid-round cancellation
for a platform whose breaker tripped.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Iterable, TypeVar

from repro.runtime.faults import DispatchTimeout, JobCancelled

__all__ = ["Executor", "TimedResult", "MODES"]

T = TypeVar("T")

#: The two dispatch modes; "concurrent" is the default everywhere.
MODES: tuple[str, ...] = ("concurrent", "sequential")


@dataclasses.dataclass(frozen=True)
class TimedResult:
    """One job's outcome: its return value (or typed error) plus its own
    wall-clock time. Exactly one of ``value`` / ``error`` is meaningful."""

    value: Any
    wall_s: float
    error: BaseException | None = None

    @property
    def ok(self) -> bool:
        return self.error is None


class Executor:
    """Maps a function over independent jobs, concurrently or serially.

    Results are always returned in input order, so swapping modes never
    changes semantics — only wall-clock overlap. Exceptions propagate by
    default (``raise_errors=True``, after all jobs have run) or come back
    as per-job :class:`TimedResult` errors (``raise_errors=False``).
    """

    def __init__(self, mode: str = "concurrent", max_workers: int | None = None,
                 tracer=None):
        if mode not in MODES:
            raise ValueError(f"unknown executor mode {mode!r}; expected one of {MODES}")
        self.mode = mode
        self.max_workers = max_workers
        #: optional :class:`repro.obs.Tracer`; when enabled, ``map_timed``
        #: callers may open one span per job via ``span_of``. Spans live on
        #: per-thread stacks, so nested spans opened inside the job body
        #: land under the job span even on pool threads.
        self.tracer = tracer

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"Executor(mode={self.mode!r}, max_workers={self.max_workers})"

    def map_timed(self, fn: Callable[[T], Any], items: Iterable[T], *,
                  raise_errors: bool = True,
                  timeout_s: float | None = None,
                  cancel: threading.Event | None = None,
                  span_of: Callable[[T], tuple[str, str]] | None = None
                  ) -> list[TimedResult]:
        """``[fn(item) for item in items]`` with a per-item wall clock.

        Concurrent mode runs every item on its own pool thread; each
        item's ``wall_s`` spans only that item's call, so per-platform
        wall times remain meaningful under overlap.

        Every job runs to an outcome — a failed job never discards its
        siblings' results. With ``raise_errors=True`` (default) the first
        failing job's exception (in *input* order, for mode parity) is
        re-raised once all jobs have finished; with ``raise_errors=False``
        failures come back in-band as ``TimedResult.error``.

        ``timeout_s`` bounds each job's wall clock: a blown job yields a
        :class:`DispatchTimeout` error (concurrent mode abandons the
        still-running thread; sequential mode marks the overrun post hoc —
        a single host thread cannot be preempted). ``cancel``, when set,
        makes jobs that have not started yet yield :class:`JobCancelled`
        instead of running.

        ``span_of`` maps an item to a ``(span name, track)`` pair; when the
        executor carries an enabled tracer, each job's run is wrapped in
        that span on its executing thread, so per-platform work shows up
        as overlapping tracks in the exported trace.
        """
        jobs = list(items)
        tracer = self.tracer
        if tracer is not None and span_of is not None \
                and getattr(tracer, "enabled", False):
            inner = fn

            def fn(item: T) -> Any:  # noqa: F811 - traced wrapper
                name, track = span_of(item)
                with tracer.span(name, track=track, cat="executor"):
                    return inner(item)

        def timed(item: T) -> TimedResult:
            if cancel is not None and cancel.is_set():
                return TimedResult(value=None, wall_s=0.0,
                                   error=JobCancelled("batch cancelled"))
            t0 = time.perf_counter()
            try:
                value = fn(item)
            except BaseException as exc:
                return TimedResult(value=None,
                                   wall_s=time.perf_counter() - t0, error=exc)
            wall = time.perf_counter() - t0
            if timeout_s is not None and wall > timeout_s:
                return TimedResult(
                    value=None, wall_s=wall,
                    error=DispatchTimeout(
                        f"job exceeded {timeout_s:.3f}s (took {wall:.3f}s)"))
            return TimedResult(value=value, wall_s=wall)

        if self.mode == "sequential" or len(jobs) <= 1:
            out = [timed(item) for item in jobs]
        else:
            out = self._map_concurrent(timed, jobs, timeout_s)
        if raise_errors:
            for r in out:
                if r.error is not None:
                    raise r.error
        return out

    def _map_concurrent(self, timed: Callable[[T], TimedResult],
                        jobs: list[T],
                        timeout_s: float | None) -> list[TimedResult]:
        workers = min(len(jobs),
                      self.max_workers or max(4, (os.cpu_count() or 4) * 2))
        pool = ThreadPoolExecutor(max_workers=workers,
                                  thread_name_prefix="repro-exec")
        try:
            futures: list[Future] = [pool.submit(timed, item) for item in jobs]
            if timeout_s is None:
                return [f.result() for f in futures]
            # Shared deadline: jobs run concurrently, so each is granted the
            # full timeout from submission; stragglers past it are abandoned
            # (their threads finish in the background, results dropped).
            deadline = time.monotonic() + timeout_s + 0.25
            pending = set(futures)
            while pending and time.monotonic() < deadline:
                done, pending = wait(pending, timeout=deadline - time.monotonic(),
                                     return_when=FIRST_COMPLETED)
            out = []
            for f in futures:
                if f in pending:
                    out.append(TimedResult(
                        value=None, wall_s=timeout_s,
                        error=DispatchTimeout(
                            f"job still running after {timeout_s:.3f}s")))
                else:
                    out.append(f.result())
            return out
        finally:
            # cancel_futures drops queued-but-unstarted jobs when a timeout
            # abandoned the batch; harmless when everything completed.
            pool.shutdown(wait=timeout_s is None, cancel_futures=True)

    def map(self, fn: Callable[[T], Any], items: Iterable[T]) -> list[Any]:
        """Like :meth:`map_timed` but returning bare values."""
        return [r.value for r in self.map_timed(fn, items)]
