"""The ``Domain`` protocol — what a workload must provide to be allocated.

The paper's workflow (characterise -> allocate -> execute, Fig. 1) is not
specific to derivatives pricing: any domain whose tasks are *divisible*
(eq. 5) and whose run-time behaviour on a platform follows small parametric
metric models (§3.1) can ride the same back-end. The companion work
(arXiv:1408.4965) frames exactly this split: domain front-ends supply
metric models and an execution hook; a shared runtime owns benchmarking,
the allocation program and the evaluation loop.

A concrete domain subclasses :class:`Domain` and provides

* a task container (anything with a ``task_id``) and a platform list
  (anything with a ``spec.name``),
* ``characterise_batch`` — online benchmarking of a launch group on one
  platform, returning one record list ("rung") per benchmark point,
* ``fit_models`` — the per-metric model fitters, turning one task's rung
  records into a model object exposing ``.combined`` (delta, gamma),
* ``work_units`` — the quality -> work inversion (paths for a CI, tokens
  for a generation length) used when shares are turned into launches,
* ``dispatch_batch`` — the execution hook, and
* ``reduction`` — the quality -> work-matrix map consumed by the solvers
  (inverse-square for MC estimators, linear for throughput domains).

Everything else — grouping, model matrices, the allocation program, solver
selection, the execute/report loop — lives in :class:`repro.runtime.Scheduler`
and is shared verbatim by every domain.
"""
from __future__ import annotations

import abc
import dataclasses
import math
import zlib
from typing import Any, Hashable, Protocol, Sequence

import numpy as np

from repro.core.allocation import mc_work_reduction
from .executor import Executor
from .faults import DispatchFault

__all__ = ["Domain", "MeshPlatformSpec", "PlatformSpec", "RunRecordLike",
           "seed_for"]


def seed_for(base_seed: int, platform_name: str, launch_key: Hashable,
             rung: int) -> int:
    """Deterministic benchmark seed for one (platform, launch group, rung).

    A stable hash (CRC32 — unlike ``hash()``, not randomised per process
    by PYTHONHASHSEED) of the identifying coordinates, so every record of
    a characterisation run is a pure function of *what* is being measured,
    never of dispatch order. This is what makes concurrent and sequential
    ladder climbs bitwise-identical regardless of thread interleaving —
    and replaces positional ``seed + i`` derivations, under which records
    depended on where in the loop a rung happened to sit.
    """
    key = f"{base_seed}|{platform_name}|{launch_key!r}|{rung}"
    return zlib.crc32(key.encode()) & 0x7FFFFFFF


@dataclasses.dataclass(frozen=True)
class PlatformSpec:
    """Static description of one execution platform (paper Table 2 row).

    ``gflops``/``rtt_ms`` are the two published characteristics the paper
    says determine beta and gamma respectively (§5.1.2); simulated
    platforms of any domain replay their latency model from them.
    ``mem_bytes`` is the device-memory budget backing the optional
    resource-capacity dimension (KV-cache bytes for LM serving); the
    default inf keeps platforms of capacity-free domains unconstrained.
    """

    name: str
    category: str        # CPU | GPU | FPGA
    device: str
    location: str
    gflops: float        # application performance (per device)
    rtt_ms: float        # network round-trip time
    mem_bytes: float = math.inf

    # Mesh-trivial view: a bare spec is a 1x1 mesh, so every consumer of
    # the effective characteristics (simulators, capacity hooks, latency
    # fitters) reads these uniformly and never branches on the subclass.

    @property
    def mesh_shape(self) -> tuple[int, int]:
        """(data, model) mesh axes; a single device is (1, 1)."""
        return (1, 1)

    @property
    def n_devices(self) -> int:
        return self.mesh_shape[0] * self.mesh_shape[1]

    @property
    def model_parallel(self) -> int:
        return self.mesh_shape[1]

    @property
    def effective_gflops(self) -> float:
        """Aggregate throughput feeding eq. 7's beta (1/gflops slope)."""
        return self.gflops

    @property
    def effective_rtt_ms(self) -> float:
        """Per-dispatch constant feeding eq. 7's gamma."""
        return self.rtt_ms

    @property
    def total_mem_bytes(self) -> float:
        """Resource budget pooled across the whole platform."""
        return self.mem_bytes


@dataclasses.dataclass(frozen=True)
class MeshPlatformSpec(PlatformSpec):
    """A platform that is a *mesh* of identical devices, not one device.

    The allocator sees one row per (device kind x mesh shape): eq. 7's
    beta falls with tensor-parallel width — discounted by
    ``tp_efficiency``, since collectives and unshardable residue keep the
    speedup sublinear — while gamma picks up a per-hop collective cost on
    top of the network RTT. Memory (the KV capacity dimension) pools
    across every device in the mesh. ``gflops``/``rtt_ms``/``mem_bytes``
    stay *per-device* numbers so the same device kind can be quoted at
    several shapes from one datasheet row.
    """

    #: (data, model) axis sizes; model = tensor-parallel width.
    mesh_shape: tuple[int, int] = (1, 1)
    #: fraction of linear speedup each added model-parallel device yields.
    tp_efficiency: float = 0.85
    #: per-decode-step collective cost per model-parallel hop (ms).
    collective_ms: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "mesh_shape",
                           tuple(int(v) for v in self.mesh_shape))
        d, m = self.mesh_shape
        if d < 1 or m < 1:
            raise ValueError(f"mesh_shape must be >= (1, 1), got {self.mesh_shape}")
        if not 0.0 <= self.tp_efficiency <= 1.0:
            raise ValueError(f"tp_efficiency must be in [0, 1], got "
                             f"{self.tp_efficiency}")

    @property
    def tp_speedup(self) -> float:
        """Sublinear tensor-parallel throughput multiplier."""
        return 1.0 + self.tp_efficiency * (self.model_parallel - 1)

    @property
    def effective_gflops(self) -> float:
        return self.gflops * self.tp_speedup

    @property
    def effective_rtt_ms(self) -> float:
        return self.rtt_ms + self.collective_ms * (self.model_parallel - 1)

    @property
    def total_mem_bytes(self) -> float:
        return self.mem_bytes * self.n_devices


class RunRecordLike(Protocol):
    """What the scheduler needs from an execution record.

    Domains may carry extra fields (price, CI, token counts, ...) for
    their own ``fit_models``/``summarise`` hooks.
    """

    platform: str
    task_id: int
    latency: float


class Domain(abc.ABC):
    """Base class for metric-modelled domains; see module docstring."""

    #: registry name; subclasses override.
    name: str = "domain"
    #: quality -> work-matrix map handed to AllocationProblem.
    reduction = staticmethod(mc_work_reduction)
    #: smallest dispatchable work amount (paths, tokens, ...).
    min_chunk: int = 1

    def __init__(self, tasks: Sequence[Any], platforms: Sequence[Any]):
        self.tasks = list(tasks)
        self.platforms = list(platforms)

    # -- identity ----------------------------------------------------------

    def platform_name(self, platform) -> str:
        return platform.spec.name

    def launch_key(self, task) -> Hashable:
        """Compilation/launch grouping key; one group = one batched launch.

        Default: every task in its own group (no batching)."""
        return task.task_id

    def group_tasks(self, tasks: Sequence[Any]) -> list[tuple[Hashable, list[Any]]]:
        groups: dict[Hashable, list[Any]] = {}
        for t in tasks:
            groups.setdefault(self.launch_key(t), []).append(t)
        return list(groups.items())

    def default_quality(self) -> np.ndarray | None:
        """Per-task quality vector when the caller passes none.

        Domains whose tasks carry an intrinsic quality target (e.g. an LM
        request's generation length) override this; returning None makes
        the quality argument mandatory."""
        return None

    # -- characterisation (paper §3.1.4) -----------------------------------

    @abc.abstractmethod
    def characterise_batch(self, platform, tasks: Sequence[Any],
                           seed: int = 1, **kw) -> list[list[RunRecordLike]]:
        """Benchmark one launch group on one platform.

        Returns one record list per benchmark rung, each aligned with
        ``tasks``."""

    @abc.abstractmethod
    def fit_models(self, records: Sequence[RunRecordLike]):
        """Fit this domain's metric models from one task's rung records."""

    def characterise(self, seed: int = 1, executor: Executor | None = None,
                     tasks: Sequence[Any] | None = None,
                     platforms: Sequence[Any] | None = None,
                     record_sink: dict | None = None,
                     skip_unavailable: bool = False,
                     **kw) -> dict[tuple[str, int], Any]:
        """Benchmark every (platform, task) pair and fit its models.

        The generic pipeline: group tasks by launch key, then climb the
        ladders as one job *per platform* — concurrently when the executor
        says so, since ladders on distinct platforms share no state. A
        platform's launch groups climb serially inside their job (they
        contend for the same device; overlapping them would corrupt the
        wall-clock latencies the models are fitted from — the same
        granularity execute uses). Seeds must derive from each rung's
        coordinates (see :func:`seed_for`), never from loop position, so
        both modes produce identical records.

        ``tasks`` / ``platforms`` restrict the sweep to subsets (incremental
        characterisation of tasks arriving mid-workload, skipping platforms
        known to be down); ``record_sink`` collects the raw benchmark
        records per (platform, task_id) — the online loop seeds its re-fit
        windows from them, and they are the characterise half of the JSONL
        record persistence. Concurrent platform jobs write disjoint keys,
        so a plain dict is safe.

        ``skip_unavailable`` makes a platform raising a
        :class:`~repro.runtime.faults.DispatchFault` (outage or transient
        blip) mid-benchmark contribute only the pairs it completed instead
        of failing the whole sweep — mid-run incremental characterisation
        is inherently fault-exposed; the caller fills the gaps."""
        groups = self.group_tasks(self.tasks if tasks is None else list(tasks))
        sweep = self.platforms if platforms is None else list(platforms)

        def climb(p) -> dict[tuple[str, int], Any]:
            fitted: dict[tuple[str, int], Any] = {}
            try:
                for _key, gtasks in groups:
                    rungs = self.characterise_batch(p, gtasks, seed=seed, **kw)
                    for k, t in enumerate(gtasks):
                        key = (self.platform_name(p), t.task_id)
                        recs = [rung[k] for rung in rungs]
                        fitted[key] = self.fit_models(recs)
                        if record_sink is not None:
                            record_sink[key] = recs
            except DispatchFault:
                if not skip_unavailable:
                    raise
            return fitted

        out: dict[tuple[str, int], Any] = {}
        for fitted in (executor or Executor(mode="sequential")).map(
                climb, sweep):
            out.update(fitted)  # job order == legacy platform-major order
        return out

    def model_coefficients(self, model) -> tuple[float, float]:
        """(delta, gamma) entries for the allocation matrices."""
        combined = model.combined
        return float(combined.delta), float(combined.gamma)

    def predicted_latency(self, model, units: float) -> float:
        """The latency the fitted model predicts for a shard of ``units``
        work — the reference the online drift detector compares measured
        latencies against. Default: the eq. 7 latency model every shipped
        domain carries as ``model.latency``."""
        return float(model.latency(units))

    def latency_params(self, model) -> tuple[float, float]:
        """(beta, gamma) of the model's latency component — the online
        tranche planner uses them to floor shard sizes so per-dispatch
        constants do not swamp high-RTT platforms under round-based
        dispatch."""
        return float(model.latency.beta), float(model.latency.gamma)

    # -- fault tolerance ----------------------------------------------------

    def degrade_quality(self, quality: float, step: float) -> float:
        """Relax one task's quality target by ``step`` along this domain's
        accuracy-for-latency trade-off (the paper's central asset): a CI
        domain loosens the target, a throughput domain shortens it. The
        online loop's graceful degradation walks its rung ladder through
        this hook when the surviving fleet cannot meet the original
        targets. ``step`` is cumulative from the *base* quality (rung 2 of
        ladder (0.25, 0.5) passes 0.5, not 0.25 twice). Default: no
        trade-off to exploit — the quality stands."""
        return quality

    def advance_platform(self, platform, elapsed: float) -> None:
        """Sync an *idle* platform's virtual clock to the workload's
        elapsed time. A platform sitting out rounds behind an open circuit
        breaker does not execute, but wall time still passes for it — on
        simulated platforms the virtual clock only advances with work, so
        without this sync a finite outage window would never end for a
        platform receiving only cheap probes. No-op for platforms with no
        virtual clock (real hardware lives on the host clock)."""
        clock = getattr(platform, "clock", None)
        if clock is not None:
            platform.clock = max(clock, elapsed)

    # -- SLO / overload control (optional) ---------------------------------

    def record_ttft(self, record: RunRecordLike, end_t: float) -> float:
        """Virtual time at which a record's *first output* became visible,
        given the virtual time ``end_t`` at which the record finished.

        Tail-latency accounting (TTFT percentiles) asks when a task first
        produced output, which for atomic records is simply when they
        finished. Domains whose records distinguish an in-record first
        response (LM serving's prefill + queueing delay inside a
        continuous batch) override this."""
        return end_t

    def task_quality(self, task) -> float:
        """Admission-time work proxy for one task — its intrinsic quality
        target in work units (tokens for LM serving), used to price a
        not-yet-characterised arrival against the admission queue budget.
        Default 1.0: every task costs one unit until characterised."""
        return 1.0

    # -- capacity (optional second constraint dimension) -------------------

    def resource_per_unit(self, platform, task) -> float:
        """Resource units one unit of this task's work holds on the
        platform while the task is being served (e.g. KV-cache bytes per
        decoded token for LM serving). The scheduler multiplies this by
        the task's total work units to build ``AllocationProblem.resource``;
        the default 0 keeps the capacity dimension inert."""
        return 0.0

    def platform_capacity(self, platform) -> float:
        """The platform's resource budget (e.g. HBM bytes); paired with
        :meth:`resource_per_unit`. inf means unconstrained."""
        return math.inf

    def record_units(self, record: RunRecordLike) -> int:
        """Work units one execution record accounts for (remaining-work
        accounting in the online loop). Default scans the common unit
        field names; domains with other record shapes override."""
        for attr in ("n_paths", "n_tokens", "units"):
            value = getattr(record, attr, None)
            if value is not None:
                return int(value)
        raise AttributeError(
            f"{type(record).__name__} carries no recognised work-unit field; "
            f"override {type(self).__name__}.record_units")

    # -- execution ---------------------------------------------------------

    @abc.abstractmethod
    def work_units(self, model, quality: float) -> float:
        """Total work units task needs at ``quality`` (eq. 8 inverted for
        MC; identity for domains measuring quality in work units)."""

    @abc.abstractmethod
    def dispatch_batch(self, platform, tasks: Sequence[Any],
                       units: Sequence[int], seed: int = 0) -> list[RunRecordLike]:
        """Execute a (task, units) shard list on a platform."""

    def summarise(self, records: Sequence[RunRecordLike], problem) -> dict:
        """Domain-specific result pooling (estimates, achieved quality...)."""
        return {}
