"""Online re-allocation: the drift-aware feedback loop (closing Fig. 1).

The paper's workflow is one-shot — characterise once, solve once, execute
once — yet its own premise (§2) is that metric models are *populated at
run time* and that execute-time records are the very shape
characterisation consumes. The companion work (arXiv:1408.4965) frames the
runtime as a continuously accessible service, and Memeti & Pllana
(arXiv:1606.05134) measure re-optimising the work distribution mid-run
paying off when system behaviour shifts. :class:`OnlineScheduler` closes
that loop:

    dispatch a tranche ──▶ records ──▶ fold into model windows
         ▲                                   │
         │                         drift? outage? arrivals?
         │                                   │ yes
    re-solve remaining work ◀── re-fit ◀─────┘
    (incumbent warm start)

Each round dispatches a tranche of the remaining work according to the
current allocation (via :meth:`Scheduler.dispatch_plan`), folds the
records back into per-(platform, task) windows, and watches a rolling
predicted-vs-measured latency ratio per platform (:class:`DriftDetector`).
Only when drift fires — or a platform dies (repeated dispatch failures),
or tasks arrive — are the models re-fitted (``Domain.fit_models`` over the
accumulated windows) and the allocation re-solved **for the remaining work
only** (:func:`repro.core.restrict_problem`: surviving platforms, active
tasks, work scaled by remaining fraction), with the executing allocation
as warm-start incumbent so a re-solve that cannot improve matters is
skipped (:func:`repro.core.heuristic.incumbent_shortcut`). An unperturbed
run therefore solves exactly once.

Round tranche sizes are *staggered* (alternating weights) so the
execute-time records of any pair span distinct unit counts — what keeps
the (beta, gamma) re-fit full-rank from tranche records alone — and are
floored per (platform, task) so a high-RTT platform is not billed its
constant every round for a sliver of work.

Determinism: tranche seeds derive from (platform, launch key, round) via
:func:`repro.runtime.domain.seed_for`, rounds are barriers, and each
platform's work is serial inside its dispatch job, so concurrent and
sequential online runs produce bitwise-identical records — drift, outages
and all.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Any, Hashable

import numpy as np

from repro.core import (
    Allocation,
    CapacityError,
    SUPPORT_ATOL,
    expand_allocation,
    patch_allocation,
    restrict_allocation,
    restrict_problem,
)
from repro.core.metrics import AccuracyModel, CombinedModel, LatencyModel
from repro.core.slo import SLOConfig, SLOTracker, quantile
from repro.obs import lift_solver_phases, metrics as obs_metrics
from .admission import (
    AdmissionConfig,
    AdmissionController,
    BrownoutTransition,
    predicted_unit_rates,
)
from .domain import RunRecordLike, seed_for
from .faults import (
    HALF_OPEN,
    CircuitBreaker,
    DegradationEvent,
    DispatchFault,
    FaultEvent,
    RetryPolicy,
    check_records,
    count_retries,
    fault_kind,
)
from .scenario import PlatformOutage, Scenario
from .scheduler import SOLVERS, Scheduler

__all__ = ["OnlineScheduler", "OnlineConfig", "OnlineReport", "DriftDetector",
           "TailDriftDetector", "RoundLog"]


@dataclasses.dataclass(frozen=True)
class OnlineConfig:
    """Knobs of the feedback loop; defaults suit the shipped simulators."""

    #: target number of dispatch tranches for a plan (late rounds flush
    #: whatever remains, so a run takes ~rounds rounds absent failures).
    rounds: int = 8
    #: hard stop — a safety net against pathological non-progress.
    max_rounds: int = 64
    #: |median measured/predicted - 1| per platform that fires drift.
    drift_threshold: float = 0.5
    #: records per platform in the rolling drift window. Small on purpose:
    #: the median flips only once half the window sits in the new regime,
    #: so detection latency is ~window/2 records on the drifting platform.
    drift_window: int = 6
    #: observations required before a platform can fire.
    min_drift_records: int = 3
    #: consecutive failed rounds before a platform is declared dead.
    outage_failures: int = 2
    #: warm-start skip tolerance forwarded to the solvers on re-solves.
    warm_tol: float = 0.05
    #: records kept per (platform, task) re-fit window (characterise rungs
    #: seed it; execute records push the stalest out).
    refit_window: int = 32
    #: alternating tranche weights — distinct per-round unit counts keep
    #: the re-fit full-rank from execute records alone.
    stagger: tuple[float, ...] = (1.25, 0.75)
    #: per-dispatch work floor, in multiples of the pair's gamma constant:
    #: a shard is grown until beta*units >= gamma_duty*gamma, consolidating
    #: a high-RTT platform's share of a task into few large dispatches —
    #: round-based dispatch pays gamma per visit, and without the floor a
    #: platform like AWS EC1 (89 ms RTT) would be billed it every round.
    #: 16 caps the constant at ~6% of each dispatch's work.
    gamma_duty: float = 16.0
    #: retry policy arming the per-dispatch fault layer (transient blips
    #: and corrupt results re-dispatched with deterministic backoff — see
    #: :class:`repro.runtime.faults.RetryPolicy`). None leaves faults
    #: unhandled: a transient fault then fails the round like an outage.
    retry: RetryPolicy | None = None
    #: circuit-breaker cooldown, in *workload elapsed virtual time*: an
    #: OPEN (dead) platform goes HALF_OPEN after this long and is probed
    #: with a cheap seeded dispatch; success re-admits it to allocation.
    #: The default inf reproduces the legacy one-way dead set (platforms
    #: never come back).
    breaker_cooldown: float = math.inf
    #: graceful-degradation rung ladder: cumulative relaxation steps fed
    #: to ``Domain.degrade_quality`` when a re-solve is infeasible
    #: (CapacityError) or blows ``deadline_s``. Empty = degradation off:
    #: an infeasible re-solve propagates.
    degrade_steps: tuple[float, ...] = ()
    #: predicted-finish deadline (virtual seconds) that triggers quality
    #: degradation when the surviving fleet cannot meet it. None = no
    #: deadline pressure; CapacityError still triggers the ladder.
    deadline_s: float | None = None
    #: arrivals-only re-solves (no drift, no deaths, no revivals) take the
    #: O(k) incremental path: only the k new columns are solved against
    #: the fleet's committed shares (:func:`repro.core.patch_allocation`),
    #: and the model re-fit is skipped — nothing about the old tasks'
    #: evidence changed. False restores the full re-solve on every arrival.
    patch_arrivals: bool = True
    #: patched-makespan tolerance vs the fresh full-problem heuristic bound
    #: before the patch is discarded for a full re-solve.
    patch_tol: float = 0.25
    #: open-loop serving mode: rounds are time barriers on a shared fleet
    #: clock (idle platforms advance to each round's start), the per-round
    #: tranche fraction is 1 (arrivals drive the pacing, not stagger), an
    #: idle fleet fast-forwards to the trace's next arrival instead of
    #: force-draining it, and exhausting ``max_rounds`` truncates the trace
    #: rather than raising — open-loop load has no drain-to-empty contract.
    open_loop: bool = False
    #: bounded admission control (queue sizing, backpressure, shedding);
    #: None admits every arrival unconditionally — the legacy behaviour,
    #: and the "guardrail off" control leg of the overload A/B.
    admission: AdmissionConfig | None = None
    #: SLO tail tracking (TTFT/TPOT/e2e percentiles per completed task);
    #: with ``degrade_steps`` set it also arms the brownout ladder, which
    #: walks quality down a rung when the recent guardrail quantile
    #: breaches the SLO and restores it when pressure clears.
    slo: SLOConfig | None = None
    #: tail-ratio drift threshold: |p-quantile(measured/predicted) - 1| per
    #: platform that fires a re-solve even when the median is quiet.
    #: None disables the tail detector (median-only, the legacy detector).
    tail_threshold: float | None = None
    #: records per platform in the tail detector's rolling window (larger
    #: than the median's — a p99 of 6 records is meaningless).
    tail_window: int = 12
    #: which tail the tail detector watches.
    tail_quantile: float = 0.99
    #: observations required before the tail detector can fire.
    min_tail_records: int = 6
    #: adopt fitted models for arrivals whose launch key matches an
    #: already-characterised task (see :meth:`Scheduler.adopt_models`)
    #: instead of re-benchmarking every arrival — the only admission cost
    #: that scales to trace-driven load. Off by default: adoption skips
    #: the arrival's own characterise records, which changes record
    #: streams for closed-loop runs that assert on them.
    adopt_family_models: bool = False


#: effectively-infinite per-unit latency, but small enough that the MILP's
#: constraint matrix stays numerically sane — 1e30-scale coefficients make
#: HiGHS declare the model infeasible, silently degrading every re-solve
#: to the heuristic fallback. 1e9 seconds/unit is ~9 orders above any real
#: coefficient here while staying comfortably inside solver tolerances.
_UNREACHABLE = 1e9


class _UnreachableModel:
    """Model placeholder for (dead platform, task) pairs.

    Tasks arriving after a platform dies cannot be benchmarked there, yet
    the scheduler's model matrices are total over platforms x tasks. This
    placeholder keeps them total while guaranteeing no solver would ever
    place work on the pair (and the online loop's restricted sub-problems
    drop the dead rows before solving anyway). The accuracy model says the
    pair needs ~no work so it never drives a task's remaining-work
    fraction; its huge delta keeps any share away regardless."""

    combined = CombinedModel(delta=_UNREACHABLE, gamma=0.0)
    latency = LatencyModel(beta=_UNREACHABLE, gamma=0.0)
    accuracy = AccuracyModel(alpha=1e-300)


class _RatioWindow:
    """Rolling predicted-vs-measured latency ratios per platform.

    Every executed record contributes ``measured / predicted`` under the
    models the *current allocation was solved with* (re-fitting must not
    wash out the signal it is meant to raise); a platform drifts when a
    subclass's summary statistic over the rolling window strays from 1 by
    more than the threshold.  An empty window reads as ratio 1.0 (zero
    error): no evidence is not evidence of drift.
    """

    def __init__(self, window: int, threshold: float, min_records: int):
        self.window = window
        self.threshold = threshold
        self.min_records = min_records
        self._ratios: dict[str, deque[float]] = {}

    def observe(self, platform: str, predicted: float, measured: float) -> None:
        self._ratios.setdefault(platform, deque(maxlen=self.window)).append(
            measured / max(predicted, 1e-12))

    def _statistic(self, ratios: list[float]) -> float:
        raise NotImplementedError

    def ratio(self, platform: str) -> float:
        """The window's summary ratio; 1.0 on an empty window."""
        rs = self._ratios.get(platform)
        return self._statistic(list(rs)) if rs else 1.0

    def error(self, platform: str) -> float:
        """|summary ratio - 1|: the rolling relative latency error."""
        if not self._ratios.get(platform):
            return 0.0
        return abs(self.ratio(platform) - 1.0)

    def drifted(self, alive: dict[str, bool] | None = None) -> tuple[str, ...]:
        fired = []
        for pn, rs in self._ratios.items():
            if alive is not None and not alive.get(pn, True):
                continue
            if len(rs) >= self.min_records and self.error(pn) > self.threshold:
                fired.append(pn)
        return tuple(sorted(fired))

    def reset(self) -> None:
        self._ratios.clear()


class DriftDetector(_RatioWindow):
    """Median-gated drift detector (the re-solve trigger since PR 4).

    The median — not the mean — gates the decision deliberately: a lone
    straggler record cannot trigger a re-solve, and by the time the median
    moves, the majority of the window sits in the new regime, so the
    median ratio doubles as an immediately usable drift-correction factor
    for stale window records at re-fit time (a mean-gated detector fires
    earlier but with a correction factor of ~1, wasting the re-solve).
    """

    def __init__(self, window: int = 8, threshold: float = 0.5,
                 min_records: int = 3):
        super().__init__(window, threshold, min_records)

    def _statistic(self, ratios: list[float]) -> float:
        return float(np.median(ratios))

    def median_ratio(self, platform: str) -> float:
        return self.ratio(platform)


class TailDriftDetector(_RatioWindow):
    """Tail-quantile companion to :class:`DriftDetector`.

    Watches the p-quantile (default p99) of the same per-platform ratio
    window: a platform whose *tail* latencies blow up — contention,
    stragglers, queueing — while the median stays quiet breaches the SLO
    long before the median detector notices.  Needs a larger window and a
    looser threshold than the median (a p99 over six records is noise).
    """

    def __init__(self, window: int = 12, threshold: float = 1.0,
                 min_records: int = 6, q: float = 0.99):
        super().__init__(window, threshold, min_records)
        self.q = q

    def _statistic(self, ratios: list[float]) -> float:
        return float(quantile(ratios, self.q))

    def tail_ratio(self, platform: str) -> float:
        return self.ratio(platform)


@dataclasses.dataclass(frozen=True)
class RoundLog:
    """What one feedback round did (the report's audit trail)."""

    round: int
    dispatched_units: dict[str, int]
    drifted: tuple[str, ...]
    failed: tuple[str, ...]
    arrivals: int
    resolved: bool
    #: "solved" | "skipped" (warm-start early exit) | "patched" (O(k)
    #: incremental arrival patch) | "patch-fallback" (patch discarded for a
    #: full solve) | None (no re-solve).
    solve_outcome: str | None
    #: platforms whose breaker probe succeeded this round (re-admitted).
    revived: tuple[str, ...] = ()
    #: platforms whose tail (p99) ratio fired this round (overload drift).
    tail_drifted: tuple[str, ...] = ()
    #: arrivals offered to admission control this round (== arrivals when
    #: admission is off).
    offered: int = 0
    #: arrivals shed this round (queue-full / capacity / timeout).
    shed: int = 0
    #: admission-queue depth at the end of the round.
    queue_depth: int = 0
    #: outstanding dispatch quota units at the end of the round — the
    #: quantity whose boundedness (vs monotone growth) is the overload
    #: acceptance criterion.
    backlog_units: float = 0.0
    #: brownout ladder rung in force at the end of the round.
    brownout_rung: int = 0
    #: tasks that completed (all quotas drained) this round.
    completions: int = 0
    #: fleet-clock time (max platform timeline) at the end of the round.
    t: float = 0.0
    #: min over alive platforms of remaining KV capacity (bytes) at the
    #: admission barrier — negative would mean the fleet oversubscribed.
    #: inf when admission control is off (no audit is computed).
    kv_headroom: float = math.inf


@dataclasses.dataclass
class OnlineReport:
    """Outcome of an online run: final state plus the adaptation history."""

    allocation: Allocation
    predicted_makespan: float       # the initial solve's prediction
    measured_makespan: float        # max over platforms of summed latency
    platform_latencies: dict[str, float]
    records: list[RunRecordLike]
    summary: dict = dataclasses.field(default_factory=dict)
    rounds: list[RoundLog] = dataclasses.field(default_factory=list)
    n_solves: int = 0               # total solves (initial + re-solves)
    n_resolves: int = 0             # re-solves that actually ran a solver
    n_skipped: int = 0              # re-solves short-circuited by warm start
    n_refits: int = 0               # model re-fit passes
    solve_wall_s: float = 0.0       # wall time inside solvers, initial incl.
    resolve_wall_s: float = 0.0     # wall time of mid-run re-solves only
    dead_platforms: tuple[str, ...] = ()
    arrivals: int = 0
    platform_wall_s: dict[str, float] = dataclasses.field(default_factory=dict)
    wall_s: float = 0.0
    mode: str = "sequential"
    #: fault-layer audit trails (see repro.runtime.faults)
    fault_events: list = dataclasses.field(default_factory=list)
    degradations: list = dataclasses.field(default_factory=list)
    breaker_transitions: list = dataclasses.field(default_factory=list)
    n_retries: int = 0              # retried dispatch attempts, all rounds
    n_probes: int = 0               # breaker recovery probes dispatched
    recovered_platforms: tuple[str, ...] = ()  # died then re-admitted
    n_patched: int = 0              # arrivals absorbed by the O(k) patch
    #: solver telemetry per solve that ran (initial + re-solves + patches):
    #: build_s/solve_s phases, n_vars/n_constraints, incremental outcome.
    solve_metas: list = dataclasses.field(default_factory=list)
    #: overload-control audit trails (see repro.runtime.admission)
    shed_events: list = dataclasses.field(default_factory=list)
    brownout_transitions: list = dataclasses.field(default_factory=list)
    n_offered: int = 0              # arrivals offered to admission control
    n_shed: int = 0                 # arrivals shed (all reasons)
    brownout_rung: int = 0          # final brownout rung
    #: rounds spent at each brownout rung (rung -> round count).
    brownout_occupancy: dict = dataclasses.field(default_factory=dict)
    #: SLOTracker.snapshot() when config.slo is set, else None: lifetime
    #: p50/p95/p99 of TTFT/TPOT/e2e over completed tasks + attainment.
    slo: dict | None = None
    #: per-completed-task latency metrics: tid -> {ttft, tpot, e2e, units}.
    task_metrics: dict = dataclasses.field(default_factory=dict)

    @property
    def shed_fraction(self) -> float:
        """Fraction of offered arrivals shed (0.0 when nothing offered)."""
        return self.n_shed / self.n_offered if self.n_offered else 0.0

    @property
    def makespan_error(self) -> float:
        """Initial-model prediction error — under drift this is exactly the
        gap adaptation closes for the *allocation*, not the forecast."""
        if self.measured_makespan == 0:
            return math.inf
        return abs(self.predicted_makespan - self.measured_makespan) / self.measured_makespan


class OnlineScheduler:
    """Executes a workload in drift-aware rounds over a :class:`Scheduler`.

        online = OnlineScheduler(Scheduler(make_domain("pricing", tasks,
                                                       platforms)))
        report = online.run(quality=0.05, method="milp")

    Platform perturbations (slowdowns, outages) live on the simulated
    platforms via ``attach_scenario``; pass the same :class:`Scenario`
    to :meth:`run` only so queued *arrivals* can join the workload.
    """

    def __init__(self, scheduler: Scheduler, config: OnlineConfig | None = None):
        self.scheduler = scheduler
        self.config = config or OnlineConfig()
        # per-pair work totals memo for _solve, keyed on (models_version,
        # task count, surviving rows, quality bytes): totals only change
        # when the models or the frame do, yet the O(mu*tau) Python loop
        # that builds them used to run on every re-solve
        self._totals_cache: tuple[tuple, dict] | None = None

    # -- helpers -----------------------------------------------------------

    @property
    def domain(self):
        return self.scheduler.domain

    def _solve(self, quality, method: str, solver_kw: dict,
               alive: dict[str, bool], done: dict[int, float],
               incumbent_A: np.ndarray | None,
               elapsed: dict[str, float] | None = None,
               done_pair: dict[tuple[str, int], float] | None = None,
               patch_tids: set[int] | None = None):
        """(Re-)solve the allocation over the remaining work only.

        Returns (allocation, A_full, quotas) — A_full is the sub-solution
        expanded back to the full frame (zero rows for dead platforms,
        zero columns for completed tasks) and ``quotas`` maps each
        supported (platform, task_id) pair to the work units it owes:
        ``ceil(share * remaining_i(task))`` under *that platform's own*
        quality->work inversion, exactly the unit accounting the one-shot
        :meth:`Scheduler.shards` uses. Rounds then drain quotas, so an
        unperturbed online run dispatches the same totals per pair as a
        single execute pass (± one unit of per-tranche rounding).

        When the problem carries a capacity dimension, ``done_pair`` (work
        units already served per (platform, task)) converts into resource
        already *held*: shards of still-active tasks keep their pages until
        the task completes, so each platform enters the restricted problem
        with only its remaining capacity — a drift-triggered re-solve
        cannot oversubscribe a platform that is part-way through its plan.

        ``patch_tids`` switches to the O(k) incremental path: the columns
        whose task ids it names are solved by :func:`patch_allocation`
        against the incumbent's committed shares (held fixed) instead of
        re-solving the whole restricted problem — the arrivals-only round's
        fast path, with patch_allocation's own bound test falling back to
        the full restricted solve when holding the old shares costs more
        than ``config.patch_tol``.
        """
        domain, sched = self.domain, self.scheduler
        c = sched.quality_vector(quality)
        problem = sched.problem(quality)
        rows = [i for i, p in enumerate(domain.platforms)
                if alive[domain.platform_name(p)]]
        if not rows:
            raise RuntimeError("every platform is down; cannot re-allocate")
        # per-(platform, task) totals and remaining under each platform's
        # own fitted model; a task stays active while any surviving
        # platform's inversion says work is outstanding. Totals are memoed
        # on the model generation — only refit/characterise change them.
        cache_key = (sched.models_version, len(domain.tasks), tuple(rows),
                     c.tobytes())
        if self._totals_cache is not None and self._totals_cache[0] == cache_key:
            totals = self._totals_cache[1]
        else:
            totals = {}
            for j, t in enumerate(domain.tasks):
                for i in rows:
                    pname = domain.platform_name(domain.platforms[i])
                    totals[(pname, t.task_id)] = max(domain.work_units(
                        sched.models[(pname, t.task_id)], float(c[j])), 1e-12)
            self._totals_cache = (cache_key, totals)
        frac_by_col: dict[int, float] = {}
        for j, t in enumerate(domain.tasks):
            best = 0.0
            for i in rows:
                pname = domain.platform_name(domain.platforms[i])
                total = totals[(pname, t.task_id)]
                rem = max(total - done.get(t.task_id, 0.0), 0.0)
                best = max(best, rem / total)
            if best > 0:
                frac_by_col[j] = min(best, 1.0)
        cols = sorted(frac_by_col)
        if not cols:
            return None, None, {}
        # each platform's elapsed busy time rides along as its offset, so
        # the re-solve minimises *finish* time — completed shares are fixed
        # history the remaining work must be balanced around
        offsets = np.array([
            (elapsed or {}).get(domain.platform_name(p), 0.0)
            for p in domain.platforms])
        # remaining capacity: pages held by already-served shards of tasks
        # still in flight stay committed on their platform until the task
        # completes; completed tasks have freed theirs (absent from cols)
        cap_rem = None
        if problem.capacity is not None:
            active = {domain.tasks[j].task_id for j in cols}
            held = np.zeros(problem.mu)
            for i, p in enumerate(domain.platforms):
                pname = domain.platform_name(p)
                for t in domain.tasks:
                    if t.task_id in active:
                        held[i] += (domain.resource_per_unit(p, t)
                                    * (done_pair or {}).get((pname, t.task_id), 0.0))
            cap_rem = np.maximum(problem.capacity - held, 0.0)
        sub = restrict_problem(problem, rows, cols,
                               [frac_by_col[j] for j in cols],
                               offsets=offsets, capacity=cap_rem)
        new_idx = ([] if not patch_tids else
                   [k for k, j in enumerate(cols)
                    if domain.tasks[j].task_id in patch_tids])
        if new_idx and incumbent_A is not None and len(new_idx) < len(cols):
            # patch base: the incumbent's shares for the columns it has
            # already committed, exact zeros for the newcomers (they carry
            # no mass yet — restrict_allocation's uniform orphan fill would
            # violate patch_allocation's precondition)
            base = np.asarray(incumbent_A, dtype=np.float64)[
                np.ix_(rows, cols)].copy()
            base[:, new_idx] = 0.0
            colsum = base.sum(axis=0)
            old = np.ones(len(cols), dtype=bool)
            old[new_idx] = False
            orphan = old & (colsum <= SUPPORT_ATOL)
            if orphan.any():
                base[:, orphan] = 1.0 / len(rows)
                colsum = base.sum(axis=0)
            base[:, old] /= colsum[old]
            alloc = patch_allocation(sub, base, new_idx, method,
                                     patch_tol=self.config.patch_tol,
                                     **solver_kw)
        else:
            kw = dict(solver_kw)
            if incumbent_A is not None and method in ("milp", "ml"):
                kw["incumbent"] = restrict_allocation(incumbent_A, rows, cols)
                kw.setdefault("warm_tol", self.config.warm_tol)
            alloc = SOLVERS[method](sub, **kw)
        A_full = expand_allocation(alloc.A, problem.mu, problem.tau, rows, cols)
        quotas: dict[tuple[str, int], float] = {}
        for i in rows:
            pname = domain.platform_name(domain.platforms[i])
            for j in cols:
                tid = domain.tasks[j].task_id
                share = A_full[i, j]
                if share <= SUPPORT_ATOL:
                    continue
                rem = max(totals[(pname, tid)] - done.get(tid, 0.0), 0.0)
                quota = float(np.ceil(share * rem))
                if quota > 0:
                    quotas[(pname, tid)] = quota
        return alloc, A_full, quotas

    def _effective_quality(self, quality, rung: int):
        """Quality target at degradation ``rung`` (0 = the original).

        Recomputed from the *base* quality over the current task list each
        time, so arrivals are covered and rung k applies the ladder's
        cumulative step, not a compounding of earlier rungs."""
        if rung == 0:
            return quality
        step = self.config.degrade_steps[rung - 1]
        c = self.scheduler.quality_vector(quality)
        return np.array([self.domain.degrade_quality(float(cj), step)
                         for cj in c])

    def _degrade(self, quality, rung: int, active_tids, round_idx: int,
                 reason: str, degradations: list) -> int:
        """Step the rung ladder down one notch, itemising per active task."""
        sched = self.scheduler
        sched.tracer.instant(f"degrade:{reason}", track="online",
                             cat="degrade", rung=rung + 1, round=round_idx)
        c_from = sched.quality_vector(self._effective_quality(quality, rung))
        c_to = sched.quality_vector(self._effective_quality(quality, rung + 1))
        for j, t in enumerate(self.domain.tasks):
            if active_tids is None or t.task_id in active_tids:
                degradations.append(DegradationEvent(
                    task_id=t.task_id, round=round_idx,
                    quality_from=float(c_from[j]), quality_to=float(c_to[j]),
                    rung=rung + 1, reason=reason))
        return rung + 1

    def _solve_degraded(self, quality, rung: int, method: str, solver_kw: dict,
                        alive: dict[str, bool], done: dict[int, float],
                        incumbent_A, elapsed=None, done_pair=None,
                        active_tids=None, round_idx: int = -1,
                        degradations: list | None = None,
                        patch_tids: set[int] | None = None):
        """:meth:`_solve` wrapped in the graceful-degradation ladder.

        An infeasible restricted problem (typed :class:`CapacityError` —
        the surviving fleet cannot even hold the active tasks' resources)
        or a feasible plan whose predicted finish blows ``deadline_s``
        relaxes the quality targets one rung (``Domain.degrade_quality``)
        and re-solves, trading the paper's central asset — accuracy — for
        latency instead of failing. The rung is monotone across the run
        (quality never silently recovers mid-workload: reporting is
        simpler and re-fit windows stay regime-consistent). Ladder
        exhausted: CapacityError propagates, a blown deadline is accepted
        as best effort. Returns (alloc, A_full, quotas, rung)."""
        cfg = self.config
        degradations = degradations if degradations is not None else []
        while True:
            try:
                alloc, A_full, quotas = self._solve(
                    self._effective_quality(quality, rung), method, solver_kw,
                    alive, done, incumbent_A, elapsed=elapsed,
                    done_pair=done_pair, patch_tids=patch_tids)
            except CapacityError:
                if rung >= len(cfg.degrade_steps):
                    raise
                rung = self._degrade(quality, rung, active_tids, round_idx,
                                     "capacity", degradations)
                continue
            if (alloc is not None and cfg.deadline_s is not None
                    and alloc.makespan > cfg.deadline_s
                    and rung < len(cfg.degrade_steps)):
                rung = self._degrade(quality, rung, active_tids, round_idx,
                                     "deadline", degradations)
                continue
            return alloc, A_full, quotas, rung

    def _probe(self, p, round_idx: int, seed: int, elapsed: float,
               quotas: dict[tuple[str, int], float]):
        """Cheap seeded dispatch testing a HALF_OPEN platform's health.

        The platform idled while its breaker was open, but wall time kept
        passing — ``Domain.advance_platform`` syncs its virtual clock to
        the fleet's elapsed time first, so a finite outage window ends
        after a bounded number of probes instead of never (the clock would
        otherwise only creep by one retry cost per probe). The probe is
        ``min_chunk`` units of the first still-active task: real work, so
        a successful probe's records count toward completion. Returns None
        when no active work remains to probe with, else
        (ok, records, FaultEvent)."""
        domain = self.domain
        pname = domain.platform_name(p)
        active = {tid for (_pn, tid), q in quotas.items() if q > 0}
        task = next((t for t in domain.tasks if t.task_id in active), None)
        if task is None:
            return None
        domain.advance_platform(p, elapsed)
        clock0 = getattr(p, "clock", None)
        probe_seed = seed_for(seed, pname, ("probe", domain.launch_key(task)),
                              round_idx)
        try:
            recs = domain.dispatch_batch(p, [task], [domain.min_chunk],
                                         seed=probe_seed)
            check_records(recs)
            return True, recs, FaultEvent(
                pname, task.task_id, round_idx, "probe", "probe-ok")
        except DispatchFault as exc:
            salvaged = list(getattr(exc, "records", []))
            burned = 0.0
            if clock0 is not None:
                burned = max(getattr(p, "clock", clock0) - clock0
                             - sum(r.latency for r in salvaged), 0.0)
            return False, salvaged, FaultEvent(
                pname, task.task_id, round_idx, fault_kind(exc),
                "probe-failed", latency=burned)

    def _plan_round(self, quotas: dict[tuple[str, int], float],
                    alive: dict[str, bool], round_idx: int,
                    solve_models: dict) -> list[tuple[Any, list[list[tuple[Any, int]]]]]:
        """Turn the outstanding quotas into this round's dispatch tranche."""
        cfg, domain = self.config, self.domain
        rounds_left = max(cfg.rounds - round_idx, 1)
        w = cfg.stagger[round_idx % len(cfg.stagger)] if cfg.stagger else 1.0
        # the final planned round flushes everything — a sub-1 stagger
        # weight there would leak a sliver into an extra leftover round.
        # Open-loop runs flush every round: the trace paces the work, and
        # holding quota back would just queue admitted requests longer.
        frac = (1.0 if rounds_left == 1 or cfg.open_loop
                else min(w / rounds_left, 1.0))
        plan = []
        for p in domain.platforms:
            pname = domain.platform_name(p)
            if not alive[pname]:
                continue
            groups: dict[Hashable, list[tuple[Any, int]]] = {}
            for t in domain.tasks:
                quota = quotas.get((pname, t.task_id), 0.0)
                if quota <= 0:
                    continue
                planned = quota * frac
                beta, gamma = domain.latency_params(
                    solve_models[(pname, t.task_id)])
                # consolidation floor: do not pay the per-dispatch constant
                # for a shard whose work does not dwarf it
                floor = cfg.gamma_duty * gamma / max(beta, 1e-300)
                units = int(np.ceil(min(
                    max(planned, floor, float(domain.min_chunk)), quota)))
                if units <= 0:
                    continue
                groups.setdefault(domain.launch_key(t), []).append((t, units))
            if groups:
                plan.append((p, list(groups.values())))
        return plan

    def _heal_unreachable(self, alive: dict[str, bool], mode,
                          characterise_kw: dict | None) -> None:
        """Retry characterisation of placeholder pairs on living platforms.

        A task arriving while a platform sits in a *transient* outage gets
        an :class:`_UnreachableModel` there; once the platform is back the
        placeholder would otherwise stick forever — harmless to MILP/ML
        (they just avoid the pair) but poisonous to the proportional
        heuristic, whose per-platform share folds every task's work into
        one latency. Each re-solve therefore re-benchmarks the stale pairs
        (outage-tolerant: still-down platforms keep their placeholder)."""
        sched, domain = self.scheduler, self.domain
        stale: dict[str, list] = {}
        for p in domain.platforms:
            pname = domain.platform_name(p)
            if not alive[pname]:
                continue
            for t in domain.tasks:
                if isinstance(sched.models.get((pname, t.task_id)),
                              _UnreachableModel):
                    stale.setdefault(pname, []).append(t)
        for p in domain.platforms:
            pname = domain.platform_name(p)
            if pname in stale:
                sched.characterise_tasks(stale[pname], mode=mode,
                                         platforms=[p],
                                         **(characterise_kw or {}))

    def _refit(self, windows: dict, detector: DriftDetector,
               drifted: tuple[str, ...], alive: dict[str, bool],
               solve_models: dict) -> None:
        """Fold the accumulated record windows back into the metric models.

        For a drifted platform the window straddles two regimes, and fresh
        tranche records alone may not identify (beta, gamma) — a pair often
        repeats one shard size. So stale records (those whose own
        measured/predicted ratio sits far from the platform's median) are
        *projected onto the new regime's line*: latency replaced by
        ``model(units) * median_ratio``. They keep their unit-count spread
        (anchoring the slope/intercept split) while the genuinely fresh
        records supply the new level. Non-drifted platforms refit from
        their raw windows — the routine fold of execute-time evidence.
        """
        updates: dict[tuple[str, int], list] = {}
        for key, win in windows.items():
            pname, _tid = key
            if not alive[pname]:
                continue
            recs = list(win)
            # tasks that arrived this round have no solve-time model yet;
            # their windows (fresh characterise rungs) pass through raw
            model = solve_models.get(key)
            if pname in drifted and recs and model is not None:
                med = detector.median_ratio(pname)
                fixed = []
                for r in recs:
                    pred = self.domain.predicted_latency(
                        model, self.domain.record_units(r))
                    ratio = r.latency / max(pred, 1e-12)
                    if med > 0 and abs(ratio - med) / med > 0.5:
                        r = dataclasses.replace(r, latency=pred * med)
                    fixed.append(r)
                recs = fixed
            updates[key] = recs
        self.scheduler.refit(updates)

    # -- the loop ----------------------------------------------------------

    def run(self, quality=None, method: str = "milp", seed: int = 3,
            mode: str | None = None, scenario: Scenario | None = None,
            characterise_kw: dict | None = None, **solver_kw) -> OnlineReport:
        """Execute the workload in rounds; adapt only when evidence demands.

        ``scenario`` here feeds *task arrivals* into the loop (slowdowns
        and outages act through the platforms they are attached to); a
        task joins once the workload's elapsed virtual makespan passes its
        arrival time, is characterised incrementally, and forces a
        re-solve so the new work is placed.
        """
        cfg, sched, domain = self.config, self.scheduler, self.domain
        t_run = time.perf_counter()
        tracer, ledger = sched.tracer, sched.ledger
        obs_on = tracer.enabled
        # task family names for the ledger's (platform, family, round) keys
        task_family: dict[int, str] = (
            {t.task_id: str(domain.launch_key(t)) for t in domain.tasks}
            if obs_on else {})
        if scenario is not None:
            # the arrival cursor belongs to a run, not the scenario object,
            # so rewind it here. (Replaying a scenario across runs also
            # needs fresh platform virtual clocks — re-attach it via each
            # simulator's attach_scenario; this loop is domain-agnostic and
            # cannot reach them.)
            scenario.reset()
            if scenario.pending_arrivals and quality is not None and np.ndim(quality) > 0:
                raise ValueError(
                    "streaming arrivals need a scalar quality or the domain "
                    "default — a per-task quality vector cannot be extended "
                    "for tasks that join mid-workload")
        if sched.models is None:
            sched.characterise(mode=mode, **(characterise_kw or {}))

        names = [domain.platform_name(p) for p in domain.platforms]
        breaker = CircuitBreaker(failure_threshold=cfg.outage_failures,
                                 cooldown_s=cfg.breaker_cooldown,
                                 tracer=tracer if obs_on else None)
        alive = {pn: True for pn in names}
        done: dict[int, float] = {}
        done_pair: dict[tuple[str, int], float] = {}
        windows: dict[tuple[str, int], deque] = {
            key: deque(recs, maxlen=cfg.refit_window)
            for key, recs in sched.characterise_records.items()}
        detector = DriftDetector(cfg.drift_window, cfg.drift_threshold,
                                 cfg.min_drift_records)
        fault_events: list[FaultEvent] = []
        degradations: list[DegradationEvent] = []
        recovered: set[str] = set()
        rung, n_probes = 0, 0

        # -- overload-control state (all round-barrier, mode-parity safe)
        admission = (AdmissionController(cfg.admission,
                                         tracer=tracer if obs_on else None)
                     if cfg.admission is not None else None)
        slo_tracker = SLOTracker(cfg.slo) if cfg.slo is not None else None
        tail = (TailDriftDetector(cfg.tail_window, cfg.tail_threshold,
                                  cfg.min_tail_records, cfg.tail_quantile)
                if cfg.tail_threshold is not None else None)
        shed_events: list = []
        brownout_transitions: list[BrownoutTransition] = []
        brown_rung = 0
        brown_occupancy: dict[int, int] = {}
        # per-task latency accounting for TTFT/TPOT/e2e: arrival time,
        # first-output time, last-output time, served units, completion
        arr_t: dict[int, float] = {}
        task_first: dict[int, float] = {}
        task_last: dict[int, float] = {}
        task_units: dict[int, int] = {}
        completed_tasks: set[int] = set()
        task_metrics: dict[int, dict] = {}
        rates_version = -1
        unit_rates: dict[str, float] = {}

        solve_t0 = time.perf_counter()
        with tracer.span("solve[initial]", track="online", cat="solve",
                         method=method):
            alloc, A_full, quotas, rung = self._solve_degraded(
                quality, rung, method, solver_kw, alive, done,
                incumbent_A=None, done_pair=done_pair,
                degradations=degradations)
        solve_wall = time.perf_counter() - solve_t0
        if obs_on and alloc is not None:
            lift_solver_phases(tracer, alloc.meta, tracer.now(),
                               label=f"{alloc.solver or method}[initial]")
        resolve_wall = 0.0
        if alloc is None:
            raise ValueError("workload has no remaining work to execute")
        predicted0 = alloc.makespan
        solve_models = dict(sched.models)
        n_solves, n_resolves, n_skipped, n_refits, n_arrivals = 1, 0, 0, 0, 0
        n_patched = 0
        solve_metas: list[dict] = [dict(alloc.meta)]

        all_records: list[RunRecordLike] = []
        plat_lat = {pn: 0.0 for pn in alive}
        plat_wall = {pn: 0.0 for pn in alive}
        rounds: list[RoundLog] = []

        for round_idx in range(cfg.max_rounds):
            round_wall_t0 = tracer.now() if obs_on else 0.0
            elapsed = max(plat_lat.values(), default=0.0)
            if cfg.open_loop:
                # rounds are *time barriers* on a shared fleet clock: a
                # platform that finished its tranche early idled until the
                # barrier, so its timeline (and virtual clock) resumes at
                # the round start, not at its own busy-time sum — this is
                # what makes per-task e2e latencies real waiting times
                for p in domain.platforms:
                    pname = domain.platform_name(p)
                    if alive[pname]:
                        plat_lat[pname] = max(plat_lat[pname], elapsed)
                        domain.advance_platform(p, elapsed)
            round_t0 = elapsed
            round_busy = 0.0
            # breaker recovery at the round barrier: OPEN platforms whose
            # cooldown (in workload elapsed virtual time) has passed go
            # HALF_OPEN and take a cheap probe; a clean probe re-admits
            # them to the allocation (the one-way dead set, undone)
            revived: list[str] = []
            for p in domain.platforms:
                pname = domain.platform_name(p)
                if breaker.poll(pname, elapsed, round_idx) != HALF_OPEN:
                    continue
                with tracer.span("probe", track=pname, cat="dispatch",
                                 round=round_idx):
                    outcome = self._probe(p, round_idx, seed, elapsed,
                                          quotas)
                if outcome is None:
                    continue
                ok, recs, event = outcome
                n_probes += 1
                fault_events.append(event)
                probe_lat = 0.0
                for rec in recs:
                    all_records.append(rec)
                    probe_lat += rec.latency
                    units = domain.record_units(rec)
                    done[rec.task_id] = done.get(rec.task_id, 0.0) + units
                    key = (pname, rec.task_id)
                    done_pair[key] = done_pair.get(key, 0.0) + units
                    quotas[key] = max(quotas.get(key, 0.0) - units, 0.0)
                    windows.setdefault(
                        key, deque(maxlen=cfg.refit_window)).append(rec)
                    end_t = elapsed + probe_lat
                    first_t = domain.record_ttft(rec, end_t)
                    prev = task_first.get(rec.task_id)
                    task_first[rec.task_id] = (
                        first_t if prev is None else min(prev, first_t))
                    task_last[rec.task_id] = max(
                        task_last.get(rec.task_id, 0.0), end_t)
                    task_units[rec.task_id] = (
                        task_units.get(rec.task_id, 0) + units)
                if ok:
                    breaker.record_success(pname, elapsed, round_idx)
                    # the platform idled while down: its timeline resumes
                    # at the fleet's elapsed time, not at its stale sum
                    plat_lat[pname] = max(plat_lat[pname],
                                          elapsed + probe_lat)
                    alive[pname] = True
                    revived.append(pname)
                    recovered.add(pname)
                else:
                    breaker.record_failure(pname, elapsed, round_idx)

            if not any(q > 0 for q in quotas.values()):
                late: list[tuple[float, Any]] = []
                if scenario is not None and scenario.pending_arrivals:
                    if cfg.open_loop:
                        # idle fleet, trace still running: fast-forward the
                        # barrier clock to the next arrival instant — open
                        # loop means requests come on their own schedule,
                        # not when the fleet is ready for them
                        target = max(elapsed, scenario.next_arrival_time)
                        for p in domain.platforms:
                            pname = domain.platform_name(p)
                            if alive[pname]:
                                plat_lat[pname] = max(plat_lat[pname], target)
                                domain.advance_platform(p, target)
                        elapsed = round_t0 = target
                    else:
                        # drain the arrival queue: no more work means
                        # virtual time cannot advance to reach stragglers,
                        # so they join now
                        late = scenario.take_arrivals_timed(0.0, force=True)
                elif admission is not None and admission.pending:
                    pass  # queued arrivals still waiting for admission
                else:
                    break
            else:
                late = []

            plan = self._plan_round(quotas, alive, round_idx, solve_models)
            results, _round_wall = ([], 0.0) if not plan else sched.dispatch_plan(
                plan,
                seed=lambda pn, key, _r=round_idx: seed_for(seed, pn, key, _r),
                mode=mode,
                # with the retry layer armed, retry-exhausted transients
                # and corrupt dispatches degrade to per-platform errors the
                # breaker counts; unarmed, only outages are survivable —
                # the legacy (and deliberately brittle) behaviour
                catch=(DispatchFault,) if cfg.retry is not None
                else (PlatformOutage,),
                retry=cfg.retry, round_idx=round_idx)

            dispatched: dict[str, int] = {}
            failed: list[str] = []
            for (p, _groups), res in zip(plan, results):
                pname = domain.platform_name(p)
                plat_wall[pname] += res.wall_s
                for rec in res.records:
                    all_records.append(rec)
                    plat_lat[pname] += rec.latency
                    round_busy += abs(rec.latency)
                    units = domain.record_units(rec)
                    dispatched[pname] = dispatched.get(pname, 0) + units
                    done[rec.task_id] = done.get(rec.task_id, 0.0) + units
                    key = (pname, rec.task_id)
                    done_pair[key] = done_pair.get(key, 0.0) + units
                    quotas[key] = max(quotas.get(key, 0.0) - units, 0.0)
                    windows.setdefault(
                        key, deque(maxlen=cfg.refit_window)).append(rec)
                    predicted = domain.predicted_latency(
                        solve_models[key], units)
                    detector.observe(pname, predicted, rec.latency)
                    if obs_on:
                        ledger.observe("latency", pname,
                                       task_family.get(rec.task_id, "?"),
                                       round_idx, predicted, rec.latency)
                    if tail is not None:
                        tail.observe(pname, predicted, rec.latency)
                    end_t = plat_lat[pname]
                    first_t = domain.record_ttft(rec, end_t)
                    prev = task_first.get(rec.task_id)
                    task_first[rec.task_id] = (
                        first_t if prev is None else min(prev, first_t))
                    task_last[rec.task_id] = max(
                        task_last.get(rec.task_id, 0.0), end_t)
                    task_units[rec.task_id] = (
                        task_units.get(rec.task_id, 0) + units)
                for ev in res.faults:
                    fault_events.append(ev)
                    # retries burn real virtual time on the platform's
                    # timeline — a storm honestly inflates its makespan
                    plat_lat[pname] += ev.latency
                if res.error is not None:
                    failed.append(pname)

            # feed round outcomes to the breaker: a failed round advances
            # a platform's streak, a clean dispatching round breaks it, an
            # idle round breaks it too — the death gate counts
            # *consecutive* failed rounds, so two isolated hiccups
            # separated by quiet rounds must not accumulate
            elapsed = max(plat_lat.values(), default=0.0)
            planned = {domain.platform_name(p) for p, _ in plan}
            was_dead = {pn: not breaker.available(pn) for pn in names}
            for pn in names:
                if pn in failed:
                    breaker.record_failure(pn, elapsed, round_idx)
                elif pn in planned:
                    breaker.record_success(pn, elapsed, round_idx)
                else:
                    breaker.reset_streak(pn)
            newly_dead = [pn for pn in failed
                          if not was_dead[pn] and not breaker.available(pn)]
            for pn in names:
                alive[pn] = breaker.available(pn)
            # -- completion barrier: tasks whose quotas fully drained this
            # round yield their TTFT/TPOT/e2e observations (streaming into
            # the SLO tracker) before any re-solve rebuilds the quotas
            completions = 0
            out_by_tid: dict[int, float] = {}
            for (_pn, tid), q in quotas.items():
                if q > 0:
                    out_by_tid[tid] = out_by_tid.get(tid, 0.0) + q
            for tid in sorted(task_first):
                if tid in completed_tasks or out_by_tid.get(tid, 0.0) > 0:
                    continue
                arr = arr_t.get(tid, 0.0)
                first = task_first[tid]
                last = max(task_last.get(tid, first), first)
                ttft = max(first - arr, 0.0)
                e2e = max(last - arr, ttft)
                units = task_units.get(tid, 1)
                tpot = (e2e - ttft) / max(units - 1, 1)
                if slo_tracker is not None:
                    slo_tracker.observe(ttft, tpot, e2e)
                task_metrics[tid] = {"ttft": ttft, "tpot": tpot,
                                     "e2e": e2e, "units": units}
                completed_tasks.add(tid)
                completions += 1

            offered_timed = list(late)
            if scenario is not None:
                offered_timed += scenario.take_arrivals_timed(elapsed)
            # idempotent admission: a task already in the workload (e.g. a
            # replayed scenario whose arrival joined permanently in an
            # earlier run on this scheduler) is simply part of it
            known = {t.task_id for t in domain.tasks}
            offered_timed = [(at, t) for at, t in offered_timed
                             if t.task_id not in known]
            round_shed = 0
            round_kv_headroom = math.inf
            if admission is None:
                joined = offered_timed
            else:
                # refresh the fleet signals the queue bound derives from
                # (service rates memoed on the model generation; remaining
                # capacity from pages held by tasks still in flight)
                alive_set = {pn for pn in names if alive[pn]}
                if rates_version != sched.models_version:
                    unit_rates = predicted_unit_rates(sched.models, alive_set)
                    rates_version = sched.models_version
                cap_rem: dict[str, float] = {}
                active_now = {tid for (_pn, tid), q in quotas.items() if q > 0}
                for p in domain.platforms:
                    pname = domain.platform_name(p)
                    if pname not in alive_set:
                        continue
                    held = sum(domain.resource_per_unit(p, t)
                               * done_pair.get((pname, t.task_id), 0.0)
                               for t in domain.tasks
                               if t.task_id in active_now)
                    cap_rem[pname] = domain.platform_capacity(p) - held
                round_kv_headroom = min(cap_rem.values(), default=math.inf)
                pool = [t for _at, t in offered_timed] + \
                       [t for _at, t, _c in admission.pending]
                mean_q = (sum(domain.task_quality(t) for t in pool)
                          / len(pool)) if pool else 1.0
                alive_plats = [p for p in domain.platforms
                               if domain.platform_name(p) in alive_set]
                mean_res = (max(domain.resource_per_unit(p, pool[0])
                                for p in alive_plats) * mean_q
                            if pool and alive_plats else 0.0)
                admission.update_fleet(unit_rates, cap_rem, mean_q, mean_res)
                span = elapsed - round_t0
                admission.observe_utilisation(round_busy, span,
                                              len(alive_set))
                for at, t in offered_timed:
                    tq = domain.task_quality(t)
                    fits = any(
                        domain.resource_per_unit(p, t) * tq
                        <= domain.platform_capacity(p) for p in alive_plats)
                    rej = admission.offer(t, at, round_idx,
                                          cost_s=admission.cost_s(tq),
                                          fits=fits)
                    if rej is not None:
                        shed_events.append(rej.event)
                        round_shed += 1
                backlog_s = admission.cost_s(
                    sum(q for q in quotas.values() if q > 0))
                joined, timed_out = admission.admit(elapsed, round_idx,
                                                    backlog_s)
                for rej in timed_out:
                    shed_events.append(rej.event)
                    round_shed += 1
            arrived = [t for _at, t in joined]
            for at, t in joined:
                arr_t[t.task_id] = min(at, elapsed)
            if arrived:
                n_arrivals += len(arrived)
                domain.tasks.extend(arrived)
                if obs_on:
                    task_family.update(
                        {t.task_id: str(domain.launch_key(t))
                         for t in arrived})
                # benchmark newcomers on the survivors only; any pair left
                # unfitted (dead platform, or an outage firing mid-ladder
                # on a not-yet-dead one) gets an unreachable placeholder so
                # the model matrices stay total — those rows never reach a
                # solver
                survivors = [p for p in domain.platforms
                             if alive[domain.platform_name(p)]]
                need_char = arrived
                if cfg.adopt_family_models:
                    # trace-scale arrival counts cannot afford a benchmark
                    # ladder per arrival: same-family newcomers inherit a
                    # donor's fitted models; only true orphans benchmark
                    need_char = sched.adopt_models(arrived,
                                                   platforms=survivors)
                if need_char:
                    sched.characterise_tasks(need_char, mode=mode,
                                             platforms=survivors,
                                             **(characterise_kw or {}))
                for t in arrived:
                    for p in domain.platforms:
                        key = (domain.platform_name(p), t.task_id)
                        if key not in sched.models:
                            sched.models[key] = _UnreachableModel()
                # the model table is total again — rebuild the matrices now
                # (characterise_tasks deferred it); the patch path has no
                # refit to do this later
                sched._delta, sched._gamma = sched.model_matrices()
                for key, recs in sched.characterise_records.items():
                    windows.setdefault(key, deque(recs, maxlen=cfg.refit_window))
                # incumbent gains zero columns for the newcomers; the
                # restricted warm start falls back to uniform shares there
                A_full = np.pad(A_full,
                                ((0, 0), (0, len(domain.tasks) - A_full.shape[1])))

            # -- brownout guardrail: walk the degradation ladder when the
            # recent guardrail quantile breaches the SLO, restore a rung
            # when pressure clears (hysteresis via enter/exit ratios).
            # Deepening waits for fresh completions so one bad window does
            # not ratchet straight to the bottom rung.
            brown_changed = False
            if (slo_tracker is not None and cfg.degrade_steps
                    and cfg.slo is not None):
                recent = slo_tracker.recent_quantile()
                if recent is not None:
                    tgt = cfg.slo.target_s
                    if (recent > tgt * cfg.slo.enter_ratio
                            and brown_rung < len(cfg.degrade_steps)
                            and completions > 0):
                        brownout_transitions.append(BrownoutTransition(
                            round=round_idx, at=elapsed,
                            rung_from=brown_rung, rung_to=brown_rung + 1,
                            direction="deepen", observed=recent,
                            target_s=tgt))
                        brown_rung += 1
                        brown_changed = True
                        tracer.instant("brownout:deepen", track="online",
                                       cat="brownout", rung=brown_rung,
                                       round=round_idx)
                    elif recent < tgt * cfg.slo.exit_ratio and brown_rung > 0:
                        brownout_transitions.append(BrownoutTransition(
                            round=round_idx, at=elapsed,
                            rung_from=brown_rung, rung_to=brown_rung - 1,
                            direction="restore", observed=recent,
                            target_s=tgt))
                        brown_rung -= 1
                        brown_changed = True
                        tracer.instant("brownout:restore", track="online",
                                       cat="brownout", rung=brown_rung,
                                       round=round_idx)

            drifted = detector.drifted(alive)
            tail_drifted = tail.drifted(alive) if tail is not None else ()
            outcome = None
            resolved = False
            if (drifted or tail_drifted or newly_dead or arrived or revived
                    or brown_changed):
                # arrivals-only rounds take the O(k) incremental path: no
                # drift means the old tasks' models are still right, so
                # the re-fit is skipped and only the k new columns solve —
                # the committed shares are the patch's fixed base
                patch_tids = None
                if (cfg.patch_arrivals and arrived
                        and not (drifted or tail_drifted or newly_dead
                                 or revived or brown_changed)):
                    patch_tids = {t.task_id for t in arrived}
                else:
                    self._heal_unreachable(alive, mode, characterise_kw)
                    # only the median detector's verdict re-projects stale
                    # windows — a blown tail with a quiet median means the
                    # *spread* changed, not the level
                    with tracer.span("refit", track="online", cat="refit",
                                     round=round_idx,
                                     drifted=sorted(drifted)):
                        self._refit(windows, detector, drifted, alive,
                                    solve_models)
                    n_refits += 1
                active_tids = ({tid for (_pn, tid), q in quotas.items()
                                if q > 0}
                               | {t.task_id for t in arrived})
                solve_t0 = time.perf_counter()
                # a revived platform has zero share in the incumbent by
                # construction, so the warm-start shortcut would wave the
                # old allocation through and the re-admitted platform
                # would never see work again — force a real solve.
                # The effective rung is the deeper of the monotone
                # (capacity/deadline) rung and the reversible brownout rung.
                eff_rung = max(rung, brown_rung)
                with tracer.span("resolve", track="online", cat="solve",
                                 round=round_idx, rung=eff_rung,
                                 patch=patch_tids is not None):
                    alloc2, A2, quotas2, solved_rung = self._solve_degraded(
                        quality, eff_rung, method, solver_kw, alive, done,
                        incumbent_A=None if revived else A_full,
                        elapsed=plat_lat,
                        done_pair=done_pair, active_tids=active_tids,
                        round_idx=round_idx, degradations=degradations,
                        patch_tids=patch_tids)
                if obs_on and alloc2 is not None:
                    lift_solver_phases(
                        tracer, alloc2.meta, tracer.now(),
                        label=f"{alloc2.solver or method}[r{round_idx}]")
                if solved_rung > eff_rung:
                    # forced (capacity/deadline) degradation stays monotone
                    rung = solved_rung
                dt = time.perf_counter() - solve_t0
                resolve_wall += dt
                solve_wall += dt
                if alloc2 is not None:
                    alloc, A_full, quotas = alloc2, A2, quotas2
                    incr = alloc.meta.get("incremental")
                    if incr == "patched":
                        outcome = "patched"
                        n_patched += 1
                    elif incr == "full_fallback":
                        outcome = "patch-fallback"
                    else:
                        outcome = alloc.meta.get("warm_start", "solved")
                    resolved = True
                    n_solves += 1
                    solve_metas.append(dict(alloc.meta))
                    if outcome == "skipped":
                        n_skipped += 1
                    else:
                        n_resolves += 1
                else:
                    # the re-fitted models say every task is already served
                    quotas = {}
                solve_models = dict(sched.models)
                detector.reset()
                if tail is not None:
                    tail.reset()

            brown_occupancy[brown_rung] = brown_occupancy.get(brown_rung, 0) + 1
            rounds.append(RoundLog(
                round=round_idx, dispatched_units=dispatched,
                drifted=drifted, failed=tuple(failed), arrivals=len(arrived),
                resolved=resolved, solve_outcome=outcome,
                revived=tuple(revived),
                tail_drifted=tail_drifted,
                offered=len(offered_timed),
                shed=round_shed,
                queue_depth=admission.queue_depth if admission else 0,
                backlog_units=float(sum(q for q in quotas.values() if q > 0)),
                brownout_rung=brown_rung,
                completions=completions,
                t=max(plat_lat.values(), default=0.0),
                kv_headroom=round_kv_headroom))
            if obs_on:
                # the round span is added retroactively: everything inside
                # it (dispatch, probes, re-solves) already traced itself,
                # so only the enclosing interval is recorded here
                tracer.add_span(
                    f"round[{round_idx}]", "online", round_wall_t0,
                    tracer.now(), cat="online",
                    args={"resolved": resolved, "arrivals": len(arrived),
                          "shed": round_shed, "completions": completions,
                          "brownout_rung": brown_rung,
                          "drifted": sorted(drifted)})
                obs_metrics.gauge("online.brownout_rung").set(brown_rung)
                obs_metrics.counter("admission.shed").inc(round_shed)

        else:
            if any(q > 0 for q in quotas.values()) and not cfg.open_loop:
                # open-loop runs are horizon-truncated, not drained: hitting
                # the round cap with work in flight just ends the trace
                raise RuntimeError(
                    f"online run exceeded max_rounds={cfg.max_rounds} with "
                    f"work remaining — no progress on "
                    f"{sorted(k for k, q in quotas.items() if q > 0)}")

        # summarise against the final (possibly degraded) quality targets —
        # predicted CI / requested tokens must reflect what the run was
        # actually asked to deliver after the ladder stepped down
        problem = sched.problem(
            self._effective_quality(quality, max(rung, brown_rung)))
        summary = domain.summarise(all_records, problem)
        measured = max(plat_lat.values(), default=0.0)
        if obs_on:
            # whole-run accountability: the *initial* predicted makespan vs
            # what the adaptive run actually measured (same inf-on-zero
            # convention as OnlineReport.makespan_error), plus delivered
            # accuracy when the domain reports it
            ledger.observe("makespan", "*", "-", -1, predicted0, measured)
            measured_ci = summary.get("measured_ci") \
                if isinstance(summary, dict) else None
            if isinstance(measured_ci, dict):
                for j, t in enumerate(domain.tasks):
                    m = measured_ci.get(t.task_id)
                    if m is not None:
                        ledger.observe("accuracy", "*",
                                       task_family.get(t.task_id, "?"), -1,
                                       float(problem.c[j]), float(m))
            obs_metrics.counter("online.rounds").inc(len(rounds))
            obs_metrics.counter("online.resolves").inc(n_resolves)
            obs_metrics.counter("online.refits").inc(n_refits)
            obs_metrics.counter("runtime.records").inc(len(all_records))
            obs_metrics.counter("runtime.faults").inc(len(fault_events))
            obs_metrics.counter("runtime.retries").inc(
                count_retries(fault_events))
        return OnlineReport(
            allocation=alloc,
            predicted_makespan=predicted0,
            measured_makespan=measured,
            platform_latencies=plat_lat,
            records=all_records,
            summary=summary,
            rounds=rounds,
            n_solves=n_solves,
            n_resolves=n_resolves,
            n_skipped=n_skipped,
            n_refits=n_refits,
            solve_wall_s=solve_wall,
            resolve_wall_s=resolve_wall,
            dead_platforms=tuple(sorted(pn for pn, ok in alive.items() if not ok)),
            arrivals=n_arrivals,
            platform_wall_s=plat_wall,
            wall_s=time.perf_counter() - t_run,
            mode=sched._executor(mode).mode,
            fault_events=fault_events,
            degradations=degradations,
            breaker_transitions=list(breaker.transitions),
            n_retries=count_retries(fault_events),
            n_probes=n_probes,
            recovered_platforms=tuple(sorted(recovered)),
            n_patched=n_patched,
            solve_metas=solve_metas,
            shed_events=shed_events,
            brownout_transitions=brownout_transitions,
            n_offered=admission.n_offered if admission else n_arrivals,
            n_shed=admission.n_shed if admission else 0,
            brownout_rung=brown_rung,
            brownout_occupancy=brown_occupancy,
            slo=slo_tracker.snapshot() if slo_tracker else None,
            task_metrics=task_metrics,
        )
