"""Fault taxonomy, retry policy and per-platform circuit breakers.

The paper's allocation story assumes every platform that starts the
workload finishes it; its companion work (arXiv:1408.4965) frames the
runtime as a *continuously accessible service*, and Memeti & Pllana
(arXiv:1606.05134) show re-optimising mid-run pays off exactly when system
behaviour shifts — which includes platforms failing and coming back. This
module is the vocabulary and state the rest of the runtime threads
through:

**Taxonomy.** Every dispatch failure is a :class:`DispatchFault` carrying
the records the batch completed before failing (the platform's virtual
clock already ran that work, so dispatchers salvage it instead of
re-executing). Three concrete kinds, by what the right reaction is:

* :class:`TransientFault` — a retryable blip (network hiccup, scheduler
  preemption); injected deterministically by ``Scenario.flaky``. Retrying
  the *unsalvaged remainder* usually succeeds, and each failed attempt
  advances the platform's virtual clock by a retry cost, so finite fault
  storms end.
* :class:`PlatformOutage` — the platform is down for a window; retrying
  within the round is pointless. The circuit breaker takes over: repeated
  failures open it, a cooldown later cheap probes test recovery.
* :class:`CorruptResult` — the dispatch *returned*, but its records fail
  sanity checks (:func:`check_records`): non-finite fields or non-positive
  latency. The work is wasted (the clock advanced); the bad records are
  discarded and the affected tasks re-dispatched.

:class:`DispatchTimeout` (a transient) marks a dispatch whose executor
wall clock blew the policy's ``timeout_s``; :class:`JobCancelled` marks a
job skipped because its batch was cancelled before it started.

**RetryPolicy** is deterministic by construction: the backoff for attempt
``k`` of (platform, round) is ``min(base * 2^(k-1), cap)`` scaled by a
seeded jitter (CRC32 of the coordinates — the same PYTHONHASHSEED-proof
scheme as :func:`repro.runtime.domain.seed_for`), so concurrent and
sequential runs retry identically and a replay reproduces the schedule
bit-for-bit. The per-(platform, round) ``budget`` bounds total retries so
a fault storm cannot spin a round forever.

**CircuitBreaker** holds one three-state machine per platform::

    CLOSED --(failure_threshold consecutive failed rounds)--> OPEN
    OPEN   --(cooldown_s of workload elapsed time)----------> HALF_OPEN
    HALF_OPEN --(cheap seeded probe dispatch succeeds)------> CLOSED
    HALF_OPEN --(probe fails)-------------------------------> OPEN

replacing the online loop's one-way dead set: a platform that comes back
(scenario outage windows are finite) re-enters the allocation instead of
staying dead forever. Time is the workload's *elapsed virtual makespan* —
a round-barrier quantity identical across executor modes — so transitions
are deterministic. Every transition is logged as a
:class:`BreakerTransition` for the run report.

All event dataclasses round-trip through :mod:`repro.runtime.records`
JSONL (they are registered builtins), so a run's fault history persists
next to its execution records.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any, Iterable, Sequence

__all__ = [
    "DispatchFault", "PlatformOutage", "TransientFault", "CorruptResult",
    "DispatchTimeout", "JobCancelled",
    "RetryPolicy", "CircuitBreaker",
    "CLOSED", "OPEN", "HALF_OPEN",
    "FaultEvent", "DegradationEvent", "BreakerTransition",
    "check_records",
]


# --------------------------------------------------------------------------
# Taxonomy
# --------------------------------------------------------------------------

class DispatchFault(RuntimeError):
    """Base of every dispatch failure.

    ``records`` carries whatever the failing batch completed before the
    fault struck — the platform's virtual clock already advanced for that
    work, so dispatchers salvage it instead of re-executing it."""

    def __init__(self, *args):
        super().__init__(*args)
        self.records: list[Any] = []


class PlatformOutage(DispatchFault):
    """A dispatch hit a platform inside one of its scenario outage windows.

    Not retryable within the round — the circuit breaker owns recovery."""


class TransientFault(DispatchFault):
    """A retryable blip: the same dispatch usually succeeds on retry."""


class CorruptResult(DispatchFault):
    """The dispatch returned records that fail sanity checks.

    ``bad`` holds the rejected records (for diagnosis); ``records`` holds
    the batch's sane siblings, salvaged as usual."""

    def __init__(self, *args):
        super().__init__(*args)
        self.bad: list[Any] = []


class DispatchTimeout(TransientFault):
    """A dispatch blew its executor wall-clock timeout."""


class JobCancelled(RuntimeError):
    """An executor job skipped because its batch was cancelled before it
    started (e.g. the platform's breaker tripped mid-round)."""


def check_records(records: Sequence[Any]) -> None:
    """Sanity-check a dispatch's records; raise :class:`CorruptResult`.

    A sane record has finite, strictly positive latency and no non-finite
    float field (a NaN price or an infinite CI is corruption, a negative
    deep-out-of-the-money price estimate is not). The raised fault carries
    the sane records in ``.records`` (salvage) and the rejected ones in
    ``.bad`` so the caller re-dispatches only the affected tasks.
    """
    good, bad = [], []
    for rec in records:
        lat = getattr(rec, "latency", None)
        sane = lat is not None and math.isfinite(lat) and lat > 0.0
        if sane and dataclasses.is_dataclass(rec):
            for f in dataclasses.fields(rec):
                v = getattr(rec, f.name)
                if isinstance(v, float) and not math.isfinite(v):
                    sane = False
                    break
        (good if sane else bad).append(rec)
    if bad:
        exc = CorruptResult(
            f"{len(bad)}/{len(records)} records failed sanity checks "
            f"(first: {bad[0]!r})")
        exc.records = good
        exc.bad = bad
        raise exc


# --------------------------------------------------------------------------
# Retry policy
# --------------------------------------------------------------------------

def _unit_jitter(*coords) -> float:
    """Deterministic uniform in [-1, 1) from a stable hash of coords —
    CRC32, like :func:`repro.runtime.domain.seed_for` (not imported to
    keep this module dependency-free)."""
    key = "|".join(repr(c) for c in coords)
    return (zlib.crc32(key.encode()) & 0xFFFFFFFF) / 2**31 - 1.0


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Deterministic capped-exponential-backoff retry schedule.

    ``max_attempts`` bounds attempts per dispatch (1 = never retry);
    ``budget`` bounds total retries per (platform, round) across all of
    that platform's launch groups, so a storm cannot spin a round forever.
    ``timeout_s`` (optional) bounds a dispatch's *executor wall clock*:
    blown dispatches surface as :class:`DispatchTimeout` — a health signal
    the breaker counts (completed work stays in the accounting; host
    threads cannot be preempted mid-dispatch).
    """

    max_attempts: int = 3
    budget: int = 8
    backoff_base_s: float = 0.0
    backoff_cap_s: float = 1.0
    jitter: float = 0.1
    timeout_s: float | None = None

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.budget < 0:
            raise ValueError(f"budget must be >= 0, got {self.budget}")

    def retryable(self, exc: BaseException) -> bool:
        """Transient blips and corrupt results are retryable; an outage is
        the breaker's business, anything else the caller's."""
        return isinstance(exc, (TransientFault, CorruptResult))

    def delay(self, seed: int, platform: str, round_idx: int,
              attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based) of (platform, round).

        ``min(base * 2^(attempt-1), cap)`` scaled by a seeded jitter in
        ``[1 - jitter, 1 + jitter)`` — a pure function of its coordinates,
        so every executor mode (and every replay) backs off identically.
        """
        if self.backoff_base_s <= 0.0:
            return 0.0
        base = min(self.backoff_base_s * 2.0 ** (attempt - 1),
                   self.backoff_cap_s)
        u = _unit_jitter("retry", seed, platform, round_idx, attempt)
        return max(base * (1.0 + self.jitter * u), 0.0)


# --------------------------------------------------------------------------
# Circuit breaker
# --------------------------------------------------------------------------

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half-open"


@dataclasses.dataclass(frozen=True)
class BreakerTransition:
    """One platform health-state change (the report's audit trail)."""

    platform: str
    frm: str
    to: str
    at: float          # workload elapsed virtual time
    round: int = -1


class CircuitBreaker:
    """Per-platform CLOSED/OPEN/HALF_OPEN health state with recovery.

    ``record_failure``/``record_success`` feed round outcomes in;
    ``poll`` applies the time-based OPEN -> HALF_OPEN transition and
    returns the current state. Time is whatever monotone scalar the
    caller supplies — the online loop uses the workload's elapsed virtual
    makespan, a round-barrier quantity identical across executor modes.
    """

    def __init__(self, failure_threshold: int = 2, cooldown_s: float = 0.0,
                 tracer=None):
        if failure_threshold < 1:
            raise ValueError(
                f"failure_threshold must be >= 1, got {failure_threshold}")
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        #: optional repro.obs.Tracer; state transitions become instant
        #: events on the platform's trace track
        self.tracer = tracer
        self._state: dict[str, str] = {}
        self._fails: dict[str, int] = {}
        self._opened_at: dict[str, float] = {}
        self.transitions: list[BreakerTransition] = []

    def _move(self, platform: str, to: str, now: float, round_idx: int) -> None:
        frm = self.state(platform)
        if frm == to:
            return
        self._state[platform] = to
        self.transitions.append(
            BreakerTransition(platform, frm, to, at=now, round=round_idx))
        if self.tracer is not None:
            self.tracer.instant(f"breaker:{to}", track=platform,
                                cat="breaker", frm=frm, at=now,
                                round=round_idx)
        if to == OPEN:
            self._opened_at[platform] = now

    # -- queries -----------------------------------------------------------

    def state(self, platform: str) -> str:
        return self._state.get(platform, CLOSED)

    def available(self, platform: str) -> bool:
        """CLOSED only: HALF_OPEN platforms take probes, not allocation."""
        return self.state(platform) == CLOSED

    def failures(self, platform: str) -> int:
        return self._fails.get(platform, 0)

    def poll(self, platform: str, now: float, round_idx: int = -1) -> str:
        """Apply the cooldown transition (OPEN -> HALF_OPEN) and return the
        state; call once per platform per round, at the round barrier."""
        if (self.state(platform) == OPEN
                and now >= self._opened_at.get(platform, 0.0) + self.cooldown_s):
            self._move(platform, HALF_OPEN, now, round_idx)
        return self.state(platform)

    # -- outcome feeds -----------------------------------------------------

    def record_failure(self, platform: str, now: float,
                       round_idx: int = -1) -> str:
        """One failed round (or failed probe): HALF_OPEN re-opens at once,
        CLOSED opens after ``failure_threshold`` consecutive failures."""
        state = self.state(platform)
        if state == HALF_OPEN:
            self._move(platform, OPEN, now, round_idx)
        else:
            self._fails[platform] = self._fails.get(platform, 0) + 1
            if state == CLOSED and self._fails[platform] >= self.failure_threshold:
                self._move(platform, OPEN, now, round_idx)
        return self.state(platform)

    def record_success(self, platform: str, now: float,
                       round_idx: int = -1) -> str:
        """A clean dispatch (or successful probe) resets the streak and
        promotes HALF_OPEN back to CLOSED — the platform re-enters the
        allocation on the next re-solve."""
        self._fails[platform] = 0
        if self.state(platform) == HALF_OPEN:
            self._move(platform, CLOSED, now, round_idx)
        return self.state(platform)

    def reset_streak(self, platform: str) -> None:
        """An idle round breaks a CLOSED platform's failure streak: the
        threshold counts *consecutive* failed rounds."""
        if self.state(platform) == CLOSED:
            self._fails[platform] = 0

    def open_platforms(self) -> tuple[str, ...]:
        """Platforms currently not CLOSED (the report's ``dead`` set)."""
        return tuple(sorted(pn for pn, st in self._state.items()
                            if st != CLOSED))


# --------------------------------------------------------------------------
# Event records (JSONL-persistable; see repro.runtime.records)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault occurrence and what the runtime did about it.

    ``task_id`` is -1 for platform-level events (probes, timeouts spanning
    a whole group); ``latency`` is the virtual time the failure itself
    burned (clock advance minus salvaged record latencies) so makespan
    accounting can charge storms honestly. The taxonomy bucket is named
    ``fault`` rather than ``kind`` because the JSONL record envelope
    (:mod:`repro.runtime.records`) reserves ``kind`` for the class name.
    """

    platform: str
    task_id: int
    round: int
    fault: str         # "transient" | "outage" | "corrupt" | "timeout" | "probe"
    action: str        # "retried" | "exhausted" | "probe-failed" | "probe-ok"
    attempt: int = 0
    latency: float = 0.0


@dataclasses.dataclass(frozen=True)
class DegradationEvent:
    """One task's quality target relaxed one rung (accuracy-for-latency)."""

    task_id: int
    round: int
    quality_from: float
    quality_to: float
    rung: int          # 1-based index into the degradation ladder
    reason: str        # "capacity" | "deadline"


def fault_kind(exc: BaseException) -> str:
    """Taxonomy bucket of a fault exception, for event records."""
    if isinstance(exc, DispatchTimeout):
        return "timeout"
    if isinstance(exc, CorruptResult):
        return "corrupt"
    if isinstance(exc, PlatformOutage):
        return "outage"
    if isinstance(exc, TransientFault):
        return "transient"
    return type(exc).__name__


def count_retries(events: Iterable[FaultEvent]) -> int:
    """Total retried attempts in a fault-event log."""
    return sum(1 for e in events if e.action == "retried")
