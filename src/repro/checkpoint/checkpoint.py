"""Sharded, resumable checkpoints with async save.

Layout: <dir>/step_<n>/
    manifest.json          step, keys, shapes, dtypes
    arrays.npz             one entry per flat key ('/' -> '::')

Save runs on a background thread (double-buffered: the arrays are
device_get'd synchronously — cheap relative to a step — and written to
disk asynchronously, so training never blocks on the filesystem). Restore
optionally re-shards onto a *different* mesh than the one that saved:
arrays are read as host numpy and placed with jax.device_put against the
target sharding, which is the elastic-rescale path (checkpoints are
mesh-shape-agnostic).

Fault tolerance contract (tested in tests/test_training.py):
  * atomic publish — the step directory is renamed into place, so a crash
    mid-write never yields a half-checkpoint;
  * ``latest_step`` scans for the newest complete checkpoint;
  * restore(step) == the exact params/opt-state/step saved, bit-for-bit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import numpy as np

__all__ = ["Checkpointer"]

_SAFE = "::"


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Future | None = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------- saving
    def save(self, step: int, trees: dict[str, dict], blocking: bool = False):
        """trees: {"params": flat dict, "opt": nested, ...}. Device arrays
        are fetched to host now; disk I/O happens on the worker thread."""
        flat: dict[str, np.ndarray] = {}
        for name, tree in trees.items():
            leaves, _ = jax.tree.flatten(tree)
            for i, l in enumerate(leaves):
                flat[f"{name}{_SAFE}{i}"] = np.asarray(jax.device_get(l))
        self.wait()
        self._pending = self._pool.submit(self._write, int(step), flat,
                                          sorted(trees))
        if blocking:
            self.wait()

    def _write(self, step: int, flat, tree_names):
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "trees": tree_names}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    # ------------------------------------------------------------ loading
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: dict[str, dict],
                shardings: dict[str, dict] | None = None) -> dict[str, dict]:
        """``like``: same-structure trees (shape/dtype templates or abstract
        values). ``shardings``: optional same-structure trees of
        jax.sharding.Sharding for cross-mesh (elastic) restore."""
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            out = {}
            for name, tree in like.items():
                leaves, treedef = jax.tree.flatten(tree)
                got = [z[f"{name}{_SAFE}{i}"] for i in range(len(leaves))]
                if shardings is not None and name in shardings:
                    sh_leaves = jax.tree.flatten(shardings[name])[0]
                    got = [jax.device_put(g, s) for g, s in zip(got, sh_leaves)]
                out[name] = jax.tree.unflatten(treedef, got)
        return out
