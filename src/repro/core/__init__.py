"""repro.core — the paper's contribution: domain metric models + allocation.

Public API:

    Metric models (§3.1/§4.2): LatencyModel, AccuracyModel, CombinedModel,
        fit_latency_model, fit_accuracy_model, relative_error
    Allocation (§3.2/§4.3): AllocationProblem, Allocation, makespan,
        proportional_allocation (eq. 11), ml_allocation (SA + LP polish),
        milp_allocation (eq. 12 via HiGHS)
    Scale: cluster_tasks / clustered_allocation (task-family super-tasks),
        patch_allocation (O(k) incremental re-solve for k arrivals)
    Synthetic characterisation (§6.1): synthetic.generate / TABLE3_CASES
    Pareto surfaces (§3.2.3): pareto.sweep / platform_curves
    SLO tail metrics: quantile, P2Quantile (streaming P-squared),
        SLOConfig / SLOTracker (TTFT/TPOT/e2e percentiles + attainment)
"""
from .allocation import (  # noqa: F401
    SUPPORT_ATOL,
    Allocation,
    AllocationProblem,
    CapacityError,
    assert_capacity_feasible,
    capacity_ok,
    check_allocation,
    expand_allocation,
    linear_work_reduction,
    makespan,
    mc_work_reduction,
    platform_latencies,
    platform_usage,
    restrict_allocation,
    restrict_problem,
)
from .annealing import anneal, lp_polish, ml_allocation  # noqa: F401
from .clustering import ClusterPlan, cluster_tasks, clustered_allocation  # noqa: F401
from .heuristic import proportional_allocation  # noqa: F401
from .incremental import patch_allocation  # noqa: F401
from .metrics import (  # noqa: F401
    AccuracyModel,
    CombinedModel,
    LatencyModel,
    fit_accuracy_model,
    fit_latency_model,
    relative_error,
    wls,
)
from .milp import milp_allocation  # noqa: F401
from .slo import P2Quantile, SLOConfig, SLOTracker, quantile  # noqa: F401
from . import pareto, synthetic  # noqa: F401
