"""Domain metric models (paper §3.1, §4.2).

The paper's first contribution: for a restricted application domain, the
observable run-time characteristics ("domain metrics") of a task upon a
platform are captured by small parametric models whose structure is known
in advance and whose coefficients are populated at run time by online
benchmarking (weighted least squares, §3.1.4).

For the derivatives-pricing domain the three models are

    latency   f_L(n) = beta * n + gamma                     (eq. 7)
    accuracy  f_C(n) = alpha * n**-0.5                      (eq. 8)
    combined  f_L(c) = delta * c**-2 + gamma, delta=beta*alpha**2   (eq. 9)

where ``n`` is the number of Monte Carlo paths (the domain *variable*) and
``c`` the 95% confidence-interval size in pricing currency.

All fitting is plain numpy; the models are deliberately tiny — the paper's
point is that simple models extrapolate well (§5.3).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "LatencyModel",
    "AccuracyModel",
    "CombinedModel",
    "fit_latency_model",
    "fit_accuracy_model",
    "relative_error",
    "wls",
]


def wls(X: np.ndarray, y: np.ndarray, w: np.ndarray | None = None) -> np.ndarray:
    """Weighted least squares:  argmin_b || W^(1/2) (X b - y) ||.

    Solved via the normal equations with an SVD-backed lstsq for rank
    robustness (benchmarking matrices are tall and thin, b x p with p<=2).
    """
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    if w is None:
        w = np.ones_like(y)
    sw = np.sqrt(np.asarray(w, dtype=np.float64))
    coef, *_ = np.linalg.lstsq(X * sw[:, None], y * sw, rcond=None)
    return coef


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """f_L(n) = beta * n + gamma  (eq. 7).

    ``beta``  — seconds per Monte Carlo path (compute capability).
    ``gamma`` — constant: setup + task communication (network RTT dominates
                for remote platforms, §5.3).
    """

    beta: float
    gamma: float

    def __call__(self, n) -> np.ndarray:
        return self.beta * np.asarray(n, dtype=np.float64) + self.gamma

    def paths_for_latency(self, t: float) -> float:
        """Invert the model: how many paths fit in a latency budget ``t``."""
        return max((t - self.gamma) / self.beta, 0.0)


@dataclasses.dataclass(frozen=True)
class AccuracyModel:
    """f_C(n) = alpha * n**-1/2  (eq. 8) — MC estimator 95% CI size.

    ``alpha`` = 1.96 * sigma-hat of the payoff distribution (per unit path).
    """

    alpha: float

    def __call__(self, n) -> np.ndarray:
        n = np.asarray(n, dtype=np.float64)
        return self.alpha / np.sqrt(n)

    def paths_for_accuracy(self, c: float) -> float:
        """Paths required to achieve a CI of size ``c``."""
        return (self.alpha / c) ** 2


@dataclasses.dataclass(frozen=True)
class CombinedModel:
    """f_L(c) = delta * c**-2 + gamma with delta = beta * alpha**2 (eq. 9).

    Latency needed on this platform to price this task to accuracy ``c`` —
    the unified model that drives the allocation program (eq. 10).
    """

    delta: float
    gamma: float

    @classmethod
    def from_models(cls, lat: LatencyModel, acc: AccuracyModel) -> "CombinedModel":
        return cls(delta=lat.beta * acc.alpha**2, gamma=lat.gamma)

    def __call__(self, c) -> np.ndarray:
        c = np.asarray(c, dtype=np.float64)
        return self.delta / (c * c) + self.gamma


def fit_latency_model(
    paths: Sequence[float],
    latencies: Sequence[float],
    weights: Sequence[float] | None = None,
) -> LatencyModel:
    """Fit eq. 7 by WLS on benchmarking observations (n_i, t_i).

    By default observations are weighted by 1/t_i (relative-error weighting):
    the paper's error metric (eq. 13) is relative, and benchmarking sweeps
    span orders of magnitude in n, so unweighted LS would let the largest
    run dominate the fit.
    """
    n = np.asarray(paths, dtype=np.float64)
    t = np.asarray(latencies, dtype=np.float64)
    w = 1.0 / np.maximum(t, 1e-12) if weights is None else np.asarray(weights)
    X = np.stack([n, np.ones_like(n)], axis=1)
    beta, gamma = wls(X, t, w)
    # Degenerate benchmarks (e.g. RTT-dominated remote platforms, §5.3) can
    # produce a slightly negative slope or intercept; clamp to the model's
    # domain R+ rather than returning an invalid program input.
    return LatencyModel(beta=float(max(beta, 1e-12)), gamma=float(max(gamma, 0.0)))


def fit_accuracy_model(
    paths: Sequence[float],
    cis: Sequence[float],
    weights: Sequence[float] | None = None,
) -> AccuracyModel:
    """Fit eq. 8 by WLS on (n_i, ci_i): linear in the basis n**-1/2."""
    n = np.asarray(paths, dtype=np.float64)
    c = np.asarray(cis, dtype=np.float64)
    w = 1.0 / np.maximum(c, 1e-300) if weights is None else np.asarray(weights)
    X = (1.0 / np.sqrt(n))[:, None]
    (alpha,) = wls(X, c, w)
    return AccuracyModel(alpha=float(max(alpha, 1e-300)))


def relative_error(predicted, observed) -> np.ndarray:
    """E_k = |f_k(n) - f̂_k,n| / f̂_k,n  (eq. 13)."""
    predicted = np.asarray(predicted, dtype=np.float64)
    observed = np.asarray(observed, dtype=np.float64)
    return np.abs(predicted - observed) / np.abs(observed)
