"""Task-family clustering: solve the allocation on super-tasks.

Production workloads are dominated by *families* of structurally identical
tasks: requests of the same (model, n_steps) pair, LM calls from the same
request family. Two tasks with the same work column, the same gamma column,
the same resource column and the same quality target are interchangeable to
eq. 10 — the objective only sees their *summed* shares per platform. This
module exploits that: group tasks by their (delta, gamma, resource, c)
signature, solve the reduced problem over one super-task per family, and
split the super-task's shares back over the members.

The work/resource dimensions reduce exactly (both are linear in the
shares), so the reduction's only modelling freedom is gamma — the constant
each platform pays *per member it touches*, which a single aggregated
column cannot express. Three models are shipped (see
:meth:`ClusterPlan.reduce`), and :func:`clustered_allocation` solves the
small reduced problem under more than one, expands each candidate, then
refines at *member* granularity: a greedy descent that moves whole member
shares off the bottleneck platform, alternated with the exact fixed-support
LP polish. The exactness anchor is the ``sum`` model with the proportional
expansion, whose reduced objective equals the expanded full-frame makespan
identically; the default (model ensemble + contiguous expansion + descent +
polish) trades that identity for near-optimal quality at a solve cost
driven by the number of *families*, not the number of tasks.

Near-identical families (``rtol > 0``) quantise the signature on a
relative grid, cluster by grid cell, and represent each family by its
summed columns — the bounded-error fallback: any member's column differs
from the family representative by at most O(rtol), so expanded latencies
differ by the same relative order. Capacity rows are re-checked after
expansion and repaired via the water-filling clamp.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .allocation import (
    Allocation,
    AllocationProblem,
    SUPPORT_ATOL,
    capacity_ok,
    makespan,
    platform_latencies,
    platform_usage,
)
from .heuristic import clamp_to_capacity, proportional_allocation

__all__ = ["ClusterPlan", "cluster_tasks", "clustered_allocation"]


@dataclasses.dataclass(frozen=True)
class ClusterPlan:
    """Mapping between a full problem's tasks and its super-tasks.

    groups : member column indices per super-task, in order of first
             appearance — ``groups[k]`` are the full-frame columns fused
             into reduced column k.
    tau    : number of tasks in the full frame.
    rtol   : the relative quantisation used to form the groups (0 = exact
             byte-identical signatures).
    """

    groups: tuple[tuple[int, ...], ...]
    tau: int
    rtol: float = 0.0

    @property
    def n_clusters(self) -> int:
        return len(self.groups)

    @property
    def member_of(self) -> np.ndarray:
        """(tau,) array mapping each full-frame column to its group index."""
        out = np.empty(self.tau, dtype=int)
        for k, members in enumerate(self.groups):
            out[list(members)] = k
        return out

    def reduce(self, problem: AllocationProblem,
               gamma_model: str = "indicator") -> AllocationProblem:
        """The super-task problem over member-summed columns.

        Work columns sum exactly: both shipped reductions are linear in
        delta, so a super-task share ``f`` on platform i costs exactly the
        work of ``f`` of every member. The reduced problem is expressed
        directly in work units (c = 1): its columns already carry the
        parent's quality reduction.

        Gamma needs a model — the true constant cost is *per member
        touched* (under the contiguous split, a platform holding share
        ``f`` of an m-member family touches ~``m*f (+1)`` members), which
        a single aggregated column cannot express:

        ``indicator`` (default): all-but-one member's gamma folds into the
        work column (linear in f) and the indicator charges one member's
        gamma — exact at ``f = 1/m`` and ``f = 1``, overcharges mid-range
        by less than one member constant per (platform, family).

        ``fold``: the whole summed gamma folds into the work column and
        the indicator vanishes — the reduced problem becomes (nearly) an
        LP, exact at every integer member count, but *under*charges tiny
        slivers, so solvers over-spread; best at high multiplicity.

        ``sum``: a platform touching the super-task pays every member's
        gamma — the conservative model, and the one under which the
        reduced objective equals the proportional expansion's true
        makespan identically (the exactness anchor). Over-prices
        spreading, so solvers over-concentrate.
        """
        if problem.tau != self.tau:
            raise ValueError(f"plan built for tau={self.tau}, problem has {problem.tau}")
        if gamma_model not in ("indicator", "fold", "sum"):
            raise ValueError(f"unknown gamma_model {gamma_model!r}")
        K = self.n_clusters
        W = problem.work
        work = np.empty((problem.mu, K))
        gamma = np.empty((problem.mu, K))
        resource = None if problem.resource is None else np.empty((problem.mu, K))
        for k, members in enumerate(self.groups):
            idx = list(members)
            g_sum = problem.gamma[:, idx].sum(axis=1)
            work[:, k] = W[:, idx].sum(axis=1)
            if gamma_model == "sum":
                gamma[:, k] = g_sum
            elif gamma_model == "fold":
                work[:, k] += g_sum
                gamma[:, k] = 0.0
            else:
                g_rep = g_sum / len(idx)
                work[:, k] += g_sum - g_rep
                gamma[:, k] = g_rep
            if resource is not None:
                resource[:, k] = problem.resource[:, idx].sum(axis=1)
        reduced = AllocationProblem.from_work(work, gamma)
        return dataclasses.replace(reduced, offsets=problem.offsets,
                                   resource=resource, capacity=problem.capacity)

    def expand(self, A_reduced: np.ndarray, mode: str = "contiguous") -> np.ndarray:
        """Split super-task shares back over the members.

        ``proportional``: every member gets the super-task's share vector —
        per-platform work/gamma-sum/usage equal the reduced solution's
        exactly, but every supporting platform touches every member.

        ``contiguous``: the members are laid out consecutively on [0, m)
        and each platform's share of the super-task becomes a contiguous
        segment; a member's share on a platform is the overlap of its unit
        interval with the platform's segment. Per-platform *mass* (work,
        usage) is unchanged, while each platform now touches only the
        members inside its segment — it sheds gamma constants relative to
        the proportional split, so its true latency is never worse for
        identical families.
        """
        A_reduced = np.asarray(A_reduced, dtype=np.float64)
        mu = A_reduced.shape[0]
        if A_reduced.shape != (mu, self.n_clusters):
            raise ValueError(f"reduced allocation is {A_reduced.shape}, "
                             f"plan has {self.n_clusters} clusters")
        A = np.zeros((mu, self.tau))
        for k, members in enumerate(self.groups):
            idx = list(members)
            m = len(idx)
            f = A_reduced[:, k]
            if m == 1 or mode == "proportional":
                A[:, idx] = f[:, None]
                continue
            bounds = m * np.concatenate(([0.0], np.cumsum(f)))
            starts = np.arange(m, dtype=np.float64)
            lo = np.maximum(bounds[:-1, None], starts[None, :])
            hi = np.minimum(bounds[1:, None], (starts + 1.0)[None, :])
            S = np.clip(hi - lo, 0.0, None)  # (mu, m) member shares
            S[S < SUPPORT_ATOL] = 0.0
            colsum = S.sum(axis=0)
            short = colsum <= SUPPORT_ATOL  # float-drift stranded a member
            if short.any():
                S[:, short] = f[:, None]
                colsum = S.sum(axis=0)
            A[:, idx] = S / colsum
        return A


def cluster_tasks(problem: AllocationProblem, rtol: float = 0.0) -> ClusterPlan:
    """Group tasks whose (delta, gamma, resource, c) columns coincide.

    ``rtol == 0`` clusters byte-identical signatures (exact). ``rtol > 0``
    quantises each positive entry onto a log grid of ratio ``1 + rtol`` and
    clusters by grid cell, merging near-identical families at bounded
    relative error.
    """
    feats = [problem.delta, problem.gamma]
    if problem.resource is not None:
        feats.append(problem.resource)
    F = np.vstack(feats + [problem.c[None, :]])
    if rtol > 0.0:
        with np.errstate(divide="ignore"):
            L = np.where(F > 0, np.log(np.maximum(F, 1e-300)), -np.inf)
        F = np.where(np.isfinite(L), np.round(L / np.log1p(rtol)), -np.inf)
    order: dict[bytes, int] = {}
    groups: list[list[int]] = []
    cols = np.ascontiguousarray(F.T)
    for j in range(problem.tau):
        key = cols[j].tobytes()
        k = order.get(key)
        if k is None:
            order[key] = len(groups)
            groups.append([j])
        else:
            groups[k].append(j)
    return ClusterPlan(groups=tuple(tuple(g) for g in groups),
                       tau=problem.tau, rtol=rtol)


def _member_descent(problem: AllocationProblem, A: np.ndarray,
                    max_moves: int = 400) -> np.ndarray:
    """Greedy member-granular descent on the true objective.

    Repeatedly move the *whole* of one (bottleneck-platform, task) share to
    the platform that minimises the resulting makespan, until no single
    move improves. This is the refinement the reduced frame cannot do —
    its gamma models misprice member placement by up to one constant per
    (platform, family), and exactly such whole-member moves repair it.
    Capacity rows veto any receiving platform the move would oversubscribe.
    """
    A = np.asarray(A, dtype=np.float64).copy()
    W, G = problem.work, problem.gamma
    R, cap = problem.resource, problem.capacity
    for _ in range(max_moves):
        H = platform_latencies(A, problem)
        order = np.argsort(H)
        b = int(order[-1])
        m_cur = H[b]
        runner = H[order[-2]] if H.size > 1 else 0.0
        js = np.nonzero(A[b] > SUPPORT_ATOL)[0]
        if js.size == 0:
            break
        shares = A[b, js]
        Hb_new = H[b] - W[b, js] * shares - G[b, js]
        supp = A[:, js] > SUPPORT_ATOL
        Hi_new = H[:, None] + W[:, js] * shares[None, :] + G[:, js] * (~supp)
        Hi_new[b] = np.inf
        cand = np.maximum(np.maximum(Hb_new[None, :], Hi_new), runner)
        if cap is not None:
            usage = platform_usage(A, problem)
            over = (usage[:, None] + R[:, js] * shares[None, :]
                    > cap[:, None] * (1 + 1e-9) + 1e-12)
            cand = np.where(over, np.inf, cand)
        i_best, j_best = np.unravel_index(np.argmin(cand), cand.shape)
        if cand[i_best, j_best] >= m_cur * (1 - 1e-12):
            break
        j = js[j_best]
        A[i_best, j] += A[b, j]
        A[b, j] = 0.0
    return A


def _refine(problem: AllocationProblem, A: np.ndarray,
            max_rounds: int = 3) -> tuple[np.ndarray, float]:
    """Alternate member descent with the exact fixed-support LP polish."""
    from .annealing import _iterated_polish

    best_A, best_m = A, makespan(A, problem)
    for _ in range(max_rounds):
        A1 = _member_descent(problem, best_A)
        A2, m2 = _iterated_polish(problem, A1)
        if A2 is None:
            A2, m2 = A1, makespan(A1, problem)
        if m2 < best_m * (1 - 1e-9):
            best_A, best_m = A2, m2
        else:
            break
    return best_A, best_m


def _solver_table():
    # local import: milp/annealing import heuristic, which this module uses
    from .annealing import ml_allocation
    from .milp import milp_allocation

    return {
        "heuristic": lambda p, **kw: proportional_allocation(p),
        "ml": ml_allocation,
        "milp": milp_allocation,
    }


def clustered_allocation(
    problem: AllocationProblem,
    method: str = "milp",
    *,
    rtol: float = 0.0,
    expand: str = "contiguous",
    plan: ClusterPlan | None = None,
    refine: bool = True,
    **solver_kw,
) -> Allocation:
    """Cluster task families, solve reduced, expand, refine at member level.

    Falls through to a plain solve when nothing clusters. With the default
    ``expand="contiguous"`` the reduced problem is solved under both the
    ``indicator`` and ``fold`` gamma models (it is small — that is the
    point), each candidate is expanded and refined (member descent + exact
    LP polish on the realised support), and the best true makespan wins.
    ``expand="proportional"`` is the exactness path: single ``sum``-model
    solve whose reduced objective equals the expanded makespan identically.

    The expanded allocation is capacity-checked (quantised clustering can
    overshoot by O(rtol)) and clamped back into the rows when needed; the
    proportional expansion — which preserves the reduced solution's usage —
    is the fallback when the clamp cannot repair it.

    The returned meta carries ``clustered_from`` / ``n_clusters`` /
    ``cluster_s`` so telemetry shows what the solver actually saw. A
    reduced MILP's dual bound certifies only the family-symmetric
    restriction of the full problem, so ``optimal``/``bound`` are not
    propagated.
    """
    t0 = time.perf_counter()
    solvers = _solver_table()
    if method not in solvers:
        raise ValueError(f"unknown method {method!r}; pick from {sorted(solvers)}")
    if plan is None:
        plan = cluster_tasks(problem, rtol)
    cluster_s = time.perf_counter() - t0
    if plan.n_clusters == problem.tau:
        alloc = solvers[method](problem, **solver_kw)
        alloc.meta.update(clustered_from=problem.tau, n_clusters=problem.tau,
                          cluster_rtol=rtol, cluster_s=cluster_s)
        return alloc

    models = ("sum",) if expand == "proportional" else ("indicator", "fold")
    best_A = None
    best_m = np.inf
    sub_meta: dict = {}
    sub_solver = method
    # every gamma-model sub-solve's meta is kept (meta["inner"], one per
    # model, tagged with which model it solved) — the flattened top level
    # still mirrors the first for backward compatibility, with phase
    # timings aggregated across all sub-solves
    inner_metas: list[dict] = []
    for gamma_model in models:
        reduced_problem = plan.reduce(problem, gamma_model=gamma_model)
        sub = solvers[method](reduced_problem, **solver_kw)
        inner_metas.append({"gamma_model": gamma_model, **sub.meta})
        if not sub_meta:
            sub_meta, sub_solver = dict(sub.meta), sub.solver
        if expand == "proportional":
            A = plan.expand(sub.A, mode="proportional")
        else:
            A = plan.expand(sub.A, mode="contiguous")
            A_prop = plan.expand(sub.A, mode="proportional")
            if makespan(A_prop, problem) < makespan(A, problem):
                # the true objective decides; either split is valid
                A = A_prop
            if problem.capacity is not None and not capacity_ok(A, problem):
                A = clamp_to_capacity(A, problem)
                if not capacity_ok(A, problem):
                    A = A_prop
        if refine and expand != "proportional":
            A, m = _refine(problem, A)
        else:
            m = makespan(A, problem)
        if m < best_m:
            best_A, best_m = A, m
    return Allocation(
        A=best_A,
        makespan=best_m,
        solver=sub_solver,
        solve_time=time.perf_counter() - t0,
        optimal=False,
        bound=None,
        meta={**sub_meta,
              # aggregate phase timings over every gamma-model sub-solve,
              # so the lifted spans account the whole clustered solve
              **{k: sum(float(m.get(k) or 0.0) for m in inner_metas)
                 for k in ("build_s", "solve_s", "polish_s")},
              "clustered_from": problem.tau,
              "n_clusters": plan.n_clusters, "cluster_rtol": rtol,
              "cluster_s": cluster_s, "expand_mode": expand,
              "gamma_models": list(models), "inner": inner_metas},
    )
