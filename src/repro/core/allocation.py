"""The allocation problem (paper §3.2, §4.3.1).

Allocating tau divisible tasks across mu platforms to minimise makespan:

    minimise_{A in R+^{mu x tau}}  G_L(A, c)
    subject to                     sum_i A[i, j] == 1  for every task j

    G_L(A, c)  = max_i H_L(A, c)[i]                               (eq. 10)
    H_L(A, c)  = (delta : c^2  o  A  +  gamma o ceil(A)) . 1

where ``delta : c^2`` is the element-wise division of the delta coefficient
matrix by the squared task accuracies (the *work* matrix W), and the
``gamma o ceil(A)`` term charges each platform the per-task constant
whenever any non-zero fraction of the task is allocated to it — the source
of the problem's non-linearity.

This module holds the problem container plus the reduction functions; the
three solvers live in :mod:`repro.core.heuristic`, :mod:`repro.core.annealing`
and :mod:`repro.core.milp`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

__all__ = [
    "AllocationProblem",
    "Allocation",
    "CapacityError",
    "platform_latencies",
    "platform_usage",
    "capacity_ok",
    "makespan",
    "check_allocation",
    "assert_capacity_feasible",
    "mc_work_reduction",
    "linear_work_reduction",
    "restrict_problem",
    "restrict_allocation",
    "expand_allocation",
    "SUPPORT_ATOL",
]

# An allocation entry below this is treated as "not allocated" for the
# purposes of the ceil() indicator. Solvers snap-to-zero below it.
SUPPORT_ATOL = 1e-9

# Relative slack granted when checking capacity rows: resource units can be
# bytes (1e6-scale), so tolerances must be multiplicative, not absolute.
CAPACITY_RTOL = 1e-6


class CapacityError(ValueError):
    """The instance cannot be allocated within the platform capacities.

    Raised by *every* solver (heuristic, ML, MILP) through the shared
    :func:`assert_capacity_feasible` pre-check, so callers can catch one
    typed error regardless of the method in play.
    """


# -- quality -> work reductions ---------------------------------------------
#
# The allocation program only sees a work matrix W[i, j]: the latency of
# running *all* of task j on platform i, excluding constants. How a task's
# quality requirement c[j] maps onto W is a *domain* property: Monte Carlo
# estimators obey the inverse-square law of eq. 9, while throughput domains
# (e.g. LM token serving) measure quality directly in work units. Solvers
# are agnostic — they only consume ``problem.work``.

def mc_work_reduction(delta: np.ndarray, c: np.ndarray) -> np.ndarray:
    """Monte Carlo domains (eq. 9): W = delta : c^2 (accuracy ~ n^-1/2)."""
    return delta / (c * c)[None, :]


def linear_work_reduction(delta: np.ndarray, c: np.ndarray) -> np.ndarray:
    """Throughput domains: W = delta o c — c counts work units directly
    (e.g. tokens to generate) and delta is seconds per unit."""
    return delta * c[None, :]


@dataclasses.dataclass(frozen=True)
class AllocationProblem:
    """Work/constant matrices for one allocation instance.

    delta : (mu, tau)  combined-model coefficients (eq. 9) per (platform, task)
    gamma : (mu, tau)  per-(platform, task) constants
    c     : (tau,)     required qualities (accuracies, token counts, ...)
    reduction : (delta, c) -> W, the domain's quality->work map.
                Defaults to the Monte Carlo inverse-square law W = delta/c^2.
    offsets : (mu,)    per-platform latency already committed before this
                solve — zero for the one-shot flow; mid-workload re-solves
                (online re-allocation) set each platform's elapsed busy
                time here so the makespan being minimised is the *finish*
                time, completed shares included, not just the remaining
                load. All three solvers honour it.
    resource : (mu, tau)  optional second constraint dimension: resource
                units platform i holds while serving the *whole* of task j
                (e.g. KV-cache bytes for an LM request) — consumption is
                linear in the allocated share, so a platform serving
                ``A[i, j]`` of the task holds ``resource[i, j] * A[i, j]``.
    capacity : (mu,)   per-platform resource budget paired with
                ``resource``; every solver keeps
                ``(resource * A).sum(axis=1) <= capacity`` as a hard row
                constraint, and raises :class:`CapacityError` when no
                allocation can satisfy it.
    """

    delta: np.ndarray
    gamma: np.ndarray
    c: np.ndarray
    reduction: Callable[[np.ndarray, np.ndarray], np.ndarray] = mc_work_reduction
    offsets: np.ndarray | None = None
    resource: np.ndarray | None = None
    capacity: np.ndarray | None = None

    def __post_init__(self):
        delta = np.asarray(self.delta, dtype=np.float64)
        gamma = np.asarray(self.gamma, dtype=np.float64)
        c = np.asarray(self.c, dtype=np.float64)
        if delta.ndim != 2 or gamma.shape != delta.shape:
            raise ValueError(f"delta/gamma must be matching 2-D: {delta.shape} vs {gamma.shape}")
        if c.shape != (delta.shape[1],):
            raise ValueError(f"c must be (tau,): {c.shape} vs tau={delta.shape[1]}")
        if (delta < 0).any() or (gamma < 0).any() or (c <= 0).any():
            raise ValueError("delta, gamma must be >= 0 and c > 0")
        offsets = (np.zeros(delta.shape[0]) if self.offsets is None
                   else np.asarray(self.offsets, dtype=np.float64))
        if offsets.shape != (delta.shape[0],):
            raise ValueError(f"offsets must be (mu,): {offsets.shape} vs mu={delta.shape[0]}")
        if (offsets < 0).any():
            raise ValueError("offsets must be >= 0")
        if (self.resource is None) != (self.capacity is None):
            raise ValueError("resource and capacity must be given together")
        resource = capacity = None
        if self.resource is not None:
            resource = np.asarray(self.resource, dtype=np.float64)
            capacity = np.asarray(self.capacity, dtype=np.float64)
            if resource.shape != delta.shape:
                raise ValueError(
                    f"resource must match delta: {resource.shape} vs {delta.shape}")
            if capacity.shape != (delta.shape[0],):
                raise ValueError(
                    f"capacity must be (mu,): {capacity.shape} vs mu={delta.shape[0]}")
            if (resource < 0).any() or (capacity < 0).any():
                raise ValueError("resource and capacity must be >= 0")
        object.__setattr__(self, "delta", delta)
        object.__setattr__(self, "gamma", gamma)
        object.__setattr__(self, "c", c)
        object.__setattr__(self, "offsets", offsets)
        object.__setattr__(self, "resource", resource)
        object.__setattr__(self, "capacity", capacity)
        # capacity-feasibility verdict cache: None = not yet established.
        # Instances are frozen, so a verdict can never go stale; every solver
        # calls assert_capacity_feasible and only the first should pay the LP.
        object.__setattr__(self, "_cap_feasible", None)

    @property
    def has_capacity(self) -> bool:
        """True when the resource/capacity constraint dimension is active."""
        return self.resource is not None and np.isfinite(self.capacity).any()

    @property
    def mu(self) -> int:
        return self.delta.shape[0]

    @property
    def tau(self) -> int:
        return self.delta.shape[1]

    @property
    def work(self) -> np.ndarray:
        """W = reduction(delta, c) — latency of the *whole* task j on
        platform i, excluding constants."""
        return self.reduction(self.delta, self.c)

    @property
    def full_latency(self) -> np.ndarray:
        """L = W + gamma — eq. 3's relative latency matrix (atomic view)."""
        return self.work + self.gamma

    @classmethod
    def from_work(cls, work: np.ndarray, gamma: np.ndarray) -> "AllocationProblem":
        """Build a problem directly from a work matrix (c folded in, c=1)."""
        work = np.asarray(work, dtype=np.float64)
        return cls(delta=work, gamma=gamma, c=np.ones(work.shape[1]))


@dataclasses.dataclass(frozen=True)
class Allocation:
    """A solver result: the allocation matrix plus solve metadata."""

    A: np.ndarray
    makespan: float
    solver: str
    solve_time: float = 0.0
    optimal: bool = False
    bound: float | None = None  # solver-reported lower bound (MILP dual)
    meta: dict = dataclasses.field(default_factory=dict)


def platform_latencies(A: np.ndarray, problem: AllocationProblem) -> np.ndarray:
    """H_L(A, c): per-platform latency vector (eq. 10's inner reduction),
    plus any already-committed per-platform offsets."""
    A = np.asarray(A, dtype=np.float64)
    support = A > SUPPORT_ATOL
    return ((problem.work * A).sum(axis=1)
            + (problem.gamma * support).sum(axis=1) + problem.offsets)


def makespan(A: np.ndarray, problem: AllocationProblem) -> float:
    """G_L(A, c) = max_i H_L(A, c)[i] (eq. 10's outer reduction)."""
    return float(platform_latencies(A, problem).max())


def platform_usage(A: np.ndarray, problem: AllocationProblem) -> np.ndarray:
    """Per-platform resource consumption of an allocation: (R o A) . 1.

    Zero everywhere when the problem carries no resource dimension."""
    if problem.resource is None:
        return np.zeros(problem.mu)
    return (problem.resource * np.asarray(A, dtype=np.float64)).sum(axis=1)


def capacity_ok(A: np.ndarray, problem: AllocationProblem,
                rtol: float = CAPACITY_RTOL) -> bool:
    """Does the allocation respect every platform's capacity row?"""
    if problem.capacity is None:
        return True
    usage = platform_usage(A, problem)
    return bool((usage <= problem.capacity * (1 + rtol) + rtol).all())


def assert_capacity_feasible(problem: AllocationProblem) -> None:
    """Raise :class:`CapacityError` when no allocation fits the capacities.

    Shared pre-check of all three solvers (heuristic, ML, MILP) so an
    infeasible instance produces the *same* typed error from every one of
    them. Feasibility of {A >= 0, columns sum to 1, (R o A).1 <= capacity}
    is a small transportation LP, but most instances never need it: the
    verdict is cached on the (frozen) problem, an unbounded platform or a
    greedy cheapest-placement that fits proves feasibility outright, and a
    cheap necessary condition (even each task's cheapest placement exceeds
    the summed capacity) short-circuits the aggregate-infeasible case with
    a precise message. The LP runs only when every cheap test is
    inconclusive.
    """
    if not problem.has_capacity:
        return
    verdict = getattr(problem, "_cap_feasible", None)
    if verdict is True:
        return
    if isinstance(verdict, CapacityError):
        raise verdict
    R, cap = problem.resource, problem.capacity
    best_case = R.min(axis=0).sum()  # every task on its cheapest platform
    total_cap = cap.sum()
    if best_case > total_cap * (1 + CAPACITY_RTOL):
        err = CapacityError(
            f"workload needs >= {best_case:.6g} resource units even on each "
            f"task's cheapest platform, but the fleet holds {total_cap:.6g}")
        object.__setattr__(problem, "_cap_feasible", err)
        raise err
    # sufficient checks, cheapest first: any unbounded platform can absorb
    # the whole workload; otherwise try placing each task wholly on its
    # cheapest platform and see whether that already fits the budgets.
    if np.isinf(cap).any():
        object.__setattr__(problem, "_cap_feasible", True)
        return
    cheapest = R.argmin(axis=0)
    usage = np.bincount(cheapest, weights=R[cheapest, np.arange(problem.tau)],
                        minlength=problem.mu)
    if (usage <= cap * (1 + CAPACITY_RTOL)).all():
        object.__setattr__(problem, "_cap_feasible", True)
        return
    # exact check: feasibility LP over the shares (HiGHS, mu*tau variables;
    # only the finite capacity rows can ever bind)
    from scipy.optimize import linprog
    import scipy.sparse as sp

    mu, tau = problem.mu, problem.tau
    n = mu * tau
    jj = np.arange(n)
    A_eq = sp.csr_matrix((np.ones(n), (jj % tau, jj)), shape=(tau, n))
    finite = np.nonzero(np.isfinite(cap))[0]
    rows = np.repeat(np.arange(finite.size), tau)
    cols = (finite[:, None] * tau + np.arange(tau)[None, :]).ravel()
    A_ub = sp.csr_matrix((R[finite].ravel(), (rows, cols)),
                         shape=(finite.size, n))
    res = linprog(np.zeros(n), A_ub=A_ub, b_ub=cap[finite], A_eq=A_eq,
                  b_eq=np.ones(tau), bounds=(0, 1), method="highs")
    if not res.success:
        err = CapacityError(
            "no allocation satisfies the per-platform capacities "
            f"(capacity={np.array2string(cap, precision=4)}; LP status "
            f"{res.status}: {res.message})")
        object.__setattr__(problem, "_cap_feasible", err)
        raise err
    object.__setattr__(problem, "_cap_feasible", True)


# -- sub-problems over remaining work (online re-allocation) -----------------
#
# Mid-workload, part of every task is already executed and some platforms may
# be gone (outage). The re-solve therefore runs on a *restricted* problem:
# surviving platform rows, still-active task columns, and each kept task's
# work scaled by its remaining fraction. Completed shares stay fixed — they
# are simply absent from the sub-problem — and the solution is expanded back
# into the full (mu, tau) frame for dispatch accounting.

def restrict_problem(
    problem: AllocationProblem,
    platforms: Sequence[int] | None = None,
    tasks: Sequence[int] | None = None,
    remaining: Sequence[float] | None = None,
    offsets: Sequence[float] | None = None,
    capacity: Sequence[float] | None = None,
) -> AllocationProblem:
    """Sub-problem over platform rows / task columns with remaining work.

    ``remaining`` (aligned with the kept ``tasks``) scales each kept task's
    delta column by its outstanding work fraction; both shipped reductions
    (inverse-square and linear) are linear in delta, so this scales the work
    matrix W by exactly that fraction while gamma — charged per dispatch,
    however little work remains — is kept whole. ``offsets`` (full-frame,
    one per original platform) carries each platform's already-elapsed
    busy time into the sub-problem, so the re-solve minimises finish time
    rather than piling remaining work onto a platform that is merely idle
    *in the sub-problem's frame*.

    The resource dimension restricts the same way: kept resource columns
    scale by ``remaining`` (consumption is linear in the outstanding
    share), and ``capacity`` (full-frame, one per original platform)
    overrides each platform's budget with whatever it has *left* — held
    shards of still-active tasks are committed history a mid-run re-solve
    must fit around, exactly as ``offsets`` carries elapsed time.
    """
    rows = np.arange(problem.mu) if platforms is None else np.asarray(platforms, dtype=int)
    cols = np.arange(problem.tau) if tasks is None else np.asarray(tasks, dtype=int)
    if rows.size == 0 or cols.size == 0:
        raise ValueError("restricted problem needs >= 1 platform and >= 1 task")
    # Avoid np.ix_ fancy-indexing copies where a cheaper path exists: a
    # full-frame restriction (rows and cols both identity) reuses the parent
    # arrays outright, and restricting only one axis copies O(kept) rather
    # than materialising the index product. Matters for the O(k) incremental
    # re-solve path, where cols is k << tau.
    rows_all = platforms is None or (
        rows.size == problem.mu and rows[0] == 0 and rows[-1] == problem.mu - 1
        and np.array_equal(rows, np.arange(problem.mu)))
    cols_all = tasks is None or (
        cols.size == problem.tau and cols[0] == 0 and cols[-1] == problem.tau - 1
        and np.array_equal(cols, np.arange(problem.tau)))

    def _take(M):
        if M is None:
            return None
        if rows_all and cols_all:
            return M
        if cols_all:
            return M[rows]
        if rows_all:
            return M[:, cols]
        return M[np.ix_(rows, cols)]

    delta = _take(problem.delta)
    resource = _take(problem.resource)
    if remaining is not None:
        r = np.asarray(remaining, dtype=np.float64)
        if r.shape != (cols.size,):
            raise ValueError(f"remaining must align with kept tasks: {r.shape} vs {cols.size}")
        if (r <= 0).any() or (r > 1 + 1e-9).any():
            raise ValueError("remaining fractions must be in (0, 1]")
        delta = delta * r[None, :]
        if resource is not None:
            resource = resource * r[None, :]
    off = problem.offsets if offsets is None else np.asarray(offsets, dtype=np.float64)
    if capacity is not None and problem.resource is None:
        raise ValueError("capacity override needs a problem with a resource matrix")
    cap = problem.capacity if capacity is None else np.asarray(capacity, dtype=np.float64)
    return AllocationProblem(delta=delta, gamma=_take(problem.gamma),
                             c=problem.c if cols_all else problem.c[cols],
                             reduction=problem.reduction,
                             offsets=off if rows_all else off[rows],
                             resource=resource,
                             capacity=cap if cap is None or rows_all else cap[rows])


def restrict_allocation(A: np.ndarray, platforms: Sequence[int],
                        tasks: Sequence[int]) -> np.ndarray:
    """Project an allocation into a sub-problem frame (warm-start incumbent).

    Columns that lose all their mass (every supporting platform dropped)
    fall back to a uniform share over the kept platforms; all columns are
    renormalised to sum to 1.
    """
    rows = np.asarray(platforms, dtype=int)
    cols = np.asarray(tasks, dtype=int)
    sub = np.asarray(A, dtype=np.float64)[np.ix_(rows, cols)].copy()
    colsum = sub.sum(axis=0)
    orphan = colsum <= SUPPORT_ATOL
    if orphan.any():
        sub[:, orphan] = 1.0 / rows.size
        colsum = sub.sum(axis=0)
    return sub / colsum


def expand_allocation(A_sub: np.ndarray, mu: int, tau: int,
                      platforms: Sequence[int], tasks: Sequence[int]) -> np.ndarray:
    """Embed a sub-problem allocation back into the full (mu, tau) frame.

    Dropped rows/columns are zero — completed tasks need no allocation and
    dead platforms must receive none — so the result is *not* a valid full
    allocation (done columns do not sum to 1); it is the dispatch plan for
    the remaining work only.
    """
    full = np.zeros((mu, tau))
    full[np.ix_(np.asarray(platforms, dtype=int), np.asarray(tasks, dtype=int))] = A_sub
    return full


def check_allocation(A: np.ndarray, problem: AllocationProblem, atol: float = 1e-6) -> None:
    """Validate the eq. 10 constraints (and, when the problem carries a
    resource dimension, the capacity rows); raises AssertionError on
    violation."""
    A = np.asarray(A)
    assert A.shape == (problem.mu, problem.tau), (A.shape, problem.mu, problem.tau)
    assert (A >= -atol).all(), "negative allocation"
    col = A.sum(axis=0)
    assert np.allclose(col, 1.0, atol=atol), f"column sums != 1 (max err {np.abs(col - 1).max():.2e})"
    if problem.capacity is not None:
        usage = platform_usage(A, problem)
        over = usage - problem.capacity
        assert capacity_ok(A, problem, rtol=max(atol, CAPACITY_RTOL)), \
            f"capacity exceeded (max over {over.max():.6g} units)"
