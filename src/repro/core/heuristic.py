"""Proportional allocation heuristic (paper §4.3.2, eq. 11).

Every task is split across all platforms with the *same* per-platform share,
inversely proportional to the makespan each platform would see if it ran the
entire workload alone:

    A[i, j] = ( L_i * sum_o 1/L_o )**-1,   L = H_L(1, c)

The heuristic is optimal when the gamma constants vanish and the work matrix
is rank-1 (platform speed independent of task); when constants dominate it
degrades badly because it charges *every* platform *every* task's constant —
exactly the regime where the ML/MILP solvers win (paper §6.3).

With a resource dimension the proportional shares are followed by a
*water-filling clamp* (:func:`clamp_to_capacity`): overloaded platforms
shed task shares onto the platforms with the most remaining capacity until
every row fits, so the heuristic stays a feasible upper bound the other
solvers are measured against.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .allocation import (
    CAPACITY_RTOL,
    Allocation,
    AllocationProblem,
    SUPPORT_ATOL,
    assert_capacity_feasible,
    capacity_ok,
    makespan,
    platform_latencies,
)

__all__ = ["proportional_allocation", "incumbent_shortcut", "clamp_to_capacity"]


def clamp_to_capacity(A: np.ndarray, problem: AllocationProblem,
                      max_sweeps: int = 64) -> np.ndarray:
    """Water-fill an allocation into the capacity rows.

    Greedy repair: the most-overloaded platform pours its largest resource
    contributions into the platforms with the most slack (cheapest work
    first among equals) until every row fits. The instance must have
    passed :func:`assert_capacity_feasible`; if the greedy pass stalls
    (pathological resource geometry), the caller is expected to fall back
    to an exact LP (see :func:`repro.core.annealing.lp_polish`, which
    carries the capacity rows).

    Returns a new matrix; the input is not modified.
    """
    if problem.capacity is None:
        return np.asarray(A, dtype=np.float64)
    A = np.asarray(A, dtype=np.float64).copy()
    R, cap, W = problem.resource, problem.capacity, problem.work
    mu = problem.mu
    slack_tol = np.where(np.isfinite(cap), cap * CAPACITY_RTOL, np.inf) + 1e-30
    for _ in range(max_sweeps):
        usage = (R * A).sum(axis=1)
        over = usage - cap
        i = int(np.argmax(over))
        if over[i] <= slack_tol[i]:
            return A
        progressed = False
        # shed this row's biggest contributions first
        for j in np.argsort(-(R[i] * A[i])):
            if over[i] <= 0 or R[i, j] <= 0 or A[i, j] <= SUPPORT_ATOL:
                continue
            need = min(A[i, j], over[i] / R[i, j])  # share to move off i
            # receivers: slack per share of task j, cheapest work first.
            # Prefix-sum fill: each receiver takes min(its room, what is
            # still needed after everyone ranked ahead of it) — one
            # vectorised pass instead of a per-receiver Python loop.
            recv = np.nonzero(cap - usage > slack_tol)[0]
            recv = recv[recv != i]
            if recv.size == 0:
                continue
            recv = recv[np.lexsort((R[recv, j], W[recv, j]))]
            with np.errstate(divide="ignore"):
                room = np.where(R[recv, j] <= 0, np.inf,
                                (cap[recv] - usage[recv]) / R[recv, j])
            ahead = np.concatenate(([0.0], np.cumsum(room)[:-1]))
            take = np.minimum(room, np.maximum(need - ahead, 0.0))
            moved = take.sum()
            if moved <= 0:
                continue
            A[recv, j] += take
            A[i, j] -= moved
            usage[recv] += take * R[recv, j]
            usage[i] -= moved * R[i, j]
            over[i] = usage[i] - cap[i]
            progressed = True
        if not progressed:
            break
    return A


def incumbent_shortcut(
    problem: AllocationProblem,
    incumbent,
    solver: str,
    warm_tol: float,
    t0: float,
) -> tuple[np.ndarray, Allocation | None, dict]:
    """Warm-start early exit shared by the optimising solvers.

    Online re-solves usually start from an incumbent allocation (the one
    currently executing). If the incumbent's predicted makespan on the
    re-fitted problem is already within ``warm_tol`` of the fresh
    proportional-heuristic bound, a full solve cannot buy enough to matter —
    e.g. a *uniform* drift that slows every platform equally leaves the
    incumbent optimal — so the solve is skipped and the incumbent returned
    with ``meta["warm_start"] == "skipped"``. Otherwise the caller proceeds
    (``meta["warm_start"] == "solved"``) with the incumbent matrix available
    as a start point.

    An incumbent that violates the problem's *capacity* rows (the re-solve
    carries remaining capacities the executing plan was never solved
    against) is never waved through, however good its makespan looks: the
    returned meta says ``warm_start == "rejected"`` so callers both solve
    for real and repair the matrix before seeding anything with it.

    With per-platform offsets (mid-run re-solves) the incumbent must clear
    the bar in *both* frames to be waved through:

    * on the offset-stripped problem — is its share of the remaining work
      well balanced on its own terms? Late in a run the committed time
      dominates finish times, and an offset-carrying ratio test alone
      would wave anything through;
    * on the offset-carrying problem (tolerance scaled by the remaining
      work, not the finish time) — does it respect who is already busy? A
      remaining-schedule that is flat-optimal can still pile work onto the
      platform with the largest committed backlog.

    With zero offsets both collapse to the plain
    ``m_inc <= heuristic * (1 + warm_tol)``.

    Returns ``(A_incumbent, shortcut, warm_meta)`` where ``shortcut`` is
    the ready Allocation when the solve should be skipped (else None) and
    ``warm_meta`` is the ``warm_start`` metadata the caller folds into its
    own result when it does solve.
    """
    A_inc = np.asarray(incumbent.A if hasattr(incumbent, "A") else incumbent,
                       dtype=np.float64)
    if A_inc.shape != (problem.mu, problem.tau):
        raise ValueError(
            f"incumbent shape {A_inc.shape} does not match problem "
            f"({problem.mu}, {problem.tau}); restrict it first")
    if not capacity_ok(A_inc, problem):
        # the executing plan no longer fits the (remaining) capacities —
        # it must not short-circuit the solve, and callers must repair it
        # before using it as a seed
        return A_inc, None, {"warm_start": "rejected"}
    flat = (dataclasses.replace(problem, offsets=None)
            if problem.offsets.any() else problem)
    heur_flat = proportional_allocation(flat)
    skip = makespan(A_inc, flat) <= heur_flat.makespan * (1.0 + warm_tol)
    if skip and problem.offsets.any():
        heur_off = proportional_allocation(problem)
        skip = (makespan(A_inc, problem)
                <= heur_off.makespan + warm_tol * heur_flat.makespan)
    if skip:
        return A_inc, Allocation(
            A=A_inc.copy(), makespan=makespan(A_inc, problem), solver=solver,
            solve_time=time.perf_counter() - t0, optimal=False,
            meta={"warm_start": "skipped", "warm_tol": warm_tol,
                  "heuristic_bound": heur_flat.makespan,
                  # phase keys are part of every Allocation.meta contract;
                  # a skipped solve ran none of them
                  "build_s": 0.0, "solve_s": 0.0, "polish_s": 0.0},
        ), {"warm_start": "skipped"}
    return A_inc, None, {"warm_start": "solved"}


def proportional_allocation(problem: AllocationProblem) -> Allocation:
    t0 = time.perf_counter()
    assert_capacity_feasible(problem)
    t_build = time.perf_counter() - t0
    ones = np.ones((problem.mu, problem.tau))
    L = platform_latencies(ones, problem)  # L = H_L(1, c)
    free = L <= 0.0
    if free.any():
        # Degenerate platform: an all-zero (delta, gamma) row means zero
        # standalone latency and 1/L blows up. Such platforms are free, so
        # snap to a uniform share across them (makespan 0 — optimal).
        shares = free / free.sum()
    else:
        inv = 1.0 / L
        shares = inv / inv.sum()  # shares[i] = (L_i * sum_o 1/L_o)^-1
    A = np.repeat(shares[:, None], problem.tau, axis=1)
    meta = {}
    if not capacity_ok(A, problem):
        A = clamp_to_capacity(A, problem)
        meta["capacity"] = "clamped"
        if not capacity_ok(A, problem):
            # greedy repair stalled: fall back to the exact LP over the
            # full support (it carries the capacity rows); feasibility is
            # guaranteed by the pre-check above
            from .annealing import lp_polish

            out = lp_polish(problem, np.ones_like(A, dtype=bool))
            if out is None:  # numerically degenerate edge — report honestly
                raise AssertionError(
                    "capacity clamp failed on a feasible instance")
            A, _ = out
            meta["capacity"] = "lp"
    total = time.perf_counter() - t0
    meta.update(build_s=t_build, solve_s=total - t_build, polish_s=0.0,
                n_vars=problem.mu * problem.tau,
                n_constraints=problem.tau + (problem.mu if problem.has_capacity else 0))
    return Allocation(
        A=A,
        makespan=makespan(A, problem),
        solver="heuristic",
        solve_time=total,
        meta=meta,
    )
