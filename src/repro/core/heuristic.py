"""Proportional allocation heuristic (paper §4.3.2, eq. 11).

Every task is split across all platforms with the *same* per-platform share,
inversely proportional to the makespan each platform would see if it ran the
entire workload alone:

    A[i, j] = ( L_i * sum_o 1/L_o )**-1,   L = H_L(1, c)

The heuristic is optimal when the gamma constants vanish and the work matrix
is rank-1 (platform speed independent of task); when constants dominate it
degrades badly because it charges *every* platform *every* task's constant —
exactly the regime where the ML/MILP solvers win (paper §6.3).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .allocation import Allocation, AllocationProblem, makespan, platform_latencies

__all__ = ["proportional_allocation", "incumbent_shortcut"]


def incumbent_shortcut(
    problem: AllocationProblem,
    incumbent,
    solver: str,
    warm_tol: float,
    t0: float,
) -> tuple[np.ndarray, Allocation | None]:
    """Warm-start early exit shared by the optimising solvers.

    Online re-solves usually start from an incumbent allocation (the one
    currently executing). If the incumbent's predicted makespan on the
    re-fitted problem is already within ``warm_tol`` of the fresh
    proportional-heuristic bound, a full solve cannot buy enough to matter —
    e.g. a *uniform* drift that slows every platform equally leaves the
    incumbent optimal — so the solve is skipped and the incumbent returned
    with ``meta["warm_start"] == "skipped"``. Otherwise the caller proceeds
    (``meta["warm_start"] == "solved"``) with the incumbent matrix available
    as a start point.

    With per-platform offsets (mid-run re-solves) the incumbent must clear
    the bar in *both* frames to be waved through:

    * on the offset-stripped problem — is its share of the remaining work
      well balanced on its own terms? Late in a run the committed time
      dominates finish times, and an offset-carrying ratio test alone
      would wave anything through;
    * on the offset-carrying problem (tolerance scaled by the remaining
      work, not the finish time) — does it respect who is already busy? A
      remaining-schedule that is flat-optimal can still pile work onto the
      platform with the largest committed backlog.

    With zero offsets both collapse to the plain
    ``m_inc <= heuristic * (1 + warm_tol)``.

    Returns ``(A_incumbent, shortcut)`` where ``shortcut`` is the ready
    Allocation when the solve should be skipped, else None.
    """
    A_inc = np.asarray(incumbent.A if hasattr(incumbent, "A") else incumbent,
                       dtype=np.float64)
    if A_inc.shape != (problem.mu, problem.tau):
        raise ValueError(
            f"incumbent shape {A_inc.shape} does not match problem "
            f"({problem.mu}, {problem.tau}); restrict it first")
    flat = (dataclasses.replace(problem, offsets=None)
            if problem.offsets.any() else problem)
    heur_flat = proportional_allocation(flat)
    skip = makespan(A_inc, flat) <= heur_flat.makespan * (1.0 + warm_tol)
    if skip and problem.offsets.any():
        heur_off = proportional_allocation(problem)
        skip = (makespan(A_inc, problem)
                <= heur_off.makespan + warm_tol * heur_flat.makespan)
    if skip:
        return A_inc, Allocation(
            A=A_inc.copy(), makespan=makespan(A_inc, problem), solver=solver,
            solve_time=time.perf_counter() - t0, optimal=False,
            meta={"warm_start": "skipped", "warm_tol": warm_tol,
                  "heuristic_bound": heur_flat.makespan},
        )
    return A_inc, None


def proportional_allocation(problem: AllocationProblem) -> Allocation:
    t0 = time.perf_counter()
    ones = np.ones((problem.mu, problem.tau))
    L = platform_latencies(ones, problem)  # L = H_L(1, c)
    free = L <= 0.0
    if free.any():
        # Degenerate platform: an all-zero (delta, gamma) row means zero
        # standalone latency and 1/L blows up. Such platforms are free, so
        # snap to a uniform share across them (makespan 0 — optimal).
        shares = free / free.sum()
    else:
        inv = 1.0 / L
        shares = inv / inv.sum()  # shares[i] = (L_i * sum_o 1/L_o)^-1
    A = np.repeat(shares[:, None], problem.tau, axis=1)
    return Allocation(
        A=A,
        makespan=makespan(A, problem),
        solver="heuristic",
        solve_time=time.perf_counter() - t0,
    )
