"""Proportional allocation heuristic (paper §4.3.2, eq. 11).

Every task is split across all platforms with the *same* per-platform share,
inversely proportional to the makespan each platform would see if it ran the
entire workload alone:

    A[i, j] = ( L_i * sum_o 1/L_o )**-1,   L = H_L(1, c)

The heuristic is optimal when the gamma constants vanish and the work matrix
is rank-1 (platform speed independent of task); when constants dominate it
degrades badly because it charges *every* platform *every* task's constant —
exactly the regime where the ML/MILP solvers win (paper §6.3).
"""
from __future__ import annotations

import time

import numpy as np

from .allocation import Allocation, AllocationProblem, makespan, platform_latencies

__all__ = ["proportional_allocation"]


def proportional_allocation(problem: AllocationProblem) -> Allocation:
    t0 = time.perf_counter()
    ones = np.ones((problem.mu, problem.tau))
    L = platform_latencies(ones, problem)  # L = H_L(1, c)
    free = L <= 0.0
    if free.any():
        # Degenerate platform: an all-zero (delta, gamma) row means zero
        # standalone latency and 1/L blows up. Such platforms are free, so
        # snap to a uniform share across them (makespan 0 — optimal).
        shares = free / free.sum()
    else:
        inv = 1.0 / L
        shares = inv / inv.sum()  # shares[i] = (L_i * sum_o 1/L_o)^-1
    A = np.repeat(shares[:, None], problem.tau, axis=1)
    return Allocation(
        A=A,
        makespan=makespan(A, problem),
        solver="heuristic",
        solve_time=time.perf_counter() - t0,
    )
