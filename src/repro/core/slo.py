"""Streaming SLO tail metrics: bounded-memory quantiles and trackers.

Overload control (runtime/admission.py, runtime/online.py) needs
p50/p95/p99 of TTFT / TPOT / end-to-end latency over an unbounded
record stream without holding the stream.  Two estimators cover the
two uses:

* :class:`P2Quantile` — the Jain & Chlamtac P-squared algorithm: five
  markers, O(1) memory, piecewise-parabolic marker adjustment.  Below
  five observations it keeps the exact sorted buffer, so short windows
  are exact and the empty window is explicitly ``nan``.
* :func:`quantile` — exact linear-interpolation quantile on a concrete
  list, used for the small *recent* windows where exactness matters
  (guardrail decisions) and by tests as the reference.

:class:`SLOTracker` bundles per-metric estimators plus a bounded
recent window and renders the ``slo`` snapshot that lands in reports
and ``BENCH_allocation.json``.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from math import nan, isnan

__all__ = ["quantile", "P2Quantile", "SLOConfig", "SLOTracker"]


def quantile(values, q: float) -> float:
    """Exact quantile with linear interpolation (numpy's default rule).

    Returns ``nan`` on an empty sequence instead of raising, because
    every caller is a streaming window that starts empty.
    """
    xs = sorted(values)
    if not xs:
        return nan
    if len(xs) == 1:
        return float(xs[0])
    pos = q * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


class P2Quantile:
    """Jain & Chlamtac's P-squared streaming quantile estimator.

    Five markers track (min, q/2, q, (1+q)/2, max); each observation
    shifts marker heights by a piecewise-parabolic rule (linear
    fallback when the parabola would cross a neighbour).  Memory is
    O(1) regardless of stream length.  With fewer than five
    observations the exact sorted buffer is the estimate, so short
    windows never extrapolate and ``value()`` on an empty stream is
    ``nan``.
    """

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = q
        self._heights: list[float] = []          # marker heights
        self._pos: list[int] = []                # actual marker positions
        self._desired: list[float] = []          # desired positions
        self._incr = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]
        self.count = 0

    def observe(self, x: float) -> None:
        self.count += 1
        h = self._heights
        if self.count <= 5:
            h.append(float(x))
            h.sort()
            if self.count == 5:
                self._pos = [0, 1, 2, 3, 4]
                self._desired = [4.0 * inc for inc in self._incr]
            return
        # locate the cell containing x, clamping the extreme markers
        if x < h[0]:
            h[0] = float(x)
            k = 0
        elif x >= h[4]:
            h[4] = float(x)
            k = 3
        else:
            k = 0
            while k < 3 and x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            self._pos[i] += 1
        for i in range(5):
            self._desired[i] += self._incr[i]
        # adjust interior markers toward their desired positions
        for i in (1, 2, 3):
            d = self._desired[i] - self._pos[i]
            if (d >= 1.0 and self._pos[i + 1] - self._pos[i] > 1) or (
                    d <= -1.0 and self._pos[i - 1] - self._pos[i] < -1):
                step = 1 if d >= 1.0 else -1
                cand = self._parabolic(i, step)
                if h[i - 1] < cand < h[i + 1]:
                    h[i] = cand
                else:
                    h[i] = self._linear(i, step)
                self._pos[i] += step

    def _parabolic(self, i: int, d: int) -> float:
        h, n = self._heights, self._pos
        num = d / (n[i + 1] - n[i - 1])
        left = (n[i] - n[i - 1] + d) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
        right = (n[i + 1] - n[i] - d) * (h[i] - h[i - 1]) / (n[i] - n[i - 1])
        return h[i] + num * (left + right)

    def _linear(self, i: int, d: int) -> float:
        h, n = self._heights, self._pos
        return h[i] + d * (h[i + d] - h[i]) / (n[i + d] - n[i])

    def value(self) -> float:
        """Current estimate; exact below 5 observations, nan when empty."""
        if self.count == 0:
            return nan
        if self.count < 5:
            return quantile(self._heights, self.q)
        return self._heights[2]


@dataclass(frozen=True)
class SLOConfig:
    """Service-level objective for the online guardrail.

    ``target_s`` bounds the ``metric`` (ttft | tpot | e2e) at
    ``quantile`` over the most recent ``window`` completed tasks.  The
    brownout ladder deepens when the recent quantile exceeds
    ``target_s * enter_ratio`` and restores a rung once it falls below
    ``target_s * exit_ratio`` — the hysteresis gap prevents rung
    flapping at the boundary.  No guardrail decision fires before
    ``min_window`` completions.
    """

    target_s: float
    metric: str = "e2e"
    quantile: float = 0.99
    window: int = 32
    min_window: int = 4
    enter_ratio: float = 1.0
    exit_ratio: float = 0.7

    def __post_init__(self):
        if self.metric not in ("ttft", "tpot", "e2e"):
            raise ValueError(f"unknown SLO metric {self.metric!r}")
        if self.target_s <= 0:
            raise ValueError("SLO target must be positive")
        if not 0.0 < self.exit_ratio <= self.enter_ratio:
            raise ValueError("need 0 < exit_ratio <= enter_ratio")


_QUANTS = (0.5, 0.95, 0.99)


@dataclass
class _MetricStream:
    estimators: dict = field(default_factory=lambda: {
        q: P2Quantile(q) for q in _QUANTS})
    count: int = 0
    total: float = 0.0
    peak: float = nan

    def observe(self, x: float) -> None:
        self.count += 1
        self.total += x
        self.peak = x if isnan(self.peak) else max(self.peak, x)
        for est in self.estimators.values():
            est.observe(x)

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "mean": self.total / self.count if self.count else nan,
            "max": self.peak,
            **{f"p{int(q * 100)}": est.value()
               for q, est in self.estimators.items()},
        }


class SLOTracker:
    """Per-task latency metrics against an :class:`SLOConfig`.

    ``observe`` takes one completed task's (ttft, tpot, e2e) seconds;
    lifetime percentiles stream through P-squared while a bounded
    ``recent`` deque backs the exact guardrail quantile.
    """

    def __init__(self, config: SLOConfig):
        self.config = config
        self._streams = {m: _MetricStream() for m in ("ttft", "tpot", "e2e")}
        self._recent: deque[float] = deque(maxlen=config.window)
        self._n_ok = 0

    @property
    def count(self) -> int:
        return self._streams["e2e"].count

    def observe(self, ttft: float, tpot: float, e2e: float) -> None:
        vals = {"ttft": ttft, "tpot": tpot, "e2e": e2e}
        for m, x in vals.items():
            self._streams[m].observe(x)
        guarded = vals[self.config.metric]
        self._recent.append(guarded)
        if guarded <= self.config.target_s:
            self._n_ok += 1

    def recent_quantile(self) -> float | None:
        """Exact guardrail quantile over the recent window.

        ``None`` until ``min_window`` observations exist — callers must
        not act on an empty or barely-populated window.
        """
        if len(self._recent) < self.config.min_window:
            return None
        return quantile(self._recent, self.config.quantile)

    def attainment(self) -> float:
        """Lifetime fraction of guarded observations within target."""
        n = self.count
        return self._n_ok / n if n else nan

    def snapshot(self) -> dict:
        return {
            "target_s": self.config.target_s,
            "metric": self.config.metric,
            "quantile": self.config.quantile,
            "count": self.count,
            "attainment": self.attainment(),
            "metrics": {m: s.snapshot() for m, s in self._streams.items()},
        }
