"""Braun-style synthetic task/platform generation (paper §6.1.1, Table 3).

Procedure s(tau, mu, theta_tau, theta_mu, omega_tau, omega_mu, psi):

 1. baseline vector x (tau integers in [1, theta_tau]) and initial matrix Y
    (mu x tau integers in [1, theta_mu]);
 2. delta[i, j] = x[j] * Y[i, j];
 3. consistency: sort the first floor(tau * omega_tau) columns (platform
    ordering made consistent for those tasks) and the first
    floor(mu * omega_mu) rows (task ordering made consistent on those
    platforms);
 4. gamma: repeat 1-3 with fresh draws, scaled by psi (the constant-to-
    coefficient ratio — the knob that controls how non-linear the
    allocation problem is).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .allocation import AllocationProblem

__all__ = ["SyntheticCase", "TABLE3_CASES", "generate", "generate_case"]


@dataclasses.dataclass(frozen=True)
class SyntheticCase:
    theta_mu: int
    omega_mu: float
    theta_tau: int
    omega_tau: float


#: Paper Table 3.
TABLE3_CASES: dict[str, SyntheticCase] = {
    "Hom-Con": SyntheticCase(theta_mu=10, omega_mu=1.0, theta_tau=100, omega_tau=1.0),
    "Het-Con": SyntheticCase(theta_mu=100, omega_mu=1.0, theta_tau=3000, omega_tau=1.0),
    "Het-Mix": SyntheticCase(theta_mu=100, omega_mu=0.5, theta_tau=3000, omega_tau=0.5),
    "Het-Inc": SyntheticCase(theta_mu=100, omega_mu=0.0, theta_tau=3000, omega_tau=0.0),
}


def _base_matrix(rng: np.random.Generator, mu: int, tau: int,
                 theta_mu: int, theta_tau: int,
                 omega_mu: float, omega_tau: float) -> np.ndarray:
    x = rng.integers(1, theta_tau + 1, size=tau)
    Y = rng.integers(1, theta_mu + 1, size=(mu, tau))
    M = (x[None, :] * Y).astype(np.float64)
    n_cols = int(np.floor(tau * omega_tau))
    if n_cols:
        M[:, :n_cols] = np.sort(M[:, :n_cols], axis=0)
    n_rows = int(np.floor(mu * omega_mu))
    if n_rows:
        M[:n_rows, :] = np.sort(M[:n_rows, :], axis=1)
    return M


def generate(
    tau: int,
    mu: int,
    theta_tau: int,
    theta_mu: int,
    omega_tau: float,
    omega_mu: float,
    psi: float,
    seed: int = 0,
) -> AllocationProblem:
    rng = np.random.default_rng(seed)
    delta = _base_matrix(rng, mu, tau, theta_mu, theta_tau, omega_mu, omega_tau)
    gamma = psi * _base_matrix(rng, mu, tau, theta_mu, theta_tau, omega_mu, omega_tau)
    return AllocationProblem(delta=delta, gamma=gamma, c=np.ones(tau))


def generate_case(case: str, tau: int, mu: int, psi: float, seed: int = 0) -> AllocationProblem:
    p = TABLE3_CASES[case]
    return generate(tau, mu, p.theta_tau, p.theta_mu, p.omega_tau, p.omega_mu, psi, seed)
