"""Multimetric Pareto surfaces via the epsilon-constraint method (§3.2.3).

For the pricing domain the two metrics are makespan (optimised) and
accuracy (constrained). The accuracy constraint is folded into the work
matrix (W = delta / c**2), so sweeping the accuracy epsilon is simply
re-solving the allocation with scaled c — each solve yields one point of
the latency/accuracy trade-off curve (Figs 9 & 10).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .allocation import Allocation, AllocationProblem

__all__ = ["ParetoPoint", "sweep", "platform_curves", "pareto_filter"]


@dataclasses.dataclass(frozen=True)
class ParetoPoint:
    accuracy: float          # CI size epsilon applied to every task
    makespan: float
    solver: str
    solve_time: float
    allocation: Allocation


def sweep(
    delta: np.ndarray,
    gamma: np.ndarray,
    accuracies: Sequence[float],
    solver: Callable[[AllocationProblem], Allocation],
) -> list[ParetoPoint]:
    """epsilon-constraint sweep: one allocation solve per accuracy target."""
    points = []
    tau = delta.shape[1]
    for c in accuracies:
        problem = AllocationProblem(delta=delta, gamma=gamma, c=np.full(tau, float(c)))
        alloc = solver(problem)
        points.append(
            ParetoPoint(accuracy=float(c), makespan=alloc.makespan,
                        solver=alloc.solver, solve_time=alloc.solve_time,
                        allocation=alloc)
        )
    return points


def platform_curves(
    delta: np.ndarray, gamma: np.ndarray, accuracies: Sequence[float]
) -> np.ndarray:
    """Fig 9: per-platform makespan of the *whole* workload vs accuracy.

    Returns [mu, len(accuracies)] — platform i running every task alone:
    sum_j delta[i,j]/c^2 + gamma[i,j]. At low accuracy (large c) gamma
    (network) dominates and platforms order geographically; at high
    accuracy compute dominates and they order by measured capability.
    """
    acc = np.asarray(accuracies, dtype=np.float64)
    return (delta.sum(axis=1)[:, None] / (acc * acc)[None, :]
            + gamma.sum(axis=1)[:, None])


def pareto_filter(points: Sequence[tuple[float, float]]) -> list[tuple[float, float]]:
    """Non-dominated subset of (accuracy, makespan) points (both minimised)."""
    pts = sorted(points)
    out: list[tuple[float, float]] = []
    best = np.inf
    for acc, mk in pts:
        if mk < best:
            out.append((acc, mk))
            best = mk
    return out
