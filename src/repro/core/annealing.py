"""Machine-learning allocation (paper §4.3.3).

The paper's ML approach starts from the proportional heuristic and improves
it with SciPy's simulated annealing followed by a "polishing" convex step
(Dantzig's simplex). SciPy removed ``anneal`` upstream, so this module
implements the same scheme natively, and goes further than 2015 hardware
allowed: the annealer is vectorised with ``jax.vmap`` over many independent
chains and compiled with ``lax.fori_loop``, which is orders of magnitude
faster than a Python-loop SA on the same CPU.

Moves operate on one task column at a time: move a fraction (or all) of a
task's share from a source platform (sampled ∝ current share) to a random
destination. "Move all" moves are essential — they are the only way to
*clear* a platform's gamma constant, i.e. to cross the non-linear part of
the objective that the LP polish cannot see.

The polish fixes the binary support B = ceil(A) found by the SA and solves
the then-*linear* restriction of eq. 10 exactly with HiGHS
(``scipy.optimize.linprog``): minimise t s.t. W∘A·1 + (gamma∘B)·1 <= t,
columns of A sum to 1, supp(A) ⊆ B. Entries the LP drives to zero shrink
the support, so the polish is iterated to a fixed point.

Problems carrying the optional resource/capacity dimension anneal a
*penalised* objective (relative capacity overflow, makespan-scaled) with
repair-biased moves (overloaded platforms are preferred sources and
avoided destinations), start every chain from a capacity-clamped seed, and
polish with the capacity rows in the LP — so the returned allocation is
always feasible.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import scipy.sparse as sp
from scipy.optimize import linprog

import jax
import jax.numpy as jnp

from .allocation import (
    CAPACITY_RTOL,
    SUPPORT_ATOL,
    Allocation,
    AllocationProblem,
    assert_capacity_feasible,
    capacity_ok,
    makespan,
)
from .heuristic import clamp_to_capacity, incumbent_shortcut, proportional_allocation

__all__ = ["ml_allocation", "lp_polish", "anneal"]


# --------------------------------------------------------------------------
# JAX annealing kernel
# --------------------------------------------------------------------------

def _objective_jnp(A, W, G, off, R, cap_safe, rho, atol=SUPPORT_ATOL):
    """Penalised makespan: eq. 10 plus a relative capacity-overflow term.

    ``cap_safe`` is the capacity vector with non-finite/zero entries
    replaced by a sentinel that makes the relative overflow 0/negative, so
    capacity-free problems pay nothing. ``rho`` carries the makespan scale
    (resource units can be bytes — the penalty must be scale-free)."""
    support = A > atol
    H = (W * A).sum(axis=1) + jnp.where(support, G, 0.0).sum(axis=1) + off
    over = jnp.maximum((R * A).sum(axis=1) / cap_safe - 1.0, 0.0)
    return H.max() + rho * over.sum()


def _anneal_chain(A0, W, G, off, R, cap_safe, rho, key,
                  steps: int, T0: float, Tf: float):
    """One SA chain; vmapped over (A0, key) by :func:`anneal`.

    The move kernel is *incremental*: a move touches one task column, so
    instead of recomputing the full O(mu*tau) objective per step the chain
    carries the per-platform aggregates the objective is made of —
    ``workH = (W∘A)·1``, ``gamH = (gamma∘ceil A)·1``, ``usage = (R∘A)·1`` —
    and updates them with the O(mu) column delta. That turns a step from
    O(mu*tau) into O(mu), which is what lets 1000-task instances anneal in
    the same wall time the canonical 16-task instance used to take.

    The chain returns its *final* state (the schedule is effectively greedy
    by the end), with the objective recomputed once from scratch so the
    reported value carries no accumulated float drift. Callers
    (:func:`ml_allocation`) never rely on the raw annealed matrix being an
    improvement — the heuristic seed and the exact LP polish both gate it.
    """
    mu, tau = W.shape
    atol = SUPPORT_ATOL
    workH0 = (W * A0).sum(axis=1)
    gamH0 = jnp.where(A0 > atol, G, 0.0).sum(axis=1)
    usage0 = (R * A0).sum(axis=1)
    m0 = ((workH0 + gamH0 + off).max()
          + rho * jnp.maximum(usage0 / cap_safe - 1.0, 0.0).sum())

    def body(k, state):
        A, workH, gamH, usage, m_cur, key = state
        key, k1, k2, k3, k4, k5, k6 = jax.random.split(key, 7)
        j = jax.random.randint(k1, (), 0, tau)
        col = jnp.take(A, j, axis=1)
        # repair bias: overloaded platforms are preferred sources and
        # avoided destinations (zero bias when no capacity row binds)
        bias = jnp.where(usage / cap_safe - 1.0 > 0, 4.0, 0.0)
        # source ∝ current share (never samples an empty platform when any
        # mass exists in the column); destination uniform among the rest.
        src = jax.random.categorical(k2, logits=jnp.log(col + 1e-12) + bias)
        dst = jax.random.categorical(k3, logits=-bias)
        move_all = jax.random.bernoulli(k4, 0.5)
        frac = jnp.where(move_all, 1.0, jax.random.uniform(k5))
        amount = col[src] * frac
        col_new = col.at[src].add(-amount).at[dst].add(amount)
        d = col_new - col
        Wj = jnp.take(W, j, axis=1)
        Gj = jnp.take(G, j, axis=1)
        Rj = jnp.take(R, j, axis=1)
        dsupp = (col_new > atol).astype(Wj.dtype) - (col > atol).astype(Wj.dtype)
        workH_new = workH + Wj * d
        gamH_new = gamH + Gj * dsupp
        usage_new = usage + Rj * d
        m_new = ((workH_new + gamH_new + off).max()
                 + rho * jnp.maximum(usage_new / cap_safe - 1.0, 0.0).sum())
        # geometric temperature schedule
        T = T0 * (Tf / T0) ** (k / steps)
        accept = (m_new < m_cur) | (
            jax.random.uniform(k6) < jnp.exp(-(m_new - m_cur) / jnp.maximum(T, 1e-30))
        )
        col_out = jnp.where(accept, col_new, col)
        A = jax.lax.dynamic_update_index_in_dim(A, col_out, j, axis=1)
        workH = jnp.where(accept, workH_new, workH)
        gamH = jnp.where(accept, gamH_new, gamH)
        usage = jnp.where(accept, usage_new, usage)
        m_cur = jnp.where(accept, m_new, m_cur)
        return A, workH, gamH, usage, m_cur, key

    state = (A0, workH0, gamH0, usage0, m0, key)
    A, _, _, _, _, _ = jax.lax.fori_loop(0, steps, body, state)
    # exact objective of the final state (no incremental float drift)
    return A, _objective_jnp(A, W, G, off, R, cap_safe, rho)


_anneal_batch = jax.jit(
    jax.vmap(_anneal_chain,
             in_axes=(0, None, None, None, None, None, None, 0, None, None, None)),
    static_argnums=(8,),
)


def anneal(
    problem: AllocationProblem,
    A_starts: np.ndarray,
    *,
    steps: int = 4000,
    seed: int = 0,
    T0_frac: float = 0.05,
    Tf_frac: float = 1e-5,
) -> tuple[np.ndarray, np.ndarray]:
    """Run one SA round over a batch of start allocations.

    Returns (annealed allocations [chains, mu, tau], their exact penalised
    objectives [chains] — equal to the makespan for capacity-feasible
    results). Each chain returns its final state: by the end of the
    geometric schedule the walk is effectively greedy, and carrying a
    running argmin would cost an O(mu*tau) copy per step — exactly the
    scaling the incremental kernel exists to avoid.
    """
    W = jnp.asarray(problem.work, dtype=jnp.float32)
    G = jnp.asarray(problem.gamma, dtype=jnp.float32)
    off = jnp.asarray(problem.offsets, dtype=jnp.float32)
    A0 = jnp.asarray(A_starts, dtype=jnp.float32)
    chains = A0.shape[0]
    # temperature scale from the offset-STRIPPED makespan: on a late online
    # re-solve the committed offsets dominate the objective's absolute value
    # while moves only shift the remaining-work part, and an offsets-scaled
    # T0 would accept everything (random walk) through most of the schedule
    m_start = makespan(A_starts[0],
                       dataclasses.replace(problem, offsets=None))
    if problem.capacity is not None:
        R = jnp.asarray(problem.resource, dtype=jnp.float32)
        cap = np.where(problem.capacity > 0, problem.capacity, 1e-30)
        cap_safe = jnp.asarray(cap, dtype=jnp.float32)
        # a row 10% over its budget costs ~40% of a makespan — steep enough
        # that the schedule anneals into the feasible region, shallow enough
        # that chains can tunnel through it early on
        rho = jnp.float32(4.0 * max(m_start, 1e-30))
    else:
        R = jnp.zeros_like(W)
        cap_safe = jnp.full((problem.mu,), jnp.inf, dtype=jnp.float32)
        rho = jnp.float32(0.0)
    keys = jax.random.split(jax.random.PRNGKey(seed), chains)
    best_A, best_m = _anneal_batch(
        A0, W, G, off, R, cap_safe, rho, keys, steps,
        m_start * T0_frac, m_start * Tf_frac
    )
    return np.asarray(best_A, dtype=np.float64), np.asarray(best_m, dtype=np.float64)


# --------------------------------------------------------------------------
# LP polish (the "simplex" step)
# --------------------------------------------------------------------------

def lp_polish(problem: AllocationProblem, support: np.ndarray) -> tuple[np.ndarray, float] | None:
    """Solve eq. 10 restricted to a fixed support exactly (it is an LP).

    Variables: one share per support entry plus the makespan t; the
    problem's capacity rows (when present) ride along as plain
    inequalities, so a polished allocation stays capacity-feasible.
    Returns (A, makespan) or None if the LP is infeasible/failed.
    """
    support = np.asarray(support, dtype=bool)
    mu, tau = support.shape
    if not support.any(axis=0).all():
        return None  # some task has no platform
    rows, cols = np.nonzero(support)
    nnz = rows.size
    W = problem.work
    gamma_const = (problem.gamma * support).sum(axis=1)  # charged regardless of split

    # objective: minimise t (last variable)
    c = np.zeros(nnz + 1)
    c[-1] = 1.0

    # equality: each task's shares sum to 1
    A_eq = sp.csr_matrix(
        (np.ones(nnz), (cols, np.arange(nnz))), shape=(tau, nnz + 1)
    )
    b_eq = np.ones(tau)

    # inequality: sum_j W_ij A_ij - t <= -gamma_const_i
    data = W[rows, cols]
    A_ub = sp.csr_matrix(
        (np.concatenate([data, -np.ones(mu)]),
         (np.concatenate([rows, np.arange(mu)]),
          np.concatenate([np.arange(nnz), np.full(mu, nnz)]))),
        shape=(mu, nnz + 1),
    )
    b_ub = -gamma_const - problem.offsets
    if problem.has_capacity:
        # capacity rows: sum_j R_ij A_ij <= capacity_i over the support
        # (finite budgets only — linprog rejects inf right-hand sides)
        finite = np.isfinite(problem.capacity)
        row_map = np.cumsum(finite) - 1
        keep = finite[rows]
        res_rows = sp.csr_matrix(
            (problem.resource[rows, cols][keep],
             (row_map[rows[keep]], np.nonzero(keep)[0])),
            shape=(int(finite.sum()), nnz + 1),
        )
        A_ub = sp.vstack([A_ub, res_rows], format="csr")
        b_ub = np.concatenate([b_ub, problem.capacity[finite]])

    bounds = [(0, 1)] * nnz + [(0, None)]
    res = linprog(c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq,
                  bounds=bounds, method="highs")
    if not res.success:
        return None
    A = np.zeros((mu, tau))
    A[rows, cols] = res.x[:nnz]
    A[A < SUPPORT_ATOL] = 0.0
    A /= A.sum(axis=0, keepdims=True)
    return A, makespan(A, problem)


def _iterated_polish(problem: AllocationProblem, A: np.ndarray, max_iters: int = 4):
    """Polish, prune entries the LP zeroed, and re-polish to a fixed point.

    A capacity-violating input only counts once the LP (which carries the
    capacity rows) has projected it into the feasible region; returns
    (None, inf) when that never happens."""
    if capacity_ok(A, problem):
        best_A, best_m = A, makespan(A, problem)
    else:
        best_A, best_m = None, np.inf
    support = A > SUPPORT_ATOL
    for _ in range(max_iters):
        out = lp_polish(problem, support)
        if out is None:
            break
        A2, m2 = out
        new_support = A2 > SUPPORT_ATOL
        if m2 < best_m:
            best_A, best_m = A2, m2
        if new_support.sum() == support.sum():
            break
        support = new_support
    return best_A, best_m


# --------------------------------------------------------------------------
# Full ML allocation
# --------------------------------------------------------------------------

def ml_allocation(
    problem: AllocationProblem,
    *,
    chains: int = 32,
    steps: int = 4000,
    rounds: int = 2,
    seed: int = 0,
    time_limit: float = 600.0,
    polish_top_k: int = 4,
    incumbent: Allocation | None = None,
    warm_tol: float = 0.05,
) -> Allocation:
    """Heuristic start → multi-chain SA → iterated LP polish (paper §4.3.3).

    ``incumbent`` (online re-solves) first tries the warm-start early exit
    (:func:`incumbent_shortcut`); when the solve does proceed, the incumbent
    seeds one SA chain so the annealer explores from the executing
    allocation as well as from scratch.
    """
    t_start = time.perf_counter()
    assert_capacity_feasible(problem)
    warm_meta = {}
    A_inc = None
    if incumbent is not None:
        A_inc, shortcut, warm_meta = incumbent_shortcut(
            problem, incumbent, "ml", warm_tol, t_start)
        if shortcut is not None:
            return shortcut
        if warm_meta.get("warm_start") == "rejected":
            # the executing plan violates the (remaining) capacities —
            # repair it before it seeds anything
            A_inc = clamp_to_capacity(A_inc, problem)
    rng = np.random.default_rng(seed)
    heur = proportional_allocation(problem)
    mu, tau = problem.mu, problem.tau

    # Chain starts: the heuristic, plus atomic random assignments (sparse
    # supports let the SA explore the low-gamma region immediately); every
    # seed is clamped into the capacity rows so chains start feasible.
    A_starts = np.zeros((chains, mu, tau))
    if chains > 1:
        choice = rng.integers(0, mu, size=(chains - 1, tau))
        A_starts[np.repeat(np.arange(1, chains), tau),
                 choice.ravel(),
                 np.tile(np.arange(tau), chains - 1)] = 1.0
        if problem.has_capacity:
            for idx in range(1, chains):
                A_starts[idx] = clamp_to_capacity(A_starts[idx], problem)
    A_starts[0] = heur.A  # keep the heuristic verbatim in chain 0
    if A_inc is not None and chains > 1:
        A_starts[1] = A_inc  # warm start: one chain anneals the incumbent
    build_s = time.perf_counter() - t_start

    best_A, best_m = heur.A, heur.makespan
    if A_inc is not None and capacity_ok(A_inc, problem):
        m_inc = makespan(A_inc, problem)
        if m_inc < best_m:
            best_A, best_m = A_inc, m_inc
    round_idx = 0
    anneal_s = polish_s = 0.0
    while round_idx < rounds and (time.perf_counter() - t_start) < time_limit:
        t_a = time.perf_counter()
        cand_A, cand_m = anneal(problem, A_starts, steps=steps, seed=seed + round_idx)
        anneal_s += time.perf_counter() - t_a
        order = np.argsort(cand_m)
        t_p = time.perf_counter()
        for idx in order[:polish_top_k]:
            if (time.perf_counter() - t_start) >= time_limit:
                break
            A2, m2 = _iterated_polish(problem, cand_A[idx])
            if A2 is not None and m2 < best_m:
                best_A, best_m = A2, m2
        polish_s += time.perf_counter() - t_p
        # re-seed the next round from the winners (exploitation)
        A_starts = cand_A[order][np.arange(chains) % max(len(order), 1)]
        round_idx += 1

    return Allocation(
        A=best_A,
        makespan=best_m,
        solver="ml",
        solve_time=time.perf_counter() - t_start,
        meta={"chains": chains, "steps": steps, "rounds": round_idx,
              "heuristic_makespan": heur.makespan,
              "build_s": build_s, "solve_s": anneal_s, "polish_s": polish_s,
              "n_vars": mu * tau,
              "n_constraints": tau + mu + (mu if problem.has_capacity else 0),
              **warm_meta},
    )
