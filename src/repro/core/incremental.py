"""Incremental re-solve: patch an executing allocation for k new tasks.

The PR 4 online controller re-solves the whole remaining problem on every
arrival. At fleet scale that is wasteful: k tasks arriving into a
1000-task allocation change k columns, and the committed shares of the
other tasks are not going anywhere mid-round anyway. :func:`patch_allocation`
solves only the delta sub-problem — the k new columns against the fleet's
*current* finish times (per-platform latencies of the executing allocation
as offsets) and *remaining* capacities — and merges the result into the
incumbent. Cost is O(k·mu) construction plus a k-column solve instead of a
full O(tau·mu) rebuild.

The patch is greedy with respect to the old tasks: their shares stay
fixed, so a patched solution can be worse than a from-scratch solve when
the arrivals are large relative to the executing work. The guard is a
bound test against the fresh full-problem heuristic: when the patched
makespan exceeds ``(1 + patch_tol)`` times that bound, the patch is
discarded and a full solve runs instead (``meta["incremental"]`` says
which path was taken, with both makespans recorded).
"""
from __future__ import annotations

import time
from typing import Sequence

import numpy as np

from .allocation import (
    Allocation,
    AllocationProblem,
    CapacityError,
    SUPPORT_ATOL,
    makespan,
    platform_latencies,
    platform_usage,
    restrict_problem,
)
from .heuristic import proportional_allocation

__all__ = ["patch_allocation"]


def _solver_table():
    from .annealing import ml_allocation
    from .milp import milp_allocation

    return {
        "heuristic": lambda p, **kw: proportional_allocation(p),
        "ml": ml_allocation,
        "milp": milp_allocation,
    }


def patch_allocation(
    problem: AllocationProblem,
    A_base: np.ndarray,
    new_tasks: Sequence[int],
    method: str = "milp",
    *,
    patch_tol: float = 0.25,
    **solver_kw,
) -> Allocation:
    """Allocate only ``new_tasks``, holding the rest of ``A_base`` fixed.

    ``problem`` is the full frame including the new columns; ``A_base``
    must be (mu, tau) with zero mass in the ``new_tasks`` columns (they
    have not been dispatched yet) and valid columns elsewhere. The delta
    sub-problem sees each platform's current finish time as its offset and
    its remaining capacity as its budget, so the k-column solve minimises
    the *fleet* finish time, not just the newcomers' own.

    Gamma accounting is exact for the newcomers (no column is charged
    twice: the new columns had no support in ``A_base``); platforms'
    existing gamma charges ride along inside the offsets.
    """
    t0 = time.perf_counter()
    solvers = _solver_table()
    if method not in solvers:
        raise ValueError(f"unknown method {method!r}; pick from {sorted(solvers)}")
    solve = solvers[method]
    new_cols = np.asarray(new_tasks, dtype=int)
    if new_cols.size == 0:
        raise ValueError("patch needs >= 1 new task")
    A_base = np.asarray(A_base, dtype=np.float64)
    if A_base.shape != (problem.mu, problem.tau):
        raise ValueError(f"A_base is {A_base.shape}, problem frame is "
                         f"({problem.mu}, {problem.tau})")
    if (np.abs(A_base[:, new_cols]) > SUPPORT_ATOL).any():
        raise ValueError("new task columns must carry no mass in A_base")

    offsets = platform_latencies(A_base, problem)
    cap_rem = None
    if problem.capacity is not None:
        cap_rem = np.maximum(problem.capacity - platform_usage(A_base, problem),
                             0.0)
    sub = restrict_problem(problem, tasks=new_cols, offsets=offsets,
                           capacity=cap_rem)

    patched_A = patched_m = None
    patch_err = None
    try:
        sub_alloc = solve(sub, **solver_kw)
        patched_A = A_base.copy()
        patched_A[:, new_cols] = sub_alloc.A
        patched_m = makespan(patched_A, problem)
    except CapacityError as err:
        # newcomers alone cannot fit the *remaining* budgets; a full solve
        # may still fit by rebalancing the old shares
        patch_err = str(err)

    patch_s = time.perf_counter() - t0
    # bound test: the fresh full-problem heuristic is an upper bound any
    # from-scratch solver would beat; a patch that can't stay within
    # patch_tol of it is holding the old shares in the wrong place
    ref = proportional_allocation(problem)
    if patched_m is not None and patched_m <= ref.makespan * (1.0 + patch_tol):
        # top level keeps the inner solver's normalised phase keys
        # (flattened, as before) *and* the full inner meta under "inner"
        # so telemetry consumers see the k-column solve's own breakdown
        inner = dict(getattr(sub_alloc, "meta", {}) or {})
        meta = dict(inner)
        meta.update(incremental="patched", patch_tasks=int(new_cols.size),
                    patch_s=patch_s, patched_makespan=float(patched_m),
                    heuristic_bound=float(ref.makespan), patch_tol=patch_tol,
                    inner=inner)
        return Allocation(A=patched_A, makespan=float(patched_m),
                          solver=sub_alloc.solver,
                          solve_time=time.perf_counter() - t0,
                          optimal=False, meta=meta)

    full = solve(problem, **solver_kw)
    inner = dict(full.meta)
    meta = dict(inner)
    meta.update(incremental="full_fallback", patch_tasks=int(new_cols.size),
                patch_s=patch_s,
                patched_makespan=None if patched_m is None else float(patched_m),
                heuristic_bound=float(ref.makespan), patch_tol=patch_tol,
                inner=inner)
    if patch_err is not None:
        meta["patch_error"] = patch_err
    return Allocation(A=full.A, makespan=full.makespan, solver=full.solver,
                      solve_time=time.perf_counter() - t0,
                      optimal=full.optimal, bound=full.bound, meta=meta)
