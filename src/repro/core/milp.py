"""MILP allocation (paper §4.3.4, eq. 12).

The non-linear ``gamma ∘ ceil(A)`` term is linearised with indicator
binaries B >= A, giving the mixed-integer linear program

    minimise_{G_L, A, B}  G_L
    s.t.   sum_i A[i,j] == 1                          (every task placed)
           (W ∘ A)·1 + (gamma ∘ B)·1 <= G_L           (per-platform latency)
           (R ∘ A)·1 <= capacity                      (per-platform resource)
           A[i,j] <= B[i,j],  A real in [0,1], B binary

(the resource rows appear only when the problem carries the optional
capacity dimension — e.g. KV-cache bytes vs HBM for LM serving.)

The paper fed this (via ZIMPL) to SCIP; we use HiGHS branch-and-bound via
``scipy.optimize.milp`` — the same problem class with a 2020s solver, which
is precisely the "progress in MILP" the paper banks on [22]. The dual bound
HiGHS reports gives the external measure of solution quality the paper
calls for (§2.2.4): a solution can be certified near-optimal without being
proven optimal.

``atomic=True`` solves the unrelaxed eq. 3 instead (A binary, no split),
used for the NP-complete baseline comparisons.
"""
from __future__ import annotations

import time

import numpy as np
import scipy.sparse as sp
from scipy.optimize import LinearConstraint, Bounds, milp

from .allocation import (
    SUPPORT_ATOL,
    Allocation,
    AllocationProblem,
    assert_capacity_feasible,
    makespan,
    platform_usage,
)
from .heuristic import incumbent_shortcut, proportional_allocation

__all__ = ["milp_allocation"]


def _build_relaxed(problem: AllocationProblem):
    """Variables x = [A (mu*tau), B (mu*tau), G_L]; A row-major (i, j)."""
    mu, tau = problem.mu, problem.tau
    n = mu * tau
    W, G = problem.work, problem.gamma

    c = np.zeros(2 * n + 1)
    c[-1] = 1.0

    # sum_i A[i, j] == 1   (tau rows)
    jj = np.arange(n)  # A index for (i, j) = i * tau + j -> column j = idx % tau
    eq = sp.csr_matrix((np.ones(n), (jj % tau, jj)), shape=(tau, 2 * n + 1))
    eq_con = LinearConstraint(eq, lb=np.ones(tau), ub=np.ones(tau))

    # per-platform latency: W_i·A_i + G_i·B_i - G_L <= 0   (mu rows)
    rows = np.repeat(np.arange(mu), tau)
    a_cols = np.arange(n)
    b_cols = n + np.arange(n)
    lat = sp.csr_matrix(
        (
            np.concatenate([W.ravel(), G.ravel(), -np.ones(mu)]),
            (
                np.concatenate([rows, rows, np.arange(mu)]),
                np.concatenate([a_cols, b_cols, np.full(mu, 2 * n)]),
            ),
        ),
        shape=(mu, 2 * n + 1),
    )
    # committed per-platform offsets shift each latency row's budget
    lat_con = LinearConstraint(lat, lb=-np.inf, ub=-problem.offsets)

    # A[i,j] - B[i,j] <= 0   (n rows)
    link = sp.csr_matrix(
        (
            np.concatenate([np.ones(n), -np.ones(n)]),
            (np.concatenate([np.arange(n), np.arange(n)]),
             np.concatenate([a_cols, b_cols])),
        ),
        shape=(n, 2 * n + 1),
    )
    link_con = LinearConstraint(link, lb=-np.inf, ub=np.zeros(n))

    cons = [eq_con, lat_con, link_con]
    if problem.has_capacity:
        # per-platform resource rows: R_i·A_i <= capacity_i   (mu rows)
        res = sp.csr_matrix(
            (problem.resource.ravel(), (rows, a_cols)), shape=(mu, 2 * n + 1))
        cons.append(LinearConstraint(res, lb=-np.inf, ub=problem.capacity))

    integrality = np.concatenate([np.zeros(n), np.ones(n), np.zeros(1)])
    bounds = Bounds(
        lb=np.concatenate([np.zeros(2 * n), [0.0]]),
        ub=np.concatenate([np.ones(2 * n), [np.inf]]),
    )
    return c, cons, integrality, bounds


def _build_atomic(problem: AllocationProblem):
    """eq. 3: A binary, L = W + gamma, no B needed."""
    mu, tau = problem.mu, problem.tau
    n = mu * tau
    L = problem.full_latency

    c = np.zeros(n + 1)
    c[-1] = 1.0
    jj = np.arange(n)
    eq = sp.csr_matrix((np.ones(n), (jj % tau, jj)), shape=(tau, n + 1))
    eq_con = LinearConstraint(eq, lb=np.ones(tau), ub=np.ones(tau))
    rows = np.repeat(np.arange(mu), tau)
    lat = sp.csr_matrix(
        (
            np.concatenate([L.ravel(), -np.ones(mu)]),
            (np.concatenate([rows, np.arange(mu)]),
             np.concatenate([jj, np.full(mu, n)])),
        ),
        shape=(mu, n + 1),
    )
    lat_con = LinearConstraint(lat, lb=-np.inf, ub=-problem.offsets)
    cons = [eq_con, lat_con]
    if problem.has_capacity:
        res = sp.csr_matrix(
            (problem.resource.ravel(), (rows, jj)), shape=(mu, n + 1))
        cons.append(LinearConstraint(res, lb=-np.inf, ub=problem.capacity))
    integrality = np.concatenate([np.ones(n), np.zeros(1)])
    bounds = Bounds(
        lb=np.zeros(n + 1),
        ub=np.concatenate([np.ones(n), [np.inf]]),
    )
    return c, cons, integrality, bounds


def milp_allocation(
    problem: AllocationProblem,
    *,
    time_limit: float = 600.0,
    mip_rel_gap: float = 1e-4,
    atomic: bool = False,
    incumbent: Allocation | None = None,
    warm_tol: float = 0.05,
) -> Allocation:
    """Solve eq. 12; ``incumbent`` enables the online warm-start early exit.

    HiGHS via scipy takes no MIP start, so the incumbent's value here is
    the skip test (:func:`incumbent_shortcut`): when the executing
    allocation is already within ``warm_tol`` of the fresh heuristic bound
    on the re-fitted problem, return it without solving.
    """
    t0 = time.perf_counter()
    assert_capacity_feasible(problem)
    warm_meta = {}
    if incumbent is not None:
        _, shortcut, warm_meta = incumbent_shortcut(
            problem, incumbent, "milp", warm_tol, t0)
        if shortcut is not None:
            return shortcut
    mu, tau = problem.mu, problem.tau
    n = mu * tau
    t_build0 = time.perf_counter()
    if atomic:
        c, cons, integrality, bounds = _build_atomic(problem)
    else:
        c, cons, integrality, bounds = _build_relaxed(problem)
    build_s = time.perf_counter() - t_build0
    n_vars = c.size
    n_constraints = sum(con.A.shape[0] for con in cons)

    t_solve0 = time.perf_counter()
    res = milp(
        c,
        constraints=cons,
        integrality=integrality,
        bounds=bounds,
        options={"time_limit": time_limit, "mip_rel_gap": mip_rel_gap},
    )
    phase_meta = {"build_s": build_s,
                  "solve_s": time.perf_counter() - t_solve0,
                  "polish_s": 0.0,  # MILP has no polish phase
                  "n_vars": int(n_vars), "n_constraints": int(n_constraints)}
    solve_time = time.perf_counter() - t0

    if res.x is None:
        # solver produced nothing within the budget — fall back to heuristic
        heur = proportional_allocation(problem)
        return Allocation(
            A=heur.A, makespan=heur.makespan, solver="milp",
            solve_time=solve_time, optimal=False,
            meta={"status": int(res.status), "fallback": "heuristic",
                  **phase_meta, **warm_meta},
        )

    A = np.asarray(res.x[:n], dtype=np.float64).reshape(mu, tau)
    A[A < SUPPORT_ATOL] = 0.0
    colsum = A.sum(axis=0)
    if (colsum <= 0).any():  # numerically degenerate column: put on best platform
        for j in np.nonzero(colsum <= 0)[0]:
            order = np.argsort(problem.full_latency[:, j])
            if problem.capacity is not None:
                # prefer the fastest platform whose capacity row still fits
                usage = platform_usage(A, problem)
                fits = [i for i in order
                        if usage[i] + problem.resource[i, j] <= problem.capacity[i]]
                order = fits or list(order)
            A[order[0], j] = 1.0
        colsum = A.sum(axis=0)
    A /= colsum

    gap = getattr(res, "mip_gap", None)
    bound = getattr(res, "mip_dual_bound", None)
    return Allocation(
        A=A,
        makespan=makespan(A, problem),
        solver="milp-atomic" if atomic else "milp",
        solve_time=solve_time,
        optimal=bool(res.status == 0),
        bound=None if bound is None else float(bound),
        meta={"status": int(res.status), "mip_gap": None if gap is None else float(gap),
              "node_count": int(getattr(res, "mip_node_count", -1) or -1),
              **phase_meta, **warm_meta},
    )
