"""RWKV-6 "Finch" (attention-free, data-dependent decay) — arXiv:2404.05892.

TPU adaptation: the sequential WKV recurrence is evaluated in *chunked
parallel* form (the flash-linear-attention factorisation): within a chunk
of C tokens the interaction is two small matmuls plus a state term —
MXU-friendly dense algebra — and the recurrent state is carried across
chunks with a lax.scan. Decode uses the exact O(1) recurrence.

Stability: the data-dependent decay w_t = exp(-exp(...)) is clamped to
log w >= -8 and the chunk factorisation is computed with a per-channel
exponent shift of half the chunk's total log-decay, bounding every factor
by e^(C*8/2); with C=16 that is e^64, inside float32 range.

WKV recurrence (per head; S is the [d_k, d_v] state):
    o_t = r_t . (S_{t-1} + (u o k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamBuilder, Rules, flat_get, stack_init, shard_act, remat_policy
from .config import ModelConfig
from .layers import cross_entropy, init_norm, rmsnorm

__all__ = ["RWKVModel", "CHUNK"]

CHUNK = 16
LOGW_MIN = -8.0
LORA_R = 64


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu


def _head_norm(o, w, eps):
    """GroupNorm-per-head stand-in: RMS-normalise each head's d_v lanes."""
    o32 = o.astype(jnp.float32)
    o32 = o32 * jax.lax.rsqrt(jnp.mean(o32 * o32, axis=-1, keepdims=True) + eps)
    return (o32 * w.astype(jnp.float32)).astype(o.dtype)


def _chunk_wkv(r, k, v, logw, u, state):
    """One chunk. r,k,v,logw: [B,H,C,K] (v: [B,H,C,V]); state [B,H,K,V].

    Returns (o [B,H,C,V], new state). All f32.
    """
    c = r.shape[2]
    L = jnp.cumsum(logw, axis=2)                     # inclusive cumulative log-decay
    L_prev = L - logw                                # exclusive
    L_tot = L[:, :, -1:, :]                          # [B,H,1,K]
    shift = 0.5 * L_tot
    rq = r * jnp.exp(L_prev - shift)                 # bounded by e^(|L|/2)
    kq = k * jnp.exp(shift - L)
    scores = jnp.einsum("bhck,bhik->bhci", rq, kq)   # exp(L_prev[c] - L[i])
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)    # strict lower triangle
    scores = jnp.where(mask[None, None], scores, 0.0)
    diag = jnp.einsum("bhck,bhck->bhc", r, u[None, :, None, :] * k)
    o = jnp.einsum("bhci,bhiv->bhcv", scores, v)     # intra-chunk
    o = o + diag[..., None] * v                      # bonus (i = t) term
    o = o + jnp.einsum("bhck,bhkv->bhcv", r * jnp.exp(L_prev), state)  # inter
    kdec = k * jnp.exp(L_tot - L)                    # decayed-to-chunk-end keys
    new_state = state * jnp.exp(L_tot).swapaxes(2, 3) \
        + jnp.einsum("bhck,bhcv->bhkv", kdec, v)
    return o, new_state


class RWKVModel:
    def __init__(self, cfg: ModelConfig, rules: Rules | None = None,
                 seq_shard: bool = True):
        self.cfg = cfg
        self.rules = rules or Rules({})
        mdl = self.rules.present("model")
        self.act_spec = P(self.rules.dp() or None,
                          mdl[0] if (seq_shard and mdl) else None, None)
        self.n_heads = cfg.n_heads
        self.hd = cfg.hd

    # ------------------------------------------------------------- params
    def _build_block(self):
        cfg, rules = self.cfg, self.rules
        d, h, hd, f = cfg.d_model, self.n_heads, self.hd, cfg.d_ff
        dp = rules.maybe(d, "data")
        mdl = rules.maybe(h, "model")
        f_sh = rules.maybe(f, "model")

        def build(key):
            b = ParamBuilder(key, cfg.pdtype)
            init_norm(b, "ln1", d)
            for nm in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"):
                b.const(nm, jnp.full((d,), 0.5), P(None))
            for nm in ("wr", "wk", "wv", "wg"):
                b.normal(nm, (d, h, hd), P(dp, mdl, None))
            b.normal("wo", (h, hd, d), P(mdl, None, dp), scale=1.0 / math.sqrt(d))
            b.const("w0", jnp.full((h, hd), -0.6), P(mdl, None))
            b.normal("w_lora_a", (d, LORA_R), P(dp, None))
            b.zeros("w_lora_b", (LORA_R, h, hd), P(None, mdl, None))
            b.const("u", jnp.full((h, hd), 0.5), P(mdl, None))
            b.ones("ln_x", (h, hd), P(mdl, None))
            # channel mix
            init_norm(b, "ln2", d)
            for nm in ("mu_ck", "mu_cr"):
                b.const(nm, jnp.full((d,), 0.5), P(None))
            b.normal("ck", (d, f), P(dp, f_sh))
            b.normal("cv", (f, d), P(f_sh, dp))
            b.normal("cr", (d, d), P(dp, None))
            return b.params, b.specs

        return build

    def init(self, key):
        cfg = self.cfg
        kb, ke = jax.random.split(key)
        params, specs = stack_init(self._build_block(), kb, cfg.n_layers)
        params = {f"blocks/{k}": v for k, v in params.items()}
        specs = {f"blocks/{k}": v for k, v in specs.items()}
        b = ParamBuilder(ke, cfg.pdtype)
        vs = self.rules.maybe(cfg.vocab, "model")
        ds = self.rules.maybe(cfg.d_model, "data")
        b.normal("embed", (cfg.vocab, cfg.d_model), P(vs, ds), scale=1.0)
        b.normal("unembed", (cfg.d_model, cfg.vocab), P(ds, vs))
        init_norm(b, "ln_f", cfg.d_model)
        params.update(b.params)
        specs.update(b.specs)
        self._specs = specs
        return params

    def abstract(self, key=None):
        shapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return shapes, dict(self._specs)

    # ----------------------------------------------------------- layer fns
    def _decay(self, p, xw):
        """Data-dependent decay (the Finch signature): log w in [LOGW_MIN, 0)."""
        lora = jnp.einsum("bcr,rhk->bchk", jnp.tanh(xw @ p["w_lora_a"]),
                          p["w_lora_b"]).astype(jnp.float32)
        logw = -jnp.exp(p["w0"].astype(jnp.float32) + lora)
        return jnp.maximum(logw, LOGW_MIN)

    def _time_mix_chunk(self, p, x, prev_tok, state):
        """x: [B, C, D] one chunk; prev_tok [B, D]; state [B,H,K,V]."""
        cfg, h, hd = self.cfg, self.n_heads, self.hd
        bsz, c, d = x.shape
        xn = rmsnorm(x, p["ln1"], cfg.eps)
        xx = jnp.concatenate([prev_tok[:, None], xn[:, :-1]], axis=1)
        proj = lambda nm, xi: jnp.einsum("bcd,dhk->bhck", xi, p[nm])
        r = proj("wr", _lerp(xn, xx, p["mu_r"])).astype(jnp.float32)
        k = proj("wk", _lerp(xn, xx, p["mu_k"])).astype(jnp.float32)
        v = proj("wv", _lerp(xn, xx, p["mu_v"])).astype(jnp.float32)
        g = proj("wg", _lerp(xn, xx, p["mu_g"]))
        logw = self._decay(p, _lerp(xn, xx, p["mu_w"])).transpose(0, 2, 1, 3)
        o, new_state = _chunk_wkv(r, k, v, logw, p["u"].astype(jnp.float32),
                                  state)
        o = _head_norm(o.astype(cfg.cdtype).transpose(0, 2, 1, 3), p["ln_x"],
                       cfg.eps)                      # [B,C,H,V]
        o = o * jax.nn.silu(g.transpose(0, 2, 1, 3))
        y = jnp.einsum("bchk,hkd->bcd", o, p["wo"])
        return x + y, xn[:, -1], new_state

    def _channel_mix_chunk(self, p, x, prev_tok):
        cfg = self.cfg
        xn = rmsnorm(x, p["ln2"], cfg.eps)
        xx = jnp.concatenate([prev_tok[:, None], xn[:, :-1]], axis=1)
        kk = jnp.square(jax.nn.relu(_lerp(xn, xx, p["mu_ck"]) @ p["ck"]))
        rr = jax.nn.sigmoid(_lerp(xn, xx, p["mu_cr"]) @ p["cr"])
        return x + rr * (kk @ p["cv"]), xn[:, -1]

    def _layer_chunk(self, p, x, carry):
        """One layer over one chunk. carry = (tmix_prev, cmix_prev, state)."""
        tprev, cprev, state = carry
        x, tprev, state = self._time_mix_chunk(p, x, tprev, state)
        x, cprev = self._channel_mix_chunk(p, x, cprev)
        return shard_act(x, self.act_spec, self.rules), (tprev, cprev, state)

    # ------------------------------------------------------------ forward
    def _zero_carry(self, bsz):
        cfg, h, hd = self.cfg, self.n_heads, self.hd
        return (jnp.zeros((bsz, cfg.d_model), cfg.cdtype),
                jnp.zeros((bsz, cfg.d_model), cfg.cdtype),
                jnp.zeros((bsz, h, hd, hd), jnp.float32))

    def _run_layers(self, params, x, carries=None):
        """x [B, S, D]; scan layers outer, chunks inner. Returns final
        hidden states + per-layer carries (the decode cache)."""
        cfg = self.cfg
        blocks = flat_get(params, "blocks")
        bsz, s, _ = x.shape
        n_chunks, tail = divmod(s, CHUNK)

        def layer_body(h_seq, xs):
            layer_p, carry0 = xs

            def chunk_body(carry, xc):
                xc, carry = self._layer_chunk(layer_p, xc, carry)
                return carry, xc

            main, rest = h_seq[:, : n_chunks * CHUNK], h_seq[:, n_chunks * CHUNK:]
            carry = carry0
            parts = []
            if n_chunks:
                chunks = main.reshape(bsz, n_chunks, CHUNK, -1).swapaxes(0, 1)
                carry, ys = jax.lax.scan(chunk_body, carry, chunks)
                parts.append(ys.swapaxes(0, 1).reshape(bsz, n_chunks * CHUNK, -1))
            if tail:  # ragged final chunk (prefill lengths % CHUNK != 0)
                yt, carry = self._layer_chunk(layer_p, rest, carry)
                parts.append(yt)
            out = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
            return out, carry

        layer_body = jax.checkpoint(layer_body,
                                    policy=remat_policy())
        if carries is None:
            z = self._zero_carry(bsz)
            carries = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), z)
        x, carries = jax.lax.scan(layer_body, x, (blocks, carries))
        return x, carries

    def loss(self, params, batch, q_chunk=None, loss_chunk=512):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(cfg.cdtype)
        x = shard_act(x, self.act_spec, self.rules)
        x, _ = self._run_layers(params, x)
        x = rmsnorm(x, params["ln_f"], cfg.eps)
        labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        return cross_entropy(lambda l: l, x, params["unembed"], labels,
                             mask=mask, chunk=loss_chunk)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        z = self._zero_carry(batch_size)
        return {
            "carries": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), z),
            "pos": jnp.asarray(0, jnp.int32),
        }

    def cache_specs(self, batch_size: int, max_seq: int):
        dp = self.rules.maybe(batch_size, "pod", "data")
        mdl = self.rules.maybe(self.n_heads, "model")
        return {
            "carries": (P(None, dp, None), P(None, dp, None),
                        P(None, dp, mdl, None, None)),
            "pos": P(),
        }

    def prefill(self, params, batch, max_seq: int, q_chunk=None):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(cfg.cdtype)
        x, carries = self._run_layers(params, x)
        x = rmsnorm(x[:, -1:], params["ln_f"], cfg.eps)
        cache = {"carries": carries,
                 "pos": jnp.asarray(batch["tokens"].shape[1], jnp.int32)}
        return cache, (x @ params["unembed"]).astype(jnp.float32)

    def decode_step(self, params, cache, tokens):
        """Exact single-token recurrence (state is O(1) in sequence)."""
        cfg = self.cfg
        x = params["embed"][tokens].astype(cfg.cdtype)  # [B, 1, D]
        blocks = flat_get(params, "blocks")

        def body(h, xs):
            layer_p, carry = xs
            h, carry = self._layer_chunk(layer_p, h, carry)
            return h, carry

        x, carries = jax.lax.scan(body, x, (blocks, cache["carries"]))
        x = rmsnorm(x, params["ln_f"], cfg.eps)
        new_cache = {"carries": carries, "pos": cache["pos"] + 1}
        return new_cache, (x @ params["unembed"]).astype(jnp.float32)

    # ------------------------------------------------------------- probes
    def probe_block(self, seq_len: int | None = None):
        """One layer over ONE chunk; multiplier = L * n_chunks."""
        def fn(layer_p, xc, tprev, cprev, state):
            y, _ = self._layer_chunk(layer_p, xc, (tprev, cprev, state))
            return y

        return fn, self.cfg.n_layers  # caller multiplies by n_chunks

    def probe_block_decode(self):
        def fn(layer_p, xc, tprev, cprev, state):
            y, (t, c, s) = self._layer_chunk(layer_p, xc, (tprev, cprev, state))
            return y, t, c, s

        return fn, self.cfg.n_layers
