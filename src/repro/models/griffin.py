"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU recurrent blocks +
local (sliding-window) MQA attention, interleaved 1:2 (rec, rec, attn).

TPU adaptation:
  * the RG-LRU linear recurrence h_t = a_t h_{t-1} + b_t runs as a
    ``jax.lax.associative_scan`` — log-depth, static HLO (exact FLOP
    accounting, no while loop), MXU-free VPU work;
  * sliding-window attention uses the banded q-chunk path in
    repro.models.layers (FLOPs scale with S*W, not S^2);
  * decode keeps an O(W) ring-buffer KV cache and an O(1) recurrent
    state, which is what makes the long_500k cell *runnable* for this
    architecture (cache size independent of sequence length).

Layers are scanned in (rec, rec, attn) triples (12 for the 38-layer 9B)
plus a scanned tail of leftover rec layers.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamBuilder, Rules, flat_get, stack_init, shard_act, remat_policy
from .config import ModelConfig
from .layers import (apply_attn, attention, cross_entropy, init_attn,
                     init_mlp, init_norm, mlp, rmsnorm, rope)

__all__ = ["GriffinModel", "rg_lru_scan"]

CONV_W = 4
C_SCALE = 8.0  # the paper's fixed 'c' in a_t = exp(-c * softplus(Lambda) * r_t)
SCAN_CHUNK = 4096  # unrolled seq-chunk size for the associative scan


def rg_lru_scan(a: jnp.ndarray, bx: jnp.ndarray, h0: jnp.ndarray):
    """h_t = a_t * h_{t-1} + bx_t with h_0 seeded by ``h0``.

    a, bx: [B, S, N]; h0: [B, N]. Associative scan over S (log-depth).
    """
    bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


class GriffinModel:
    def __init__(self, cfg: ModelConfig, rules: Rules | None = None,
                 seq_shard: bool = True):
        self.cfg = cfg
        self.rules = rules or Rules({})
        mdl = self.rules.present("model")
        self.act_spec = P(self.rules.dp() or None,
                          mdl[0] if (seq_shard and mdl) else None, None)
        pat = cfg.hybrid_pattern or ("rec", "rec", "attn")
        self.pattern = pat
        self.n_groups = cfg.n_layers // len(pat)
        self.n_tail = cfg.n_layers - self.n_groups * len(pat)
        assert all(pat[i % len(pat)] == "rec" for i in range(self.n_tail)), \
            "tail layers must be recurrent for uniform stacking"

    # ------------------------------------------------------------- params
    def _init_rec(self, b: ParamBuilder, prefix: str):
        cfg, rules = self.cfg, self.rules
        d, n = cfg.d_model, cfg.rnn_width
        dp, nr = rules.maybe(d, "data"), rules.maybe(n, "model")
        init_norm(b, f"{prefix}/ln", d)
        b.normal(f"{prefix}/w_x", (d, n), P(dp, nr))
        b.normal(f"{prefix}/w_gate", (d, n), P(dp, nr))
        b.normal(f"{prefix}/conv_w", (CONV_W, n), P(None, nr),
                 scale=1.0 / math.sqrt(CONV_W))
        b.zeros(f"{prefix}/conv_b", (n,), P(nr))
        # RG-LRU gates + Lambda
        b.normal(f"{prefix}/w_ra", (n, n), P(nr, None))
        b.zeros(f"{prefix}/b_ra", (n,), P(nr))
        b.normal(f"{prefix}/w_ix", (n, n), P(nr, None))
        b.zeros(f"{prefix}/b_ix", (n,), P(nr))
        b.const(f"{prefix}/lam", jnp.full((n,), 0.7), P(nr))
        b.normal(f"{prefix}/w_out", (n, d), P(nr, dp))
        init_norm(b, f"{prefix}/ln_mlp", d)
        init_mlp(b, cfg, rules, prefix=f"{prefix}/mlp")

    def _init_attn_layer(self, b: ParamBuilder, prefix: str):
        cfg, rules = self.cfg, self.rules
        init_norm(b, f"{prefix}/ln", cfg.d_model)
        init_attn(b, cfg, rules, prefix=f"{prefix}/attn")
        init_norm(b, f"{prefix}/ln_mlp", cfg.d_model)
        init_mlp(b, cfg, rules, prefix=f"{prefix}/mlp")

    def _build_group(self):
        def build(key):
            b = ParamBuilder(key, self.cfg.pdtype)
            for i, kind in enumerate(self.pattern):
                if kind == "rec":
                    self._init_rec(b, f"l{i}")
                else:
                    self._init_attn_layer(b, f"l{i}")
            return b.params, b.specs
        return build

    def _build_tail(self):
        def build(key):
            b = ParamBuilder(key, self.cfg.pdtype)
            self._init_rec(b, "rec")
            return b.params, b.specs
        return build

    def init(self, key):
        cfg = self.cfg
        kg, kt, ke = jax.random.split(key, 3)
        params, specs = stack_init(self._build_group(), kg, self.n_groups)
        params = {f"groups/{k}": v for k, v in params.items()}
        specs = {f"groups/{k}": v for k, v in specs.items()}
        if self.n_tail:
            tp, ts = stack_init(self._build_tail(), kt, self.n_tail)
            params.update({f"tail/{k}": v for k, v in tp.items()})
            specs.update({f"tail/{k}": v for k, v in ts.items()})
        b = ParamBuilder(ke, cfg.pdtype)
        vs = self.rules.maybe(cfg.vocab, "model")
        ds = self.rules.maybe(cfg.d_model, "data")
        b.normal("embed", (cfg.vocab, cfg.d_model), P(vs, ds), scale=1.0)
        b.normal("unembed", (cfg.d_model, cfg.vocab), P(ds, vs))
        init_norm(b, "ln_f", cfg.d_model)
        params.update(b.params)
        specs.update(b.specs)
        self._specs = specs
        return params

    def abstract(self, key=None):
        shapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return shapes, dict(self._specs)

    # --------------------------------------------------------- rec layer
    def _rec_layer(self, p, prefix, x, carry, decode: bool):
        """carry = (conv_buf [B, CONV_W-1, N], h [B, N]) or None (train)."""
        cfg = self.cfg
        xn = rmsnorm(x, p[f"{prefix}/ln"], cfg.eps)
        u = xn @ p[f"{prefix}/w_x"]
        gate = jax.nn.gelu(xn @ p[f"{prefix}/w_gate"])
        # causal depthwise conv, width 4
        if carry is None:
            hist = jnp.zeros((x.shape[0], CONV_W - 1, u.shape[-1]), u.dtype)
        else:
            hist = carry[0]
        ext = jnp.concatenate([hist, u], axis=1)
        conv = sum(ext[:, CONV_W - 1 - j: ext.shape[1] - j] *
                   p[f"{prefix}/conv_w"][CONV_W - 1 - j]
                   for j in range(CONV_W))
        conv = conv + p[f"{prefix}/conv_b"]
        new_hist = ext[:, -(CONV_W - 1):]
        # RG-LRU
        c32 = conv.astype(jnp.float32)
        r = jax.nn.sigmoid(c32 @ p[f"{prefix}/w_ra"].astype(jnp.float32)
                           + p[f"{prefix}/b_ra"].astype(jnp.float32))
        i = jax.nn.sigmoid(c32 @ p[f"{prefix}/w_ix"].astype(jnp.float32)
                           + p[f"{prefix}/b_ix"].astype(jnp.float32))
        log_a = -C_SCALE * jax.nn.softplus(p[f"{prefix}/lam"].astype(jnp.float32)) * r
        a = jnp.exp(log_a)
        bx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * c32)
        h0 = (jnp.zeros_like(bx[:, 0]) if carry is None
              else carry[1].astype(jnp.float32))
        if decode:
            h = (a * h0[:, None] + bx)           # single step (S == 1)
        elif a.shape[1] > SCAN_CHUNK:
            # python-unrolled sequence chunks: bounds the associative-scan
            # working set (levels x [B, chunk, N] f32) at long prefill
            # lengths while keeping the HLO static (exact FLOP counting).
            hs = []
            hc = h0
            for c0 in range(0, a.shape[1], SCAN_CHUNK):
                sl = slice(c0, c0 + SCAN_CHUNK)
                hch = rg_lru_scan(a[:, sl], bx[:, sl], hc)
                hc = hch[:, -1]
                hs.append(hch)
            h = jnp.concatenate(hs, axis=1)
        else:
            h = rg_lru_scan(a, bx, h0)
        new_carry = (new_hist, h[:, -1].astype(cfg.cdtype))
        y = (h.astype(cfg.cdtype) * gate) @ p[f"{prefix}/w_out"]
        x = shard_act(x + y, self.act_spec, self.rules)
        x = x + mlp(p, cfg, rmsnorm(x, p[f"{prefix}/ln_mlp"], cfg.eps),
                    prefix=f"{prefix}/mlp")
        return shard_act(x, self.act_spec, self.rules), new_carry

    # -------------------------------------------------------- attn layer
    def _attn_layer_train(self, p, prefix, x, q_chunk, unroll=False):
        cfg = self.cfg
        positions = jnp.arange(x.shape[1])
        h, _ = apply_attn(p, cfg, rmsnorm(x, p[f"{prefix}/ln"], cfg.eps),
                          positions=positions, window=cfg.local_window,
                          q_chunk=q_chunk, prefix=f"{prefix}/attn",
                          unroll=unroll)
        x = shard_act(x + h, self.act_spec, self.rules)
        x = x + mlp(p, cfg, rmsnorm(x, p[f"{prefix}/ln_mlp"], cfg.eps),
                    prefix=f"{prefix}/mlp")
        return shard_act(x, self.act_spec, self.rules)

    def _attn_layer_ring(self, p, prefix, x, ring, pos):
        """Decode with an O(window) ring-buffer cache.

        ring = (k [B, W, KVH, hd], v, slot_pos [W] int32).
        """
        cfg = self.cfg
        k_r, v_r, slot_pos = ring
        w = k_r.shape[1]
        xn = rmsnorm(x, p[f"{prefix}/ln"], cfg.eps)
        pr = f"{prefix}/attn"
        q = jnp.einsum("bsd,dhk->bshk", xn, p[f"{pr}/wq"])
        k = jnp.einsum("bsd,dhk->bshk", xn, p[f"{pr}/wk"])
        v = jnp.einsum("bsd,dhk->bshk", xn, p[f"{pr}/wv"])
        posn = pos + jnp.arange(1)
        q = rope(q, posn, cfg.rope_theta)
        k = rope(k, posn, cfg.rope_theta)           # absolute-position rope
        slot = pos % w
        k_r = jax.lax.dynamic_update_slice(k_r, k.astype(k_r.dtype), (0, slot, 0, 0))
        v_r = jax.lax.dynamic_update_slice(v_r, v.astype(v_r.dtype), (0, slot, 0, 0))
        slot_pos = jax.lax.dynamic_update_slice(slot_pos, pos[None], (slot,))
        # manual masked attention over the ring
        b, _, hh, hd = q.shape
        kvh = k_r.shape[2]
        qg = q.reshape(b, 1, kvh, hh // kvh, hd)
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, k_r,
                            preferred_element_type=jnp.float32)
        logits = logits / math.sqrt(hd)
        valid = (slot_pos <= pos) & (slot_pos > pos - w) & (slot_pos >= 0)
        logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v_r.dtype), v_r)
        o = o.reshape(b, 1, hh, hd)
        y = jnp.einsum("bshk,hkd->bsd", o, p[f"{pr}/wo"])
        x = shard_act(x + y, self.act_spec, self.rules)
        x = x + mlp(p, cfg, rmsnorm(x, p[f"{prefix}/ln_mlp"], cfg.eps),
                    prefix=f"{prefix}/mlp")
        return shard_act(x, self.act_spec, self.rules), (k_r, v_r, slot_pos)

    # ------------------------------------------------------------ forward
    def _group_train(self, p, x, q_chunk, unroll=False):
        for i, kind in enumerate(self.pattern):
            if kind == "rec":
                x, _ = self._rec_layer(p, f"l{i}", x, None, decode=False)
            else:
                x = self._attn_layer_train(p, f"l{i}", x, q_chunk, unroll)
        return x

    def hidden_states(self, params, batch, q_chunk=None):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(cfg.cdtype)
        x = shard_act(x, self.act_spec, self.rules)
        groups = flat_get(params, "groups")

        def body(h, gp):
            return self._group_train(gp, h, q_chunk), None

        body = jax.checkpoint(body, policy=remat_policy())
        x, _ = jax.lax.scan(body, x, groups)
        if self.n_tail:
            tail = flat_get(params, "tail")

            def tbody(h, tp):
                h, _ = self._rec_layer(tp, "rec", h, None, decode=False)
                return h, None

            x, _ = jax.lax.scan(jax.checkpoint(
                tbody, policy=remat_policy()), x, tail)
        return x

    def loss(self, params, batch, q_chunk=None, loss_chunk=512):
        cfg = self.cfg
        x = self.hidden_states(params, batch, q_chunk=q_chunk)
        x = rmsnorm(x, params["ln_f"], cfg.eps)
        labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        return cross_entropy(lambda l: l, x, params["unembed"], labels,
                             mask=mask, chunk=loss_chunk)

    # ------------------------------------------------------------ serving
    def _zero_group_cache(self, bsz):
        cfg = self.cfg
        w = cfg.local_window
        n = cfg.rnn_width
        rec = lambda: (jnp.zeros((bsz, CONV_W - 1, n), cfg.cdtype),
                       jnp.zeros((bsz, n), cfg.cdtype))
        out = {}
        for i, kind in enumerate(self.pattern):
            if kind == "rec":
                out[f"l{i}"] = rec()
            else:
                out[f"l{i}"] = (
                    jnp.zeros((bsz, w, cfg.n_kv_heads, cfg.hd), cfg.pdtype),
                    jnp.zeros((bsz, w, cfg.n_kv_heads, cfg.hd), cfg.pdtype),
                    jnp.full((w,), -10**9, jnp.int32),
                )
        return out

    def init_cache(self, batch_size: int, max_seq: int):
        g = self._zero_group_cache(batch_size)
        stack = lambda tree: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n_groups,) + a.shape).copy(), tree)
        cache = {"groups": stack(g), "pos": jnp.asarray(0, jnp.int32)}
        if self.n_tail:
            rec = self._zero_group_cache(batch_size)["l0"]
            cache["tail"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_tail,) + a.shape).copy(), rec)
        return cache

    def cache_specs(self, batch_size: int, max_seq: int):
        dp = self.rules.maybe(batch_size, "pod", "data")
        rec_spec = (P(None, dp, None, None), P(None, dp, None))
        out = {}
        for i, kind in enumerate(self.pattern):
            if kind == "rec":
                out[f"l{i}"] = rec_spec
            else:
                out[f"l{i}"] = (P(None, dp, None, None, None),
                                P(None, dp, None, None, None), P(None, None))
        specs = {"groups": out, "pos": P()}
        if self.n_tail:
            specs["tail"] = rec_spec
        return specs

    def prefill(self, params, batch, max_seq: int, q_chunk=None):
        """Forward over the prompt, then rebuild decode caches from the
        final window/state (per-layer python loop inside the group scan)."""
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(cfg.cdtype)
        x = shard_act(x, self.act_spec, self.rules)
        s = x.shape[1]
        w = cfg.local_window
        groups = flat_get(params, "groups")

        def body(h, gp):
            caches = {}
            for i, kind in enumerate(self.pattern):
                if kind == "rec":
                    h2, carry = self._rec_layer(gp, f"l{i}", h, None, decode=False)
                    # rebuild conv history from the last CONV_W-1 inputs is
                    # already inside carry; keep it
                    caches[f"l{i}"] = carry
                    h = h2
                else:
                    # run windowed attention, then build the ring buffer
                    xn = rmsnorm(h, gp[f"l{i}/ln"], cfg.eps)
                    pr = f"l{i}/attn"
                    positions = jnp.arange(s)
                    k = rope(jnp.einsum("bsd,dhk->bshk", xn, gp[f"{pr}/wk"]),
                             positions, cfg.rope_theta)
                    v = jnp.einsum("bsd,dhk->bshk", xn, gp[f"{pr}/wv"])
                    h = self._attn_layer_train(gp, f"l{i}", h, q_chunk)
                    take = min(s, w)
                    kk, vv = k[:, -take:], v[:, -take:]
                    pos_taken = jnp.arange(s - take, s)
                    slots = pos_taken % w
                    k_r = jnp.zeros((h.shape[0], w, cfg.n_kv_heads, cfg.hd),
                                    cfg.pdtype).at[:, slots].set(kk.astype(cfg.pdtype))
                    v_r = jnp.zeros_like(k_r).at[:, slots].set(vv.astype(cfg.pdtype))
                    slot_pos = jnp.full((w,), -10**9, jnp.int32).at[slots].set(pos_taken)
                    caches[f"l{i}"] = (k_r, v_r, slot_pos)
            return h, caches

        x, gcaches = jax.lax.scan(body, x, groups)
        cache = {"groups": gcaches, "pos": jnp.asarray(s, jnp.int32)}
        if self.n_tail:
            tail = flat_get(params, "tail")

            def tbody(h, tp):
                h, carry = self._rec_layer(tp, "rec", h, None, decode=False)
                return h, carry

            x, tcaches = jax.lax.scan(tbody, x, tail)
            cache["tail"] = tcaches
        x = rmsnorm(x[:, -1:], params["ln_f"], cfg.eps)
        return cache, (x @ params["unembed"]).astype(jnp.float32)

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = params["embed"][tokens].astype(cfg.cdtype)
        pos = cache["pos"]
        groups = flat_get(params, "groups")

        def body(h, xs):
            gp, gc = xs
            new_c = {}
            for i, kind in enumerate(self.pattern):
                if kind == "rec":
                    h, new_c[f"l{i}"] = self._rec_layer(gp, f"l{i}", h,
                                                        gc[f"l{i}"], decode=True)
                else:
                    h, new_c[f"l{i}"] = self._attn_layer_ring(gp, f"l{i}", h,
                                                              gc[f"l{i}"], pos)
            return h, new_c

        x, gcaches = jax.lax.scan(body, x, (groups, cache["groups"]))
        new_cache = {"groups": gcaches, "pos": pos + 1}
        if self.n_tail:
            tail = flat_get(params, "tail")

            def tbody(h, xs):
                tp, tc = xs
                h, carry = self._rec_layer(tp, "rec", h, tc, decode=True)
                return h, carry

            x, tcaches = jax.lax.scan(tbody, x, (tail, cache["tail"]))
            new_cache["tail"] = tcaches
        x = rmsnorm(x, params["ln_f"], cfg.eps)
        return new_cache, (x @ params["unembed"]).astype(jnp.float32)

    # ------------------------------------------------------------- probes
    def probe_block(self, q_chunk=None):
        def fn(group_p, x):
            # unroll=True: probes need static banded HLO for exact costs
            return self._group_train(group_p, x, q_chunk=q_chunk, unroll=True)
        return fn, self.n_groups  # tail folded into the multiplier

    def probe_block_decode(self):
        def fn(group_p, x, gc, pos):
            new_c = {}
            h = x
            for i, kind in enumerate(self.pattern):
                if kind == "rec":
                    h, new_c[f"l{i}"] = self._rec_layer(group_p, f"l{i}", h,
                                                        gc[f"l{i}"], decode=True)
                else:
                    h, new_c[f"l{i}"] = self._attn_layer_ring(group_p, f"l{i}",
                                                              h, gc[f"l{i}"], pos)
            return h, new_c
        return fn, self.n_groups
