"""Shared neural layers: RMSNorm, RoPE, GQA attention, MLP, embeddings.

All functions are pure and operate on flat param sub-dicts. Attention
supports full, causal, sliding-window and query-chunked evaluation, and a
single code path serves train, prefill and decode (q_offset shifts the
causal mask for cached decoding).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamBuilder, Rules

__all__ = ["rmsnorm", "rope", "attention", "mlp", "init_attn", "init_mlp",
           "cross_entropy", "apply_attn", "init_norm"]


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * w.astype(jnp.float32)).astype(dt)


def init_norm(b: ParamBuilder, name: str, d: int) -> None:
    b.ones(name, (d,), P(None))


# ------------------------------------------------------------------- RoPE

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [S] or [B, S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]                      # [1, S, 1, half]
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]                      # [B, S, 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention

def _attend_block(q, k, v, q_pos, k_pos, causal, window):
    """q [B,Sq,KV,G,D], k/v [B,Skv,KV,D] -> [B,Sq,KV,G,D]; f32 softmax."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((q.shape[1], k.shape[1]), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out


def attention(q, k, v, *, causal: bool = True, q_offset=0,
              window: int | None = None, q_chunk: int | None = None,
              unroll: bool = False):
    """Grouped-query attention.

    q: [B, Sq, H, D]; k, v: [B, Skv, KVH, D]. H % KVH == 0.
    ``q_offset``: absolute position of q[0] (for cached decode; may be a
    traced scalar). ``q_chunk``: evaluate queries in chunks of this size so
    the [Sq, Skv] score matrix never fully materialises (the memory-
    feasibility knob for 32k prefill). Chunks run under ``lax.map`` by
    default (one chunk's buffers live at a time); ``unroll=True`` emits
    static per-chunk HLO instead — used by the roofline probes, whose cost
    analysis cannot see through a while loop. With ``window`` set and a
    static offset, chunks use *banded* key slices: FLOPs scale with
    Sq x (window + chunk), not Sq x Skv.
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    k_pos = jnp.arange(k.shape[1])
    q_pos = jnp.asarray(q_offset) + jnp.arange(sq)
    if q_chunk is None or sq <= q_chunk:
        out = _attend_block(qg, k, v, q_pos, k_pos, causal, window)
        return out.reshape(b, sq, h, d)

    assert sq % q_chunk == 0, (sq, q_chunk)
    n_chunks = sq // q_chunk
    static_offset = isinstance(q_offset, int)
    banded = window is not None and static_offset and sq == k.shape[1]

    if banded:
        # left-pad keys by `window` so every chunk sees a uniform
        # (window + q_chunk)-wide band at an affine offset
        pad = ((0, 0), (window, 0), (0, 0), (0, 0))
        kp, vp = jnp.pad(k, pad), jnp.pad(v, pad)
        kp_pos = jnp.concatenate([jnp.full((window,), -(10**9)), k_pos])

        def chunk(c):
            qs = jax.lax.dynamic_slice_in_dim(qg, c * q_chunk, q_chunk, 1)
            ks = jax.lax.dynamic_slice_in_dim(kp, c * q_chunk,
                                              window + q_chunk, 1)
            vs = jax.lax.dynamic_slice_in_dim(vp, c * q_chunk,
                                              window + q_chunk, 1)
            ps = jax.lax.dynamic_slice_in_dim(kp_pos, c * q_chunk,
                                              window + q_chunk, 0)
            qp = jax.lax.dynamic_slice_in_dim(q_pos, c * q_chunk, q_chunk, 0)
            return _attend_block(qs, ks, vs, qp, ps, causal, window)
    else:
        def chunk(c):
            qs = jax.lax.dynamic_slice_in_dim(qg, c * q_chunk, q_chunk, 1)
            qp = jax.lax.dynamic_slice_in_dim(q_pos, c * q_chunk, q_chunk, 0)
            return _attend_block(qs, k, v, qp, k_pos, causal, window)

    if unroll:
        out = jnp.concatenate([chunk(c) for c in range(n_chunks)], axis=1)
    else:
        ys = jax.lax.map(chunk, jnp.arange(n_chunks))   # [n, B, qc, ...]
        out = jnp.moveaxis(ys, 0, 1).reshape(b, sq, kvh, g, d)
    return out.reshape(b, sq, h, d)


def init_attn(b: ParamBuilder, cfg, rules: Rules, prefix: str = "attn") -> None:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dp, mdl = rules.maybe(d, "data"), rules.maybe(h, "model")
    kv_mdl = rules.maybe(kvh, "model")
    b.normal(f"{prefix}/wq", (d, h, hd), P(dp, mdl, None))
    b.normal(f"{prefix}/wk", (d, kvh, hd), P(dp, kv_mdl, None))
    b.normal(f"{prefix}/wv", (d, kvh, hd), P(dp, kv_mdl, None))
    b.normal(f"{prefix}/wo", (h, hd, d), P(mdl, None, dp),
             scale=1.0 / math.sqrt(h * hd))
    if cfg.qkv_bias:
        b.zeros(f"{prefix}/bq", (h, hd), P(mdl, None))
        b.zeros(f"{prefix}/bk", (kvh, hd), P(kv_mdl, None))
        b.zeros(f"{prefix}/bv", (kvh, hd), P(kv_mdl, None))


def apply_attn(p: dict, cfg, x: jnp.ndarray, *, positions, cache=None,
               window: int | None = None, q_chunk: int | None = None,
               prefix: str = "attn", kv_override=None, use_rope: bool = True,
               unroll: bool = False):
    """Full attention sub-block: qkv proj -> rope -> (cache) -> attn -> out.

    cache: None (training/prefill without cache) or dict with keys
    {"k": [B, Smax, KVH, D], "v": ..., "pos": scalar} — decode appends at
    ``pos`` and attends over the first pos+Sq entries.
    kv_override: (k, v) for cross-attention (keys from the encoder).
    Returns (out, new_cache).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p[f"{prefix}/wq"])
    if f"{prefix}/bq" in p:
        q = q + p[f"{prefix}/bq"]
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p[f"{prefix}/wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p[f"{prefix}/wv"])
        if f"{prefix}/bk" in p:
            k = k + p[f"{prefix}/bk"]
            v = v + p[f"{prefix}/bv"]
        if use_rope:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        causal = True
    else:
        k, v = kv_override
        if use_rope:
            q = rope(q, positions, cfg.rope_theta)
        causal = False

    new_cache = None
    q_offset = 0
    if cache is not None:
        pos = cache["pos"]
        k = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                         (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                         (0, pos, 0, 0))
        new_cache = {"k": k, "v": v, "pos": pos + x.shape[1]}
        q_offset = pos

    out = attention(q, k, v, causal=causal, q_offset=q_offset,
                    window=window, q_chunk=q_chunk, unroll=unroll)
    # mask out not-yet-written cache slots is handled by the causal mask
    # (q_offset bounds the attended range).
    y = jnp.einsum("bshk,hkd->bsd", out, p[f"{prefix}/wo"])
    return y, new_cache


# -------------------------------------------------------------------- MLP

def init_mlp(b: ParamBuilder, cfg, rules: Rules, prefix: str = "mlp",
             d_ff: int | None = None) -> None:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dp, mdl = rules.maybe(d, "data"), rules.maybe(f, "model")
    if cfg.mlp_variant in ("swiglu", "geglu"):
        b.normal(f"{prefix}/w_gate", (d, f), P(dp, mdl))
    b.normal(f"{prefix}/w_in", (d, f), P(dp, mdl))
    b.normal(f"{prefix}/w_out", (f, d), P(mdl, dp))


def mlp(p: dict, cfg, x: jnp.ndarray, prefix: str = "mlp") -> jnp.ndarray:
    h = x @ p[f"{prefix}/w_in"]
    if cfg.mlp_variant == "swiglu":
        h = jax.nn.silu(x @ p[f"{prefix}/w_gate"]) * h
    elif cfg.mlp_variant == "geglu":
        h = jax.nn.gelu(x @ p[f"{prefix}/w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p[f"{prefix}/w_out"]


# ---------------------------------------------------------- loss / logits

def cross_entropy(logits_fn, x: jnp.ndarray, unembed: jnp.ndarray,
                  labels: jnp.ndarray, mask: jnp.ndarray | None = None,
                  chunk: int = 512):
    """Chunked next-token cross-entropy.

    ``x`` [B, S, D] final hidden states; ``unembed`` [D, V]; ``labels``
    [B, S]. The [B, chunk, V] logits are materialised one sequence-chunk
    at a time (python-unrolled: exact HLO flops, bounded memory even at
    V=256k). logits_fn lets callers post-process logits (e.g. cap/scale).
    """
    b, s, _ = x.shape
    chunk = min(chunk, s)
    while s % chunk:  # snap to the largest divisor of s not above chunk
        chunk -= 1
    total = jnp.float32(0.0)
    count = jnp.float32(0.0)
    for c in range(s // chunk):
        sl = slice(c * chunk, (c + 1) * chunk)
        logits = logits_fn(x[:, sl] @ unembed).astype(jnp.float32)  # [B,c,V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = labels[:, sl]
        picked = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = lse - picked
        m = jnp.ones_like(nll) if mask is None else mask[:, sl].astype(jnp.float32)
        total = total + (nll * m).sum()
        count = count + m.sum()
    return total / jnp.maximum(count, 1.0)
