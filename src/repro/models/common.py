"""Parameter construction, sharding rules and numeric helpers.

Parameters are a *flat* ``dict[str, jnp.ndarray]`` with '/'-joined path
keys ("blocks/attn/wq", ...). Flat dicts keep sharding specs, optimizer
state, and checkpoint shards trivially alignable. Layer-stacked parameters
(for lax.scan over layers) carry a leading L dimension.

Sharding follows the MaxText-style FSDP x TP recipe on the
("data", "model") mesh (+ "pod" for pure DP in the multi-pod mesh):

  * weight matrices [d_in, d_out]-like: P("data", "model") — d_in sharded
    over the data axis (FSDP / ZeRO-3: XLA SPMD inserts per-layer
    all-gathers), d_out over the model axis (TP).
  * layer-boundary activations [B, S, D]: P(("pod","data"), SP?, None) —
    batch over DP axes; with sequence parallelism the S dim additionally
    shards over "model" between blocks.
  * axes are only sharded when divisible — ``maybe`` drops a mesh axis for
    dims it does not divide (e.g. 4 KV heads on a 16-way model axis).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["ParamBuilder", "Rules", "flat_get", "subtree", "stack_init",
           "shard_act", "DEFAULT_DP", "MODEL", "remat_policy", "REMAT_POLICY"]

#: per-layer activation-checkpoint policy: "nothing" (recompute everything,
#: minimum memory) or "dots" (save matmul outputs — less recompute, more
#: HBM). A §Perf hillclimb lever; switch via repro.models.common.
REMAT_POLICY = "nothing"


def remat_policy():
    policies = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }
    return policies[REMAT_POLICY]

Params = dict[str, jnp.ndarray]

DEFAULT_DP: tuple[str, ...] = ("pod", "data")  # logical DP axes (pod may be absent)
MODEL = "model"


class Rules:
    """Axis-sharding helper bound to a concrete mesh axis-size mapping.

    ``axis_sizes`` maps axis name -> size; axes absent from the current
    mesh (e.g. "pod" on the single-pod mesh) must be pre-filtered by the
    caller via ``present``.
    """

    def __init__(self, axis_sizes: dict[str, int]):
        self.axis_sizes = dict(axis_sizes)

    def present(self, *axes: str) -> tuple[str, ...]:
        return tuple(a for a in axes if a in self.axis_sizes)

    def maybe(self, dim: int, *axes: str):
        """Return the (possibly compound) mesh axes for a dim, or None if
        the dim is not divisible by their product."""
        axes = self.present(*axes)
        if not axes:
            return None
        prod = math.prod(self.axis_sizes[a] for a in axes)
        if dim % prod != 0:
            return None
        return axes if len(axes) > 1 else axes[0]

    def dp(self) -> tuple[str, ...]:
        return self.present(*DEFAULT_DP)


#: Replicated rules used for single-device smoke tests.
REPLICATED = Rules({})


class ParamBuilder:
    """Initialises a flat param dict and its matching PartitionSpec dict."""

    def __init__(self, key: jax.Array, dtype):
        self._key = key
        self.dtype = dtype
        self.params: Params = {}
        self.specs: dict[str, P] = {}

    def _next(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def normal(self, name: str, shape: tuple[int, ...], spec: P,
               scale: float | None = None) -> None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
        self.params[name] = (jax.random.normal(self._next(), shape, jnp.float32)
                             * scale).astype(self.dtype)
        self.specs[name] = spec

    def zeros(self, name: str, shape: tuple[int, ...], spec: P) -> None:
        self.params[name] = jnp.zeros(shape, self.dtype)
        self.specs[name] = spec

    def ones(self, name: str, shape: tuple[int, ...], spec: P) -> None:
        self.params[name] = jnp.ones(shape, self.dtype)
        self.specs[name] = spec

    def const(self, name: str, value, spec: P) -> None:
        self.params[name] = jnp.asarray(value, self.dtype)
        self.specs[name] = spec


def flat_get(params: Params, prefix: str) -> Params:
    """Sub-dict of keys under ``prefix/``, with the prefix stripped."""
    pre = prefix + "/"
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


def subtree(params: Params, prefix: str) -> Params:
    return flat_get(params, prefix)


def stack_init(builder_fn: Callable[[jax.Array], tuple[Params, dict]],
               key: jax.Array, n: int) -> tuple[Params, dict]:
    """Initialise ``n`` copies of a layer and stack them on a leading dim,
    prepending None to each spec (the layer-stack dim is never sharded)."""
    keys = jax.random.split(key, n)
    stacked: dict[str, list] = {}
    specs: dict[str, P] = {}
    for i in range(n):
        p, s = builder_fn(keys[i])
        for k, v in p.items():
            stacked.setdefault(k, []).append(v)
        if i == 0:
            specs = {k: P(None, *tuple(sp)) for k, sp in s.items()}
    return {k: jnp.stack(v) for k, v in stacked.items()}, specs


def shard_act(x: jnp.ndarray, spec: P | None, rules: "Rules | None" = None):
    """with_sharding_constraint that (a) is a no-op outside a mesh context
    and (b) drops spec axes that do not divide the dim (e.g. batch=1 decode
    cells on a 16-way data axis)."""
    if spec is None:
        return x
    if rules is not None:
        dims = list(spec) + [None] * (x.ndim - len(spec))
        fixed = []
        for size, ax in zip(x.shape, dims):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            prod = math.prod(rules.axis_sizes.get(a, 1) for a in axes)
            fixed.append(ax if prod and size % prod == 0 else None)
        spec = P(*fixed)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
