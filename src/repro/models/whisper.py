"""Whisper-tiny (arXiv:2212.04356): encoder-decoder with a conv audio
frontend. Per the assignment spec, the conv frontend is a STUB —
``input_specs()`` supplies precomputed mel-frame embeddings [B, T_a, D];
the model projects them and runs the transformer backbone.

Encoder: bidirectional attention over audio frames (learned positions).
Decoder: causal self-attention (KV cache) + cross-attention to the
encoder output (cross K/V computed once at prefill and cached).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamBuilder, Rules, flat_get, stack_init, shard_act, remat_policy
from .config import ModelConfig
from .layers import (apply_attn, cross_entropy, init_attn, init_mlp,
                     init_norm, mlp, rmsnorm)

__all__ = ["WhisperModel"]


class WhisperModel:
    def __init__(self, cfg: ModelConfig, rules: Rules | None = None,
                 seq_shard: bool = True):
        self.cfg = cfg
        self.rules = rules or Rules({})
        mdl = self.rules.present("model")
        self.act_spec = P(self.rules.dp() or None,
                          mdl[0] if (seq_shard and mdl) else None, None)

    # ------------------------------------------------------------- params
    def _build_enc_block(self):
        cfg, rules = self.cfg, self.rules

        def build(key):
            b = ParamBuilder(key, cfg.pdtype)
            init_norm(b, "ln1", cfg.d_model)
            init_attn(b, cfg, rules)
            init_norm(b, "ln2", cfg.d_model)
            init_mlp(b, cfg, rules)
            return b.params, b.specs

        return build

    def _build_dec_block(self):
        cfg, rules = self.cfg, self.rules

        def build(key):
            b = ParamBuilder(key, cfg.pdtype)
            init_norm(b, "ln1", cfg.d_model)
            init_attn(b, cfg, rules, prefix="self_attn")
            init_norm(b, "ln_x", cfg.d_model)
            init_attn(b, cfg, rules, prefix="cross_attn")
            init_norm(b, "ln2", cfg.d_model)
            init_mlp(b, cfg, rules)
            return b.params, b.specs

        return build

    def init(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        enc, enc_s = stack_init(self._build_enc_block(), k1, cfg.encoder_layers)
        dec, dec_s = stack_init(self._build_dec_block(), k2, cfg.n_layers)
        params = {f"enc/{k}": v for k, v in enc.items()}
        params.update({f"dec/{k}": v for k, v in dec.items()})
        specs = {f"enc/{k}": v for k, v in enc_s.items()}
        specs.update({f"dec/{k}": v for k, v in dec_s.items()})
        b = ParamBuilder(k3, cfg.pdtype)
        vs = self.rules.maybe(cfg.vocab, "model")
        ds = self.rules.maybe(cfg.d_model, "data")
        b.normal("embed", (cfg.vocab, cfg.d_model), P(vs, ds), scale=1.0)
        b.normal("unembed", (cfg.d_model, cfg.vocab), P(ds, vs))
        b.normal("audio_proj", (cfg.d_model, cfg.d_model), P(ds, None))
        b.normal("enc_pos", (cfg.frontend_len, cfg.d_model), P(None, ds),
                 scale=0.02)
        # sized to cover the decode_32k cell (32768 positions + margin)
        b.normal("dec_pos", (40960, cfg.d_model), P(None, ds), scale=0.02)
        init_norm(b, "ln_enc", cfg.d_model)
        init_norm(b, "ln_f", cfg.d_model)
        params.update(b.params)
        specs.update(b.specs)
        self._specs = specs
        return params

    def abstract(self, key=None):
        shapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return shapes, dict(self._specs)

    # ------------------------------------------------------------ encoder
    def encode(self, params, audio):
        cfg = self.cfg
        x = audio.astype(cfg.cdtype) @ params["audio_proj"]
        x = x + params["enc_pos"][: x.shape[1]].astype(cfg.cdtype)
        x = shard_act(x, self.act_spec, self.rules)
        blocks = flat_get(params, "enc")
        positions = jnp.arange(x.shape[1])

        def body(h, layer_p):
            hn = rmsnorm(h, layer_p["ln1"], cfg.eps)
            # bidirectional self-attention (kv_override with own k/v, no rope)
            k = jnp.einsum("bsd,dhk->bshk", hn, layer_p["attn/wk"])
            v = jnp.einsum("bsd,dhk->bshk", hn, layer_p["attn/wv"])
            a, _ = apply_attn(layer_p, cfg, hn, positions=positions,
                              kv_override=(k, v), use_rope=False)
            h = shard_act(h + a, self.act_spec, self.rules)
            h = h + mlp(layer_p, cfg, rmsnorm(h, layer_p["ln2"], cfg.eps))
            return shard_act(h, self.act_spec, self.rules), None

        x, _ = jax.lax.scan(body, x, blocks)
        return rmsnorm(x, params["ln_enc"], cfg.eps)

    # ------------------------------------------------------------ decoder
    def _dec_block(self, p, x, enc_kv, *, positions, cache=None, q_chunk=None):
        cfg = self.cfg
        h, new_cache = apply_attn(p, cfg, rmsnorm(x, p["ln1"], cfg.eps),
                                  positions=positions, cache=cache,
                                  q_chunk=q_chunk, prefix="self_attn",
                                  use_rope=False)
        x = shard_act(x + h, self.act_spec, self.rules)
        h, _ = apply_attn(p, cfg, rmsnorm(x, p["ln_x"], cfg.eps),
                          positions=positions, kv_override=enc_kv,
                          prefix="cross_attn", use_rope=False)
        x = shard_act(x + h, self.act_spec, self.rules)
        x = x + mlp(p, cfg, rmsnorm(x, p["ln2"], cfg.eps))
        return shard_act(x, self.act_spec, self.rules), new_cache

    def _cross_kv(self, params, enc_out):
        """Per-decoder-layer cross K/V from the encoder output (cached)."""
        blocks = flat_get(params, "dec")

        def body(_, layer_p):
            k = jnp.einsum("bsd,dhk->bshk", enc_out, layer_p["cross_attn/wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, layer_p["cross_attn/wv"])
            return 0, (k, v)

        _, (ks, vs) = jax.lax.scan(body, 0, blocks)
        return ks, vs

    def _dec_embed(self, params, tokens, pos0):
        cfg = self.cfg
        x = params["embed"][tokens].astype(cfg.cdtype)
        pos = params["dec_pos"]
        sl = jax.lax.dynamic_slice_in_dim(pos, pos0, tokens.shape[1]) \
            if not isinstance(pos0, int) else pos[pos0: pos0 + tokens.shape[1]]
        return shard_act(x + sl.astype(cfg.cdtype), self.act_spec, self.rules)

    def loss(self, params, batch, q_chunk=None, loss_chunk=512):
        cfg = self.cfg
        enc_out = self.encode(params, batch["audio"])
        cross = self._cross_kv(params, enc_out)
        x = self._dec_embed(params, batch["tokens"], 0)
        positions = jnp.arange(x.shape[1])
        blocks = flat_get(params, "dec")

        def body(h, xs):
            layer_p, ck, cv = xs
            h, _ = self._dec_block(layer_p, h, (ck, cv), positions=positions,
                                   q_chunk=q_chunk)
            return h, None

        body = jax.checkpoint(body, policy=remat_policy())
        x, _ = jax.lax.scan(body, x, (blocks, *cross))
        x = rmsnorm(x, params["ln_f"], cfg.eps)
        labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        return cross_entropy(lambda l: l, x, params["unembed"], labels,
                             mask=mask, chunk=loss_chunk)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        kv = (cfg.n_layers, batch_size, max_seq, cfg.n_kv_heads, cfg.hd)
        cross = (cfg.n_layers, batch_size, cfg.frontend_len, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(kv, cfg.pdtype), "v": jnp.zeros(kv, cfg.pdtype),
                "ck": jnp.zeros(cross, cfg.pdtype),
                "cv": jnp.zeros(cross, cfg.pdtype),
                "pos": jnp.asarray(0, jnp.int32)}

    def cache_specs(self, batch_size: int, max_seq: int):
        dp = self.rules.maybe(batch_size, "pod", "data")
        kv_sh = self.rules.maybe(self.cfg.n_kv_heads, "model")
        s = P(None, dp, None, kv_sh, None)
        return {"k": s, "v": s, "ck": s, "cv": s, "pos": P()}

    def prefill(self, params, batch, max_seq: int, q_chunk=None):
        cfg = self.cfg
        enc_out = self.encode(params, batch["audio"])
        ck, cv = self._cross_kv(params, enc_out)
        cache = self.init_cache(batch["tokens"].shape[0], max_seq)
        cache["ck"], cache["cv"] = ck.astype(cfg.pdtype), cv.astype(cfg.pdtype)
        x = self._dec_embed(params, batch["tokens"], 0)
        positions = jnp.arange(x.shape[1])
        blocks = flat_get(params, "dec")

        def body(h, xs):
            layer_p, k_l, v_l, ck_l, cv_l = xs
            lcache = {"k": k_l, "v": v_l, "pos": jnp.asarray(0, jnp.int32)}
            h, nc = self._dec_block(layer_p, h, (ck_l, cv_l),
                                    positions=positions, cache=lcache,
                                    q_chunk=q_chunk)
            return h, (nc["k"], nc["v"])

        x, (ks, vs) = jax.lax.scan(body, x, (blocks, cache["k"], cache["v"],
                                             cache["ck"], cache["cv"]))
        cache["k"], cache["v"] = ks, vs
        cache["pos"] = jnp.asarray(x.shape[1], jnp.int32)
        x = rmsnorm(x[:, -1:], params["ln_f"], cfg.eps)
        return cache, (x @ params["unembed"]).astype(jnp.float32)

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        pos = cache["pos"]
        x = self._dec_embed(params, tokens, pos)
        blocks = flat_get(params, "dec")

        def body(h, xs):
            layer_p, k_l, v_l, ck_l, cv_l = xs
            lcache = {"k": k_l, "v": v_l, "pos": pos}
            h, nc = self._dec_block(layer_p, h, (ck_l, cv_l),
                                    positions=pos + jnp.arange(1),
                                    cache=lcache)
            return h, (nc["k"], nc["v"])

        x, (ks, vs) = jax.lax.scan(body, x, (blocks, cache["k"], cache["v"],
                                             cache["ck"], cache["cv"]))
        new_cache = dict(cache, k=ks, v=vs, pos=pos + 1)
        x = rmsnorm(x, params["ln_f"], cfg.eps)
        return new_cache, (x @ params["unembed"]).astype(jnp.float32)

    # ------------------------------------------------------------- probes
    def probe_block(self, seq_len=None):
        cfg = self.cfg

        def fn(layer_p, x, enc_k, enc_v):
            positions = jnp.arange(x.shape[1])
            y, _ = self._dec_block(layer_p, x, (enc_k, enc_v),
                                   positions=positions)
            return y

        return fn, cfg.n_layers

    def probe_block_decode(self):
        cfg = self.cfg

        def fn(layer_p, x, k, v, ck, cv, pos):
            lcache = {"k": k, "v": v, "pos": pos}
            y, nc = self._dec_block(layer_p, x, (ck, cv),
                                    positions=pos + jnp.arange(1), cache=lcache)
            return y, nc["k"], nc["v"]

        return fn, cfg.n_layers
