"""Model factory: family -> implementation class."""
from __future__ import annotations

from .common import Rules
from .config import ModelConfig
from .griffin import GriffinModel
from .moe import MoEModel
from .rwkv import RWKVModel
from .transformer import DenseModel
from .whisper import WhisperModel

__all__ = ["build_model"]

_FAMILIES = {
    "dense": DenseModel,
    "vlm": DenseModel,
    "moe": MoEModel,
    "rwkv": RWKVModel,
    "hybrid": GriffinModel,
    "encdec": WhisperModel,
}


def build_model(cfg: ModelConfig, rules: Rules | None = None,
                seq_shard: bool = True):
    try:
        cls = _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r} for {cfg.name}") from None
    return cls(cfg, rules=rules, seq_shard=seq_shard)
