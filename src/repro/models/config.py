"""Model configuration for the 10 assigned architectures.

One frozen dataclass covers every family; family-specific fields are
ignored where inapplicable. Exact full-size configs live in
``repro.configs.<arch>``; reduced smoke configs are derived with
``ModelConfig.smoke()``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["ModelConfig", "Shape", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qkv_bias: bool = False           # qwen2.5
    mlp_variant: str = "swiglu"      # swiglu | gelu
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False     # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    # --- hybrid (recurrentgemma / griffin) ---
    local_window: int = 2048
    d_rnn: int | None = None
    hybrid_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    # --- enc-dec / modality frontends (stubs provide embeddings) ---
    encoder_layers: int = 0
    frontend_len: int = 0            # stub frontend tokens (vision patches / audio frames)
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    rope_theta: float = 10_000.0
    eps: float = 1e-5
    # --- capability flags ---
    subquadratic: bool = False       # supports long_500k decode
    has_decoder: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def rnn_width(self) -> int:
        return self.d_rnn if self.d_rnn is not None else self.d_model

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def smoke(self, **over) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 if not self.hybrid_pattern else len(self.hybrid_pattern)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            local_window=32,
            d_rnn=64 if self.d_rnn is not None else None,
            encoder_layers=min(self.encoder_layers, 2),
            frontend_len=min(self.frontend_len, 8),
            param_dtype="float32",
            compute_dtype="float32",
            name=self.name + "-smoke",
        )
        small.update(over)
        return dataclasses.replace(self, **small)

    # ---------------- analytic parameter counts (for MODEL_FLOPS) ----------

    def param_count(self) -> tuple[int, int]:
        """(total params N, active params N_active) — embeddings excluded
        from the FLOP-relevant count per the 6ND convention's usual usage,
        but unembed matmul is counted separately in roofline."""
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.mlp_variant == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        total = active = 0
        n_dec = self.n_layers
        if self.family == "moe":
            moe = self.n_experts * mlp
            act_moe = self.top_k * mlp
            dense_part = mlp if self.dense_residual else 0
            per_layer_total = attn + moe + dense_part
            per_layer_active = attn + act_moe + dense_part
            total += n_dec * per_layer_total
            active += n_dec * per_layer_active
        elif self.family == "rwkv":
            # time-mix ~ 4 d^2 (+ small loras), channel-mix ~ 2*d*d_ff
            per = 5 * d * d + 2 * d * self.d_ff
            total += n_dec * per
            active += n_dec * per
        elif self.family == "hybrid":
            pat = self.hybrid_pattern or ("rec",)
            n_rec = sum(1 for _ in range(n_dec) if pat[_ % len(pat)] == "rec")
            n_att = n_dec - n_rec
            rec = 3 * d * self.rnn_width + self.rnn_width * d  # in/gate/out + conv
            per_att = attn
            total += n_rec * (rec + mlp) + n_att * (per_att + mlp)
            active = total
        else:  # dense / vlm / encdec
            per = attn + mlp
            total += (n_dec + self.encoder_layers) * per
            if self.encoder_layers:  # cross-attention in decoder
                total += n_dec * (d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2)
            active = total
        return total, active


@dataclasses.dataclass(frozen=True)
class Shape:
    """One assigned input-shape cell."""
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, Shape] = {
    "train_4k":    Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  Shape("decode_32k", "decode", 32_768, 128),
    "long_500k":   Shape("long_500k", "decode", 524_288, 1),
}
