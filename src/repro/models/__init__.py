"""repro.models — the 10 assigned architectures as composable JAX modules."""
from .api import build_model  # noqa: F401
from .config import ModelConfig, SHAPES, Shape  # noqa: F401
from .common import Rules  # noqa: F401
