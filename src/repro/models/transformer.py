"""Dense decoder-only transformer (starcoder2 / yi / minitron / qwen2.5)
plus the VLM variant (internvl2: same backbone, patch-embedding stub).

Layer-stacked parameters + lax.scan over layers keep the HLO compact for
the 512-device dry-run; single-block probe entry points give the roofline
exact per-layer costs (XLA's cost analysis counts a while body once).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamBuilder, Rules, flat_get, stack_init, shard_act, remat_policy
from .config import ModelConfig
from .layers import (apply_attn, cross_entropy, init_attn, init_mlp,
                     init_norm, mlp, rmsnorm)

__all__ = ["DenseModel", "init_block", "apply_block"]


def init_block(cfg: ModelConfig, rules: Rules):
    """Builder for one decoder block's params (flat dict + specs)."""

    def build(key):
        b = ParamBuilder(key, cfg.pdtype)
        init_norm(b, "ln1", cfg.d_model)
        init_attn(b, cfg, rules)
        init_norm(b, "ln2", cfg.d_model)
        init_mlp(b, cfg, rules)
        return b.params, b.specs

    return build


def apply_block(p: dict, cfg: ModelConfig, x, *, positions, cache=None,
                q_chunk=None, act_spec=None, window=None, rules=None):
    """Pre-norm block: x + attn(ln(x)); x + mlp(ln(x)). Returns (x, cache)."""
    h, new_cache = apply_attn(p, cfg, rmsnorm(x, p["ln1"], cfg.eps),
                              positions=positions, cache=cache,
                              q_chunk=q_chunk, window=window)
    x = shard_act(x + h, act_spec, rules)
    x = x + mlp(p, cfg, rmsnorm(x, p["ln2"], cfg.eps))
    return shard_act(x, act_spec, rules), new_cache


class DenseModel:
    """family in {"dense", "vlm"}."""

    block_key = "blocks"

    def __init__(self, cfg: ModelConfig, rules: Rules | None = None,
                 seq_shard: bool = True):
        self.cfg = cfg
        self.rules = rules or Rules({})
        # sequence-parallel layer-boundary activations (hillclimb lever)
        mdl = self.rules.present("model")
        self.act_spec = P(self.rules.dp() or None,
                          mdl[0] if (seq_shard and mdl) else None, None)

    # ------------------------------------------------------------- params
    def _build_block(self):
        return init_block(self.cfg, self.rules)

    def init(self, key):
        cfg, rules = self.cfg, self.rules
        kb, ke, ku, kf = jax.random.split(key, 4)
        params, specs = stack_init(self._build_block(), kb, cfg.n_layers)
        params = {f"{self.block_key}/{k}": v for k, v in params.items()}
        specs = {f"{self.block_key}/{k}": v for k, v in specs.items()}
        b = ParamBuilder(ke, cfg.pdtype)
        vocab_sh = rules.maybe(cfg.vocab, "model")
        d_sh = rules.maybe(cfg.d_model, "data")
        b.normal("embed", (cfg.vocab, cfg.d_model), P(vocab_sh, d_sh), scale=1.0)
        b.normal("unembed", (cfg.d_model, cfg.vocab), P(d_sh, vocab_sh))
        init_norm(b, "ln_f", cfg.d_model)
        if cfg.family == "vlm":
            # patch-embedding stub: a projection of precomputed ViT features
            b.normal("vision_proj", (cfg.d_model, cfg.d_model), P(d_sh, None))
        params.update(b.params)
        specs.update(b.specs)
        self._specs = specs
        return params

    def abstract(self, key=None):
        """(shapes, specs) without allocating — dry-run entry."""
        shapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return shapes, dict(self._specs)

    # ------------------------------------------------------------ forward
    def embed_inputs(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(cfg.cdtype)
        if cfg.family == "vlm":
            vis = batch["vision"].astype(cfg.cdtype) @ params["vision_proj"]
            x = jnp.concatenate([vis, x], axis=1)
        return shard_act(x, self.act_spec, self.rules)

    def _scan_blocks(self, params, x, positions, q_chunk, window=None):
        cfg = self.cfg
        blocks = flat_get(params, self.block_key)

        def body(h, layer_p):
            h, _ = apply_block(layer_p, cfg, h, positions=positions,
                               q_chunk=q_chunk, act_spec=self.act_spec,
                               window=window, rules=self.rules)
            return h, None

        body = jax.checkpoint(body, policy=remat_policy())
        x, _ = jax.lax.scan(body, x, blocks)
        return x

    def hidden_states(self, params, batch, q_chunk=None):
        x = self.embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        return self._scan_blocks(params, x, positions, q_chunk)

    def loss(self, params, batch, q_chunk=None, loss_chunk=512):
        """Next-token CE. For VLM, loss is only on the text positions."""
        cfg = self.cfg
        x = self.hidden_states(params, batch, q_chunk=q_chunk)
        x = rmsnorm(x, params["ln_f"], cfg.eps)
        tokens = batch["tokens"]
        n_front = x.shape[1] - tokens.shape[1]
        x_text = x[:, n_front:]
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        return cross_entropy(lambda l: l, x_text, params["unembed"], labels,
                             mask=mask, chunk=loss_chunk)

    # ------------------------------------------------------------ serving
    def cache_shape(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        kvh_sh = self.rules.maybe(cfg.n_kv_heads, "model")
        seq_sh = self.rules.maybe(max_seq, "model") if kvh_sh is None else None
        bsp = self.rules.maybe(batch_size, "pod", "data")
        spec = P(None, bsp, seq_sh, kvh_sh, None)
        shape = (cfg.n_layers, batch_size, max_seq, cfg.n_kv_heads, cfg.hd)
        return {"k": (shape, spec), "v": (shape, spec), "pos": ((), P())}

    def init_cache(self, batch_size: int, max_seq: int):
        shapes = self.cache_shape(batch_size, max_seq)
        cache = {k: jnp.zeros(s, self.cfg.pdtype if k != "pos" else jnp.int32)
                 for k, (s, _) in shapes.items()}
        cache["pos"] = jnp.asarray(0, jnp.int32)
        return cache

    def cache_specs(self, batch_size: int, max_seq: int):
        return {k: spec for k, (_, spec) in self.cache_shape(batch_size, max_seq).items()}

    def _blocks_with_cache(self, params, x, cache, q_chunk=None):
        cfg = self.cfg
        blocks = flat_get(params, self.block_key)
        positions = cache["pos"] + jnp.arange(x.shape[1])

        def body(h, xs):
            layer_p, k_l, v_l = xs
            lcache = {"k": k_l, "v": v_l, "pos": cache["pos"]}
            h, new_c = apply_block(layer_p, cfg, h, positions=positions,
                                   cache=lcache, q_chunk=q_chunk,
                                   act_spec=self.act_spec, rules=self.rules)
            return h, (new_c["k"], new_c["v"])

        x, (ks, vs) = jax.lax.scan(body, x, (blocks, cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "pos": cache["pos"] + x.shape[1]}
        return x, new_cache

    def prefill(self, params, batch, max_seq: int, q_chunk=None):
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        cache = self.init_cache(x.shape[0], max_seq)
        x, cache = self._blocks_with_cache(params, x, cache, q_chunk=q_chunk)
        x = rmsnorm(x[:, -1:], params["ln_f"], cfg.eps)
        return cache, (x @ params["unembed"]).astype(jnp.float32)

    def decode_step(self, params, cache, tokens):
        """tokens [B, 1] -> (new_cache, logits [B, 1, V])."""
        cfg = self.cfg
        x = params["embed"][tokens].astype(cfg.cdtype)
        x, cache = self._blocks_with_cache(params, x, cache)
        x = rmsnorm(x, params["ln_f"], cfg.eps)
        return cache, (x @ params["unembed"]).astype(jnp.float32)

    # ------------------------------------------------------------- probes
    def probe_block(self):
        """(fn, multiplier): one decoder block, for exact per-layer costs."""
        cfg = self.cfg

        def fn(layer_p, x):
            positions = jnp.arange(x.shape[1])
            y, _ = apply_block(layer_p, cfg, x, positions=positions,
                               act_spec=self.act_spec, rules=self.rules)
            return y

        return fn, cfg.n_layers

    def probe_block_decode(self):
        cfg = self.cfg

        def fn(layer_p, x, k, v, pos):
            positions = pos + jnp.arange(x.shape[1])
            y, c = apply_block(layer_p, cfg, x, positions=positions,
                               cache={"k": k, "v": v, "pos": pos},
                               act_spec=self.act_spec, rules=self.rules)
            return y, c["k"], c["v"]

        return fn, cfg.n_layers
