"""Mixture-of-Experts decoder (moonshot 64e top-6; arctic 128e top-2 +
dense residual).

Dispatch is scatter/gather-based, NOT one-hot-einsum-based: the GShard
dispatch einsum inflates HLO FLOPs by O(E*C/k) (~100x here), which would
poison the roofline's compute term. Instead each (token, k) copy computes
its position inside its expert's capacity buffer with a cumsum rank, is
scatter-added into the [B, E, C, D] buffer, processed by the batched
expert matmul (the only real FLOPs), and gathered back. Tokens beyond an
expert's capacity are dropped (standard capacity-factor semantics).

Expert weights are sharded over the "model" axis (expert parallelism);
the buffer is sharded [B->data, E->model], so dispatch/return traffic
shows up as the collective term the paper's gamma would model.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamBuilder, Rules, shard_act, remat_policy
from .config import ModelConfig
from .layers import apply_attn, init_attn, init_mlp, init_norm, mlp, rmsnorm
from .transformer import DenseModel

__all__ = ["MoEModel", "moe_ffn", "init_moe_ffn"]


def init_moe_ffn(b: ParamBuilder, cfg: ModelConfig, rules: Rules,
                 prefix: str = "moe") -> None:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ep = rules.maybe(e, "model")
    dp = rules.maybe(d, "data")
    b.normal(f"{prefix}/router", (d, e), P(dp, None))
    b.normal(f"{prefix}/w_gate", (e, d, f), P(ep, dp, None))
    b.normal(f"{prefix}/w_in", (e, d, f), P(ep, dp, None))
    b.normal(f"{prefix}/w_out", (e, f, d), P(ep, None, dp))


#: "scatter" — baseline: the dispatch scatter writes straight into the
#: expert-sharded buffer (GSPMD resolves the sharded scatter with gathers).
#: "a2a" — beyond-paper optimisation (§Perf iteration 1): the scatter stays
#: local to the token (data) sharding and ONE explicit reshard moves the
#: buffer to expert (model) sharding — the classic MoE all-to-all expressed
#: as a sharding-constraint pair.
DISPATCH_MODE = "scatter"


def moe_ffn(p: dict, cfg: ModelConfig, x: jnp.ndarray, rules: Rules,
            prefix: str = "moe", dispatch: str | None = None) -> jnp.ndarray:
    """x: [B, S, D] -> [B, S, D]. Capacity is per sequence (group = batch
    row), so rank cumsums stay local to the unsharded sequence dim."""
    dispatch = dispatch or DISPATCH_MODE
    if x.shape[1] == 1 and x.shape[0] > 1:
        # decode: per-sequence capacity wastes ~E*C/k slots per token —
        # use ONE batch-global group (buf [E, C, D] is tiny)
        return _moe_ffn_decode(p, cfg, x, rules, prefix)
    if dispatch == "a2a_sp":
        return _moe_ffn_sp(p, cfg, x, rules, prefix)
    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(math.ceil(s * k * cfg.capacity_factor / e)), 4)
    dp = rules.dp() or None
    ep = rules.maybe(e, "model")
    token_spec = P(dp, None, None, None)
    expert_spec = P(dp, ep, None, None)

    scores = (x @ p[f"{prefix}/router"]).astype(jnp.float32)      # [B,S,E]
    gates = jax.nn.softmax(scores, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)                          # [B,S,K]
    topw = (topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    # rank of each (token, k) copy within its expert, per sequence
    flat_i = topi.reshape(bsz, s * k)                             # [B, S*K]
    onehot = jax.nn.one_hot(flat_i, e, dtype=jnp.int32)           # [B, S*K, E]
    ranks_all = jnp.cumsum(onehot, axis=1) - onehot               # rank if chosen
    rank = jnp.take_along_axis(ranks_all, flat_i[..., None], axis=-1)[..., 0]
    keep = (rank < cap)                                           # capacity drop
    rank_c = jnp.minimum(rank, cap - 1)

    # scatter token copies into the expert buffer [B, E, C, D]
    bidx = jnp.broadcast_to(jnp.arange(bsz)[:, None], flat_i.shape)
    updates = jnp.repeat(x, k, axis=1) * keep[..., None].astype(x.dtype)
    buf = jnp.zeros((bsz, e, cap, d), x.dtype)
    if dispatch == "a2a":
        # keep the scatter local to the token sharding...
        buf = shard_act(buf, token_spec, rules)
        buf = buf.at[bidx, flat_i, rank_c].add(updates)
        buf = shard_act(buf, token_spec, rules)
        # ...then pay ONE explicit reshard to expert sharding (the a2a)
        buf = shard_act(buf, expert_spec, rules)
    else:
        buf = buf.at[bidx, flat_i, rank_c].add(updates)
        buf = shard_act(buf, expert_spec, rules)

    # the real compute: batched expert matmuls [B,E,C,D] x [E,D,F]
    h = jnp.einsum("becd,edf->becf", buf, p[f"{prefix}/w_in"])
    if cfg.mlp_variant == "swiglu":
        g = jnp.einsum("becd,edf->becf", buf, p[f"{prefix}/w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("becf,efd->becd", h, p[f"{prefix}/w_out"])

    if dispatch == "a2a":
        # reshard back so the return gather is token-local
        y = shard_act(y, token_spec, rules)

    # gather copies back and combine with gate weights
    out = y[bidx, flat_i, rank_c]                                 # [B, S*K, D]
    out = out * (topw.reshape(bsz, s * k) * keep.astype(x.dtype))[..., None]
    return out.reshape(bsz, s, k, d).sum(axis=2)


def _moe_ffn_decode(p: dict, cfg: ModelConfig, x: jnp.ndarray, rules: Rules,
                    prefix: str = "moe") -> jnp.ndarray:
    """§Perf iteration (decode cells): batch-global dispatch group.

    At S=1 the per-sequence capacity layout allocates B x E x C slots for
    B x k token copies (~255x padding for arctic) and its gathers dominate
    the decode collectives. Treating the whole batch as one group shrinks
    the buffer to [E, C, D] with C = ceil(B*k*cf/E) — a few MB — at the
    cost of a batch-wide (still tiny) rank cumsum."""
    bsz, s, d = x.shape
    assert s == 1
    e, k = cfg.n_experts, cfg.top_k
    # 2x the train capacity factor: collisions across the whole batch
    # are the only drop source at decode and the buffer is tiny anyway
    cap = max(int(math.ceil(bsz * k * 2 * cfg.capacity_factor / e)), 4)
    ep = rules.maybe(e, "model")

    xt = x[:, 0]                                               # [B, D]
    scores = (xt @ p[f"{prefix}/router"]).astype(jnp.float32)  # [B, E]
    gates = jax.nn.softmax(scores, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)                       # [B, K]
    topw = (topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    flat_i = topi.reshape(bsz * k)                             # [N]
    onehot = jax.nn.one_hot(flat_i, e, dtype=jnp.int32)
    ranks_all = jnp.cumsum(onehot, axis=0) - onehot
    rank = jnp.take_along_axis(ranks_all, flat_i[:, None], axis=-1)[:, 0]
    keep = rank < cap
    rank_c = jnp.minimum(rank, cap - 1)

    updates = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_i, rank_c].add(updates)
    buf = shard_act(buf, P(ep, None, None), rules)

    h = jnp.einsum("ecd,edf->ecf", buf, p[f"{prefix}/w_in"])
    if cfg.mlp_variant == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, p[f"{prefix}/w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("ecf,efd->ecd", h, p[f"{prefix}/w_out"])

    out = y[flat_i, rank_c]                                    # [N, D]
    out = out * (topw.reshape(bsz * k) * keep.astype(x.dtype))[:, None]
    return out.reshape(bsz, k, d).sum(axis=1)[:, None]


def _moe_ffn_sp(p: dict, cfg: ModelConfig, x: jnp.ndarray, rules: Rules,
                prefix: str = "moe") -> jnp.ndarray:
    """§Perf iteration 2: SP-aligned dispatch.

    Tokens arrive sequence-sharded over "model" (SP). Grouping the
    dispatch by (batch, SP shard) makes the routing cumsum AND the
    capacity scatter fully local — the only cross-device traffic left is
    the single buffer reshard [B, G, E, C', D]: G("model")->E("model"),
    i.e. a true all-to-all of exactly the dispatched activations. Capacity
    becomes per-(sequence, SP-block) — same expected drop rate, locality
    bounded (documented semantic change vs the per-sequence baseline).
    """
    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    dp = rules.dp() or None
    mdl = rules.maybe(s, "model")
    g = rules.axis_sizes.get("model", 1) if mdl is not None else 1
    if s % max(g, 1) or g <= 1:
        g = 1
    sg = s // g
    cap = max(int(math.ceil(sg * k * cfg.capacity_factor / e)), 4)
    ep = rules.maybe(e, "model")
    grp = P(dp, "model" if g > 1 else None, None, None, None)

    xg = x.reshape(bsz, g, sg, d)
    xg = shard_act(xg, P(dp, "model" if g > 1 else None, None, None), rules)
    scores = (xg @ p[f"{prefix}/router"]).astype(jnp.float32)   # [B,G,Sg,E]
    gates = jax.nn.softmax(scores, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)                        # [B,G,Sg,K]
    topw = (topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    flat_i = topi.reshape(bsz, g, sg * k)                       # [B,G,N]
    onehot = jax.nn.one_hot(flat_i, e, dtype=jnp.int32)
    ranks_all = jnp.cumsum(onehot, axis=2) - onehot             # local cumsum
    rank = jnp.take_along_axis(ranks_all, flat_i[..., None], axis=-1)[..., 0]
    keep = rank < cap
    rank_c = jnp.minimum(rank, cap - 1)

    updates = jnp.repeat(xg, k, axis=2) * keep[..., None].astype(x.dtype)
    # scatter with EXPLICIT batch dims (vmap over B and G): GSPMD then
    # partitions the scatter over dp x model instead of replicating — a
    # 4-index-array scatter hides the batch structure from the partitioner
    scat = jax.vmap(jax.vmap(lambda b, i, r, u: b.at[i, r].add(u)))
    buf = jnp.zeros((bsz, g, e, cap, d), x.dtype)
    buf = shard_act(buf, grp, rules)
    buf = scat(buf, flat_i, rank_c, updates)                    # fully local
    buf = shard_act(buf, grp, rules)
    # THE all-to-all: G("model") -> E("model")
    buf = shard_act(buf, P(dp, None, ep, None, None), rules)

    h = jnp.einsum("bgecd,edf->bgecf", buf, p[f"{prefix}/w_in"])
    if cfg.mlp_variant == "swiglu":
        gg = jnp.einsum("bgecd,edf->bgecf", buf, p[f"{prefix}/w_gate"])
        h = jax.nn.silu(gg) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("bgecf,efd->bgecd", h, p[f"{prefix}/w_out"])
    # all-to-all back: E("model") -> G("model")
    y = shard_act(y, grp, rules)

    gath = jax.vmap(jax.vmap(lambda yy, i, r: yy[i, r]))
    out = gath(y, flat_i, rank_c)                               # [B,G,N,D]
    out = out * (topw.reshape(bsz, g, sg * k) * keep.astype(x.dtype))[..., None]
    return out.reshape(bsz, g, sg, k, d).sum(axis=3).reshape(bsz, s, d)


class MoEModel(DenseModel):
    """Dense attention + MoE FFN (+ optional parallel dense-residual MLP)."""

    def _build_block(self):
        cfg, rules = self.cfg, self.rules

        def build(key):
            b = ParamBuilder(key, cfg.pdtype)
            init_norm(b, "ln1", cfg.d_model)
            init_attn(b, cfg, rules)
            init_norm(b, "ln2", cfg.d_model)
            init_moe_ffn(b, cfg, rules)
            if cfg.dense_residual:
                init_mlp(b, cfg, rules, prefix="dense_mlp")
            return b.params, b.specs

        return build

    def _apply_block(self, p, x, *, positions, cache=None, q_chunk=None):
        cfg = self.cfg
        h, new_cache = apply_attn(p, cfg, rmsnorm(x, p["ln1"], cfg.eps),
                                  positions=positions, cache=cache,
                                  q_chunk=q_chunk)
        x = shard_act(x + h, self.act_spec, self.rules)
        hn = rmsnorm(x, p["ln2"], cfg.eps)
        y = moe_ffn(p, cfg, hn, self.rules)
        if cfg.dense_residual:
            y = y + mlp(p, cfg, hn, prefix="dense_mlp")
        return shard_act(x + y, self.act_spec, self.rules), new_cache

    # override the scan bodies to use the MoE block
    def _scan_blocks(self, params, x, positions, q_chunk, window=None):
        from .common import flat_get
        blocks = flat_get(params, self.block_key)

        def body(h, layer_p):
            h, _ = self._apply_block(layer_p, h, positions=positions,
                                     q_chunk=q_chunk)
            return h, None

        body = jax.checkpoint(body, policy=remat_policy())
        x, _ = jax.lax.scan(body, x, blocks)
        return x

    def _blocks_with_cache(self, params, x, cache, q_chunk=None):
        from .common import flat_get
        blocks = flat_get(params, self.block_key)
        positions = cache["pos"] + jnp.arange(x.shape[1])

        def body(h, xs):
            layer_p, k_l, v_l = xs
            lcache = {"k": k_l, "v": v_l, "pos": cache["pos"]}
            h, new_c = self._apply_block(layer_p, h, positions=positions,
                                         cache=lcache, q_chunk=q_chunk)
            return h, (new_c["k"], new_c["v"])

        x, (ks, vs) = jax.lax.scan(body, x, (blocks, cache["k"], cache["v"]))
        return x, {"k": ks, "v": vs, "pos": cache["pos"] + x.shape[1]}

    def probe_block(self):
        cfg = self.cfg

        def fn(layer_p, x):
            positions = jnp.arange(x.shape[1])
            y, _ = self._apply_block(layer_p, x, positions=positions)
            return y

        return fn, cfg.n_layers

    def probe_block_decode(self):
        cfg = self.cfg

        def fn(layer_p, x, k, v, pos):
            positions = pos + jnp.arange(x.shape[1])
            y, c = self._apply_block(layer_p, x, positions=positions,
                                     cache={"k": k, "v": v, "pos": pos})
            return y, c["k"], c["v"]

        return fn, cfg.n_layers
