"""Training step: microbatched gradient accumulation, remat, and the
distributed-optimization extras (gradient compression for the cross-pod
all-reduce).

Structure (per global step):
    scan over microbatches:
        forward (remat-per-layer inside the model) + backward
        accumulate grads in float32
    [optional] int8-compressed cross-pod all-reduce of the accumulated
        grads (multi-pod mesh only — the pod axis is the slow DCN link,
        exactly the gamma-dominated regime of the paper's latency model)
    AdamW update

Within-pod DP/FSDP/TP gradient reductions are inserted by XLA SPMD from
the shardings; the pod axis is kept *out* of the batch specs when
compression is on, and reduced explicitly in int8 via shard_map — halving
(vs f32: quartering) the slowest collective's bytes.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import AdamW

__all__ = ["make_train_step", "compressed_psum", "make_eval_step"]


def compressed_psum(tree, axis: str, bits: int = 8):
    """All-reduce ``tree`` over ``axis`` in int8 (inside shard_map).

    Per-leaf symmetric quantisation: s = pmax(|g|)/127; q = round(g/s);
    accumulate int32 (exact for <= 2^23 pods); dequantise with the shared
    scale. Error is bounded by s/2 per element per pod.
    """
    assert bits == 8, "int8 is the supported compressed format"

    def one(g):
        g32 = g.astype(jnp.float32)
        s = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis) / 127.0
        s = jnp.maximum(s, 1e-20)
        q = jnp.clip(jnp.round(g32 / s), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        return (total.astype(jnp.float32) * s).astype(g.dtype)

    return jax.tree.map(one, tree)


def _split_microbatches(batch, n):
    def split(x):
        assert x.shape[0] % n == 0, (x.shape, n)
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(model, opt: AdamW, *, microbatches: int = 1,
                    loss_kwargs: dict | None = None,
                    grad_compress_axis: str | None = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). jit/shard the result at the call site (launch/train.py or
    launch/dryrun.py)."""
    loss_kwargs = loss_kwargs or {}

    def loss_fn(params, mb):
        return model.loss(params, mb, **loss_kwargs)

    def grads_of(params, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        mbs = _split_microbatches(batch, microbatches)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            loss_acc, gacc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                gacc, grads)
            return (loss_acc + loss, gacc), None

        (loss_sum, gsum), _ = jax.lax.scan(body, (jnp.float32(0.0), zero), mbs)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        new_params, new_state, om = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return new_params, new_state, metrics

    if grad_compress_axis is None:
        return train_step

    # Multi-pod variant: per-pod grads computed with the pod axis manual
    # (each pod sees its own batch shard), then reduced in int8 over the
    # slow inter-pod links before the (replicated) optimizer update.
    def train_step_compressed(params, opt_state, batch, *, mesh):
        axis = grad_compress_axis

        def per_pod(params, opt_state, batch):
            loss, grads = grads_of(params, batch)
            grads = compressed_psum(grads, axis)
            npods = jax.lax.psum(1, axis)
            grads = jax.tree.map(lambda g: g / npods, grads)
            loss = jax.lax.pmean(loss, axis)
            new_params, new_state, om = opt.update(grads, opt_state, params)
            return new_params, new_state, {"loss": loss, **om}

        from repro import compat

        return compat.shard_map(
            per_pod, mesh=mesh,
            in_specs=(P(), P(), P(axis)),
            out_specs=(P(), P(), P()),
            axis_names={axis},
        )(params, opt_state, batch)

    return train_step_compressed


def make_eval_step(model, loss_kwargs: dict | None = None):
    loss_kwargs = loss_kwargs or {}

    def eval_step(params, batch):
        return model.loss(params, batch, **loss_kwargs)

    return eval_step
