"""jit'd public wrappers around the Pallas Monte Carlo kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.pricing.contracts import PricingTask
from .mc_paths import mc_moments_kernel_call

__all__ = ["mc_moments"]


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def mc_moments(task: PricingTask, n_paths: int, seed: int = 0,
               block_paths: int = 4096, interpret: bool = True):
    """(sum payoff, sum payoff^2) over ``n_paths`` paths via the TPU kernel.

    The per-block partials are reduced on-device; combined with
    ``repro.pricing.mc._finalize`` this yields price + 95% CI.
    """
    partial = mc_moments_kernel_call(task, n_paths, seed,
                                     block_paths=block_paths,
                                     interpret=interpret)
    return partial[:, 0].sum(), partial[:, 1].sum()
