"""jit'd public wrappers around the Pallas Monte Carlo kernels."""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.pricing.contracts import PricingTask, TaskBatch
from repro.pricing.mc import record_trace
from .mc_paths import (
    DEFAULT_BLOCK_PATHS,
    mc_moments_batch_kernel_call,
)

__all__ = ["mc_moments", "mc_moments_batch", "default_interpret"]


@functools.cache
def _no_tpu_present() -> bool:
    try:
        return not any(d.platform == "tpu" for d in jax.devices())
    except RuntimeError:  # no backends initialised at all
        return True


def default_interpret() -> bool:
    """Interpret the Pallas kernels only when no TPU is present.

    Override with ``REPRO_PALLAS_INTERPRET=1`` (force the interpreter, e.g.
    for debugging on TPU hosts) or ``=0`` (force compiled mode).  The env
    var is re-read on every call so it can be toggled at runtime; only the
    device probe is cached.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.lower() not in ("0", "false", "no")
    return _no_tpu_present()


@functools.partial(jax.jit,
                   static_argnames=("n_paths_max", "block_paths", "interpret"))
def _mc_moments_batch_jit(batch: TaskBatch, n_active, seed, *,
                          n_paths_max: int, block_paths: int, interpret: bool):
    record_trace("pallas_batch")
    partial = mc_moments_batch_kernel_call(
        batch, n_active, seed, n_paths_max=n_paths_max,
        block_paths=block_paths, interpret=interpret)
    return partial[:, :, 0].sum(axis=1), partial[:, :, 1].sum(axis=1)


def mc_moments_batch(batch: TaskBatch, n_active, seed: int = 0,
                     block_paths: int | None = None,
                     interpret: bool | None = None):
    """Per-task (sum payoff, sum payoff^2) for a task family, one launch.

    ``n_active`` is a per-task path-count sequence; it is padded up to a
    whole number of path blocks (masked inside the kernel), so the compiled
    executable depends only on (family, padded shape, block_paths) — the
    whole benchmarking ladder of a characterisation run reuses it.
    """
    if block_paths is None:
        block_paths = DEFAULT_BLOCK_PATHS
    if interpret is None:
        interpret = default_interpret()
    n_act = np.asarray(n_active, dtype=np.uint32).reshape(-1)
    n_max = int(n_act.max())
    n_pad = max(-(-n_max // block_paths), 1) * block_paths
    return _mc_moments_batch_jit(
        batch, jnp.asarray(n_act), jnp.asarray([seed], jnp.uint32),
        n_paths_max=n_pad, block_paths=block_paths, interpret=interpret)


def mc_moments(task: PricingTask, n_paths: int, seed: int = 0,
               block_paths: int | None = None, interpret: bool | None = None):
    """(sum payoff, sum payoff^2) over ``n_paths`` paths via the TPU kernel.

    A thin wrapper over a batch of one: task parameters are runtime
    operands, so pricing N tasks of one family compiles once, not N times.
    Combined with ``repro.pricing.mc._finalize`` this yields price + 95% CI.
    """
    batch = TaskBatch.from_tasks([task])
    sums, sqs = mc_moments_batch(batch, [n_paths], seed,
                                 block_paths=block_paths, interpret=interpret)
    return sums[0], sqs[0]
