"""Counter-based RNG shared by the Pallas kernels and the jnp oracles.

Threefry-2x32 (20 rounds) — the same generator JAX uses internally —
implemented with only uint32 add/xor/rotate so the identical code runs

  * inside a Pallas TPU kernel body (VPU integer ops), and
  * in the pure-jnp reference oracle,

which makes kernel-vs-oracle comparisons exact up to float summation
order. Counter-based generation is the right shape for Monte Carlo on a
systolic/SIMD machine: the stream for (path p, step s) is a pure function
of (seed, p, s), so any tiling of paths across blocks/devices draws the
*same* numbers — reproducibility is independent of the parallel
decomposition (this is also what makes the domain task divisible, the
property the paper's allocation relaxation (eq. 5) relies on).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["threefry2x32", "uniforms", "normal_pair"]

_ROT = (13, 15, 26, 6, 17, 29, 16, 24)
# NOTE: kept as a Python int (not a module-level jnp array) so that Pallas
# kernels using this module do not close over a device constant.
_PARITY = 0x1BD11BDA


def _rotl(x, r):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0, k1, x0, x1):
    """20-round Threefry-2x32: (key0, key1, ctr0, ctr1) -> (out0, out1).

    All arguments are uint32 arrays (broadcastable); returns two uint32
    arrays of the broadcast shape.
    """
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    x0 = jnp.asarray(x0, jnp.uint32) + k0
    x1 = jnp.asarray(x1, jnp.uint32) + k1
    k2 = k0 ^ k1 ^ jnp.uint32(_PARITY)
    ks = (k0, k1, k2)
    for block in range(5):  # 5 x 4 = 20 rounds
        rots = _ROT[:4] if block % 2 == 0 else _ROT[4:]
        for r in rots:
            x0 = x0 + x1
            x1 = _rotl(x1, r) ^ x0
        # key injection after each 4-round block
        x0 = x0 + ks[(block + 1) % 3]
        x1 = x1 + ks[(block + 2) % 3] + jnp.uint32(block + 1)
    return x0, x1


def uniforms(k0, k1, x0, x1):
    """Two U(0,1) floats per counter, strictly inside the open interval.

    The top 24 bits are used so the uint->float conversion is exact in
    float32 (values >= 2**24 would round and could push u to exactly 1.0,
    which poisons log(u) in Box-Muller).
    """
    a, b = threefry2x32(k0, k1, x0, x1)
    scale = jnp.float32(2.0**-24)
    u0 = ((a >> jnp.uint32(8)).astype(jnp.float32) + jnp.float32(0.5)) * scale
    u1 = ((b >> jnp.uint32(8)).astype(jnp.float32) + jnp.float32(0.5)) * scale
    return u0, u1


def normal_pair(k0, k1, x0, x1):
    """Two independent N(0,1) floats per counter via Box-Muller."""
    u0, u1 = uniforms(k0, k1, x0, x1)
    r = jnp.sqrt(jnp.float32(-2.0) * jnp.log(u0))
    theta = jnp.float32(2.0 * 3.14159265358979) * u1
    return r * jnp.cos(theta), r * jnp.sin(theta)
