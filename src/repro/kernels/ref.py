"""Pure-jnp oracle for the Monte Carlo kernels.

The oracle *is* the production jnp engine (repro.pricing.mc): both draw
the identical Threefry stream per (task, path, step), so kernel-vs-oracle
agreement is exact up to float32 summation order. Tests sweep shapes,
payoff types and underlyings and assert allclose.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.pricing.contracts import PricingTask, payoff_from_stats
from repro.pricing.mc import path_stats

__all__ = ["mc_moments_ref", "mc_block_moments_ref"]


def mc_moments_ref(task: PricingTask, n_paths: int, seed: int = 0):
    """(sum payoff, sum payoff^2) — single flat reduction."""
    s_t, avg, mn, mx = path_stats(task, n_paths, seed)
    pay = payoff_from_stats(s_t, avg, mn, mx, task.option)
    return pay.sum(), (pay * pay).sum()


def mc_block_moments_ref(task: PricingTask, n_paths: int, seed: int,
                         block_paths: int):
    """Per-block (sum, sumsq) with the kernel's exact blocking — for
    bitwise-closer comparisons of the partial outputs."""
    blocks = n_paths // block_paths
    s_t, avg, mn, mx = path_stats(task, n_paths, seed)
    pay = payoff_from_stats(s_t, avg, mn, mx, task.option)
    pay = pay.reshape(blocks, block_paths)
    return jnp.stack([pay.sum(axis=1), (pay * pay).sum(axis=1)], axis=1)
