"""Pallas TPU kernels: Monte Carlo path simulation + payoff moments.

The per-platform compute hot-spot the paper accelerates (F3's OpenCL/Max
back-ends) re-thought for the TPU memory hierarchy:

  * Grid over *path blocks*: each program instance owns a
    (SUBLANES x LANES)-shaped tile of paths that stays resident in
    VMEM/VREGs for the entire time loop — path state never touches HBM.
  * RNG is counter-based Threefry-2x32 (repro.kernels.prng) computed
    in-register on the VPU: no RNG state to load/store, and the stream for
    (path, step) is identical no matter how paths are tiled across blocks
    or devices.
  * The only HBM traffic is the per-block output: (sum payoff, sum
    payoff^2) — 8 bytes out per ~10^5-10^6 FLOPs of path work, i.e. the
    kernel is pure-compute by construction (arithmetic intensity ~1e5).
  * Payoffs need only 4 path statistics (terminal, mean, min, max), all
    accumulated in registers, so one kernel serves every Table 1 contract.

GPU-vs-TPU adaptation note: F3's GPU back-end is thread-per-path with a
block-level tree reduction in shared memory. On TPU the natural unit is
the (8, 128) VREG tile; the reduction is a free vector reduce at the end
of the block. There is no warp-shuffle analogue to port — the VPU's dense
2-D tiles make the GPU trick unnecessary.

Block-shape trade-off (VMEM): state per path is 6 f32 scalars for Heston
(S, v, acc, mn, mx + normals) -> a (32, 128) tile costs ~100 KiB of
VREG/VMEM working set, far under the ~16 MiB/core budget; larger tiles
amortise grid overhead until register pressure spills. ops.py exposes
``block_paths`` so the sweep in tests/benchmarks can pick the knee.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.prng import normal_pair
from repro.pricing.contracts import (
    BlackScholes,
    Heston,
    PricingTask,
    payoff_from_stats,
)

__all__ = ["mc_moments_kernel_call", "SUBLANES", "LANES"]

SUBLANES = 8
LANES = 128


def _mc_kernel(o_ref, *, task: PricingTask, seed: int, block_paths: int,
               n_steps: int):
    """One grid step: simulate ``block_paths`` paths, write (sum, sumsq).

    The path tile is shaped (block_paths // LANES, LANES) — a stack of VREG
    rows. All state lives in the fori_loop carry (registers/VMEM).
    """
    u = task.underlying
    dt = task.maturity / n_steps
    rows = block_paths // LANES
    block = pl.program_id(0)

    # global path ids for this block: (rows, LANES) uint32
    base = block * block_paths
    pid = (base
           + jax.lax.broadcasted_iota(jnp.uint32, (rows, LANES), 0) * LANES
           + jax.lax.broadcasted_iota(jnp.uint32, (rows, LANES), 1))
    k0 = jnp.uint32(seed)
    k1 = jnp.uint32(task.task_id)

    spot = jnp.full((rows, LANES), jnp.float32(u.spot))

    if isinstance(u, BlackScholes):
        drift = jnp.float32((u.rate - 0.5 * u.volatility**2) * dt)
        vol = jnp.float32(u.volatility * np.sqrt(dt))

        def step(s_idx, state):
            s, acc, mn, mx = state
            z, _ = normal_pair(k0, k1, pid, jnp.full_like(pid, s_idx))
            s = s * jnp.exp(drift + vol * z)
            return s, acc + s, jnp.minimum(mn, s), jnp.maximum(mx, s)

        init: Any = (spot, jnp.zeros_like(spot), spot, spot)
        s_t, acc, mn, mx = jax.lax.fori_loop(0, n_steps, step, init)
    else:
        dt32 = jnp.float32(dt)
        kappa, theta, xi = (jnp.float32(u.kappa), jnp.float32(u.theta),
                            jnp.float32(u.xi))
        rate, rho = jnp.float32(u.rate), jnp.float32(u.rho)
        rho_c = jnp.float32(np.sqrt(1.0 - u.rho**2))
        sqrt_dt = jnp.float32(np.sqrt(dt))

        def step(s_idx, state):
            s, v, acc, mn, mx = state
            z_s, z2 = normal_pair(k0, k1, pid, jnp.full_like(pid, s_idx))
            z_v = rho * z_s + rho_c * z2
            v_plus = jnp.maximum(v, jnp.float32(0.0))
            sqrt_v = jnp.sqrt(v_plus)
            s = s * jnp.exp((rate - 0.5 * v_plus) * dt32 + sqrt_v * sqrt_dt * z_s)
            v = v + kappa * (theta - v_plus) * dt32 + xi * sqrt_v * sqrt_dt * z_v
            return s, v, acc + s, jnp.minimum(mn, s), jnp.maximum(mx, s)

        init = (spot, jnp.full((rows, LANES), jnp.float32(u.v0)),
                jnp.zeros_like(spot), spot, spot)
        s_t, _, acc, mn, mx = jax.lax.fori_loop(0, n_steps, step, init)

    avg = acc / jnp.float32(n_steps)
    pay = payoff_from_stats(s_t, avg, mn, mx, task.option)
    o_ref[0, 0] = jnp.sum(pay)
    o_ref[0, 1] = jnp.sum(pay * pay)


def mc_moments_kernel_call(task: PricingTask, n_paths: int, seed: int,
                           block_paths: int = 4096, interpret: bool = True):
    """pallas_call wrapper: returns per-block (sum, sumsq) of shape (blocks, 2).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container has no TPU); on real hardware pass ``interpret=False``.
    """
    if block_paths % LANES:
        raise ValueError(f"block_paths must be a multiple of {LANES}")
    if n_paths % block_paths:
        raise ValueError("n_paths must be a multiple of block_paths")
    blocks = n_paths // block_paths

    kernel = functools.partial(
        _mc_kernel, task=task, seed=seed, block_paths=block_paths,
        n_steps=task.n_steps,
    )
    return pl.pallas_call(
        kernel,
        grid=(blocks,),
        out_specs=pl.BlockSpec((1, 2), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks, 2), jnp.float32),
        interpret=interpret,
    )()
