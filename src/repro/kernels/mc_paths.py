"""Pallas TPU kernels: Monte Carlo path simulation + payoff moments.

The per-platform compute hot-spot the paper accelerates (F3's OpenCL/Max
back-ends) re-thought for the TPU memory hierarchy:

  * Grid over *path blocks*: each program instance owns a
    (SUBLANES x LANES)-shaped tile of paths that stays resident in
    VMEM/VREGs for the entire time loop — path state never touches HBM.
  * RNG is counter-based Threefry-2x32 (repro.kernels.prng) computed
    in-register on the VPU: no RNG state to load/store, and the stream for
    (path, step) is identical no matter how paths are tiled across blocks
    or devices.
  * The only HBM traffic is the per-block output: (sum payoff, sum
    payoff^2) — 8 bytes out per ~10^5-10^6 FLOPs of path work, i.e. the
    kernel is pure-compute by construction (arithmetic intensity ~1e5).
  * Payoffs need only 4 path statistics (terminal, mean, min, max), all
    accumulated in registers, so one kernel serves every Table 1 contract.

GPU-vs-TPU adaptation note: F3's GPU back-end is thread-per-path with a
block-level tree reduction in shared memory. On TPU the natural unit is
the (8, 128) VREG tile; the reduction is a free vector reduce at the end
of the block. There is no warp-shuffle analogue to port — the VPU's dense
2-D tiles make the GPU trick unnecessary.

Block-shape trade-off (VMEM): state per path is 6 f32 scalars for Heston
(S, v, acc, mn, mx + normals) -> a (32, 128) tile costs ~100 KiB of
VREG/VMEM working set, far under the ~16 MiB/core budget; larger tiles
amortise grid overhead until register pressure spills. ops.py exposes
``block_paths`` so the sweep in tests/benchmarks can pick the knee.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.prng import normal_pair
from repro.pricing.contracts import (
    COL,
    BlackScholes,
    Heston,
    PricingTask,
    TaskBatch,
    bs_step_fn,
    heston_step_fn,
    payoff_from_stats,
    payoff_from_stats_coded,
)

__all__ = [
    "mc_moments_kernel_call", "mc_moments_batch_kernel_call",
    "validate_blocking", "SUBLANES", "LANES", "DEFAULT_BLOCK_PATHS",
]

SUBLANES = 8
LANES = 128

#: The one path-tile default shared by every engine entry point
#: (``mc.price``/``price_batch``, ``ops.mc_moments``, the kernel calls).
#:
#: VMEM trade-off: state per path is <= 6 f32 scalars (Heston: S, v, acc,
#: mn, mx + a normal pair), so a 1024-path block is an (8, 128) VREG tile
#: stack costing ~24 KiB of working set — far under the ~16 MiB/core VMEM
#: budget, while already amortising grid overhead; larger tiles buy little
#: until they start spilling registers, and smaller ones multiply dispatch
#: overhead.  Tests/benchmarks sweep ``block_paths`` explicitly to probe
#: the knee; production callers take this default.
DEFAULT_BLOCK_PATHS = 1024


def validate_blocking(n_paths: int, block_paths: int) -> int:
    """The single divisibility check for path tiling; returns #blocks."""
    if block_paths % LANES:
        raise ValueError(f"block_paths={block_paths} must be a multiple of {LANES}")
    if n_paths % block_paths:
        raise ValueError(
            f"n_paths={n_paths} must be a multiple of block_paths={block_paths}")
    return n_paths // block_paths


def _mc_kernel(o_ref, *, task: PricingTask, seed: int, block_paths: int,
               n_steps: int):
    """One grid step: simulate ``block_paths`` paths, write (sum, sumsq).

    The path tile is shaped (block_paths // LANES, LANES) — a stack of VREG
    rows. All state lives in the fori_loop carry (registers/VMEM).
    """
    u = task.underlying
    dt = task.maturity / n_steps
    rows = block_paths // LANES
    block = pl.program_id(0)

    # global path ids for this block: (rows, LANES) uint32
    base = block * block_paths
    pid = (base
           + jax.lax.broadcasted_iota(jnp.uint32, (rows, LANES), 0) * LANES
           + jax.lax.broadcasted_iota(jnp.uint32, (rows, LANES), 1))
    k0 = jnp.uint32(seed)
    k1 = jnp.uint32(task.task_id)

    spot = jnp.full((rows, LANES), jnp.float32(u.spot))

    if isinstance(u, BlackScholes):
        f = bs_step_fn(jnp.float32(u.rate), jnp.float32(u.volatility),
                       jnp.float32(dt))

        def step(s_idx, state):
            s, acc, mn, mx = state
            z = normal_pair(k0, k1, pid, jnp.full_like(pid, s_idx))
            s = f(s, z)
            return s, acc + s, jnp.minimum(mn, s), jnp.maximum(mx, s)

        init: Any = (spot, jnp.zeros_like(spot), spot, spot)
        s_t, acc, mn, mx = jax.lax.fori_loop(0, n_steps, step, init)
    else:
        f = heston_step_fn(jnp.float32(u.rate), jnp.float32(u.kappa),
                           jnp.float32(u.theta), jnp.float32(u.xi),
                           jnp.float32(u.rho), jnp.float32(dt))

        def step(s_idx, state):
            s, v, acc, mn, mx = state
            z = normal_pair(k0, k1, pid, jnp.full_like(pid, s_idx))
            s, v = f((s, v), z)
            return s, v, acc + s, jnp.minimum(mn, s), jnp.maximum(mx, s)

        init = (spot, jnp.full((rows, LANES), jnp.float32(u.v0)),
                jnp.zeros_like(spot), spot, spot)
        s_t, _, acc, mn, mx = jax.lax.fori_loop(0, n_steps, step, init)

    avg = acc / jnp.float32(n_steps)
    pay = payoff_from_stats(s_t, avg, mn, mx, task.option)
    o_ref[0, 0] = jnp.sum(pay)
    o_ref[0, 1] = jnp.sum(pay * pay)


def mc_moments_kernel_call(task: PricingTask, n_paths: int, seed: int,
                           block_paths: int = DEFAULT_BLOCK_PATHS,
                           interpret: bool = True):
    """pallas_call wrapper: returns per-block (sum, sumsq) of shape (blocks, 2).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container has no TPU); on real hardware pass ``interpret=False``.

    This is the legacy single-task kernel (task baked in as a static trace
    constant — one compile per task).  Production paths go through
    :func:`mc_moments_batch_kernel_call`, which takes task parameters as
    runtime SMEM operands and compiles once per task family.
    """
    blocks = validate_blocking(n_paths, block_paths)

    kernel = functools.partial(
        _mc_kernel, task=task, seed=seed, block_paths=block_paths,
        n_steps=task.n_steps,
    )
    return pl.pallas_call(
        kernel,
        grid=(blocks,),
        out_specs=pl.BlockSpec((1, 2), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks, 2), jnp.float32),
        interpret=interpret,
    )()


# --------------------------------------------------------------------------
# Batched runtime-parameter kernel: one compile per task family
# --------------------------------------------------------------------------

def _mc_batch_kernel(params_ref, tid_ref, kind_ref, nact_ref, seed_ref, o_ref,
                     *, model_kind: str, block_paths: int, n_steps: int):
    """One (task, path-block) grid step of the family-batched kernel.

    Per-task scalars (spot, rate, dt, vol/Heston params, strike, barriers,
    payout, call sign) arrive through an SMEM params ref whose BlockSpec is
    indexed by ``pl.program_id(0)`` — they are *runtime operands*, so the
    compiled kernel is shared by every task of the family.  The path tile
    design is unchanged from the single-task kernel: a
    (block_paths // LANES, LANES) stack of VREG rows resident for the whole
    time loop, with only (sum, sumsq) leaving for HBM.

    Paths with global id >= n_active (batch padding for ragged per-task
    path counts) are simulated but masked out of the payoff sums, so each
    task's moments are exactly those of its first n_active counter-based
    draws — bit-identical in distribution to the per-task run.
    """
    rows = block_paths // LANES
    block = pl.program_id(1)

    base = block * block_paths
    pid = (base
           + jax.lax.broadcasted_iota(jnp.uint32, (rows, LANES), 0) * LANES
           + jax.lax.broadcasted_iota(jnp.uint32, (rows, LANES), 1))
    k0 = seed_ref[0]
    k1 = tid_ref[0]

    spot = jnp.full((rows, LANES), params_ref[0, COL["spot"]])
    rate = params_ref[0, COL["rate"]]
    dt = params_ref[0, COL["dt"]]

    if model_kind == "black-scholes":
        f = bs_step_fn(rate, params_ref[0, COL["vol"]], dt)

        def step(s_idx, state):
            s, acc, mn, mx = state
            z = normal_pair(k0, k1, pid, jnp.full_like(pid, s_idx))
            s = f(s, z)
            return s, acc + s, jnp.minimum(mn, s), jnp.maximum(mx, s)

        init: Any = (spot, jnp.zeros_like(spot), spot, spot)
        s_t, acc, mn, mx = jax.lax.fori_loop(0, n_steps, step, init)
    else:
        f = heston_step_fn(rate, params_ref[0, COL["kappa"]],
                           params_ref[0, COL["theta"]],
                           params_ref[0, COL["xi"]],
                           params_ref[0, COL["rho"]], dt)

        def step(s_idx, state):
            s, v, acc, mn, mx = state
            z = normal_pair(k0, k1, pid, jnp.full_like(pid, s_idx))
            s, v = f((s, v), z)
            return s, v, acc + s, jnp.minimum(mn, s), jnp.maximum(mx, s)

        init = (spot, jnp.full((rows, LANES), params_ref[0, COL["v0"]]),
                jnp.zeros_like(spot), spot, spot)
        s_t, _, acc, mn, mx = jax.lax.fori_loop(0, n_steps, step, init)

    avg = acc / jnp.float32(n_steps)
    pay = payoff_from_stats_coded(
        s_t, avg, mn, mx,
        strike=params_ref[0, COL["strike"]], lower=params_ref[0, COL["lower"]],
        upper=params_ref[0, COL["upper"]], payout=params_ref[0, COL["payout"]],
        call_sign=params_ref[0, COL["call_sign"]], kind=kind_ref[0])
    pay = jnp.where(pid < nact_ref[0], pay, jnp.float32(0.0))
    o_ref[0, 0, 0] = jnp.sum(pay)
    o_ref[0, 0, 1] = jnp.sum(pay * pay)


def mc_moments_batch_kernel_call(batch: TaskBatch, n_active, seed,
                                 n_paths_max: int,
                                 block_paths: int = DEFAULT_BLOCK_PATHS,
                                 interpret: bool = True):
    """Family-batched pallas_call over a 2-D grid (task, path_block).

    ``n_active`` is a (T,) uint32 array of per-task path counts;
    ``n_paths_max`` (a multiple of ``block_paths``) sets the padded grid.
    ``seed`` is a (1,) uint32 array — a runtime operand, so re-seeding the
    benchmark ladder never retraces.  Returns (T, blocks, 2) partial
    (sum, sumsq) per (task, block).
    """
    blocks = validate_blocking(n_paths_max, block_paths)
    T = batch.n_tasks

    kernel = functools.partial(
        _mc_batch_kernel, model_kind=batch.model_kind,
        block_paths=block_paths, n_steps=batch.n_steps,
    )
    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)
    return pl.pallas_call(
        kernel,
        grid=(T, blocks),
        in_specs=[
            smem((1, len(COL)), lambda t, b: (t, 0)),  # params row
            smem((1,), lambda t, b: (t,)),             # task_id
            smem((1,), lambda t, b: (t,)),             # payoff kind
            smem((1,), lambda t, b: (t,)),             # n_active
            smem((1,), lambda t, b: (0,)),             # seed
        ],
        out_specs=pl.BlockSpec((1, 1, 2), lambda t, b: (t, b, 0)),
        out_shape=jax.ShapeDtypeStruct((T, blocks, 2), jnp.float32),
        interpret=interpret,
    )(batch.params, batch.task_ids, batch.payoff_kinds,
      jnp.asarray(n_active, jnp.uint32), jnp.asarray(seed, jnp.uint32))
