"""repro.domains — Domain implementations for the shared runtime.

Each module is one self-contained front-end plugging a workload into
:class:`repro.runtime.Scheduler`:

    pricing     — derivatives pricing (paper §4): MC paths vs CI accuracy
    lm_serving  — LM token serving: decode tokens vs generation length

Import the domain class directly, or go through the registry:

    from repro.runtime import make_domain
    domain = make_domain("pricing", tasks, platforms)
"""
