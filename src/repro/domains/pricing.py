"""Derivatives pricing as a runtime :class:`Domain` (paper §4).

The original front-end of the paper, re-expressed against the shared
runtime: Monte Carlo paths are the work unit, the 95% CI is the quality
metric, and the quality->work reduction is the inverse-square law of
eq. 9 (W = delta / c^2). All heavy lifting — the batched MC engine,
Table 2 platforms, online benchmarking ladders, model fitting — stays in
:mod:`repro.pricing`; this module is the thin adapter the ISSUE's "every
future domain is a one-file plug-in" refers to.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.allocation import mc_work_reduction
from repro.pricing.contracts import PricingTask, launch_key
from repro.pricing import platforms as _platforms
from repro.pricing.platforms import (
    RunRecord,
    TaskPlatformModel,
    benchmark_adaptive_batch,
    benchmark_batch,
    dispatch_batch,
    fit_models,
)
from repro.runtime.domain import Domain

__all__ = ["PricingDomain"]


class PricingDomain(Domain):
    """Monte Carlo option pricing: paths for CI accuracy."""

    name = "pricing"
    reduction = staticmethod(mc_work_reduction)
    #: smallest shard worth a launch — matches the historical solver floor.
    min_chunk = 64

    # -- identity ----------------------------------------------------------

    def launch_key(self, task: PricingTask):
        return launch_key(task)  # (model kind, n_steps): the compile unit

    # -- characterisation ---------------------------------------------------

    def characterise_batch(self, platform, tasks: Sequence[PricingTask],
                           seed: int = 1, path_ladder=None) -> list[list[RunRecord]]:
        if path_ladder is not None:
            return benchmark_batch(platform, tasks, path_ladder, seed)
        return benchmark_adaptive_batch(platform, tasks, seed=seed)

    def characterise(self, seed: int = 1, path_ladder=None, batched: bool = True,
                     executor=None, tasks=None, platforms=None,
                     record_sink=None,
                     skip_unavailable: bool = False,
                     ) -> dict[tuple[str, int], TaskPlatformModel]:
        if not batched:
            # legacy per-task loop, kept for A/B comparisons. It honours
            # task/platform subsets (incremental arrivals) but cannot fill
            # a record_sink — the legacy pipeline returns fitted models
            # only, so online re-fit windows start empty under
            # batched=False.
            return _platforms.characterise(
                self.platforms if platforms is None else list(platforms),
                self.tasks if tasks is None else list(tasks),
                path_ladder, seed, batched=False)
        return super().characterise(seed=seed, executor=executor, tasks=tasks,
                                    platforms=platforms,
                                    record_sink=record_sink,
                                    skip_unavailable=skip_unavailable,
                                    path_ladder=path_ladder)

    def fit_models(self, records: Sequence[RunRecord]) -> TaskPlatformModel:
        return fit_models(records)

    # -- execution ----------------------------------------------------------

    def work_units(self, model: TaskPlatformModel, quality: float) -> float:
        return model.accuracy.paths_for_accuracy(quality)  # eq. 8 inverted

    def degrade_quality(self, quality: float, step: float) -> float:
        """Loosen the CI target by ``step`` — via eq. 9's inverse-square
        law, a 25% looser CI needs ~36% fewer paths."""
        return quality * (1.0 + step)

    def record_units(self, record: RunRecord) -> int:
        return int(record.n_paths)

    def dispatch_batch(self, platform, tasks: Sequence[PricingTask],
                       units: Sequence[int], seed: int = 0) -> list[RunRecord]:
        return dispatch_batch(platform, tasks, units, seed=seed)

    def summarise(self, records: Sequence[RunRecord], problem) -> dict:
        """Pool per-shard estimates inverse-variance style.

        A task split across platforms yields several (price, ci, n) shards
        drawn from the same payoff distribution; the pooled estimate is the
        path-weighted mean and the pooled CI obeys

            ci^2 = sum_i (n_i * ci_i)^2 / (sum_i n_i)^2
        """
        num = {t.task_id: 0.0 for t in self.tasks}
        den = {t.task_id: 0.0 for t in self.tasks}
        var = {t.task_id: 0.0 for t in self.tasks}
        for rec in records:
            num[rec.task_id] += rec.n_paths * rec.price
            den[rec.task_id] += rec.n_paths
            var[rec.task_id] += (rec.n_paths * rec.ci95) ** 2
        prices = {tid: num[tid] / den[tid] for tid in num}
        measured_ci = {tid: float(np.sqrt(var[tid])) / den[tid] for tid in num}
        predicted_ci = {t.task_id: float(problem.c[j])
                        for j, t in enumerate(self.tasks)}
        return {"prices": prices, "measured_ci": measured_ci,
                "predicted_ci": predicted_ci}
