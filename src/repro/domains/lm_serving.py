"""LM token serving as a runtime :class:`Domain`.

The second metric-modelled domain (paper §3/§7: the workflow generalises
beyond pricing). A task is a batched generation request against one of the
repo's model configurations (:mod:`repro.configs` + :mod:`repro.models`);
the domain *variable* is the number of decoded tokens, and serving latency
follows exactly the paper's eq. 7:

    f_L(tokens) = beta * tokens + gamma

with beta the per-token decode cost and gamma the constant part (prefill +
dispatch for a local engine, network RTT for a remote one). The quality
metric is the *generation length*: unlike the MC domain there is no
estimator noise, so the quality->work reduction is linear (W = beta o c)
rather than inverse-square — supplied to the solvers via
:func:`repro.core.allocation.linear_work_reduction`. Requests are divisible
the same way MC tasks are: a 64-token generation can be served as chunks
on different platforms (speculative / segmented serving), which is what
lets the same MILP/annealing/heuristic solvers allocate a mixed fleet.

Two platform kinds mirror the pricing domain: ``LocalLMPlatform`` runs the
real JAX engine (:class:`repro.launch.serve.ServeEngine`) with wall-clock
latency; ``SimulatedLMPlatform`` replays a fleet spec from its two
characteristics (application GFLOPS, network RTT) using the model's
analytic FLOPs-per-token.

Both platforms serve with **continuous batching**: the requests of a
dispatch share one running decode batch — joining when their KV pages fit
the platform's memory budget, leaving the step their generation target is
reached — rather than each paying a solo decode pass. Each request's
record carries its *attributed* share of the shared steps, so per-platform
record sums remain the platform's busy time and eq. 7 fits stay linear in
the token count. The KV pages a request pins while resident
(:func:`kv_bytes_per_token` x tokens, from the model shapes in
:mod:`repro.configs`) are also what the domain reports to the allocator as
the resource/capacity dimension: ``resource[p, t] = kv_bytes_per_token``
per decoded token vs ``capacity[p] = spec.mem_bytes`` (HBM), so the
solvers see memory, not just eq. 7 latency.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import threading
import time
import zlib
from collections import deque
from typing import Sequence

import numpy as np

from repro.core.allocation import CapacityError, linear_work_reduction
from repro.core.metrics import CombinedModel, LatencyModel, fit_latency_model
from repro.runtime.domain import Domain, MeshPlatformSpec, PlatformSpec, seed_for
from repro.runtime.scenario import Scenario, apply_scenario, salvage_runs

__all__ = [
    "LMRequest", "ServeRecord", "LMServingModel",
    "LocalLMPlatform", "SimulatedLMPlatform",
    "LM_FLEET_SPECS", "LM_MESH_FLEET_SPECS", "build_lm_fleet",
    "smoke_requests", "LMServingDomain", "flops_per_token",
    "kv_bytes_per_token", "request_kv_bytes",
]


# --------------------------------------------------------------------------
# Tasks
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMRequest:
    """One batched generation request (divisible by generated tokens).

    ``gen_tokens`` is the request's quality target — the domain's default
    quality vector — and ``max_new_tokens`` bounds the KV cache so every
    request family shares one compiled (prefill, decode) executable pair.
    """

    arch: str                 # repro.configs name, e.g. "qwen25_3b"
    prompt_len: int
    gen_tokens: int           # quality target: tokens to generate
    batch: int = 1
    max_new_tokens: int = 64
    task_id: int = 0
    smoke: bool = True        # reduced same-family config (CPU-friendly)

    def __post_init__(self):
        if not 1 <= self.gen_tokens <= self.max_new_tokens:
            raise ValueError(
                f"gen_tokens={self.gen_tokens} must be in "
                f"[1, max_new_tokens={self.max_new_tokens}] — the KV cache "
                "is sized for max_new_tokens and platforms cannot serve past it")

    def config(self):
        from repro.configs import get_config

        cfg = get_config(self.arch)
        return cfg.smoke() if self.smoke else cfg

    @property
    def max_seq(self) -> int:
        return self.prompt_len + self.max_new_tokens + 8


@dataclasses.dataclass(frozen=True)
class ServeRecord:
    """One executed generation shard.

    ``queue_delay`` is time the request spent *waiting* inside its
    dispatch for KV pages to free before joining the decode batch — it
    is not part of ``latency`` (record latencies sum to platform busy
    time, and waiting is not work), but TTFT accounting adds it back.
    """

    platform: str
    task_id: int
    n_tokens: int
    latency: float            # seconds, prefill included
    prefill_latency: float = 0.0
    queue_delay: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.n_tokens / max(self.latency, 1e-12)


@dataclasses.dataclass(frozen=True)
class LMServingModel:
    """Fitted per-(platform, request) metric model: eq. 7 on tokens.

    The combined model is the latency model itself — quality *is* the
    token count, so delta = beta and the work reduction is linear."""

    latency: LatencyModel

    @property
    def combined(self) -> CombinedModel:
        return CombinedModel(delta=self.latency.beta, gamma=self.latency.gamma)


# --------------------------------------------------------------------------
# FLOPs model (for simulated platforms)
# --------------------------------------------------------------------------

def flops_per_token(cfg, batch: int = 1) -> float:
    """Decode FLOPs per generated token: the 2*N_active convention, per
    batch element (a decode step advances the whole batch together)."""
    _, active = cfg.param_count()
    return 2.0 * active * batch


# --------------------------------------------------------------------------
# KV-cache memory model (the capacity dimension)
# --------------------------------------------------------------------------

def kv_bytes_per_token(cfg, batch: int = 1) -> float:
    """Bytes of KV cache one decoded token pins, per request.

    From the model shapes: 2 (K and V) x attention layers x n_kv_heads x
    head_dim x cache dtype x the request's internal batch. Recurrent
    families hold constant-size state (no per-token growth); hybrids pay
    only their attention layers.
    """
    if not cfg.has_decoder or cfg.family == "rwkv":
        return 0.0
    layers = cfg.n_layers
    if cfg.family == "hybrid" and cfg.hybrid_pattern:
        pat = cfg.hybrid_pattern
        layers = sum(1 for i in range(cfg.n_layers) if pat[i % len(pat)] != "rec")
    itemsize = np.dtype(cfg.param_dtype).itemsize
    return float(2 * layers * cfg.n_kv_heads * cfg.hd * itemsize * batch)


@functools.lru_cache(maxsize=1024)
def _kv_per_token(arch: str, smoke: bool, batch: int) -> float:
    from repro.configs import get_config

    cfg = get_config(arch)
    return kv_bytes_per_token(cfg.smoke() if smoke else cfg, batch)


def request_kv_bytes(req: "LMRequest", n_tokens: int | None = None) -> float:
    """KV pages the request holds while resident in a decode batch:
    prompt pages plus one page per decoded token (``max_new_tokens``
    when ``n_tokens`` is not given — the reservation the engine makes)."""
    n = req.max_new_tokens if n_tokens is None else int(n_tokens)
    return _kv_per_token(req.arch, req.smoke, req.batch) * (req.prompt_len + n)


# --------------------------------------------------------------------------
# Platforms
# --------------------------------------------------------------------------

#: A small heterogeneous serving fleet, same schema as the paper's Table 2:
#: application performance (GFLOPS, smoke-model scale) + network RTT +
#: device memory (KV-cache budget, smoke-model scale so workloads of a few
#: hundred KB of pages genuinely contend). The spread is chosen so the
#: constant term matters — the regime where the MILP/annealing solvers
#: beat the proportional heuristic (§6.3).
LM_FLEET_SPECS: list[PlatformSpec] = [
    PlatformSpec("Edge Accelerator", "CPU", "embedded NPU", "on-prem",     2.0,   0.200, mem_bytes=128 * 1024),
    PlatformSpec("Rack GPU",         "GPU", "rack server",  "on-prem",    50.0,   4.000, mem_bytes=512 * 1024),
    PlatformSpec("Cloud GPU",        "GPU", "cloud vm",     "us-east",   200.0,  60.000, mem_bytes=2 * 1024 ** 2),
    PlatformSpec("Cloud Pod",        "GPU", "accelerator pod", "us-west", 800.0, 120.000, mem_bytes=8 * 1024 ** 2),
]

#: The mesh-shaped fleet: the *same* device kind quoted at several
#: tensor-parallel widths, so the solvers genuinely trade one wide mesh
#: (lowest beta, pooled KV, collective-inflated gamma) against many
#: narrow ones (cheap gamma, per-device KV, request-level parallelism).
#: ``gflops``/``rtt_ms``/``mem_bytes`` stay the Rack GPU datasheet row;
#: only the shape varies.
def _rack_mesh(model: int) -> MeshPlatformSpec:
    return MeshPlatformSpec(
        f"Rack GPU 1x{model}", "GPU", "rack server", "on-prem",
        50.0, 4.000, mem_bytes=512 * 1024, mesh_shape=(1, model),
        tp_efficiency=0.85, collective_ms=2.0)


LM_MESH_FLEET_SPECS: list[MeshPlatformSpec] = [
    _rack_mesh(1), _rack_mesh(2), _rack_mesh(4), _rack_mesh(8),
]


class _LMPlatformBase:
    """Shared platform plumbing: the token clamp and batched dispatch."""

    spec: PlatformSpec

    def _clamp(self, req: LMRequest, n_tokens: int) -> int:
        # the KV cache is sized for max_new_tokens; never generate past it
        return min(max(int(n_tokens), 1), req.max_new_tokens)

    def _admission_guard(self, reqs: Sequence[LMRequest],
                         tokens: Sequence[int]) -> None:
        # KV pools across every device of a mesh platform; a single
        # device is the trivial (1, 1) mesh, so total == mem_bytes there
        cap = self.spec.total_mem_bytes
        for req, n in zip(reqs, tokens):
            if request_kv_bytes(req, n) > cap:
                raise CapacityError(
                    f"request {req.task_id}: {request_kv_bytes(req, n):.0f} "
                    f"KV bytes exceed {self.spec.name}'s {cap:.0f}-byte budget "
                    "on its own — no batch schedule can serve it")

    def run(self, req: LMRequest, n_tokens: int, seed: int = 0) -> ServeRecord:
        raise NotImplementedError

    def run_batch(self, reqs: Sequence[LMRequest], n_tokens,
                  seed: int = 0) -> list[ServeRecord]:
        # fallback for third-party platforms: solo serves back-to-back. An
        # outage striking mid-batch re-raises with the completed records
        # attached (see scenario.salvage_runs) so dispatchers keep them
        return salvage_runs(lambda rn: self.run(rn[0], rn[1], seed=seed),
                            list(zip(reqs, _as_token_list(reqs, n_tokens))))


class LocalLMPlatform(_LMPlatformBase):
    """Real platform: serves with the JAX engine, wall-clock latency.

    Engines are cached per request family ((config, batch, prompt_len,
    max_seq) — the compile unit), and warmed outside the timed region, so
    gamma measures prefill + dispatch, not compilation."""

    def __init__(self, name: str = "Local JAX LM", rtt_ms: float = 0.05,
                 tp: int = 1):
        if tp > 1:
            self.spec: PlatformSpec = MeshPlatformSpec(
                name, "CPU", "jax-cpu", "localhost",
                gflops=float("nan"), rtt_ms=rtt_ms, mesh_shape=(1, tp))
        else:
            self.spec = PlatformSpec(name, "CPU", "jax-cpu", "localhost",
                                     gflops=float("nan"), rtt_ms=rtt_ms)
        self.tp = int(tp)
        self._mesh = None
        self._engines: dict[tuple, object] = {}
        # characterisation threads for different launch groups share this
        # platform; double-checked locking keeps build+warm once per family
        self._engines_lock = threading.Lock()

    def _host_mesh(self):
        if self._mesh is None and self.tp > 1:
            from repro.launch.mesh import make_host_mesh

            self._mesh = make_host_mesh(data=1, model=self.tp)
        return self._mesh

    def _engine(self, req: LMRequest):
        key = (req.arch, req.smoke, req.batch, req.prompt_len, req.max_seq)
        eng = self._engines.get(key)
        if eng is None:
            with self._engines_lock:
                eng = self._engines.get(key)
                if eng is None:
                    from repro.launch.serve import ServeEngine

                    eng = ServeEngine(req.config(), batch=req.batch,
                                      prompt_len=req.prompt_len,
                                      max_seq=req.max_seq,
                                      mesh=self._host_mesh())
                    eng.warm()
                    self._engines[key] = eng
        return eng

    def run(self, req: LMRequest, n_tokens: int, seed: int = 0) -> ServeRecord:
        n = self._clamp(req, n_tokens)
        result = self._engine(req).generate(n, seed=seed)
        return ServeRecord(self.spec.name, req.task_id, n,
                           result.total_latency, result.prefill_latency)

    def run_batch(self, reqs: Sequence[LMRequest], n_tokens,
                  seed: int = 0) -> list[ServeRecord]:
        """Continuous batching on the real engine.

        Same-family requests (one dispatch group shares a launch key by
        construction) ride one running decode loop
        (:meth:`repro.launch.serve.ServeEngine.generate_many`) in KV-gated
        admission waves: a wave joins when its pages fit ``mem_bytes``,
        each request leaves the step its target is reached. Mixed-family
        calls fall back to solo serves."""
        tokens = [self._clamp(r, n) for r, n in
                  zip(reqs, _as_token_list(reqs, n_tokens))]
        if len({(r.arch, r.smoke, r.batch, r.prompt_len, r.max_seq)
                for r in reqs}) > 1:
            return super().run_batch(reqs, tokens, seed=seed)
        self._admission_guard(reqs, tokens)
        engine = self._engine(reqs[0])
        out: list[ServeRecord] = []
        wave: list[int] = []
        held = 0.0
        cap = self.spec.total_mem_bytes

        def flush():
            if not wave:
                return
            results = engine.generate_many([tokens[i] for i in wave], seed=seed)
            for i, res in zip(wave, results):
                out.append(ServeRecord(self.spec.name, reqs[i].task_id,
                                       tokens[i], res.total_latency,
                                       res.prefill_latency))

        for i, (req, n) in enumerate(zip(reqs, tokens)):
            need = request_kv_bytes(req, n)
            if wave and held + need > cap:
                flush()
                wave, held = [], 0.0
            wave.append(i)
            held += need
        flush()
        return out


class SimulatedLMPlatform(_LMPlatformBase):
    """Replays a fleet spec row from (GFLOPS, RTT, HBM) — the published
    characteristics that determine beta, gamma and the KV budget (§5.1.2):

        latency(tokens) = (prefill + tokens) * flops_tok / GFLOPS
                          + RTT + lognormal jitter

    A dispatch's requests share a continuous decode batch: they join in
    submission order as their KV pages (prompt + decoded tokens) fit
    ``spec.mem_bytes``, decode in lockstep, and leave at their token
    target, freeing pages for the queue. A shared step over ``k`` residents
    costs ``(1 + batch_alpha * (k - 1))`` solo steps (decode is
    memory-bound, so batching is sub-linear) attributed equally — each
    record carries its request's share, so per-platform record sums stay
    the platform's busy time and a solo serve reproduces the formula above
    exactly.
    """

    #: marginal cost of one extra resident per decode step, as a fraction
    #: of a solo step; 0 = perfectly memory-bound, 1 = no batching win.
    batch_alpha: float = 0.6

    def __init__(self, spec: PlatformSpec, jitter: float = 0.02, seed: int = 0,
                 realtime: float = 0.0, scenario: Scenario | None = None):
        self.spec = spec
        self.jitter = jitter
        self._seed = seed
        #: sleep(latency * realtime) per run: occupy host wall clock so
        #: overlap benchmarks see true concurrency; records are unchanged.
        self.realtime = realtime
        #: optional drift scenario, consulted at the platform's virtual
        #: clock (cumulative replayed latency) — same hook as the pricing
        #: simulator's.
        self.scenario = scenario
        self.clock = 0.0

    def attach_scenario(self, scenario: Scenario | None) -> None:
        """Attach (or clear) a scenario and rewind the virtual clock."""
        self.scenario = scenario
        self.clock = 0.0

    def _continuous_plan(self, reqs: Sequence[LMRequest],
                         tokens: Sequence[int]) -> tuple[
                             list[float], list[float], list[float]]:
        """Clean (jitter-free) per-request (prefill, attributed decode,
        queue wait) seconds under KV-gated lockstep continuous batching.

        ``wait[i]`` is the in-dispatch time request ``i`` spent queued for
        KV pages before joining the decode batch — zero for everything
        admitted in the first wave, and the TTFT-visible queueing delay
        for requests gated behind a full cache.
        """
        # mesh platforms: beta falls with the (efficiency-discounted)
        # tensor-parallel width, KV pools across every device
        cap = self.spec.total_mem_bytes
        gps = self.spec.effective_gflops * 1e9
        d = [flops_per_token(r.config(), r.batch) / gps for r in reqs]
        prefill = [r.prompt_len * di for r, di in zip(reqs, d)]
        need = [request_kv_bytes(r, n) for r, n in zip(reqs, tokens)]
        decode = [0.0] * len(reqs)
        wait = [0.0] * len(reqs)
        remaining = [int(n) for n in tokens]
        queue = deque(range(len(reqs)))
        active: list[int] = []
        held = 0.0
        t_clock = 0.0  # wall time inside this dispatch's shared batch
        while queue or active:
            while queue and held + need[queue[0]] <= cap:
                i = queue.popleft()
                active.append(i)
                held += need[i]
                wait[i] = t_clock
            k = len(active)
            share = (1.0 + self.batch_alpha * (k - 1)) / k
            step = min(remaining[i] for i in active)
            for i in active:
                decode[i] += d[i] * share * step
                remaining[i] -= step
            t_clock += share * step * sum(d[i] for i in active)
            for i in [i for i in active if remaining[i] <= 0]:
                active.remove(i)
                held -= need[i]
        return prefill, decode, wait

    def run(self, req: LMRequest, n_tokens: int, seed: int = 0) -> ServeRecord:
        return self.run_batch([req], n_tokens, seed=seed)[0]

    def run_batch(self, reqs: Sequence[LMRequest], n_tokens,
                  seed: int = 0) -> list[ServeRecord]:
        tokens = [self._clamp(r, n) for r, n in
                  zip(reqs, _as_token_list(reqs, n_tokens))]
        self._admission_guard(reqs, tokens)
        prefill, decode, wait = self._continuous_plan(reqs, tokens)

        def finish(item) -> ServeRecord:
            req, n, pre_s, dec_s, wait_s = item
            # stable across processes (unlike hash(): PYTHONHASHSEED
            # randomises str hashing), so seeded runs reproduce exactly
            key = zlib.crc32(f"{self.spec.name}/{req.task_id}/{n}/{seed}".encode())
            rng = np.random.default_rng(key + self._seed)
            jitter = rng.lognormal(0.0, self.jitter)
            pre = pre_s * jitter
            qd = wait_s * jitter
            # gamma picks up the per-hop collective cost on mesh platforms
            latency = (pre_s + dec_s + self.spec.effective_rtt_ms * 1e-3) * jitter
            if self.scenario is not None:
                stretched = apply_scenario(self, latency)
                scale = stretched / max(latency, 1e-300)
                pre *= scale
                qd *= abs(scale)  # waiting stretches with the slowdown too
                latency = stretched
            if self.realtime:
                # corrupt-window runs report a negated latency; the real
                # work still took |latency| of wall clock
                time.sleep(abs(latency) * self.realtime)
            return ServeRecord(self.spec.name, req.task_id, n, latency,
                               prefill_latency=pre, queue_delay=qd)

        # an outage striking mid-batch re-raises with the completed records
        # attached (see scenario.salvage_runs) so dispatchers keep them
        return salvage_runs(finish,
                            list(zip(reqs, tokens, prefill, decode, wait)))


def _as_token_list(reqs: Sequence[LMRequest], n_tokens) -> list[int]:
    return [int(n) for n in
            np.broadcast_to(np.asarray(n_tokens, dtype=np.int64), (len(reqs),))]


def build_lm_fleet(include_local: bool = True,
                   specs: Sequence[PlatformSpec] | None = None,
                   mesh: bool = False) -> list:
    """The evaluation fleet (optionally + the real local engine).

    ``mesh=True`` swaps in :data:`LM_MESH_FLEET_SPECS` — the same device
    kind at several tensor-parallel widths — so the solvers choose between
    one wide mesh and many narrow ones."""
    if specs is None:
        specs = LM_MESH_FLEET_SPECS if mesh else LM_FLEET_SPECS
    fleet: list = [SimulatedLMPlatform(s) for s in specs]
    if include_local:
        fleet.append(LocalLMPlatform())
    return fleet


def smoke_requests(n: int = 4, arch: str = "qwen25_3b", batch: int = 2,
                   prompt_len: int = 8, seed: int = 0) -> list[LMRequest]:
    """A small single-family request workload (one compile unit)."""
    rng = np.random.default_rng(seed)
    return [LMRequest(arch=arch, prompt_len=prompt_len,
                      gen_tokens=int(rng.integers(8, 25)), batch=batch,
                      max_new_tokens=32, task_id=i)
            for i in range(n)]


# --------------------------------------------------------------------------
# The domain
# --------------------------------------------------------------------------

class LMServingDomain(Domain):
    """LM token serving: decode tokens for a generation-length target."""

    name = "lm_serving"
    reduction = staticmethod(linear_work_reduction)
    min_chunk = 1

    #: default online-benchmarking ladder (token counts per rung).
    TOKEN_LADDER: tuple[int, ...] = (2, 4, 8, 16)

    # -- identity ----------------------------------------------------------

    def launch_key(self, req: LMRequest):
        # one compiled (prefill, decode) executable pair per family
        return (req.arch, req.smoke, req.batch, req.prompt_len, req.max_seq)

    def default_quality(self) -> np.ndarray:
        return np.asarray([r.gen_tokens for r in self.tasks], dtype=np.float64)

    # -- capacity: KV-cache memory vs HBM ----------------------------------

    def resource_per_unit(self, platform, req: LMRequest) -> float:
        """Each decoded token pins one KV page on the serving platform for
        the request's residency (continuous batching holds the cache until
        the request leaves). Prompt pages are the per-dispatch analogue of
        gamma — constant, not per-unit — so the linear dimension the
        solvers see is tokens x bytes/token."""
        return _kv_per_token(req.arch, req.smoke, req.batch)

    def platform_capacity(self, platform) -> float:
        """The KV budget the allocator sees: pooled across every device of
        a mesh platform (``total_mem_bytes``; a bare spec's 1x1 mesh makes
        this its plain ``mem_bytes``)."""
        spec = platform.spec
        return float(getattr(spec, "total_mem_bytes",
                             getattr(spec, "mem_bytes", math.inf)))

    # -- characterisation ---------------------------------------------------

    def characterise_batch(self, platform, reqs: Sequence[LMRequest],
                           seed: int = 1, token_ladder=None) -> list[list[ServeRecord]]:
        # launch_key includes max_seq, so max_new_tokens is uniform within a
        # group; clamp the ladder once and dedupe — repeated rungs at the cap
        # would make the (beta, gamma) fit rank-deficient.
        cap = min(r.max_new_tokens for r in reqs)
        ladder = sorted({min(int(n), cap) for n in (token_ladder or self.TOKEN_LADDER)})
        if len(ladder) < 2 and cap > 1:  # need 2 distinct points for eq. 7
            ladder = sorted({max(1, cap // 2), cap})
        # seeds are a stable hash of (platform, launch group, rung), not the
        # loop position, so records are independent of dispatch interleaving
        pname = self.platform_name(platform)
        key = self.launch_key(reqs[0])
        return [platform.run_batch(reqs, n, seed=seed_for(seed, pname, key, i))
                for i, n in enumerate(ladder)]

    def fit_models(self, records: Sequence[ServeRecord]) -> LMServingModel:
        lat = fit_latency_model([r.n_tokens for r in records],
                                [r.latency for r in records])
        return LMServingModel(latency=lat)

    # -- execution ----------------------------------------------------------

    def work_units(self, model: LMServingModel, quality: float) -> float:
        return float(quality)  # quality is measured in work units (tokens)

    def degrade_quality(self, quality: float, step: float) -> float:
        """Shorten the generation target by ``step`` (never below one
        token): the latency win is linear in tokens dropped."""
        return max(float(np.floor(quality * (1.0 - step))), 1.0)

    def record_units(self, record: ServeRecord) -> int:
        return int(record.n_tokens)

    # -- SLO / overload control --------------------------------------------

    def record_ttft(self, record: ServeRecord, end_t: float) -> float:
        """First-token time for a serve record: the record's span starts
        at ``end_t - |latency|``; the first token lands after the
        in-dispatch queue wait plus prefill (clamped into the record's
        span so corrupt/stretched records stay well-ordered)."""
        span = abs(record.latency)
        first = record.queue_delay + abs(record.prefill_latency)
        return end_t - span + min(first, span)

    def task_quality(self, req: LMRequest) -> float:
        return float(req.gen_tokens)

    def dispatch_batch(self, platform, reqs: Sequence[LMRequest],
                       units: Sequence[int], seed: int = 0) -> list[ServeRecord]:
        return platform.run_batch(reqs, units, seed=seed)

    def summarise(self, records: Sequence[ServeRecord], problem) -> dict:
        tokens = {r.task_id: 0 for r in self.tasks}
        latency = {r.task_id: 0.0 for r in self.tasks}
        for rec in records:
            tokens[rec.task_id] += rec.n_tokens
            latency[rec.task_id] += rec.latency
        throughput = {tid: tokens[tid] / latency[tid] if latency[tid] > 0 else math.inf
                      for tid in tokens}
        requested = {t.task_id: float(problem.c[j])
                     for j, t in enumerate(self.tasks)}
        return {"tokens": tokens, "requested_tokens": requested,
                "throughput_tok_s": throughput}
