"""Version-compat shims for the jax API surface this repo relies on.

The repo targets the jax the container bakes in (0.4.x) while using the
modern spellings where available, so the same source runs on both.
"""
from __future__ import annotations

from typing import Any

import jax

__all__ = ["shard_map"]


def shard_map(f, mesh, in_specs, out_specs, axis_names: set[str] | None = None):
    """``jax.shard_map`` with replication checking off, on any jax.

    Newer jax exposes ``jax.shard_map(..., check_vma=...)``; 0.4.x only has
    ``jax.experimental.shard_map.shard_map(..., check_rep=...)`` (where all
    mesh axes are manual by default, so ``axis_names`` is implicit).  The
    check is disabled in both spellings for the same reason: our workers
    derive varying values from ``axis_index``, which the static analysis
    cannot see through.
    """
    if hasattr(jax, "shard_map"):
        kwargs: dict[str, Any] = {"check_vma": False}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
