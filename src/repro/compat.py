"""Version-compat shims for the jax API surface this repo relies on.

The repo targets the jax the container bakes in (0.4.x) while using the
modern spellings where available, so the same source runs on both.
"""
from __future__ import annotations

from typing import Any

import jax

__all__ = ["shard_map"]


def shard_map(f, mesh, in_specs, out_specs, axis_names: set[str] | None = None):
    """``jax.shard_map`` with replication checking off, on any jax.

    Newer jax exposes ``jax.shard_map(..., check_vma=...)``; 0.4.x only has
    ``jax.experimental.shard_map.shard_map(..., check_rep=...)``, where all
    mesh axes are manual by default and a *subset* ``axis_names`` must be
    spelled as the complementary ``auto`` axes. When the old API predates
    the ``auto`` parameter the request cannot be honoured — that raises
    instead of silently treating every axis as manual (which would change
    collective semantics between jax versions). The replication check is
    disabled in both spellings for the same reason: our workers derive
    varying values from ``axis_index``, which the static analysis cannot
    see through.
    """
    if axis_names is not None and not set(axis_names) <= set(mesh.axis_names):
        raise ValueError(
            f"axis_names {sorted(axis_names)} not a subset of mesh axes "
            f"{mesh.axis_names}")
    if hasattr(jax, "shard_map"):
        kwargs: dict[str, Any] = {"check_vma": False}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    import inspect

    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = {"check_rep": False}
    if axis_names is not None and set(axis_names) != set(mesh.axis_names):
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if "auto" not in inspect.signature(_shard_map).parameters:
            raise NotImplementedError(
                f"this jax's shard_map cannot leave axes {sorted(auto)} "
                f"automatic (no `auto` parameter); pass axis_names covering "
                f"every mesh axis or upgrade jax")
        kwargs["auto"] = auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
