"""F3-style solver: characterise -> allocate -> execute (paper Fig 1 flow).

This is the orchestration layer a domain user ("Julia") touches:

    solver = PricingSolver(tasks, platforms)
    solver.characterise()                       # online benchmarking, (2)
    alloc = solver.allocate(accuracy=0.05,      # trade-off selection, (3-4)
                            method="milp")
    report = solver.execute(alloc)              # evaluation, (5)

``execute`` converts the allocation shares back into per-platform path
counts through each platform's own fitted accuracy coefficient (this is
exactly what delta[i,j] = beta_i * alpha_ij**2 encodes), runs every
(platform, task) shard, pools the partial estimates inverse-variance
style, and reports predicted vs measured makespan and accuracy — the
quantities compared in the paper's Figs 8 & 10.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import (
    Allocation,
    AllocationProblem,
    SUPPORT_ATOL,
    makespan,
    milp_allocation,
    ml_allocation,
    proportional_allocation,
)
from .contracts import PricingTask, launch_key
from .platforms import (
    Platform,
    RunRecord,
    TaskPlatformModel,
    characterise as _characterise,
    dispatch_batch,
    model_matrices,
)

__all__ = ["PricingSolver", "ExecutionReport", "SOLVERS"]

SOLVERS: dict[str, Callable[..., Allocation]] = {
    "heuristic": lambda p, **kw: proportional_allocation(p),
    "ml": lambda p, **kw: ml_allocation(p, **kw),
    "milp": lambda p, **kw: milp_allocation(p, **kw),
}


@dataclasses.dataclass
class ExecutionReport:
    allocation: Allocation
    predicted_makespan: float
    measured_makespan: float
    platform_latencies: dict[str, float]
    prices: dict[int, float]
    predicted_ci: dict[int, float]
    measured_ci: dict[int, float]
    records: list[RunRecord]

    @property
    def makespan_error(self) -> float:
        return abs(self.predicted_makespan - self.measured_makespan) / self.measured_makespan


class PricingSolver:
    def __init__(self, tasks: Sequence[PricingTask], platforms: Sequence[Platform]):
        self.tasks = list(tasks)
        self.platforms = list(platforms)
        self.models: dict[tuple[str, int], TaskPlatformModel] | None = None
        self._delta: np.ndarray | None = None
        self._gamma: np.ndarray | None = None

    # -- step 2: characterisation ------------------------------------------
    def characterise(self, path_ladder: Sequence[int] | None = None,
                     seed: int = 1, batched: bool = True) -> None:
        self.models = _characterise(self.platforms, self.tasks, path_ladder,
                                    seed, batched=batched)
        self._delta, self._gamma = model_matrices(self.models, self.platforms, self.tasks)

    def problem(self, accuracy: float | np.ndarray) -> AllocationProblem:
        if self._delta is None:
            raise RuntimeError("characterise() first")
        c = np.broadcast_to(np.asarray(accuracy, dtype=np.float64),
                            (len(self.tasks),)).copy()
        return AllocationProblem(delta=self._delta, gamma=self._gamma, c=c)

    # -- steps 3-4: allocation ---------------------------------------------
    def allocate(self, accuracy: float | np.ndarray, method: str = "milp",
                 **solver_kw) -> Allocation:
        return SOLVERS[method](self.problem(accuracy), **solver_kw)

    # -- step 5: execution ---------------------------------------------------
    def execute(self, allocation: Allocation, accuracy: float | np.ndarray,
                seed: int = 3) -> ExecutionReport:
        assert self.models is not None
        problem = self.problem(accuracy)
        A = allocation.A
        records: list[RunRecord] = []
        plat_lat = {p.spec.name: 0.0 for p in self.platforms}
        # per-task accumulators for pooled estimates
        num = {t.task_id: 0.0 for t in self.tasks}
        den = {t.task_id: 0.0 for t in self.tasks}
        var = {t.task_id: 0.0 for t in self.tasks}

        for i, p in enumerate(self.platforms):
            # Collect this platform's supported shards, then issue one
            # batched launch per compilation group (runtime-parameter
            # batching: ragged n_ij within a group rides one executable).
            shards: dict[tuple, list[tuple[PricingTask, int]]] = {}
            for j, t in enumerate(self.tasks):
                share = A[i, j]
                if share <= SUPPORT_ATOL:
                    continue
                m = self.models[(p.spec.name, t.task_id)]
                n_needed = m.accuracy.paths_for_accuracy(float(problem.c[j]))
                n_ij = max(int(np.ceil(share * n_needed)), 64)
                shards.setdefault(launch_key(t), []).append((t, n_ij))
            for group in shards.values():
                gtasks = [t for t, _ in group]
                g_ns = [n for _, n in group]
                for rec in dispatch_batch(p, gtasks, g_ns, seed=seed):
                    records.append(rec)
                    plat_lat[p.spec.name] += rec.latency
                    num[rec.task_id] += rec.n_paths * rec.price
                    den[rec.task_id] += rec.n_paths
                    # pooled CI: ci^2 = sum (n_ij * ci_ij)^2 / n_tot^2
                    var[rec.task_id] += (rec.n_paths * rec.ci95) ** 2

        prices = {tid: num[tid] / den[tid] for tid in num}
        measured_ci = {tid: float(np.sqrt(var[tid])) / den[tid] for tid in num}
        predicted_ci = {t.task_id: float(problem.c[j])
                        for j, t in enumerate(self.tasks)}
        return ExecutionReport(
            allocation=allocation,
            predicted_makespan=makespan(A, problem),
            measured_makespan=max(plat_lat.values()),
            platform_latencies=plat_lat,
            prices=prices,
            predicted_ci=predicted_ci,
            measured_ci=measured_ci,
            records=records,
        )
