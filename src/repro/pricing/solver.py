"""F3-style solver: characterise -> allocate -> execute (paper Fig 1 flow).

This is the orchestration layer a domain user ("Julia") touches:

    solver = PricingSolver(tasks, platforms)
    solver.characterise()                       # online benchmarking, (2)
    alloc = solver.allocate(accuracy=0.05,      # trade-off selection, (3-4)
                            method="milp")
    report = solver.execute(alloc)              # evaluation, (5)

Since the runtime refactor this class is a thin compatibility wrapper: the
loop itself lives in the domain-agnostic :class:`repro.runtime.Scheduler`
driving :class:`repro.domains.pricing.PricingDomain` — the same code path
that serves every other domain (e.g. LM token serving). ``execute`` still
returns the pricing-shaped :class:`ExecutionReport` (pooled prices,
predicted vs measured CI and makespan — the paper's Figs 8 & 10
quantities), unpacked from the scheduler's generic report.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core import Allocation, AllocationProblem
from repro.runtime import Scheduler
from repro.runtime.scheduler import SOLVERS  # noqa: F401  (re-export, stable API)
from .contracts import PricingTask
from .platforms import Platform, RunRecord, TaskPlatformModel

__all__ = ["PricingSolver", "ExecutionReport", "SOLVERS"]


@dataclasses.dataclass
class ExecutionReport:
    allocation: Allocation
    predicted_makespan: float
    measured_makespan: float
    platform_latencies: dict[str, float]
    prices: dict[int, float]
    predicted_ci: dict[int, float]
    measured_ci: dict[int, float]
    records: list[RunRecord]

    @property
    def makespan_error(self) -> float:
        if self.measured_makespan == 0:
            return math.inf  # nothing dispatched: model unassessable
        return abs(self.predicted_makespan - self.measured_makespan) / self.measured_makespan


class PricingSolver:
    def __init__(self, tasks: Sequence[PricingTask], platforms: Sequence[Platform],
                 mode: str = "concurrent"):
        # Imported here: repro.pricing.__init__ imports this module before
        # the package is fully initialised, and the domain adapter imports
        # back into repro.pricing.
        from repro.domains.pricing import PricingDomain

        self.domain = PricingDomain(tasks, platforms)
        self.scheduler = Scheduler(self.domain, mode=mode)

    @property
    def tasks(self) -> list[PricingTask]:
        return self.domain.tasks

    @property
    def platforms(self) -> list[Platform]:
        return self.domain.platforms

    @property
    def models(self) -> dict[tuple[str, int], TaskPlatformModel] | None:
        return self.scheduler.models

    @property
    def _delta(self) -> np.ndarray | None:
        return self.scheduler._delta

    @property
    def _gamma(self) -> np.ndarray | None:
        return self.scheduler._gamma

    # -- step 2: characterisation ------------------------------------------
    def characterise(self, path_ladder: Sequence[int] | None = None,
                     seed: int = 1, batched: bool = True) -> None:
        self.scheduler.characterise(seed=seed, path_ladder=path_ladder,
                                    batched=batched)

    def problem(self, accuracy: float | np.ndarray) -> AllocationProblem:
        return self.scheduler.problem(accuracy)

    # -- steps 3-4: allocation ---------------------------------------------
    def allocate(self, accuracy: float | np.ndarray, method: str = "milp",
                 **solver_kw) -> Allocation:
        return self.scheduler.allocate(accuracy, method=method, **solver_kw)

    # -- step 5: execution ---------------------------------------------------
    def execute(self, allocation: Allocation, accuracy: float | np.ndarray,
                seed: int = 3) -> ExecutionReport:
        rep = self.scheduler.execute(allocation, accuracy, seed=seed)
        return ExecutionReport(
            allocation=rep.allocation,
            predicted_makespan=rep.predicted_makespan,
            measured_makespan=rep.measured_makespan,
            platform_latencies=rep.platform_latencies,
            prices=rep.summary["prices"],
            predicted_ci=rep.summary["predicted_ci"],
            measured_ci=rep.summary["measured_ci"],
            records=rep.records,
        )
