"""repro.pricing — the derivatives-pricing application domain (paper §4).

The F3 framework re-built in JAX: contracts (underlyings + derivatives),
the Monte Carlo engine (jnp / Pallas / shard_map backends), the Table 1
workload, the Table 2 platform cluster, online benchmarking, and the
characterise -> allocate -> execute solver flow.
"""
from .contracts import (  # noqa: F401
    ASIAN,
    BARRIER,
    DIGITAL_DOUBLE_BARRIER,
    DOUBLE_BARRIER,
    EUROPEAN,
    BlackScholes,
    Heston,
    Option,
    PricingTask,
    asian,
    barrier,
    digital_double_barrier,
    double_barrier,
    european,
    payoff_from_stats,
)
from .mc import PriceResult, path_stats, price, price_sharded  # noqa: F401
from .platforms import (  # noqa: F401
    TABLE2_SPECS,
    LocalJaxPlatform,
    Platform,
    PlatformSpec,
    RunRecord,
    SimulatedPlatform,
    TaskPlatformModel,
    benchmark,
    build_cluster,
    characterise,
    kflop_per_path,
    model_matrices,
)
from .solver import SOLVERS, ExecutionReport, PricingSolver  # noqa: F401
from .workload import TABLE1_CATEGORIES, make_task, table1_workload  # noqa: F401
