"""repro.pricing — the derivatives-pricing application domain (paper §4).

The F3 framework re-built in JAX: contracts (underlyings + derivatives),
the Monte Carlo engine (jnp / Pallas / shard_map backends), the Table 1
workload, the Table 2 platform cluster, online benchmarking, and the
characterise -> allocate -> execute solver flow.
"""
from .contracts import (  # noqa: F401
    ASIAN,
    BARRIER,
    DIGITAL_DOUBLE_BARRIER,
    DOUBLE_BARRIER,
    EUROPEAN,
    BlackScholes,
    Heston,
    Option,
    PricingTask,
    TaskBatch,
    asian,
    barrier,
    digital_double_barrier,
    double_barrier,
    european,
    family_key,
    group_by_family,
    group_by_launch,
    launch_key,
    payoff_from_stats,
    payoff_from_stats_coded,
)
from .mc import (  # noqa: F401
    PriceResult,
    path_stats,
    price,
    price_batch,
    price_sharded,
    reset_trace_counts,
    trace_counts,
)
from .platforms import (  # noqa: F401
    TABLE2_SPECS,
    LocalJaxPlatform,
    Platform,
    PlatformSpec,
    RunRecord,
    SimulatedPlatform,
    TaskPlatformModel,
    benchmark,
    benchmark_batch,
    build_cluster,
    characterise,
    dispatch_batch,
    kflop_per_path,
    model_matrices,
)
from .solver import SOLVERS, ExecutionReport, PricingSolver  # noqa: F401
from .workload import TABLE1_CATEGORIES, make_task, table1_workload  # noqa: F401
