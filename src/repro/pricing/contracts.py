"""Derivatives-pricing domain types (paper §4.1.2).

The domain has two data types — *underlyings* (the stochastic model of the
asset) and *derivatives* (the contract payoff) — and one function,
``price``. This module defines both types plus the payoff algebra.

All five option classes of the paper's Table 1 workload are expressible
from four per-path statistics (terminal price, running arithmetic mean,
running min, running max), which is what lets a single Monte Carlo kernel
serve every contract:

    European              max(±(S_T - K), 0)
    Asian (arithmetic)    max(±(avg - K), 0)
    Barrier (up-and-out)  1[max < B_up] * European
    Double barrier (KO)   1[B_lo < min and max < B_up] * European
    Digital double (no-touch)  Q * 1[B_lo < min and max < B_up]
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BlackScholes", "Heston", "bs_step_fn", "heston_step_fn",
    "EUROPEAN", "ASIAN", "BARRIER", "DOUBLE_BARRIER", "DIGITAL_DOUBLE_BARRIER",
    "Option", "european", "asian", "barrier", "double_barrier",
    "digital_double_barrier", "payoff_from_stats", "payoff_from_stats_coded",
    "PricingTask", "TaskBatch", "PARAM_COLS", "COL", "N_PARAMS",
    "family_key", "group_by_family", "launch_key", "group_by_launch",
]


# --------------------------------------------------------------------------
# Underlyings
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlackScholes:
    """Geometric Brownian motion: dS = r S dt + sigma S dW."""

    spot: float
    rate: float
    volatility: float

    kind: str = dataclasses.field(default="black-scholes", init=False, repr=False)


@dataclasses.dataclass(frozen=True)
class Heston:
    """Heston stochastic volatility:

        dS = r S dt + sqrt(v) S dW_S
        dv = kappa (theta - v) dt + xi sqrt(v) dW_v,  corr(dW_S, dW_v) = rho

    Simulated with the full-truncation Euler scheme (v clamped at 0 inside
    drift and diffusion), the standard bias/robustness trade-off.
    """

    spot: float
    rate: float
    v0: float
    kappa: float
    theta: float
    xi: float
    rho: float

    kind: str = dataclasses.field(default="heston", init=False, repr=False)


# --------------------------------------------------------------------------
# Underlying dynamics — the single definition of each Euler step
# --------------------------------------------------------------------------
#
# Scalar-parameterised step builders shared verbatim by the jnp oracle
# (float operands), the batched engine (traced param-row scalars) and the
# Pallas kernels (SMEM scalars) — so every backend integrates the identical
# scheme and a future change cannot silently diverge one of them.

def bs_step_fn(rate, vol, dt):
    """GBM log-Euler step: ``step(s, (z, _)) -> s'``.  Pure jnp."""
    drift = (rate - jnp.float32(0.5) * vol * vol) * dt
    vol_dt = vol * jnp.sqrt(dt)

    def step(s, z):
        z_s, _ = z
        return s * jnp.exp(drift + vol_dt * z_s)

    return step


def heston_step_fn(rate, kappa, theta, xi, rho, dt):
    """Full-truncation Euler Heston step: ``step((s, v), (z_s, z2))``.

    v is clamped at 0 inside drift and diffusion (the standard
    bias/robustness trade-off); z2 is mixed into the vol shock via rho.
    """
    rho_c = jnp.sqrt(jnp.maximum(jnp.float32(1.0) - rho * rho, jnp.float32(0.0)))
    sqrt_dt = jnp.sqrt(dt)

    def step(carry, z):
        s, v = carry
        z_s, z2 = z
        z_v = rho * z_s + rho_c * z2
        v_plus = jnp.maximum(v, jnp.float32(0.0))
        sqrt_v = jnp.sqrt(v_plus)
        s_new = s * jnp.exp((rate - jnp.float32(0.5) * v_plus) * dt
                            + sqrt_v * sqrt_dt * z_s)
        v_new = v + kappa * (theta - v_plus) * dt + xi * sqrt_v * sqrt_dt * z_v
        return (s_new, v_new)

    return step


# --------------------------------------------------------------------------
# Derivatives
# --------------------------------------------------------------------------

EUROPEAN, ASIAN, BARRIER, DOUBLE_BARRIER, DIGITAL_DOUBLE_BARRIER = range(5)

_PAYOFF_NAMES = {
    EUROPEAN: "E", ASIAN: "A", BARRIER: "B",
    DOUBLE_BARRIER: "DB", DIGITAL_DOUBLE_BARRIER: "DDB",
}


@dataclasses.dataclass(frozen=True)
class Option:
    payoff: int
    strike: float = 0.0
    lower: float = 0.0
    upper: float = math.inf
    payout: float = 1.0  # digital options
    call: bool = True

    @property
    def code(self) -> str:
        return _PAYOFF_NAMES[self.payoff]


def european(strike: float, call: bool = True) -> Option:
    return Option(EUROPEAN, strike=strike, call=call)


def asian(strike: float, call: bool = True) -> Option:
    return Option(ASIAN, strike=strike, call=call)


def barrier(strike: float, upper: float, call: bool = True) -> Option:
    """Up-and-out knock-out barrier option (discretely monitored)."""
    return Option(BARRIER, strike=strike, upper=upper, call=call)


def double_barrier(strike: float, lower: float, upper: float, call: bool = True) -> Option:
    return Option(DOUBLE_BARRIER, strike=strike, lower=lower, upper=upper, call=call)


def digital_double_barrier(payout: float, lower: float, upper: float) -> Option:
    """No-touch digital: pays ``payout`` iff the path stays inside (lo, up)."""
    return Option(DIGITAL_DOUBLE_BARRIER, payout=payout, lower=lower, upper=upper)


def payoff_from_stats(s_t, avg, mn, mx, option: Option):
    """Undiscounted payoff from per-path statistics.

    Pure jnp; shared verbatim by the Pallas kernel body, the jnp oracle and
    the distributed engine, so every backend prices identically.
    """
    sign = jnp.float32(1.0 if option.call else -1.0)
    strike = jnp.float32(option.strike)
    vanilla = jnp.maximum(sign * (s_t - strike), jnp.float32(0.0))
    asian_p = jnp.maximum(sign * (avg - strike), jnp.float32(0.0))
    alive_up = mx < jnp.float32(option.upper)
    alive = alive_up & (mn > jnp.float32(option.lower))
    zero = jnp.float32(0.0)
    if option.payoff == EUROPEAN:
        return vanilla
    if option.payoff == ASIAN:
        return asian_p
    if option.payoff == BARRIER:
        return jnp.where(alive_up, vanilla, zero)
    if option.payoff == DOUBLE_BARRIER:
        return jnp.where(alive, vanilla, zero)
    if option.payoff == DIGITAL_DOUBLE_BARRIER:
        return jnp.where(alive, jnp.float32(option.payout), zero)
    raise ValueError(f"unknown payoff {option.payoff}")


def payoff_from_stats_coded(s_t, avg, mn, mx, strike, lower, upper, payout,
                            call_sign, kind):
    """Runtime-parameterised payoff: every contract field is a traced operand.

    The batched engine's counterpart of :func:`payoff_from_stats` — the
    payoff *kind* is an int32 code selected with ``jnp.where`` masking, so
    one compiled computation serves any mix of Table 1 contracts.  All
    operands broadcast (per-task scalars against per-path statistics), and
    the same expression runs verbatim inside the Pallas kernel body (with
    SMEM scalars) and the vmapped jnp oracle.  Payoff evaluation is a
    handful of FLOPs against ~1e5 per path of simulation, so evaluating all
    five branches and masking costs nothing measurable.
    """
    zero = jnp.float32(0.0)
    vanilla = jnp.maximum(call_sign * (s_t - strike), zero)
    asian_p = jnp.maximum(call_sign * (avg - strike), zero)
    alive_up = mx < upper
    alive = alive_up & (mn > lower)
    return jnp.where(
        kind == EUROPEAN, vanilla,
        jnp.where(
            kind == ASIAN, asian_p,
            jnp.where(
                kind == BARRIER, jnp.where(alive_up, vanilla, zero),
                jnp.where(
                    kind == DOUBLE_BARRIER, jnp.where(alive, vanilla, zero),
                    jnp.where(alive, payout, zero)))))


# --------------------------------------------------------------------------
# Task = underlying + derivative + simulation spec
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PricingTask:
    """One atomic (but divisible-by-paths) pricing task.

    ``task_id`` seeds the RNG stream so every task draws from a disjoint,
    decomposition-independent random stream.
    """

    underlying: BlackScholes | Heston
    option: Option
    maturity: float
    n_steps: int
    task_id: int = 0
    category: str = ""

    @property
    def discount(self) -> float:
        return math.exp(-self.underlying.rate * self.maturity)

    @property
    def normals_per_step(self) -> int:
        return 2 if isinstance(self.underlying, Heston) else 1


# --------------------------------------------------------------------------
# Task families and struct-of-arrays batching
# --------------------------------------------------------------------------
#
# The unit of *compilation* is the task family — (underlying model, payoff
# family, n_steps), of which Table 1 has 9 — not the individual task.  All
# per-task numbers (spot, rate, vol/Heston params, maturity-derived dt,
# strike, barriers, payout, call sign) are packed into one (T, N_PARAMS)
# f32 array and enter the compiled computation as *traced operands*, so two
# workloads from the same family with the same batch shape share one XLA
# executable.

#: Column layout of ``TaskBatch.params`` — shared by the jnp oracle and the
#: Pallas kernel (which reads them as SMEM scalars indexed by program id).
PARAM_COLS: tuple[str, ...] = (
    "spot", "rate", "dt",                        # simulation
    "vol",                                       # Black-Scholes
    "v0", "kappa", "theta", "xi", "rho",         # Heston
    "strike", "lower", "upper", "payout",        # contract
    "call_sign",
)
COL: dict[str, int] = {name: i for i, name in enumerate(PARAM_COLS)}
N_PARAMS = len(PARAM_COLS)


def family_key(task: PricingTask) -> tuple[str, int, int]:
    """(model kind, payoff family, n_steps) — the Table 1 family key."""
    return (task.underlying.kind, task.option.payoff, task.n_steps)


def launch_key(task: PricingTask) -> tuple[str, int]:
    """(model kind, n_steps) — the *compilation* grouping key.

    Strictly coarser than :func:`family_key`: payoff kind is a runtime code
    (see :func:`payoff_from_stats_coded`), so families differing only in
    contract type share one compiled executable.  Only the step function
    (BS vs Heston) and the loop bound are structural.
    """
    return (task.underlying.kind, task.n_steps)


def _group_by(tasks: Sequence[PricingTask], key):
    groups: dict[tuple, list[tuple[int, PricingTask]]] = {}
    for i, t in enumerate(tasks):
        groups.setdefault(key(t), []).append((i, t))
    return list(groups.items())


def group_by_family(tasks: Sequence[PricingTask]):
    """Group task *indices* by Table 1 family, preserving first-seen order.

    Returns ``[(family_key, [(index, task), ...]), ...]``.
    """
    return _group_by(tasks, family_key)


def group_by_launch(tasks: Sequence[PricingTask]):
    """Group task *indices* by compilation unit (model kind, n_steps)."""
    return _group_by(tasks, launch_key)


def _task_param_row(task: PricingTask) -> list[float]:
    u = task.underlying
    o = task.option
    dt = task.maturity / task.n_steps
    if isinstance(u, BlackScholes):
        model = [u.volatility, 0.0, 0.0, 0.0, 0.0, 0.0]
    else:
        model = [0.0, u.v0, u.kappa, u.theta, u.xi, u.rho]
    # float32(inf) upper barriers survive the cast; comparisons stay exact.
    return [u.spot, u.rate, dt, *model,
            o.strike, o.lower, o.upper, o.payout,
            1.0 if o.call else -1.0]


@dataclasses.dataclass(frozen=True)
class TaskBatch:
    """Struct-of-arrays packing of a task family for one batched launch.

    ``params``/``task_ids``/``payoff_kinds`` are runtime arrays (traced jit
    operands); only ``model_kind`` and ``n_steps`` are static — they select
    the step function and the loop bound, which is why a batch must be
    family-uniform in those two.  Payoff kinds *may* mix within a batch
    (they are runtime codes), but :func:`group_by_family` keeps launches
    family-pure so the compile-count accounting matches the paper's ~9
    Table 1 families.
    """

    params: Any        # (T, N_PARAMS) f32
    task_ids: Any      # (T,) uint32 — RNG key half, unchanged convention
    payoff_kinds: Any  # (T,) int32
    model_kind: str    # static: "black-scholes" | "heston"
    n_steps: int       # static: scan/loop bound

    @property
    def n_tasks(self) -> int:
        return self.params.shape[0]

    @classmethod
    def from_tasks(cls, tasks: Sequence[PricingTask]) -> "TaskBatch":
        if not tasks:
            raise ValueError("empty task batch")
        kinds = {t.underlying.kind for t in tasks}
        steps = {t.n_steps for t in tasks}
        if len(kinds) > 1 or len(steps) > 1:
            raise ValueError(
                f"TaskBatch must be family-uniform in (model, n_steps); "
                f"got models={sorted(kinds)} n_steps={sorted(steps)}")
        # Validate payoff codes here, while they are still concrete ints —
        # the coded payoff's where-chain inside jit cannot raise, and an
        # unknown code would otherwise silently price as the final branch.
        bad = {t.option.payoff for t in tasks} - set(_PAYOFF_NAMES)
        if bad:
            raise ValueError(f"unknown payoff kinds {sorted(bad)}")
        params = np.asarray([_task_param_row(t) for t in tasks], np.float32)
        return cls(
            params=jnp.asarray(params),
            task_ids=jnp.asarray([t.task_id for t in tasks], jnp.uint32),
            payoff_kinds=jnp.asarray([t.option.payoff for t in tasks], jnp.int32),
            model_kind=next(iter(kinds)),
            n_steps=next(iter(steps)),
        )


def _taskbatch_flatten(b: TaskBatch):
    return (b.params, b.task_ids, b.payoff_kinds), (b.model_kind, b.n_steps)


def _taskbatch_unflatten(aux, children):
    return TaskBatch(*children, model_kind=aux[0], n_steps=aux[1])


jax.tree_util.register_pytree_node(TaskBatch, _taskbatch_flatten, _taskbatch_unflatten)
