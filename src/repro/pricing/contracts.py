"""Derivatives-pricing domain types (paper §4.1.2).

The domain has two data types — *underlyings* (the stochastic model of the
asset) and *derivatives* (the contract payoff) — and one function,
``price``. This module defines both types plus the payoff algebra.

All five option classes of the paper's Table 1 workload are expressible
from four per-path statistics (terminal price, running arithmetic mean,
running min, running max), which is what lets a single Monte Carlo kernel
serve every contract:

    European              max(±(S_T - K), 0)
    Asian (arithmetic)    max(±(avg - K), 0)
    Barrier (up-and-out)  1[max < B_up] * European
    Double barrier (KO)   1[B_lo < min and max < B_up] * European
    Digital double (no-touch)  Q * 1[B_lo < min and max < B_up]
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

__all__ = [
    "BlackScholes", "Heston",
    "EUROPEAN", "ASIAN", "BARRIER", "DOUBLE_BARRIER", "DIGITAL_DOUBLE_BARRIER",
    "Option", "european", "asian", "barrier", "double_barrier",
    "digital_double_barrier", "payoff_from_stats", "PricingTask",
]


# --------------------------------------------------------------------------
# Underlyings
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlackScholes:
    """Geometric Brownian motion: dS = r S dt + sigma S dW."""

    spot: float
    rate: float
    volatility: float

    kind: str = dataclasses.field(default="black-scholes", init=False, repr=False)


@dataclasses.dataclass(frozen=True)
class Heston:
    """Heston stochastic volatility:

        dS = r S dt + sqrt(v) S dW_S
        dv = kappa (theta - v) dt + xi sqrt(v) dW_v,  corr(dW_S, dW_v) = rho

    Simulated with the full-truncation Euler scheme (v clamped at 0 inside
    drift and diffusion), the standard bias/robustness trade-off.
    """

    spot: float
    rate: float
    v0: float
    kappa: float
    theta: float
    xi: float
    rho: float

    kind: str = dataclasses.field(default="heston", init=False, repr=False)


# --------------------------------------------------------------------------
# Derivatives
# --------------------------------------------------------------------------

EUROPEAN, ASIAN, BARRIER, DOUBLE_BARRIER, DIGITAL_DOUBLE_BARRIER = range(5)

_PAYOFF_NAMES = {
    EUROPEAN: "E", ASIAN: "A", BARRIER: "B",
    DOUBLE_BARRIER: "DB", DIGITAL_DOUBLE_BARRIER: "DDB",
}


@dataclasses.dataclass(frozen=True)
class Option:
    payoff: int
    strike: float = 0.0
    lower: float = 0.0
    upper: float = math.inf
    payout: float = 1.0  # digital options
    call: bool = True

    @property
    def code(self) -> str:
        return _PAYOFF_NAMES[self.payoff]


def european(strike: float, call: bool = True) -> Option:
    return Option(EUROPEAN, strike=strike, call=call)


def asian(strike: float, call: bool = True) -> Option:
    return Option(ASIAN, strike=strike, call=call)


def barrier(strike: float, upper: float, call: bool = True) -> Option:
    """Up-and-out knock-out barrier option (discretely monitored)."""
    return Option(BARRIER, strike=strike, upper=upper, call=call)


def double_barrier(strike: float, lower: float, upper: float, call: bool = True) -> Option:
    return Option(DOUBLE_BARRIER, strike=strike, lower=lower, upper=upper, call=call)


def digital_double_barrier(payout: float, lower: float, upper: float) -> Option:
    """No-touch digital: pays ``payout`` iff the path stays inside (lo, up)."""
    return Option(DIGITAL_DOUBLE_BARRIER, payout=payout, lower=lower, upper=upper)


def payoff_from_stats(s_t, avg, mn, mx, option: Option):
    """Undiscounted payoff from per-path statistics.

    Pure jnp; shared verbatim by the Pallas kernel body, the jnp oracle and
    the distributed engine, so every backend prices identically.
    """
    sign = jnp.float32(1.0 if option.call else -1.0)
    strike = jnp.float32(option.strike)
    vanilla = jnp.maximum(sign * (s_t - strike), jnp.float32(0.0))
    asian_p = jnp.maximum(sign * (avg - strike), jnp.float32(0.0))
    alive_up = mx < jnp.float32(option.upper)
    alive = alive_up & (mn > jnp.float32(option.lower))
    zero = jnp.float32(0.0)
    if option.payoff == EUROPEAN:
        return vanilla
    if option.payoff == ASIAN:
        return asian_p
    if option.payoff == BARRIER:
        return jnp.where(alive_up, vanilla, zero)
    if option.payoff == DOUBLE_BARRIER:
        return jnp.where(alive, vanilla, zero)
    if option.payoff == DIGITAL_DOUBLE_BARRIER:
        return jnp.where(alive, jnp.float32(option.payout), zero)
    raise ValueError(f"unknown payoff {option.payoff}")


# --------------------------------------------------------------------------
# Task = underlying + derivative + simulation spec
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PricingTask:
    """One atomic (but divisible-by-paths) pricing task.

    ``task_id`` seeds the RNG stream so every task draws from a disjoint,
    decomposition-independent random stream.
    """

    underlying: BlackScholes | Heston
    option: Option
    maturity: float
    n_steps: int
    task_id: int = 0
    category: str = ""

    @property
    def discount(self) -> float:
        return math.exp(-self.underlying.rate * self.maturity)

    @property
    def normals_per_step(self) -> int:
        return 2 if isinstance(self.underlying, Heston) else 1
