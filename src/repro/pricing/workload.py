"""The paper's Table 1 evaluation workload: 128 option-pricing tasks.

Category counts are taken verbatim from Table 1. Domain parameters are
drawn uniformly within the Kaiserslautern option-pricing benchmark ranges
[30], with the paper's rejection procedure keeping relative task
complexity within an order of magnitude (we reject parameter draws whose
payoff variance is degenerate — deep out-of-the-money knock-outs — since
those yield alpha ~= 0 and carry no information for the accuracy models).
"""
from __future__ import annotations

import numpy as np

from .contracts import (
    BlackScholes,
    Heston,
    PricingTask,
    asian,
    barrier,
    digital_double_barrier,
    double_barrier,
    european,
)

__all__ = ["TABLE1_CATEGORIES", "make_task", "table1_workload"]

#: (designation, count) rows of Table 1 — 128 tasks total.
TABLE1_CATEGORIES: list[tuple[str, int]] = [
    ("BS-A", 10), ("BS-B", 10), ("BS-DB", 10), ("BS-DDB", 5),
    ("H-A", 25), ("H-B", 29), ("H-DB", 29), ("H-DDB", 5), ("H-E", 5),
]


def _draw_underlying(rng: np.random.Generator, model: str):
    spot = rng.uniform(80.0, 120.0)
    rate = rng.uniform(0.01, 0.1)
    if model == "BS":
        return BlackScholes(spot=spot, rate=rate, volatility=rng.uniform(0.1, 0.5))
    return Heston(
        spot=spot, rate=rate,
        v0=rng.uniform(0.02, 0.2), kappa=rng.uniform(0.5, 4.0),
        theta=rng.uniform(0.02, 0.2), xi=rng.uniform(0.1, 0.8),
        rho=rng.uniform(-0.9, -0.1),
    )


def _draw_option(rng: np.random.Generator, code: str, spot: float):
    strike = spot * rng.uniform(0.85, 1.15)
    lo = spot * rng.uniform(0.5, 0.75)
    hi = spot * rng.uniform(1.35, 1.9)
    call = bool(rng.random() < 0.5)
    if code == "E":
        return european(strike, call)
    if code == "A":
        return asian(strike, call)
    if code == "B":
        return barrier(strike, upper=hi, call=call)
    if code == "DB":
        return double_barrier(strike, lower=lo, upper=hi, call=call)
    if code == "DDB":
        return digital_double_barrier(payout=rng.uniform(5.0, 20.0), lower=lo, upper=hi)
    raise ValueError(code)


def make_task(category: str, task_id: int, rng: np.random.Generator,
              n_steps: int = 256) -> PricingTask:
    model, code = category.split("-", 1)
    underlying = _draw_underlying(rng, model)
    option = _draw_option(rng, code, underlying.spot)
    return PricingTask(
        underlying=underlying,
        option=option,
        maturity=float(rng.uniform(0.5, 2.0)),
        n_steps=n_steps,
        task_id=task_id,
        category=category,
    )


def table1_workload(seed: int = 2015, n_steps: int = 256,
                    categories: list[tuple[str, int]] | None = None) -> list[PricingTask]:
    """Generate the 128-task workload (or a scaled-down subset for tests)."""
    rng = np.random.default_rng(seed)
    tasks: list[PricingTask] = []
    tid = 0
    for category, count in (categories or TABLE1_CATEGORIES):
        for _ in range(count):
            # Rejection procedure: redraw tasks whose knock-out structure is
            # degenerate (barriers inside +-5% of spot knock out ~all paths).
            for _attempt in range(16):
                task = make_task(category, tid, rng, n_steps=n_steps)
                u, o = task.underlying, task.option
                if o.upper < u.spot * 1.2 or (o.lower and o.lower > u.spot * 0.9):
                    continue
                break
            tasks.append(task)
            tid += 1
    return tasks
