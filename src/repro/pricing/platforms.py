"""Heterogeneous platforms (paper §5.1.2, Table 2) and online benchmarking.

Two platform kinds:

``LocalJaxPlatform``
    Real execution: the JAX Monte Carlo engine on this host's devices,
    latency measured by wall clock. This is the analogue of the paper's
    "Desktop/Localhost" row and grounds the whole study in measured data.

``SimulatedPlatform``
    Replays a Table 2 row. We obviously cannot SSH into the paper's 2015
    cluster, so remote platforms are simulated from their two published
    characteristics — application performance (GFLOPS, Kaiserslautern
    benchmark) and network RTT — exactly the quantities the paper says
    determine beta and gamma respectively (§5.1.2):

        latency(n) = task_flops(n) / GFLOPS + RTT + lognormal jitter

    The *statistics* (price, CI) of a simulated run come from the task's
    true payoff moments (platform-independent, estimated once per task by
    the local engine) plus seeded estimator noise — a remote platform
    changes where the paths are computed, not their distribution.

The online benchmarking procedure (§3.1.4) runs a geometric ladder of path
counts on each platform and fits the (beta, gamma, alpha) coefficients by
weighted least squares, yielding the CombinedModel (delta, gamma) entries
that the allocation matrices are built from.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
import zlib
from typing import Protocol, Sequence

import numpy as np

from repro.core.metrics import (
    AccuracyModel,
    CombinedModel,
    LatencyModel,
    fit_accuracy_model,
    fit_latency_model,
)
from repro.runtime.domain import PlatformSpec
from repro.runtime.scenario import Scenario, apply_scenario, salvage_runs
from .contracts import Heston, PricingTask, group_by_launch
from . import mc

__all__ = [
    "PlatformSpec", "TABLE2_SPECS", "RunRecord", "Platform",
    "LocalJaxPlatform", "SimulatedPlatform", "TaskPlatformModel",
    "benchmark", "benchmark_adaptive", "benchmark_batch",
    "benchmark_adaptive_batch", "characterise", "kflop_per_path",
    "build_cluster",
]


#: Paper Table 2, verbatim.
TABLE2_SPECS: list[PlatformSpec] = [
    PlatformSpec("Desktop",        "CPU",  "Intel Core i7-2600",    "ICL London",      5.916,   0.024),
    PlatformSpec("Local Server",   "CPU",  "AMD Opteron 6272",      "ICL London",     27.002,   0.380),
    PlatformSpec("Local Pi",       "CPU",  "ARM 11 76JZF-S",        "ICL London",      0.049,   2.463),
    PlatformSpec("Remote Server",  "CPU",  "Intel Xeon E5-2680",    "UCT Cape Town",  11.523, 3300.000),
    PlatformSpec("AWS Server EC1", "CPU",  "Intel Xeon E5-2680",    "AWS US-East",    12.269,  88.859),
    PlatformSpec("AWS Server EC2", "CPU",  "Intel Xeon E5-2670",    "AWS US-East",     4.913,  88.216),
    PlatformSpec("AWS Server WC1", "CPU",  "Intel Xeon E5-2680",    "AWS US-West",    12.200, 157.100),
    PlatformSpec("AWS Server WC2", "CPU",  "Intel Xeon E5-2670",    "AWS US-West",     4.926, 159.578),
    PlatformSpec("GCE Server",     "CPU",  "Intel Xeon",            "GCE US-Central",  6.022, 111.232),
    PlatformSpec("Local GPU 1",    "GPU",  "AMD FirePro W5000",     "ICL London",    212.798,   0.269),
    PlatformSpec("Local GPU 2",    "GPU",  "Nvidia Quadro K4000",   "ICL London",    250.027,   0.278),
    PlatformSpec("Remote Phi",     "GPU",  "Intel Xeon Phi 3120P",  "UCT Cape Town",  70.850, 3300.000),
    PlatformSpec("AWS GPU EC",     "GPU",  "Nvidia Grid GK104",     "AWS US-East",   441.274,  88.216),
    PlatformSpec("AWS GPU WC",     "GPU",  "Nvidia Grid GK104",     "AWS US-West",   406.230, 159.578),
    PlatformSpec("Local FPGA 1",   "FPGA", "Xilinx Virtex 6 475T",  "ICL London",    114.590,   0.217),
    PlatformSpec("Local FPGA 2",   "FPGA", "Altera Stratix V D5",   "ICL London",    161.074,   0.299),
]

#: Paper Table 1 computational work (kFLOP per path) by task category.
TABLE1_KFLOP: dict[str, float] = {
    "BS-A": 139.267, "BS-B": 139.266, "BS-DB": 143.360, "BS-DDB": 143.361,
    "H-A": 319.492, "H-B": 319.491, "H-DB": 323.585, "H-DDB": 323.586,
    "H-E": 315.395,
}


def kflop_per_path(task: PricingTask) -> float:
    """FLOP model for a task, anchored to Table 1 (256-step baseline)."""
    base = TABLE1_KFLOP.get(task.category)
    if base is None:  # uncatalogued task: estimate from the step kind
        base = 319.5 if isinstance(task.underlying, Heston) else 139.3
    return base * (task.n_steps / 256.0)


@dataclasses.dataclass(frozen=True)
class RunRecord:
    platform: str
    task_id: int
    n_paths: int
    price: float
    ci95: float
    latency: float  # seconds


class Platform(Protocol):
    spec: PlatformSpec

    def run(self, task: PricingTask, n_paths: int, seed: int = 0) -> RunRecord: ...


def _as_path_list(tasks: Sequence[PricingTask], n_paths) -> list[int]:
    return [int(n) for n in
            np.broadcast_to(np.asarray(n_paths, dtype=np.int64), (len(tasks),))]


def dispatch_batch(platform: Platform, tasks: Sequence[PricingTask],
                   n_paths, seed: int = 0) -> list[RunRecord]:
    """Run a (task, n_paths) shard list on a platform, batched if it can.

    Platforms exposing ``run_batch`` (the family-batched fast path) get one
    launch for the whole list; anything else degrades to the per-task loop.
    """
    fn = getattr(platform, "run_batch", None)
    ns = _as_path_list(tasks, n_paths)
    if fn is not None:
        return fn(tasks, ns, seed=seed)
    return [platform.run(t, n, seed=seed) for t, n in zip(tasks, ns)]


class LocalJaxPlatform:
    """Real platform: prices with the JAX engine, wall-clock latency.

    The jit cache is warmed per (family, batch shape) outside the timed
    region — in production the compiled binary is cached, so gamma measures
    dispatch + host sync, not compilation (the paper's gamma likewise
    excludes F3's code generation, which happens once)."""

    def __init__(self, name: str = "Local JAX", backend: str = "jnp",
                 rtt_ms: float = 0.05):
        self.backend = backend
        self.spec = PlatformSpec(name, "CPU", "jax-cpu", "localhost",
                                 gflops=float("nan"), rtt_ms=rtt_ms)

    def run_batch(self, tasks: Sequence[PricingTask], n_paths,
                  seed: int = 0) -> list[RunRecord]:
        """One batched launch per task family; latency split by path share.

        The batch wall clock is attributed to tasks proportionally to their
        path counts, so per-platform latency totals (and hence measured
        makespans) are preserved while per-task betas reflect the *batched*
        throughput — the number production allocation actually sees.
        """
        ns = _as_path_list(tasks, n_paths)
        warm = mc.price_batch(tasks, ns, seed=seed, backend=self.backend)
        for r in warm:  # drain async dispatch so it cannot leak into t0
            r.price.block_until_ready()
        t0 = time.perf_counter()
        results = mc.price_batch(tasks, ns, seed=seed, backend=self.backend)
        for r in results:
            r.price.block_until_ready()
        latency = time.perf_counter() - t0
        total = max(sum(ns), 1)
        return [RunRecord(self.spec.name, t.task_id, n,
                          float(r.price), float(r.ci95), latency * n / total)
                for t, n, r in zip(tasks, ns, results)]

    def run(self, task: PricingTask, n_paths: int, seed: int = 0) -> RunRecord:
        return self.run_batch([task], [n_paths], seed=seed)[0]


class _TaskMoments:
    """Per-task true payoff moments, estimated once by the local engine.

    The cache is shared by every simulated platform and primed from
    concurrent per-platform characterisation threads; the lock keeps the
    calibration batched (first caller prices the whole family, the rest
    hit the cache) instead of racing to duplicate launches.
    """

    def __init__(self, calib_paths: int = 65536):
        self.calib_paths = calib_paths
        self._cache: dict[int, tuple[float, float]] = {}
        self._lock = threading.Lock()

    def prime(self, tasks: Sequence[PricingTask]) -> None:
        """Calibrate all uncached tasks in family-batched launches."""
        with self._lock:
            todo = [t for t in tasks if t.task_id not in self._cache]
            if not todo:
                return
            for t, res in zip(todo, mc.price_batch(todo, self.calib_paths,
                                                   seed=10_007)):
                # alpha = ci * sqrt(n): the eq. 8 coefficient
                alpha = float(res.ci95) * math.sqrt(self.calib_paths)
                self._cache[t.task_id] = (float(res.price), alpha)

    def __call__(self, task: PricingTask) -> tuple[float, float]:
        if task.task_id not in self._cache:
            self.prime([task])
        return self._cache[task.task_id]


_SHARED_MOMENTS = _TaskMoments()


class SimulatedPlatform:
    """Replays a Table 2 row; see module docstring for the model.

    ``realtime`` makes the platform *occupy* host wall clock for a scaled
    fraction of each replayed latency (``sleep(latency * realtime)``), so
    overlap benchmarks can observe true concurrent makespans without real
    remote hardware; the returned records are identical either way.

    ``scenario`` attaches a :class:`repro.runtime.scenario.Scenario`: each
    run consults it at the platform's virtual clock (cumulative replayed
    latency) for slowdown factors and outage windows, so mid-workload drift
    is reproducible without hardware. With no scenario the clock is not
    tracked and behaviour is bit-for-bit the pre-scenario one.
    """

    def __init__(self, spec: PlatformSpec, jitter: float = 0.02,
                 moments: _TaskMoments | None = None, seed: int = 0,
                 realtime: float = 0.0, scenario: Scenario | None = None):
        self.spec = spec
        self.jitter = jitter
        self.moments = moments or _SHARED_MOMENTS
        self._seed = seed
        self.realtime = realtime
        self.scenario = scenario
        self.clock = 0.0

    def attach_scenario(self, scenario: Scenario | None) -> None:
        """Attach (or clear) a scenario and rewind the virtual clock —
        fresh clocks let one scenario drive an A/B pair of runs."""
        self.scenario = scenario
        self.clock = 0.0

    def run_batch(self, tasks: Sequence[PricingTask], n_paths,
                  seed: int = 0) -> list[RunRecord]:
        """Batched replay: one family-batched *calibration* launch, then the
        (cheap, analytic) per-task latency/accuracy model.

        An outage striking mid-batch re-raises with the completed records
        attached (the virtual clock already ran them — see
        :func:`repro.runtime.scenario.salvage_runs`)."""
        self.moments.prime(tasks)
        return salvage_runs(lambda tn: self.run(tn[0], tn[1], seed=seed),
                            list(zip(tasks, _as_path_list(tasks, n_paths))))

    def run(self, task: PricingTask, n_paths: int, seed: int = 0) -> RunRecord:
        price_true, alpha = self.moments(task)
        # stable across processes (unlike hash(): PYTHONHASHSEED randomises
        # str hashing), so seeded runs reproduce exactly
        key = zlib.crc32(
            f"{self.spec.name}/{task.task_id}/{n_paths}/{seed}".encode())
        rng = np.random.default_rng(key + self._seed)
        flops = kflop_per_path(task) * 1e3 * n_paths
        compute = flops / (self.spec.gflops * 1e9)
        latency = (compute + self.spec.rtt_ms * 1e-3) * rng.lognormal(0.0, self.jitter)
        latency = apply_scenario(self, latency)
        stderr = alpha / (2 * 1.96) / math.sqrt(n_paths)
        price = price_true + rng.normal(0.0, stderr)
        # measured CI wobbles with the sample variance estimate (chi^2_k/k)
        k = max(n_paths - 1, 1)
        ci = alpha / math.sqrt(n_paths) * math.sqrt(rng.chisquare(min(k, 10**6)) / min(k, 10**6))
        if self.realtime:
            # corrupt-window runs report a negated latency; the real work
            # still took |latency| of wall clock
            time.sleep(abs(latency) * self.realtime)
        return RunRecord(self.spec.name, task.task_id, n_paths, price, ci, latency)


def build_cluster(include_local: bool = True,
                  specs: Sequence[PlatformSpec] | None = None) -> list[Platform]:
    """The 16-platform evaluation cluster (optionally + the real local one)."""
    cluster: list[Platform] = [SimulatedPlatform(s) for s in (specs or TABLE2_SPECS)]
    if include_local:
        cluster.append(LocalJaxPlatform())
    return cluster


# --------------------------------------------------------------------------
# Online benchmarking & characterisation (§3.1.4)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TaskPlatformModel:
    latency: LatencyModel
    accuracy: AccuracyModel

    @property
    def combined(self) -> CombinedModel:
        return CombinedModel.from_models(self.latency, self.accuracy)


def benchmark(platform: Platform, task: PricingTask,
              path_ladder: Sequence[int], seed: int = 1) -> list[RunRecord]:
    return [platform.run(task, int(n), seed=seed + i)
            for i, n in enumerate(path_ladder)]


def benchmark_adaptive(platform: Platform, task: PricingTask,
                       start: int = 1024, min_time: float = 0.25,
                       max_rungs: int = 10, seed: int = 1) -> list[RunRecord]:
    """Online benchmarking with a latency floor (paper §5.3 lesson).

    Fixed ladders mis-fit beta on fast platforms behind long RTTs (the
    paper's Remote Phi/Server failure): every rung is pure gamma and the
    slope is noise. Keep quadrupling the path count until a run's latency
    clearly exceeds the constant floor — then the slope is identified."""
    records = [platform.run(task, start, seed=seed)]
    n = start
    for i in range(1, max_rungs):
        n *= 4
        records.append(platform.run(task, n, seed=seed + i))
        if (records[-1].latency > max(min_time, 5.0 * records[0].latency)
                and len(records) >= 3):
            break
    return records


def benchmark_batch(platform: Platform, tasks: Sequence[PricingTask],
                    path_ladder: Sequence[int],
                    seed: int = 1) -> list[list[RunRecord]]:
    """Run a fixed path ladder over a task family: one launch per rung.

    Returns one record list per rung (aligned with ``tasks``)."""
    return [dispatch_batch(platform, tasks, int(n), seed=seed + i)
            for i, n in enumerate(path_ladder)]


def benchmark_adaptive_batch(platform: Platform, tasks: Sequence[PricingTask],
                             start: int = 1024, min_time: float = 0.25,
                             max_rungs: int = 10,
                             seed: int = 1) -> list[list[RunRecord]]:
    """Family-batched analogue of :func:`benchmark_adaptive`.

    The whole family climbs the ladder together; the stopping rule uses the
    rung's *total* latency — the batch wall-clock for a local platform
    (per-task latencies are attributed shares of one launch), the summed
    sequential time for a simulated one — so a rung stops growing once the
    launch as a whole clearly dominates the constant floor.  Tasks of a
    family share computational structure (same kFLOP model within ~3%, see
    Table 1), which is what makes a joint ladder statistically safe."""
    rungs = [dispatch_batch(platform, tasks, start, seed=seed)]
    n = start
    for i in range(1, max_rungs):
        n *= 4
        rungs.append(dispatch_batch(platform, tasks, n, seed=seed + i))
        total0 = sum(r.latency for r in rungs[0])
        total_last = sum(r.latency for r in rungs[-1])
        if total_last > max(min_time, 5.0 * total0) and len(rungs) >= 3:
            break
    return rungs


def fit_models(records: Sequence[RunRecord]) -> TaskPlatformModel:
    n = [r.n_paths for r in records]
    lat = fit_latency_model(n, [r.latency for r in records])
    acc = fit_accuracy_model(n, [r.ci95 for r in records])
    return TaskPlatformModel(latency=lat, accuracy=acc)


def characterise(
    platforms: Sequence[Platform],
    tasks: Sequence[PricingTask],
    path_ladder: Sequence[int] | None = None,
    seed: int = 1,
    batched: bool = True,
) -> dict[tuple[str, int], TaskPlatformModel]:
    """Benchmark every (platform, task) pair and fit its metric models.

    Default is the adaptive ladder (latency floor); pass an explicit
    ``path_ladder`` to reproduce fixed-budget sweeps (Figs 3-6).

    With ``batched=True`` (default) tasks are grouped by compilation unit
    (model kind, n_steps — payoff is a runtime code) and the whole ladder
    is issued as batched launches: task parameters and path counts are
    runtime operands, so the run performs at most one trace/compile per
    (family, ladder shape) — in practice one per underlying model — not per
    (platform, task, rung).  Set ``batched=False`` to replay the legacy
    per-task loop."""
    out: dict[tuple[str, int], TaskPlatformModel] = {}
    if not batched:
        for p in platforms:
            for t in tasks:
                recs = (benchmark(p, t, path_ladder, seed) if path_ladder
                        else benchmark_adaptive(p, t, seed=seed))
                out[(p.spec.name, t.task_id)] = fit_models(recs)
        return out

    groups = group_by_launch(tasks)
    for p in platforms:
        for _key, group in groups:
            gtasks = [t for _, t in group]
            rungs = (benchmark_batch(p, gtasks, path_ladder, seed)
                     if path_ladder
                     else benchmark_adaptive_batch(p, gtasks, seed=seed))
            for k, t in enumerate(gtasks):
                out[(p.spec.name, t.task_id)] = fit_models(
                    [rung[k] for rung in rungs])
    return out


def model_matrices(
    models: dict[tuple[str, int], TaskPlatformModel],
    platforms: Sequence[Platform],
    tasks: Sequence[PricingTask],
) -> tuple[np.ndarray, np.ndarray]:
    """(delta, gamma) matrices for AllocationProblem, ordered [platform, task]."""
    mu, tau = len(platforms), len(tasks)
    delta = np.zeros((mu, tau))
    gamma = np.zeros((mu, tau))
    for i, p in enumerate(platforms):
        for j, t in enumerate(tasks):
            m = models[(p.spec.name, t.task_id)].combined
            delta[i, j] = m.delta
            gamma[i, j] = m.gamma
    return delta, gamma
