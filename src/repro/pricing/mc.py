"""Monte Carlo pricing engine (paper §4.1.3's F3 execution layer, in JAX).

Three backends, all drawing the *same* Threefry stream per (task, path,
step) so results agree across decompositions:

  * ``path_stats`` / ``price``          — pure jnp (lax.scan), the oracle
  * ``price(..., backend="pallas")``    — Pallas TPU kernels (repro.kernels)
  * ``price_sharded``                   — shard_map over a mesh axis; each
        device simulates a disjoint path range and partial moments are
        combined with psum (the domain's "divisible task" property,
        eq. 5, realised as data parallelism)

The engine returns the two domain metrics directly: the price estimate and
the 95% confidence interval (the *accuracy* metric, eq. 8).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.kernels.prng import normal_pair
from .contracts import (
    COL,
    BlackScholes,
    Heston,
    Option,
    PricingTask,
    TaskBatch,
    bs_step_fn,
    group_by_launch,
    heston_step_fn,
    payoff_from_stats,
    payoff_from_stats_coded,
)

__all__ = [
    "path_stats", "price", "price_batch", "price_sharded", "PriceResult",
    "trace_counts", "reset_trace_counts",
]


# --------------------------------------------------------------------------
# Trace accounting
# --------------------------------------------------------------------------
#
# Each traced function bumps a counter in its Python body, which runs only
# when jax (re)traces — jit cache hits never touch it.  Tests assert the
# batched engine compiles O(#families) times for a multi-task characterise
# instead of O(#tasks x #rungs).

_TRACE_COUNTS: collections.Counter = collections.Counter()


def record_trace(name: str) -> None:
    _TRACE_COUNTS[name] += 1


def trace_counts() -> dict[str, int]:
    """Snapshot of {engine name: number of traces} since the last reset."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


# --------------------------------------------------------------------------
# Path simulation (pure jnp — this IS the oracle the kernels are tested on)
# --------------------------------------------------------------------------

def _bs_step(u: BlackScholes, dt: float):
    f = bs_step_fn(jnp.float32(u.rate), jnp.float32(u.volatility),
                   jnp.float32(dt))

    def step(carry, inputs):
        return f(carry, inputs), carry

    return step


def _heston_step(u: Heston, dt: float):
    f = heston_step_fn(jnp.float32(u.rate), jnp.float32(u.kappa),
                       jnp.float32(u.theta), jnp.float32(u.xi),
                       jnp.float32(u.rho), jnp.float32(dt))

    def step(carry, inputs):
        new = f(carry, inputs)
        return new, carry[0]

    return step


def path_stats(task: PricingTask, n_paths: int, seed: int, path_offset: int = 0):
    """Simulate ``n_paths`` paths; return (s_t, avg, mn, mx), each (n_paths,).

    Conventions (shared with the kernels): the running average is over the
    n_steps post-initial observations; min/max include the initial spot.
    The RNG counter is (path_index, step); the key is (seed, task_id), so
    the draw for a given (task, path, step) is decomposition-independent.
    """
    u = task.underlying
    dt = task.maturity / task.n_steps
    paths = jnp.asarray(path_offset, jnp.uint32) + jnp.arange(n_paths, dtype=jnp.uint32)
    k0 = jnp.uint32(seed)
    k1 = jnp.uint32(task.task_id)
    steps = jnp.arange(task.n_steps, dtype=jnp.uint32)

    # Draw this step's normals from the (path, step) counter.
    def normals(step_idx):
        return normal_pair(k0, k1, paths, jnp.broadcast_to(step_idx, paths.shape))

    spot = jnp.full((n_paths,), jnp.float32(u.spot))
    if isinstance(u, BlackScholes):
        step_fn = _bs_step(u, dt)
        carry0: Any = spot
    else:
        step_fn = _heston_step(u, dt)
        carry0 = (spot, jnp.full((n_paths,), jnp.float32(u.v0)))

    def body(state, step_idx):
        carry, acc, mn, mx = state
        z = normals(step_idx)
        new_carry, _ = step_fn(carry, z)
        s_new = new_carry[0] if isinstance(new_carry, tuple) else new_carry
        acc = acc + s_new
        mn = jnp.minimum(mn, s_new)
        mx = jnp.maximum(mx, s_new)
        return (new_carry, acc, mn, mx), None

    # Carry running (sum, min, max) instead of materialising the whole
    # (n_steps, n_paths) path matrix: O(paths) memory at any path count.
    state0 = (carry0, jnp.zeros_like(spot), spot, spot)
    (carry, acc, mn, mx), _ = jax.lax.scan(body, state0, steps)
    s_t = carry[0] if isinstance(carry, tuple) else carry
    avg = acc / jnp.float32(task.n_steps)
    return s_t, avg, mn, mx


def _moments(task: PricingTask, n_paths: int, seed: int, path_offset: int = 0):
    """Partial sums (sum payoff, sum payoff^2) — the mergeable statistic."""
    s_t, avg, mn, mx = path_stats(task, n_paths, seed, path_offset)
    pay = payoff_from_stats(s_t, avg, mn, mx, task.option)
    return pay.sum(), (pay * pay).sum()


@functools.partial(dataclasses.dataclass, frozen=True)
class PriceResult:
    price: Any
    ci95: Any          # the paper's accuracy metric: size of the 95% CI
    std_error: Any
    n_paths: int

    def __repr__(self):
        return (f"PriceResult(price={float(self.price):.6f}, "
                f"ci95={float(self.ci95):.6f}, n={int(self.n_paths)})")


def _finalize(task: PricingTask, pay_sum, pay_sq, n) -> PriceResult:
    n = jnp.float32(n)
    mean = pay_sum / n
    var = jnp.maximum(pay_sq / n - mean * mean, 0.0)
    disc = jnp.float32(task.discount)
    stderr = disc * jnp.sqrt(var / n)
    return PriceResult(price=disc * mean, ci95=jnp.float32(2 * 1.96) * stderr,
                       std_error=stderr, n_paths=n)


# --------------------------------------------------------------------------
# Batched runtime-parameter engine: one compilation per task family
# --------------------------------------------------------------------------
#
# Task parameters enter as traced arrays (TaskBatch) and the path count is
# a traced chunk count (fixed-size chunks, fori_loop), so the XLA cache key
# is only (model kind, n_steps, batch size) — pricing a 128-task Table 1
# workload compiles ~2 times (one per underlying model), and the whole
# benchmarking ladder of a characterisation run rides the same executable.

#: Fixed path-chunk width of the jnp batched oracle.  The chunk shape is
#: what XLA compiles; the number of chunks is a runtime loop bound, so any
#: n_paths reuses the executable.  512 keeps the (T, 512) working set tiny
#: while leaving path-count latency resolution finer than the benchmark
#: ladders use.
CHUNK_PATHS = 512


def _batch_path_stats(batch: TaskBatch, n_paths: int, seed, path_offset=0):
    """Simulate every task in the batch over ``n_paths`` paths.

    Returns (s_t, avg, mn, mx), each (T, n_paths).  The RNG counter
    convention is unchanged — key (seed, task_id), counter (path, step) —
    so each task's draws are bit-identical to its per-task run.
    ``path_offset`` shifts the global path ids (chunked execution).
    """
    n_steps = batch.n_steps
    paths = (jnp.asarray(path_offset, jnp.uint32)
             + jnp.arange(n_paths, dtype=jnp.uint32))
    steps = jnp.arange(n_steps, dtype=jnp.uint32)
    k0 = jnp.asarray(seed, jnp.uint32)

    def one_task(prow, tid):
        spot = jnp.full((n_paths,), prow[COL["spot"]])
        dt = prow[COL["dt"]]
        rate = prow[COL["rate"]]

        def normals(step_idx):
            return normal_pair(k0, tid, paths, jnp.broadcast_to(step_idx, paths.shape))

        if batch.model_kind == "black-scholes":
            step_fn = bs_step_fn(rate, prow[COL["vol"]], dt)

            def s_of(carry):
                return carry

            carry0: Any = spot
        else:
            step_fn = heston_step_fn(rate, prow[COL["kappa"]],
                                     prow[COL["theta"]], prow[COL["xi"]],
                                     prow[COL["rho"]], dt)

            def s_of(carry):
                return carry[0]

            carry0 = (spot, jnp.full((n_paths,), prow[COL["v0"]]))

        def body(state, step_idx):
            carry, acc, mn, mx = state
            carry = step_fn(carry, normals(step_idx))
            s_new = s_of(carry)
            return (carry, acc + s_new, jnp.minimum(mn, s_new),
                    jnp.maximum(mx, s_new)), None

        state0 = (carry0, jnp.zeros_like(spot), spot, spot)
        (carry, acc, mn, mx), _ = jax.lax.scan(body, state0, steps)
        return s_of(carry), acc / jnp.float32(n_steps), mn, mx

    return jax.vmap(one_task)(batch.params, batch.task_ids)


def _batch_moments_impl(batch: TaskBatch, n_active, n_chunks, seed, *,
                        chunk_paths: int):
    """Per-task (sum payoff, sum payoff^2), masked to each task's n_active.

    Paths are simulated in fixed (T, chunk_paths) chunks inside a fori_loop
    whose bound ``n_chunks`` is a *runtime* scalar, so the compiled shape
    never depends on the requested path count — one executable serves the
    whole benchmark ladder and any execution-time shard size.  Because the
    RNG is counter-based on the global path index, chunking is invisible to
    the statistics (the same decomposition-independence price_sharded
    relies on).
    """
    record_trace("jnp_batch")
    p = batch.params
    T = batch.n_tasks
    zeros = jnp.zeros((T,), jnp.float32)

    def chunk_body(c, acc):
        sums, sqs = acc
        offset = (c * chunk_paths).astype(jnp.uint32)
        s_t, avg, mn, mx = _batch_path_stats(batch, chunk_paths, seed,
                                             path_offset=offset)
        pay = payoff_from_stats_coded(
            s_t, avg, mn, mx,
            strike=p[:, COL["strike"], None], lower=p[:, COL["lower"], None],
            upper=p[:, COL["upper"], None], payout=p[:, COL["payout"], None],
            call_sign=p[:, COL["call_sign"], None],
            kind=batch.payoff_kinds[:, None])
        pid = offset + jnp.arange(chunk_paths, dtype=jnp.uint32)
        mask = pid[None, :] < n_active[:, None]
        pay = jnp.where(mask, pay, jnp.float32(0.0))
        return sums + pay.sum(axis=1), sqs + (pay * pay).sum(axis=1)

    return jax.lax.fori_loop(0, n_chunks, chunk_body, (zeros, zeros))


_batch_moments = jax.jit(_batch_moments_impl, static_argnames=("chunk_paths",))

#: Max spread of per-task path counts co-batched into one padded launch.
#: Padding waste per task is bounded by this factor; splitting costs at
#: most one extra trace per distinct sub-batch size, which the runtime-n
#: chunk loop keeps rare.
_RAGGED_RATIO = 4


def _ragged_buckets(ns: Sequence[int]) -> list[list[int]]:
    """Partition positions of ``ns`` into buckets with max/min <= ratio.

    Greedy over ascending counts; uniform inputs (the common case) always
    yield a single bucket.  Returns lists of positions into ``ns``.
    """
    order = sorted(range(len(ns)), key=lambda k: ns[k])
    buckets: list[list[int]] = []
    bucket_min = None
    for k in order:
        n = max(int(ns[k]), 1)
        if bucket_min is None or n > bucket_min * _RAGGED_RATIO:
            buckets.append([])
            bucket_min = n
        buckets[-1].append(k)
    return buckets


def price_batch(tasks: Sequence[PricingTask], n_paths,
                seed: int = 0, backend: str = "jnp",
                block_paths: int | None = None) -> list[PriceResult]:
    """Price many tasks with one compiled launch per compilation group.

    ``n_paths`` is an int (shared by all tasks) or a per-task sequence;
    ragged path counts within a group are padded (to the next chunk for the
    jnp oracle, path block for the Pallas kernel) and masked, so every
    task's estimate uses exactly its own first ``n`` counter-based draws —
    identical in distribution to a per-task run.

    Tasks are grouped by :func:`launch_key` — (model kind, n_steps), the
    only *structural* task properties — so a full mixed Table 1 workload
    needs two compiled executables, and re-pricing any same-shaped workload
    needs none.  Within a group, wildly ragged path counts are split into
    magnitude buckets (max/min <= ``_RAGGED_RATIO``) before padding, so a
    64-path shard never simulates a co-batched task's million paths; the
    uniform-n hot paths (benchmark ladders, calibration) stay one launch.

    Returns one :class:`PriceResult` per task, in input order.
    """
    tasks = list(tasks)
    ns = np.broadcast_to(np.asarray(n_paths, dtype=np.int64), (len(tasks),))
    results: list[PriceResult | None] = [None] * len(tasks)
    for _key, group in group_by_launch(tasks):
        for bucket in _ragged_buckets([int(ns[i]) for i, _ in group]):
            sub = [group[k] for k in bucket]
            batch = TaskBatch.from_tasks([t for _, t in sub])
            n_act = np.asarray([ns[i] for i, _ in sub], dtype=np.uint32)
            if backend == "pallas":
                from repro.kernels import ops  # local import: kernels are optional

                sums, sqs = ops.mc_moments_batch(batch, n_act, seed,
                                                 block_paths=block_paths)
            else:
                n_chunks = -(-int(n_act.max()) // CHUNK_PATHS)
                sums, sqs = _batch_moments(batch, jnp.asarray(n_act),
                                           jnp.int32(n_chunks),
                                           jnp.uint32(seed),
                                           chunk_paths=CHUNK_PATHS)
            for k, (i, t) in enumerate(sub):
                results[i] = _finalize(t, sums[k], sqs[k], int(ns[i]))
    return results  # type: ignore[return-value]


def price(task: PricingTask, n_paths: int, seed: int = 0,
          backend: str = "jnp", block_paths: int | None = None) -> PriceResult:
    """Price one task — a thin wrapper over a batch of one.

    ``backend`` in {"jnp", "pallas"}.  The CI convention follows the paper:
    accuracy = *size* of the 95% interval (2 x 1.96 x stderr), in pricing
    currency.  Because task parameters are runtime operands, repeated calls
    across a task family reuse one compiled executable.
    """
    return price_batch([task], n_paths, seed=seed, backend=backend,
                       block_paths=block_paths)[0]


# --------------------------------------------------------------------------
# Distributed pricing: shard_map over a mesh axis
# --------------------------------------------------------------------------

def price_sharded(task: PricingTask, n_paths: int, mesh: Mesh,
                  axis: str = "data", seed: int = 0) -> PriceResult:
    """Split paths across ``mesh[axis]``; merge partial moments with psum.

    Because the RNG is counter-based on the *global* path index, the result
    is bit-identical in distribution to the single-device run (up to float
    reduction order) for any device count — the allocation layer may
    re-split tasks freely (eq. 5) without statistical consequences.
    """
    n_dev = mesh.shape[axis]
    if n_paths % n_dev:
        raise ValueError(f"n_paths={n_paths} not divisible by mesh[{axis}]={n_dev}")
    local = n_paths // n_dev

    def worker():
        idx = jax.lax.axis_index(axis)
        offset = (idx * local).astype(jnp.uint32)
        s, s2 = _moments(task, local, seed, path_offset=offset)
        return jax.lax.psum(s, axis), jax.lax.psum(s2, axis)

    spec = P()  # fully replicated scalars
    # Replication checking is off (see repro.compat.shard_map): the scan
    # carry starts replicated and becomes varying through the
    # axis_index-derived path offset, which the static check cannot see.
    fn = compat.shard_map(worker, mesh=mesh, in_specs=(), out_specs=(spec, spec))
    pay_sum, pay_sq = jax.jit(fn)()
    return _finalize(task, pay_sum, pay_sq, n_paths)
