"""Monte Carlo pricing engine (paper §4.1.3's F3 execution layer, in JAX).

Three backends, all drawing the *same* Threefry stream per (task, path,
step) so results agree across decompositions:

  * ``path_stats`` / ``price``          — pure jnp (lax.scan), the oracle
  * ``price(..., backend="pallas")``    — Pallas TPU kernels (repro.kernels)
  * ``price_sharded``                   — shard_map over a mesh axis; each
        device simulates a disjoint path range and partial moments are
        combined with psum (the domain's "divisible task" property,
        eq. 5, realised as data parallelism)

The engine returns the two domain metrics directly: the price estimate and
the 95% confidence interval (the *accuracy* metric, eq. 8).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.prng import normal_pair
from .contracts import BlackScholes, Heston, Option, PricingTask, payoff_from_stats

__all__ = ["path_stats", "price", "price_sharded", "PriceResult"]


# --------------------------------------------------------------------------
# Path simulation (pure jnp — this IS the oracle the kernels are tested on)
# --------------------------------------------------------------------------

def _bs_step(u: BlackScholes, dt: float):
    drift = jnp.float32((u.rate - 0.5 * u.volatility**2) * dt)
    vol = jnp.float32(u.volatility * np.sqrt(dt))

    def step(carry, inputs):
        s = carry
        z, _ = inputs
        return s * jnp.exp(drift + vol * z), s

    return step


def _heston_step(u: Heston, dt: float):
    dt32 = jnp.float32(dt)
    kappa, theta, xi = jnp.float32(u.kappa), jnp.float32(u.theta), jnp.float32(u.xi)
    rate = jnp.float32(u.rate)
    rho = jnp.float32(u.rho)
    rho_c = jnp.float32(np.sqrt(1.0 - u.rho**2))
    sqrt_dt = jnp.float32(np.sqrt(dt))

    def step(carry, inputs):
        s, v = carry
        z_s, z2 = inputs
        z_v = rho * z_s + rho_c * z2
        v_plus = jnp.maximum(v, jnp.float32(0.0))
        sqrt_v = jnp.sqrt(v_plus)
        s_new = s * jnp.exp((rate - 0.5 * v_plus) * dt32 + sqrt_v * sqrt_dt * z_s)
        v_new = v + kappa * (theta - v_plus) * dt32 + xi * sqrt_v * sqrt_dt * z_v
        return (s_new, v_new), s

    return step


def path_stats(task: PricingTask, n_paths: int, seed: int, path_offset: int = 0):
    """Simulate ``n_paths`` paths; return (s_t, avg, mn, mx), each (n_paths,).

    Conventions (shared with the kernels): the running average is over the
    n_steps post-initial observations; min/max include the initial spot.
    The RNG counter is (path_index, step); the key is (seed, task_id), so
    the draw for a given (task, path, step) is decomposition-independent.
    """
    u = task.underlying
    dt = task.maturity / task.n_steps
    paths = jnp.asarray(path_offset, jnp.uint32) + jnp.arange(n_paths, dtype=jnp.uint32)
    k0 = jnp.uint32(seed)
    k1 = jnp.uint32(task.task_id)
    steps = jnp.arange(task.n_steps, dtype=jnp.uint32)

    # Draw this step's normals from the (path, step) counter.
    def normals(step_idx):
        return normal_pair(k0, k1, paths, jnp.broadcast_to(step_idx, paths.shape))

    spot = jnp.full((n_paths,), jnp.float32(u.spot))
    if isinstance(u, BlackScholes):
        step_fn = _bs_step(u, dt)
        carry0: Any = spot
    else:
        step_fn = _heston_step(u, dt)
        carry0 = (spot, jnp.full((n_paths,), jnp.float32(u.v0)))

    def body(state, step_idx):
        carry, acc, mn, mx = state
        z = normals(step_idx)
        new_carry, _ = step_fn(carry, z)
        s_new = new_carry[0] if isinstance(new_carry, tuple) else new_carry
        acc = acc + s_new
        mn = jnp.minimum(mn, s_new)
        mx = jnp.maximum(mx, s_new)
        return (new_carry, acc, mn, mx), None

    # Carry running (sum, min, max) instead of materialising the whole
    # (n_steps, n_paths) path matrix: O(paths) memory at any path count.
    state0 = (carry0, jnp.zeros_like(spot), spot, spot)
    (carry, acc, mn, mx), _ = jax.lax.scan(body, state0, steps)
    s_t = carry[0] if isinstance(carry, tuple) else carry
    avg = acc / jnp.float32(task.n_steps)
    return s_t, avg, mn, mx


def _moments(task: PricingTask, n_paths: int, seed: int, path_offset: int = 0):
    """Partial sums (sum payoff, sum payoff^2) — the mergeable statistic."""
    s_t, avg, mn, mx = path_stats(task, n_paths, seed, path_offset)
    pay = payoff_from_stats(s_t, avg, mn, mx, task.option)
    return pay.sum(), (pay * pay).sum()


@functools.partial(dataclasses.dataclass, frozen=True)
class PriceResult:
    price: Any
    ci95: Any          # the paper's accuracy metric: size of the 95% CI
    std_error: Any
    n_paths: int

    def __repr__(self):
        return (f"PriceResult(price={float(self.price):.6f}, "
                f"ci95={float(self.ci95):.6f}, n={int(self.n_paths)})")


def _finalize(task: PricingTask, pay_sum, pay_sq, n) -> PriceResult:
    n = jnp.float32(n)
    mean = pay_sum / n
    var = jnp.maximum(pay_sq / n - mean * mean, 0.0)
    disc = jnp.float32(task.discount)
    stderr = disc * jnp.sqrt(var / n)
    return PriceResult(price=disc * mean, ci95=jnp.float32(2 * 1.96) * stderr,
                       std_error=stderr, n_paths=n)


def price(task: PricingTask, n_paths: int, seed: int = 0,
          backend: str = "jnp", block_paths: int = 1024) -> PriceResult:
    """Price one task. ``backend`` in {"jnp", "pallas"}.

    The CI convention follows the paper: accuracy = *size* of the 95%
    interval (2 x 1.96 x stderr), in pricing currency.
    """
    if backend == "pallas":
        from repro.kernels import ops  # local import: kernels are optional

        pay_sum, pay_sq = ops.mc_moments(task, n_paths, seed, block_paths=block_paths)
    else:
        # task is a frozen (hashable) dataclass: static under jit.
        pay_sum, pay_sq = jax.jit(_moments, static_argnums=(0, 1))(task, n_paths, seed)
    return _finalize(task, pay_sum, pay_sq, n_paths)


# --------------------------------------------------------------------------
# Distributed pricing: shard_map over a mesh axis
# --------------------------------------------------------------------------

def price_sharded(task: PricingTask, n_paths: int, mesh: Mesh,
                  axis: str = "data", seed: int = 0) -> PriceResult:
    """Split paths across ``mesh[axis]``; merge partial moments with psum.

    Because the RNG is counter-based on the *global* path index, the result
    is bit-identical in distribution to the single-device run (up to float
    reduction order) for any device count — the allocation layer may
    re-split tasks freely (eq. 5) without statistical consequences.
    """
    n_dev = mesh.shape[axis]
    if n_paths % n_dev:
        raise ValueError(f"n_paths={n_paths} not divisible by mesh[{axis}]={n_dev}")
    local = n_paths // n_dev

    def worker():
        idx = jax.lax.axis_index(axis)
        offset = (idx * local).astype(jnp.uint32)
        s, s2 = _moments(task, local, seed, path_offset=offset)
        return jax.lax.psum(s, axis), jax.lax.psum(s2, axis)

    spec = P()  # fully replicated scalars
    # check_vma=False: the scan carry starts replicated and becomes varying
    # through the axis_index-derived path offset, which the static VMA check
    # cannot see through.
    fn = jax.shard_map(worker, mesh=mesh, in_specs=(), out_specs=(spec, spec),
                       check_vma=False)
    pay_sum, pay_sq = jax.jit(fn)()
    return _finalize(task, pay_sum, pay_sq, n_paths)
