"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892]: attention-free, data-dependent
decay. Sub-quadratic: O(1) decode state => long_500k runs."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="rwkv",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, head_dim=64,
    subquadratic=True,
)
SMOKE = CONFIG.smoke()
