"""Whisper-tiny [arXiv:2212.04356]: enc-dec; conv mel frontend is a STUB
(input_specs supplies frame embeddings [B, 1500, 384])."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, head_dim=64,
    mlp_variant="gelu", encoder_layers=4,
    frontend_len=1500,  # 30 s of audio at 50 Hz after the conv stub
)
SMOKE = CONFIG.smoke()
