"""Snowflake Arctic [hf:Snowflake/snowflake-arctic-base]: 128-expert top-2
MoE with a parallel dense-residual MLP."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, head_dim=128,
    n_experts=128, top_k=2, dense_residual=True, mlp_variant="swiglu",
)
SMOKE = CONFIG.smoke()
