"""RecurrentGemma-9B / Griffin [arXiv:2402.19427]: RG-LRU + local MQA
(window 2048), pattern (rec, rec, attn). Sub-quadratic: O(window) cache
=> long_500k runs."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    mlp_variant="geglu", local_window=2048, d_rnn=4096,
    hybrid_pattern=("rec", "rec", "attn"),
    subquadratic=True,
)
SMOKE = CONFIG.smoke()
