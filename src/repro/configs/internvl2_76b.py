"""InternVL2-Llama3-76B [arXiv:2404.16821]: InternViT frontend (STUB:
input_specs supplies patch embeddings) + 80L GQA backbone."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    mlp_variant="swiglu", rope_theta=5e5,
    frontend_len=256,  # ViT patch tokens per image (stubbed embeddings)
)
SMOKE = CONFIG.smoke()
