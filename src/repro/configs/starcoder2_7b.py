"""StarCoder2-7B [arXiv:2402.19173]: dense GQA + RoPE, non-gated GELU MLP."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab=49152, head_dim=128,
    mlp_variant="gelu", rope_theta=1e5,
)
SMOKE = CONFIG.smoke()
