"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B]: 64-expert top-6
MoE (3B active), MHA (kv=16)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, head_dim=128,
    n_experts=64, top_k=6, mlp_variant="swiglu",
)
SMOKE = CONFIG.smoke()
