"""Minitron-8B [arXiv:2407.14679]: pruned Nemotron-4, GQA kv=8, 256k vocab."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab=256000, head_dim=128,
    mlp_variant="gelu",  # nemotron uses squared-relu; non-gated family
    rope_theta=1e4,
)
SMOKE = CONFIG.smoke()
