"""Assigned-architecture registry: ``get_config(name)`` / ``ARCHS``.

Every entry is the exact published configuration from the assignment
table; ``get_config(name).smoke()`` derives the reduced same-family config
used by the CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, SHAPES, Shape

__all__ = ["ARCHS", "get_config", "cells_for", "all_cells"]

ARCHS: tuple[str, ...] = (
    "starcoder2_7b",
    "yi_9b",
    "minitron_8b",
    "qwen25_3b",
    "rwkv6_1b6",
    "internvl2_76b",
    "whisper_tiny",
    "moonshot_v1_16b_a3b",
    "arctic_480b",
    "recurrentgemma_9b",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def cells_for(cfg: ModelConfig) -> list[Shape]:
    """The runnable (arch x shape) cells. long_500k needs sub-quadratic
    attention (skips noted in DESIGN.md §Arch-applicability); decode
    shapes need a decoder."""
    cells = []
    for shape in SHAPES.values():
        if shape.kind == "decode" and not cfg.has_decoder:
            continue
        if shape.name == "long_500k" and not cfg.subquadratic:
            continue
        cells.append(shape)
    return cells


def all_cells() -> list[tuple[str, Shape]]:
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in cells_for(cfg):
            out.append((arch, shape))
    return out
