"""Yi-9B [arXiv:2403.04652]: llama-architecture GQA, SwiGLU."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64000, head_dim=128,
    mlp_variant="swiglu", rope_theta=1e4,
)
SMOKE = CONFIG.smoke()
