"""Qwen2.5-3B [hf:Qwen/Qwen2.5]: GQA kv=2 with QKV bias, SwiGLU, 152k vocab."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab=151936, head_dim=128,
    qkv_bias=True, mlp_variant="swiglu", rope_theta=1e6,
)
SMOKE = CONFIG.smoke()
