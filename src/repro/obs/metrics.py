"""Process-wide metrics registry: counters, gauges, P²-backed histograms.

Complements span tracing (:mod:`repro.obs.trace`) with the aggregate
view: counts of records/faults/retries/sheds, gauges for brownout rung
and backlog, and latency/solve-time histograms whose quantiles come from
the same streaming P² estimators the SLO tracker uses
(:class:`repro.core.slo.P2Quantile` — O(1) memory, no sample buffers).

Snapshots serialise through the existing JSONL record stream:
:class:`MetricSnapshot` is registered with :mod:`repro.runtime.records`,
so ``dump_records(path, registry.snapshot())`` round-trips like any
fault/record stream. The discriminator field is ``metric`` (``counter`` /
``gauge`` / ``histogram``) — ``kind`` is reserved by the record codec.
"""
from __future__ import annotations

import dataclasses
import math
import threading

from repro.core.slo import P2Quantile

__all__ = ["Counter", "Gauge", "Histogram", "MetricSnapshot",
           "MetricsRegistry", "REGISTRY", "counter", "gauge", "histogram"]


def _finite(x: float) -> float | None:
    """JSON-safe: non-finite stats become None rather than NaN tokens."""
    return float(x) if isinstance(x, (int, float)) and math.isfinite(x) \
        else None


@dataclasses.dataclass(frozen=True)
class MetricSnapshot:
    """One metric's state at a point in time, JSONL-persistable."""
    name: str
    metric: str          # "counter" | "gauge" | "histogram"
    value: float         # count / gauge level / observation count
    at: float = 0.0      # caller-supplied timestamp (seconds)
    stats: dict = dataclasses.field(default_factory=dict)


class Counter:
    """Monotone event count."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self, at: float = 0.0) -> MetricSnapshot:
        return MetricSnapshot(self.name, "counter", self._value, at)


class Gauge:
    """Last-write-wins level (brownout rung, backlog seconds, ...)."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, dv: float) -> None:
        with self._lock:
            self._value += dv

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self, at: float = 0.0) -> MetricSnapshot:
        return MetricSnapshot(self.name, "gauge", self._value, at)


class Histogram:
    """Streaming distribution: count/mean/min/max plus P² p50/p95/p99."""

    QS = (0.5, 0.95, 0.99)

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._q = {q: P2Quantile(q) for q in self.QS}
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, x: float) -> None:
        x = float(x)
        if not math.isfinite(x):
            return
        with self._lock:
            self._count += 1
            self._sum += x
            self._min = min(self._min, x)
            self._max = max(self._max, x)
            for est in self._q.values():
                est.observe(x)

    @property
    def count(self) -> int:
        return self._count

    def stats(self) -> dict:
        with self._lock:
            if self._count == 0:
                return {"count": 0}
            out = {"count": self._count,
                   "mean": _finite(self._sum / self._count),
                   "min": _finite(self._min), "max": _finite(self._max)}
            for q, est in self._q.items():
                out[f"p{int(q * 100)}"] = _finite(est.value())
            return out

    def snapshot(self, at: float = 0.0) -> MetricSnapshot:
        return MetricSnapshot(self.name, "histogram", float(self._count),
                              at, self.stats())


class MetricsRegistry:
    """Get-or-create registry; one instance (:data:`REGISTRY`) serves the
    whole process, mirroring how production metric libraries work."""

    _TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, kind: str, name: str):
        cls = self._TYPES[kind]
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get("counter", name)

    def gauge(self, name: str) -> Gauge:
        return self._get("gauge", name)

    def histogram(self, name: str) -> Histogram:
        return self._get("histogram", name)

    def snapshot(self, at: float = 0.0) -> list[MetricSnapshot]:
        """Every metric's current state, ready for ``dump_records``."""
        with self._lock:
            metrics = sorted(self._metrics.values(), key=lambda m: m.name)
        return [m.snapshot(at) for m in metrics]

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


#: the process-wide registry used by the instrumented runtime.
REGISTRY = MetricsRegistry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)
