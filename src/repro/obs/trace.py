"""Span tracing with dual clocks, exported as Chrome trace-event JSON.

The runtime's phases — characterise, solve (with the PR 7 per-phase
``build_s``/``solve_s``/``polish_s`` meta lifted into real spans), dispatch
per platform, online rounds, probes, re-fits — become *spans*: named
intervals on named tracks. Two clocks ride on every dispatch span:

* the **wall clock** (``time.perf_counter`` relative to the tracer epoch)
  is what the span's ``ts``/``dur`` encode — true host concurrency, so a
  Perfetto timeline shows per-platform work genuinely overlapping;
* the **virtual clock** (the platform's replayed-latency cumulative time,
  the mode-parity-safe quantity everything else in the runtime keys on)
  rides in the span ``args`` (``virt0``/``virt1``) when the caller
  supplies it via :meth:`Span.set_virtual`.

Spans are thread-safe and *propagate through Executor jobs*: each thread
keeps its own open-span stack (``threading.local``), so a dispatch span
opened inside a pool thread nests its launch-group children correctly
while sibling platforms overlap on their own tracks. Export is the Chrome
trace-event JSON array format (``B``/``E`` duration events plus ``i``
instants and ``M`` thread-name metadata, one ``tid`` per track), which
loads directly in Perfetto / ``chrome://tracing``.

Everything is off by default and zero-dependency: a disabled tracer's
:meth:`Tracer.span` returns a shared no-op context manager (no allocation,
no lock), so instrumented code paths cost nothing measurable when tracing
is off. ``REPRO_TRACE=1`` enables the process-default tracer and registers
an atexit hook that writes ``REPRO_TRACE_PATH`` (default
``repro_trace.json``); ``Scheduler(trace=...)`` scopes a tracer to one
scheduler instead.
"""
from __future__ import annotations

import atexit
import json
import os
import threading
import time
from math import inf, isfinite

__all__ = [
    "Span", "Tracer", "default_tracer", "set_default_tracer",
    "resolve_tracer", "env_enabled", "lift_solver_phases",
    "validate_chrome_trace", "render_span_tree",
]

#: solver meta keys lifted into per-phase spans (PR 7 telemetry).
PHASE_KEYS = ("build_s", "solve_s", "polish_s")


def env_enabled() -> bool:
    """True when the ``REPRO_TRACE`` environment variable opts in."""
    return os.environ.get("REPRO_TRACE", "").strip().lower() not in (
        "", "0", "false", "off", "no")


class Span:
    """One interval on a track; also its own context manager.

    ``args`` is a plain mutable dict the instrumented code may annotate
    while the span is open (record counts, fault counts, ...); wall-time
    values must stay out of it — the concurrent==sequential span parity
    contract compares args bitwise across executor modes.
    """

    __slots__ = ("name", "track", "cat", "t0", "t1", "args",
                 "_tracer", "_seq0", "_seq1")

    def __init__(self, tracer: "Tracer", name: str, track: str, cat: str,
                 args: dict):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.cat = cat
        self.args = args
        self.t0 = self.t1 = 0.0
        self._seq0 = self._seq1 = 0

    def set_virtual(self, v0, v1) -> None:
        """Attach the platform virtual-clock endpoints to the span."""
        if v0 is not None:
            self.args["virt0"] = float(v0)
        if v1 is not None:
            self.args["virt1"] = float(v1)

    def __enter__(self) -> "Span":
        self.t0, self._seq0 = self._tracer._open(self)
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._close(self)
        return False


class _NullSpan:
    """Shared no-op span handed out by disabled tracers.

    ``args`` is one shared dict (instrumentation keys are a small fixed
    vocabulary, so it stays bounded); nothing written here is ever read.
    """

    __slots__ = ()
    args: dict = {}

    def set_virtual(self, v0, v1) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Thread-safe span collector with Chrome trace-event export."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._local = threading.local()
        self._seq = 0
        #: finished spans, in close order.
        self.spans: list[Span] = []
        #: (name, track, cat, ts, seq, args) instant events.
        self.instants: list[tuple] = []

    # -- clock -------------------------------------------------------------

    def now(self) -> float:
        """Seconds since the tracer epoch (host wall clock)."""
        return time.perf_counter() - self._epoch

    def _next_seq(self) -> int:
        with self._lock:
            self._seq += 1
            return self._seq

    # -- recording ---------------------------------------------------------

    def span(self, name: str, track: str = "main", cat: str = "runtime",
             **args):
        """Open a span as a context manager; no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, track, cat, dict(args))

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _open(self, span: Span) -> tuple[float, int]:
        self._stack().append(span)
        return self.now(), self._next_seq()

    def _close(self, span: Span) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        span.t1 = max(self.now(), span.t0)
        span._seq1 = self._next_seq()
        with self._lock:
            self.spans.append(span)

    def current(self) -> Span | _NullSpan:
        """The innermost span open on *this* thread (the null span when
        none is, so callers may annotate unconditionally)."""
        st = getattr(self._local, "stack", None)
        return st[-1] if st else _NULL_SPAN

    def add_span(self, name: str, track: str, t0: float, t1: float,
                 cat: str = "runtime", args: dict | None = None) -> None:
        """Record a span with explicit endpoints (seconds since epoch) —
        how retrospective intervals (solver phase meta, whole rounds) are
        lifted into the trace after the fact."""
        if not self.enabled:
            return
        span = Span(self, name, track, cat, dict(args or {}))
        span.t0 = float(t0)
        span.t1 = max(float(t1), span.t0)
        span._seq0 = self._next_seq()
        span._seq1 = self._next_seq()
        with self._lock:
            self.spans.append(span)

    def instant(self, name: str, track: str = "main", cat: str = "event",
                **args) -> None:
        """Record a point event (fault, shed, breaker/brownout move)."""
        if not self.enabled:
            return
        ts, seq = self.now(), self._next_seq()
        with self._lock:
            self.instants.append((name, track, cat, ts, seq, dict(args)))

    # -- export ------------------------------------------------------------

    def chrome_events(self) -> list[dict]:
        """Chrome trace-event dicts: thread-name metadata first, then the
        B/E/i stream with globally monotone ``ts`` and balanced, properly
        nested B/E per tid.

        Ordering comes from span *geometry*, not emission order: spans are
        lifted into the trace retroactively (solver phases, whole rounds)
        so a parent can be recorded after its children. Each track is
        swept with an interval stack — spans sorted by
        ``(t0, -t1, seq)`` so enclosing spans open first, closes emitted
        lazily when the next span starts past them — which yields a valid
        nesting even at exactly-equal boundary timestamps."""
        with self._lock:
            spans = list(self.spans)
            instants = list(self.instants)
        tids: dict[str, int] = {}

        def tid(track: str) -> int:
            if track not in tids:
                tids[track] = len(tids) + 1
            return tids[track]

        by_track: dict[str, list[Span]] = {}
        for s in spans:
            tid(s.track)
            by_track.setdefault(s.track, []).append(s)
        for name, track, cat, ts, seq, args in instants:
            tid(track)

        raw: list[tuple[float, int, dict]] = []
        order = 0  # per-emission tiebreak; per-tid order is preserved
        for track, group in by_track.items():
            t = tids[track]
            group.sort(key=lambda s: (s.t0, -s.t1, s._seq0))
            stack: list[Span] = []
            cursor = 0.0  # monotone floor: a clamped E never rewinds ts

            def emit(ph: str, s: Span, ts: float) -> float:
                nonlocal order, cursor
                cursor = max(ts, cursor)
                ev = {"name": s.name, "cat": s.cat, "ph": ph,
                      "pid": 1, "tid": t}
                if ph == "E":
                    ev["args"] = dict(s.args)
                order += 1
                raw.append((cursor, order, ev))
                return cursor

            for s in group:
                while stack and stack[-1].t1 <= s.t0:
                    top = stack.pop()
                    emit("E", top, top.t1)
                emit("B", s, s.t0)
                stack.append(s)
            while stack:
                top = stack.pop()
                emit("E", top, top.t1)
        for name, track, cat, ts, seq, args in instants:
            order += 1
            raw.append((ts, order, {"name": name, "cat": cat, "ph": "i",
                                    "s": "t", "pid": 1, "tid": tids[track],
                                    "args": dict(args)}))
        raw.sort(key=lambda ev: (ev[0], ev[1]))
        out = [{"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
                "args": {"name": "repro"}}]
        for track, t in sorted(tids.items(), key=lambda kv: kv[1]):
            out.append({"ph": "M", "name": "thread_name", "pid": 1,
                        "tid": t, "args": {"name": track}})
        for ts, _seq, ev in raw:
            ev["ts"] = round(ts * 1e6, 3)  # microseconds, Perfetto's unit
            out.append(ev)
        return out

    def chrome_trace(self) -> dict:
        return {"traceEvents": self.chrome_events(),
                "displayTimeUnit": "ms"}

    def write(self, path: str | os.PathLike) -> str:
        """Dump the Chrome trace JSON; returns the path written."""
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh)
        return os.fspath(path)

    # -- parity ------------------------------------------------------------

    def parity_keys(self) -> list[tuple]:
        """The mode-parity view of the trace: every span/instant as
        (track, name, cat, sorted args) with wall-clock-valued keys
        (``*_s``) dropped — virtual clocks, counts and rounds stay, and
        the multiset must be bitwise identical across executor modes."""
        def canon(args: dict) -> tuple:
            return tuple(sorted((k, repr(v)) for k, v in args.items()
                                if not k.endswith("_s")))
        with self._lock:
            keys = [(s.track, s.name, s.cat, canon(s.args))
                    for s in self.spans]
            keys += [(track, name, cat, canon(args))
                     for name, track, cat, _ts, _seq, args in self.instants]
        return sorted(keys)


# --------------------------------------------------------------------------
# Process-default tracer (the REPRO_TRACE=1 path)
# --------------------------------------------------------------------------

_DEFAULT: Tracer | None = None
_DEFAULT_LOCK = threading.Lock()


def _write_default() -> None:  # pragma: no cover - exercised via examples
    t = _DEFAULT
    if t is None or not t.enabled or not (t.spans or t.instants):
        return
    path = os.environ.get("REPRO_TRACE_PATH", "repro_trace.json")
    t.write(path)
    from .log import get_logger
    get_logger("obs.trace").info(
        "trace: %d spans on %d tracks written to %s (load in Perfetto / "
        "chrome://tracing)", len(t.spans),
        len({s.track for s in t.spans}), path)


def default_tracer() -> Tracer:
    """The process tracer: enabled iff ``REPRO_TRACE`` opts in, created
    (and its atexit writer registered) on first use."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                t = Tracer(enabled=env_enabled())
                if t.enabled:
                    atexit.register(_write_default)
                _DEFAULT = t
    return _DEFAULT


def set_default_tracer(tracer: Tracer | None) -> None:
    """Replace the process tracer (tests; embedding)."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        _DEFAULT = tracer


def resolve_tracer(trace) -> Tracer:
    """The ``Scheduler(trace=...)`` contract: a :class:`Tracer` is used
    as-is, ``True``/``False`` force a fresh enabled/disabled tracer, and
    ``None`` defers to the process default (``REPRO_TRACE``)."""
    if isinstance(trace, Tracer):
        return trace
    if trace is None:
        return default_tracer()
    return Tracer(enabled=bool(trace))


# --------------------------------------------------------------------------
# Lifting solver phase meta into spans
# --------------------------------------------------------------------------

def lift_solver_phases(tracer: Tracer, meta: dict, t1: float, *,
                       label: str = "solve", track: str = "solver",
                       depth: int = 0) -> None:
    """Turn an :class:`~repro.core.Allocation`'s per-phase meta timings
    (``build_s``/``solve_s``/``polish_s``, PR 7) into spans ending at
    ``t1``. Nested inner-solver meta (``meta["inner"]`` from clustered /
    incremental solves) recurses one track level down, laid inside the
    parent window.
    """
    if not tracer.enabled or not isinstance(meta, dict):
        return
    phases = [(k[:-2], float(meta.get(k) or 0.0)) for k in PHASE_KEYS]
    total = sum(d for _n, d in phases)
    extra = sum(float(meta.get(k) or 0.0)
                for k in ("cluster_s", "patch_s"))
    t0 = t1 - total - extra
    counts = {k: meta[k] for k in ("n_vars", "n_constraints", "n_clusters",
                                   "warm_start", "incremental", "status")
              if k in meta}
    tracer.add_span(label, track, t0, t1, cat="solver", args=counts)
    cur = t0 + extra  # clustering/patch bookkeeping precedes the phases
    for name, dur in phases:
        if dur > 0.0:
            tracer.add_span(name, track, cur, cur + dur, cat="solver")
            cur += dur
    inner = meta.get("inner")
    if depth < 2 and inner:
        inners = inner if isinstance(inner, list) else [inner]
        for i, m in enumerate(inners):
            if isinstance(m, dict):
                itot = (sum(float(m.get(k) or 0.0) for k in PHASE_KEYS)
                        or (t1 - t0) / max(len(inners), 1))
                lift_solver_phases(
                    tracer, m, min(t0 + extra + (i + 1) * itot, t1),
                    label=f"{label}.inner[{i}]", track=f"{track}.inner",
                    depth=depth + 1)


# --------------------------------------------------------------------------
# Validation + text rendering (shared by tests, CI and trace_report)
# --------------------------------------------------------------------------

def validate_chrome_trace(events: list[dict]) -> dict:
    """Validate a Chrome trace-event list: required keys on every event,
    globally monotone ``ts``, and balanced, properly-nested B/E per tid.
    Raises :class:`ValueError` on the first violation; returns summary
    counts on success."""
    if not isinstance(events, list) or not events:
        raise ValueError("trace must be a non-empty event list")
    stacks: dict[int, list[str]] = {}
    last_ts = -inf
    n_spans = n_instants = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object: {ev!r}")
        ph = ev.get("ph")
        if ph not in ("B", "E", "i", "M"):
            raise ValueError(f"event {i} has unknown ph {ph!r}")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"event {i} has no name")
        if ph == "M":
            continue
        for key in ("ts", "pid", "tid"):
            if not isinstance(ev.get(key), (int, float)):
                raise ValueError(f"event {i} ({ev['name']!r}) missing {key}")
        ts = float(ev["ts"])
        if not isfinite(ts) or ts < 0.0:
            raise ValueError(f"event {i} has bad ts {ts!r}")
        if ts < last_ts:
            raise ValueError(
                f"event {i} ({ev['name']!r}) ts {ts} < previous {last_ts}: "
                f"ts not monotone")
        last_ts = ts
        stack = stacks.setdefault(int(ev["tid"]), [])
        if ph == "B":
            stack.append(ev["name"])
            n_spans += 1
        elif ph == "E":
            if not stack:
                raise ValueError(
                    f"event {i}: E {ev['name']!r} with no open B on "
                    f"tid {ev['tid']}")
            top = stack.pop()
            if top != ev["name"]:
                raise ValueError(
                    f"event {i}: E {ev['name']!r} closes open span "
                    f"{top!r} on tid {ev['tid']} (bad nesting)")
        else:
            n_instants += 1
    open_left = {tid: st for tid, st in stacks.items() if st}
    if open_left:
        raise ValueError(f"unbalanced B/E: still open {open_left}")
    return {"events": len(events), "spans": n_spans,
            "instants": n_instants, "tracks": len(stacks)}


def render_span_tree(events: list[dict]) -> str:
    """Render a validated event list as an indented per-track span tree
    with wall durations — the ``examples/trace_report.py`` view."""
    names: dict[int, str] = {}
    by_tid: dict[int, list[dict]] = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[int(ev["tid"])] = ev["args"]["name"]
        elif ev.get("ph") in ("B", "E", "i"):
            by_tid.setdefault(int(ev["tid"]), []).append(ev)
    lines: list[str] = []
    for tid in sorted(by_tid):
        lines.append(f"{names.get(tid, f'track {tid}')}")
        stack: list[tuple[str, float]] = []
        for ev in by_tid[tid]:
            indent = "  " * (len(stack) + 1)
            if ev["ph"] == "B":
                stack.append((ev["name"], float(ev["ts"])))
            elif ev["ph"] == "E":
                name, ts0 = stack.pop()
                indent = "  " * (len(stack) + 1)
                dur_ms = (float(ev["ts"]) - ts0) / 1e3
                args = ev.get("args") or {}
                note = ", ".join(f"{k}={_fmt(v)}" for k, v in args.items())
                lines.append(f"{indent}{name:<24s} {dur_ms:9.3f} ms"
                             + (f"  ({note})" if note else ""))
            else:
                args = ev.get("args") or {}
                note = ", ".join(f"{k}={_fmt(v)}" for k, v in args.items())
                lines.append(f"{indent}* {ev['name']}"
                             + (f"  ({note})" if note else ""))
    return "\n".join(lines)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
