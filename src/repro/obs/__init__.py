"""Observability layer: span tracing, metrics, prediction ledger, logging.

Zero-dependency and off by default. Enable tracing per scheduler with
``Scheduler(trace=True)`` (or pass a :class:`Tracer`), or process-wide
with ``REPRO_TRACE=1`` — the default tracer then writes a Perfetto-ready
Chrome trace JSON (``REPRO_TRACE_PATH``, default ``repro_trace.json``)
at exit. The :class:`PredictionLedger` rides the same switch and streams
the paper's within-10% prediction claim live.
"""
from .ledger import LedgerEntry, PredictionLedger, relative_error
from .log import get_logger
from .metrics import (REGISTRY, Counter, Gauge, Histogram, MetricSnapshot,
                      MetricsRegistry, counter, gauge, histogram)
from .trace import (Span, Tracer, default_tracer, env_enabled,
                    lift_solver_phases, render_span_tree, resolve_tracer,
                    set_default_tracer, validate_chrome_trace)

__all__ = [
    "Span", "Tracer", "default_tracer", "env_enabled", "resolve_tracer",
    "set_default_tracer", "lift_solver_phases", "validate_chrome_trace",
    "render_span_tree",
    "Counter", "Gauge", "Histogram", "MetricSnapshot", "MetricsRegistry",
    "REGISTRY", "counter", "gauge", "histogram",
    "LedgerEntry", "PredictionLedger", "relative_error",
    "get_logger",
]
