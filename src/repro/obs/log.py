"""``repro``-namespaced logging so library code never calls ``print``.

Everything under the ``repro`` logger hierarchy goes to stdout with a
message-only format by default (so converted call sites look exactly
like the prints they replace), at level ``REPRO_LOG_LEVEL`` (default
``INFO``). Applications that want timestamps/routing can attach their
own handlers to the ``repro`` logger and the defaults step aside.
"""
from __future__ import annotations

import logging
import os
import sys

__all__ = ["get_logger", "setup"]

_CONFIGURED = False


def setup(level: str | int | None = None, stream=None) -> logging.Logger:
    """Idempotently configure the ``repro`` root logger.

    A plain ``StreamHandler(sys.stdout)`` with a ``%(message)s`` format
    keeps example stdout byte-identical to the old prints; the level
    comes from ``REPRO_LOG_LEVEL`` unless given explicitly.
    """
    global _CONFIGURED
    root = logging.getLogger("repro")
    if not _CONFIGURED:
        if not root.handlers:
            handler = logging.StreamHandler(stream or sys.stdout)
            handler.setFormatter(logging.Formatter("%(message)s"))
            root.addHandler(handler)
        root.propagate = False
        _CONFIGURED = True
    if level is None:
        level = os.environ.get("REPRO_LOG_LEVEL", "INFO")
    if isinstance(level, str):
        level = level.upper()
    root.setLevel(level)
    return root


def get_logger(name: str = "") -> logging.Logger:
    """A logger under the configured ``repro`` namespace."""
    setup()
    if not name:
        return logging.getLogger("repro")
    if name == "repro" or name.startswith("repro."):
        return logging.getLogger(name)
    return logging.getLogger(f"repro.{name}")
