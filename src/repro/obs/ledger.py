"""Prediction-accountability ledger: every prediction meets its outcome.

The paper's §5 claim — metric models predict makespan and accuracy
"generally within 10% of the run-time performance" — is only checkable
post-hoc in the bench JSON today. The ledger makes it a *live* metric:
each time the runtime acts on a solver prediction (per-record latency,
whole-run makespan, delivered accuracy CI) the instrumented paths call
:meth:`PredictionLedger.observe` with the matching measurement, keyed by
(platform, task family, round). Re-solves, degradation rungs and
brownout transitions simply keep observing under later round indices, so
the error stream spans the whole adaptive trajectory.

Relative error uses the same zero-measured convention as
``RuntimeReport.makespan_error``: ``inf`` when the measured value is
zero (e.g. an all-shed open-loop round), never a ``ZeroDivisionError``.
Infinite errors are tallied separately (they would poison the P² marker
state) and count against the within-tolerance fraction.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from collections import deque

from repro.core.slo import P2Quantile

__all__ = ["LedgerEntry", "PredictionLedger", "relative_error"]


def relative_error(predicted: float, measured: float) -> float:
    """|predicted - measured| / |measured|; ``inf`` when measured == 0
    and predicted != 0; 0.0 when both are zero."""
    if measured == 0.0:
        return 0.0 if predicted == 0.0 else math.inf
    return abs(predicted - measured) / abs(measured)


@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    """One prediction paired with its measured outcome."""
    phase: str        # "latency" | "makespan" | "accuracy"
    platform: str
    family: str       # task launch-key family ("-" when not applicable)
    round: int        # online round index; -1 for whole-run entries
    predicted: float
    measured: float

    @property
    def error(self) -> float:
        return relative_error(self.predicted, self.measured)


class _ErrorStream:
    """Streaming error stats for one (phase[, platform]) bucket."""

    QS = (0.5, 0.9, 0.99)
    #: flush the pending buffer into the P2 markers at this size even
    #: without a query, bounding memory on very long runs.
    FLUSH_AT = 4096

    def __init__(self, tol: float, qs: tuple = QS):
        self.tol = tol
        self.count = 0
        self.inf_count = 0
        self.within_count = 0
        self.max_error = 0.0
        self._q = {q: P2Quantile(q) for q in qs}
        #: errors not yet folded into the P2 markers. observe() sits on
        #: the per-record hot path of instrumented runs, so it only bumps
        #: counters and appends here; the marker updates are amortised
        #: into the (rare) quantile queries.
        self._pending: list[float] = []

    def observe(self, err: float) -> None:
        self.count += 1
        if not math.isfinite(err):
            self.inf_count += 1
            return
        if err <= self.tol:
            self.within_count += 1
        if err > self.max_error:
            self.max_error = err
        self._pending.append(err)
        if len(self._pending) >= self.FLUSH_AT:
            self._flush()

    def _flush(self) -> None:
        if self._pending:
            for est in self._q.values():
                for err in self._pending:
                    est.observe(err)
            self._pending.clear()

    def quantiles(self) -> dict:
        self._flush()
        out = {}
        for q, est in self._q.items():
            v = est.value()
            out[f"p{int(q * 100)}"] = float(v) if math.isfinite(v) else None
        return out

    def summary(self) -> dict:
        return {"count": self.count, "inf_errors": self.inf_count,
                f"within_{int(self.tol * 100)}pct":
                    (self.within_count / self.count) if self.count else None,
                "max_error": self.max_error if self.count > self.inf_count
                    else None,
                **self.quantiles()}


class PredictionLedger:
    """Thread-safe ledger of prediction-vs-measurement pairs.

    Keeps the most recent ``max_entries`` raw entries (for reports and
    JSONL export) plus O(1)-memory streaming error stats per phase and
    per (phase, platform) — the live within-10% view.
    """

    def __init__(self, tol: float = 0.1, max_entries: int = 50_000):
        self.tol = tol
        self._lock = threading.Lock()
        self._entries: deque[LedgerEntry] = deque(maxlen=max_entries)
        self._phases: dict[str, _ErrorStream] = {}
        self._plat: dict[tuple[str, str], _ErrorStream] = {}

    def observe(self, phase: str, platform: str, family: str,
                round_idx: int, predicted: float,
                measured: float) -> LedgerEntry:
        entry = LedgerEntry(phase, platform, family, int(round_idx),
                            float(predicted), float(measured))
        err = entry.error
        with self._lock:
            self._entries.append(entry)
            st = self._phases.get(phase)
            if st is None:
                st = self._phases[phase] = _ErrorStream(self.tol)
            st.observe(err)
            key = (phase, platform)
            pst = self._plat.get(key)
            if pst is None:
                # per-platform buckets only ever report p50 + within, so
                # they carry one P2 marker set — observe() sits on the
                # per-record hot path of instrumented runs
                pst = self._plat[key] = _ErrorStream(self.tol, qs=(0.5,))
            pst.observe(err)
        return entry

    # -- queries -----------------------------------------------------------

    def entries(self, phase: str | None = None) -> list[LedgerEntry]:
        with self._lock:
            es = list(self._entries)
        return es if phase is None else [e for e in es if e.phase == phase]

    @property
    def count(self) -> int:
        return sum(st.count for st in self._phases.values())

    def error_quantiles(self, phase: str) -> dict:
        """{"p50": ..., "p90": ..., "p99": ...} for one phase (None when
        the phase has no finite errors yet)."""
        with self._lock:
            st = self._phases.get(phase)
            return st.quantiles() if st is not None else \
                {"p50": None, "p90": None, "p99": None}

    def within(self, phase: str, tol: float | None = None) -> float:
        """Fraction of ``phase`` entries with error <= tol (infinite
        errors count as misses). NaN when the phase is empty."""
        tol = self.tol if tol is None else tol
        es = self.entries(phase)
        if not es:
            return math.nan
        hits = sum(1 for e in es
                   if math.isfinite(e.error) and e.error <= tol)
        return hits / len(es)

    def summary(self) -> dict:
        """Per-phase streaming error summary (the live §5 scoreboard)."""
        with self._lock:
            return {phase: st.summary()
                    for phase, st in sorted(self._phases.items())}

    def platform_summary(self, phase: str) -> dict:
        with self._lock:
            return {plat: st.summary()
                    for (ph, plat), st in sorted(self._plat.items())
                    if ph == phase}

    # -- rendering ---------------------------------------------------------

    def render(self) -> str:
        """Text scoreboard for ``examples/trace_report.py``."""
        lines = ["prediction ledger (predicted vs measured, relative "
                 f"error, tol {self.tol:.0%})"]
        summ = self.summary()
        if not summ:
            return lines[0] + "\n  (empty)"
        wkey = f"within_{int(self.tol * 100)}pct"
        lines.append(f"  {'phase':<10s} {'n':>6s} {'p50':>8s} {'p90':>8s} "
                     f"{'p99':>8s} {'within':>7s} {'inf':>4s}")
        for phase, st in summ.items():
            lines.append(
                f"  {phase:<10s} {st['count']:>6d}"
                f" {_pct(st['p50']):>8s} {_pct(st['p90']):>8s}"
                f" {_pct(st['p99']):>8s} {_pct(st[wkey]):>7s}"
                f" {st['inf_errors']:>4d}")
        plat = self.platform_summary("latency")
        if plat:
            lines.append("  latency by platform:")
            for name, st in plat.items():
                lines.append(f"    {name:<22s} n={st['count']:<5d} "
                             f"p50 {_pct(st['p50'])}  "
                             f"within {_pct(st[wkey])}")
        return "\n".join(lines)


def _pct(v) -> str:
    return "-" if v is None else f"{v:.1%}"
