"""AdamW on flat param dicts, with cosine schedule and global-norm clip.

Optimizer moments are stored in float32 and inherit each parameter's
PartitionSpec, so under the FSDP x TP weight sharding the optimizer state
is fully sharded (ZeRO) with no extra machinery.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "cosine_schedule", "global_norm", "clip_by_global_norm"]

Params = dict[str, jnp.ndarray]


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype),
                        tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Any = 3e-4                 # float or callable(step) -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Params) -> dict:
        zeros = {k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()}
        return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
                "step": jnp.asarray(0, jnp.int32)}

    def state_specs(self, param_specs: dict) -> dict:
        from jax.sharding import PartitionSpec as P
        return {"m": dict(param_specs), "v": dict(param_specs), "step": P()}

    def update(self, grads: Params, state: dict, params: Params):
        step = state["step"] + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        new_m, new_v, new_p = {}, {}, {}
        for k, g in grads.items():
            g32 = g.astype(jnp.float32)
            m = b1 * state["m"][k] + (1 - b1) * g32
            v = b2 * state["v"][k] + (1 - b2) * g32 * g32
            mhat = m / bc1
            vhat = v / bc2
            upd = mhat / (jnp.sqrt(vhat) + self.eps)
            p32 = params[k].astype(jnp.float32)
            decay = self.weight_decay if params[k].ndim >= 2 else 0.0
            p32 = p32 - lr * (upd + decay * p32)
            new_m[k], new_v[k] = m, v
            new_p[k] = p32.astype(params[k].dtype)
        return new_p, {"m": new_m, "v": new_v, "step": step}, {
            "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
