"""Three-term roofline from dry-run artifacts (TPU v5e targets).

    compute    = FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory     = HBM bytes / (chips x 819e9 B/s)
    collective = collective bytes / (chips x 50e9 B/s ICI)

FLOPs / bytes / collective-bytes are reconstructed from single-layer
probes x static trip counts (see repro.launch.probes for why the full-HLO
numbers cannot be used: scan bodies are counted once). Probe cost numbers
from XLA are per-*program*; under SPMD the program is the per-device
shard, so terms come out per device and the chip count divides only into
the MODEL_FLOPS utilisation ratio.
"""
from __future__ import annotations

import dataclasses

__all__ = ["HW", "RooflineTerms", "analyze", "format_table"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12   # bf16 / chip
    hbm_bw: float = 819e9        # B/s / chip
    ici_bw: float = 50e9         # B/s / link (conservative single-link)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float          # reconstructed, per device
    chips: int
    microbatches: int

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound = max term (perfect overlap) — we report
        the max; the sum is the zero-overlap bound."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs across chips — remat/redundancy."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """model FLOPs / (chips x peak x step_time) — roofline fraction."""
        denom = self.chips * HW().peak_flops * self.step_time_s
        return self.model_flops / denom if denom else 0.0


def _probe_totals(probes: dict) -> tuple[float, float, float]:
    flops = bytes_ = coll = 0.0
    for name, p in probes.items():
        if not isinstance(p, dict) or "multiplier" not in p:
            continue
        m = p["multiplier"]
        flops += p.get("flops", 0.0) * m
        bytes_ += p.get("bytes", 0.0) * m
        coll += p.get("coll_bytes", 0.0) * m
    return flops, bytes_, coll


def analyze(stats, chips: int, hw: HW = HW()) -> RooflineTerms:
    """stats: CellStats (or its to_json dict)."""
    if not isinstance(stats, dict):
        stats = stats.to_json()
    flops, bytes_, coll = _probe_totals(stats.get("probes", {}))
    # outside-the-scan residue from the full program (embedding transfers,
    # final collectives) — counted once, which is exactly its trip count.
    coll += stats.get("full_collective_bytes", 0)
    return RooflineTerms(
        arch=stats["arch"], shape=stats["shape"], mesh=stats["mesh"],
        compute_s=flops / hw.peak_flops,
        memory_s=bytes_ / hw.hbm_bw,
        collective_s=coll / hw.ici_bw,
        model_flops=stats.get("model_flops", 0.0),
        hlo_flops=flops,
        chips=chips,
        microbatches=stats.get("microbatches", 1),
    )


def format_table(rows: list[RooflineTerms]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':9s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'bound':>10s} "
           f"{'MFU':>7s} {'useful':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.mesh:9s} {r.compute_s:10.4g} "
            f"{r.memory_s:10.4g} {r.collective_s:10.4g} {r.bottleneck:>10s} "
            f"{r.mfu:7.2%} {r.useful_flops_ratio:7.2%}")
    return "\n".join(lines)
