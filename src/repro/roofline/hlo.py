"""Post-SPMD HLO parsing: collective payload bytes per op class.

``compiled.as_text()`` is the per-device program after GSPMD partitioning
(shapes are local shards; collectives are explicit ops). For every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
(including async -start forms) we take

    payload = max(result bytes, largest operand bytes)

as the per-device traffic proxy (all-gather's result and reduce-scatter's
operand are the "big end" of the transfer; for all-reduce both ends match).

NOTE (scan bodies): ops inside while loops are counted ONCE by this parse,
exactly like XLA's cost analysis. The roofline therefore never reads the
full-model HLO for per-layer terms — it scales single-layer *probe* HLOs
by the known layer/microbatch multipliers (see repro.launch.probes), and
uses the full-model parse only for the outside-the-scan residue.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "parse_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[16,128]{1,0}  or  bf16[4,8,128]  or (tuples handled per-element)
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict[str, list[int]]:
    """op-kind -> list of per-op payload bytes (per device)."""
    out: dict[str, list[int]] = defaultdict(list)
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:60]:
            continue
        result_types, kind = m.group(1), m.group(2)
        result_bytes = _shape_bytes(result_types)
        # operand types are printed inline in the call parens
        args = line[m.end():]
        operand_bytes = _shape_bytes(args.split("),", 1)[0]) if args else 0
        out[kind].append(max(result_bytes, operand_bytes))
    return dict(out)


def collective_bytes(hlo_text: str) -> int:
    """Total per-device collective payload bytes in this HLO module."""
    return sum(sum(v) for v in parse_collectives(hlo_text).values())
