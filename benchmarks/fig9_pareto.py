"""Paper Fig 9: per-platform latency/accuracy trade-off curves.

At loose accuracy (large CI) gamma (network RTT) dominates and platforms
order geographically; at tight accuracy compute dominates and they order
by GFLOPS — the crossover the paper highlights. We assert both orderings
from the generated curves."""
from __future__ import annotations

import numpy as np

from repro.core.pareto import pareto_filter, platform_curves
from repro.pricing import PricingSolver, build_cluster
from repro.pricing.solver import SOLVERS

from .common import emit, small_workload, timer


def main(fast: bool = True) -> None:
    tasks = small_workload(1, n_steps=64)
    cluster = build_cluster(include_local=False)
    solver = PricingSolver(tasks, cluster)
    solver.characterise()  # adaptive online benchmarking
    delta, gamma = solver._delta, solver._gamma

    accuracies = np.geomspace(1.0, 0.001, 7)
    curves = platform_curves(delta, gamma, accuracies)  # [mu, n_acc]
    names = [p.spec.name for p in cluster]

    loose = int(np.argmin(curves[:, 0]))   # best at CI=$1 (gamma-dominated)
    tight = int(np.argmin(curves[:, -1]))  # best at CI=$0.001 (compute)
    emit("fig9.best_platform.loose_accuracy", 0.0, f"name={names[loose]}")
    emit("fig9.best_platform.tight_accuracy", 0.0, f"name={names[tight]}")
    for i in (loose, tight):
        pts = ";".join(f"{a:.3g}:{curves[i, j]:.3g}"
                       for j, a in enumerate(accuracies))
        emit(f"fig9.curve.{names[i].replace(' ', '_')}", 0.0, pts)

    # cluster-level Pareto frontier via the heuristic (cheap sweep)
    from repro.core import AllocationProblem, proportional_allocation
    pts = []
    for acc in accuracies:
        prob = AllocationProblem(delta=delta, gamma=gamma,
                                 c=np.full(delta.shape[1], acc))
        with timer() as t:
            a = proportional_allocation(prob)
        pts.append((float(acc), a.makespan))
    front = pareto_filter(pts)
    emit("fig9.cluster_pareto.heuristic", t.us,
         ";".join(f"{a:.3g}:{m:.3g}" for a, m in front))


if __name__ == "__main__":
    main()
