"""Paper Table 2: the 16-platform heterogeneous cluster."""
from __future__ import annotations

from repro.pricing import TABLE2_SPECS, SimulatedPlatform, table1_workload

from .common import emit, timer


def main(fast: bool = True) -> None:
    task = table1_workload(n_steps=64)[0]
    assert len(TABLE2_SPECS) == 16
    for spec in TABLE2_SPECS:
        p = SimulatedPlatform(spec)
        with timer() as t:
            rec = p.run(task, 100_000)
        emit(f"table2.run100k.{spec.name.replace(' ', '_')}", t.us,
             f"gflops={spec.gflops};rtt_ms={spec.rtt_ms};"
             f"sim_latency_s={rec.latency:.4f}")


if __name__ == "__main__":
    main()
