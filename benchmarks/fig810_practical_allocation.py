"""Paper Figs 8 & 10: the practical verification — Table 1 tasks on the
Table 2 cluster, characterise -> allocate -> execute, predicted vs
measured makespan/accuracy per solver over a range of accuracies.

The paper's headline: ML/MILP beat the heuristic by orders of magnitude
once accuracy requirements are loose enough that the per-(task,platform)
constants dominate (CI > $0.005 regime ~ psi large)."""
from __future__ import annotations

from repro.pricing import PricingSolver, build_cluster

from .common import emit, small_workload, timer


def main(fast: bool = True) -> None:
    tasks = small_workload(2 if fast else 15, n_steps=64)
    cluster = build_cluster(include_local=False)  # the 16 Table 2 rows
    solver = PricingSolver(tasks, cluster)
    with timer() as t:
        solver.characterise()  # adaptive online benchmarking
    emit("fig8.characterise", t.us,
         f"pairs={len(cluster)}x{len(tasks)}")

    for acc in (0.5, 0.05, 0.005):
        results = {}
        for method, kw in (("heuristic", {}),
                           ("ml", dict(chains=16, steps=3000, rounds=1,
                                       time_limit=30 if fast else 600)),
                           ("milp", dict(time_limit=30 if fast else 600))):
            with timer() as t:
                alloc = solver.allocate(acc, method=method, **kw)
            rep = solver.execute(alloc, acc)
            results[method] = rep
            emit(f"fig8.acc_{acc}.{method}", t.us,
                 f"predicted_makespan={rep.predicted_makespan:.2f};"
                 f"measured_makespan={rep.measured_makespan:.2f};"
                 f"model_err={rep.makespan_error:.3f}")
        h = results["heuristic"].measured_makespan
        for m in ("ml", "milp"):
            emit(f"fig10.acc_{acc}.{m}_vs_heuristic", 0.0,
                 f"improvement={h/results[m].measured_makespan:.2f}x")
        # measured accuracy should approximate the requested CI
        rep = results["milp"]
        worst = max(rep.measured_ci.values())
        emit(f"fig8.acc_{acc}.achieved_ci", 0.0,
             f"requested={acc};worst_measured={worst:.4f}")


if __name__ == "__main__":
    main()
