"""Shared benchmark plumbing: CSV emission + workload/cluster subsets."""
from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.3f},{derived}")


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
        self.us = self.seconds * 1e6
        return False


def small_workload(n_per_cat: int = 2, n_steps: int = 64):
    """A reduced Table 1 workload (same 9 categories) for fast benches."""
    from repro.pricing.workload import TABLE1_CATEGORIES, table1_workload
    cats = [(c, min(n, n_per_cat)) for c, n in TABLE1_CATEGORIES]
    return table1_workload(seed=2015, n_steps=n_steps, categories=cats)
