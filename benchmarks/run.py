"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--slow] [--only NAME]

Emits ``name,us_per_call,derived`` CSV lines per the harness contract.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "table1_workload",      # Table 1
    "table2_platforms",     # Table 2
    "fig34_latency_model",  # Figs 3-4
    "fig56_accuracy_model", # Figs 5-6
    "fig7_synthetic_allocation",  # Fig 7 (+ Table 3)
    "fig810_practical_allocation",  # Figs 8 & 10
    "fig9_pareto",          # Fig 9
    "allocation_bench",     # canonical 16x4 instance -> BENCH_allocation.json
    "kernel_bench",         # Pallas MC kernels
    "roofline_report",      # §Roofline (from dry-run artifacts)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slow", action="store_true",
                    help="full-size sweeps (paper-scale)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failures = 0
    for name in MODULES:
        if args.only and args.only != name:
            continue
        print(f"# === {name} ===", flush=True)
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        t0 = time.time()
        try:
            mod.main(fast=not args.slow)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name}.FAILED,0.0,", flush=True)
        print(f"# --- {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
