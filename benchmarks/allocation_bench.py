"""Canonical allocation benchmark -> ``BENCH_allocation.json``.

A fixed 16-task x 4-platform pricing instance (seeded Table 1 subset on
seeded Table 2 rows) run through the full characterise -> allocate ->
execute flow for all three solvers. The JSON is the perf-trajectory
artifact tracked from PR 2 onward: solver makespans, solve times, and
predicted-vs-measured model error on an instance that never changes.
"""
from __future__ import annotations

import json
import os

from .common import emit, timer

#: Table 2 rows: Desktop, AWS Server EC1, Local GPU 1, Local FPGA 1 —
#: one per latency/throughput regime so the instance is genuinely
#: heterogeneous.
PLATFORM_ROWS = (0, 4, 9, 14)
N_TASKS = 16
ACCURACY = 0.05
OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "BENCH_allocation.json")


def main(fast: bool = True) -> None:
    from repro.pricing import SimulatedPlatform, TABLE2_SPECS, table1_workload
    from repro.pricing.platforms import _TaskMoments
    from repro.runtime import Scheduler, make_domain

    tasks = table1_workload(seed=2015, n_steps=64)[:N_TASKS]
    moments = _TaskMoments(calib_paths=16384)
    platforms = [SimulatedPlatform(TABLE2_SPECS[i], moments=moments, seed=7)
                 for i in PLATFORM_ROWS]
    sched = Scheduler(make_domain("pricing", tasks, platforms))

    with timer() as t_char:
        sched.characterise(seed=1, path_ladder=(1_024, 4_096, 16_384, 65_536))
    emit("allocation.characterise", t_char.us,
         f"pairs={len(platforms)}x{len(tasks)}")

    solvers = {}
    for method, kw in (("heuristic", {}),
                       ("ml", dict(chains=16, steps=3000, rounds=1, seed=0,
                                   time_limit=30 if fast else 600)),
                       ("milp", dict(time_limit=30 if fast else 600))):
        alloc = sched.allocate(ACCURACY, method=method, **kw)
        rep = sched.execute(alloc, ACCURACY, seed=3)
        solvers[method] = {
            "makespan": alloc.makespan,
            "solve_time_s": alloc.solve_time,
            "predicted_makespan": rep.predicted_makespan,
            "measured_makespan": rep.measured_makespan,
            "prediction_error": rep.makespan_error,
            "optimal": alloc.optimal,
            "dual_bound": alloc.bound,
        }
        emit(f"allocation.{method}", alloc.solve_time * 1e6,
             f"makespan={alloc.makespan:.4f};"
             f"measured={rep.measured_makespan:.4f};"
             f"model_err={rep.makespan_error:.3f}")

    payload = {
        "benchmark": "allocation_16x4",
        "instance": {"tasks": N_TASKS, "platforms": len(platforms),
                     "platform_rows": list(PLATFORM_ROWS),
                     "accuracy": ACCURACY, "workload_seed": 2015,
                     "ladder": [1_024, 4_096, 16_384, 65_536]},
        "characterise_s": t_char.seconds,
        "solvers": solvers,
    }
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
    emit("allocation.json", 0.0, f"path={os.path.basename(OUT_PATH)}")


if __name__ == "__main__":
    main()
