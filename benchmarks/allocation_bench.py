"""Canonical allocation benchmark -> ``BENCH_allocation.json``.

A fixed 16-task x 4-platform pricing instance (seeded Table 1 subset on
seeded Table 2 rows) run through the full characterise -> allocate ->
execute flow for all three solvers. The JSON is the perf-trajectory
artifact tracked from PR 2 onward: solver makespans, solve times, and
predicted-vs-measured model error on an instance that never changes.

The ``overlap`` section (PR 3 onward) A/Bs sequential vs concurrent
dispatch on the same instance with *realtime* simulated platforms — each
replayed latency occupies host wall clock scaled by ``TIME_SCALE`` — so
the measured speedup is true wall-clock overlap, not bookkeeping: the
sequential wall tracks the sum of per-platform latencies, the concurrent
wall tracks their max (the paper's makespan semantics, §3).

The ``capacity`` section (PR 5 onward) re-solves the same fitted instance
with a second constraint dimension: every task consumes one resource unit
per allocated share and every platform holds ``CAPACITY_SLOTS`` — a
concurrent-working-set budget (the pricing analogue of LM serving's
KV-cache bytes vs HBM). Tracked per solver: the unconstrained vs
constrained makespan (the price of feasibility) and the number of
oversubscribed platforms, which must be zero for all three.

The ``online`` section (PR 4 onward) A/Bs static vs adaptive execution
under the canonical drift scenario — the busiest platform slows
``SLOWDOWN_FACTOR``x at the static plan's half-makespan. The static leg
rides the drift out; the adaptive leg (:class:`repro.runtime.
OnlineScheduler`) detects it, re-fits the metric models from execute-time
records and re-solves the remaining work. Tracked: the adaptation speedup
(regression bar: >= 1.5x), re-solve counts and wall time, and that the
unperturbed online run still solves exactly once.

The ``scaling`` section (PR 7 onward) sweeps fleet-scale instances —
{10, 100, 1000} tasks x {4, 16, 64} platforms of the paper's hardest
synthetic case (Het-Inc, tiled task families) — through all three solvers,
unclustered vs family-clustered (:func:`repro.core.clustered_allocation`),
recording per-phase build/solve walls and the clustered-vs-unclustered
makespan ratio. Two focused sub-benchmarks ride along: the sparse COO MILP
construction vs a per-cell ``lil_matrix`` baseline (the regression bar for
the vectorised build), and the O(k) incremental patch
(:func:`repro.core.patch_allocation`) vs a from-scratch re-solve for 10
arrivals into the 1000x64 incumbent. Every ML solve is preceded by an
untimed warm-up at the same shape so JIT compilation never pollutes the
timed region.

The ``slo`` section (PR 8 onward) drives a simulated three-platform LM
fleet with a seeded open-loop Poisson trace at {0.5, 1.0, 2.0}x
offered/capacity — capacity measured from a closed-loop calibration run,
not the fitted models' optimistic token rates — with bounded admission,
shedding, and the SLO brownout ladder armed. Tracked per ratio: TTFT and
e2e p50/p95/p99 of admitted requests, shed fraction, brownout rung
occupancy, peak backlog, and the admission barrier's minimum KV headroom
(zero oversubscription). A guardrail-off control leg at 2.0x rides along:
its unbounded backlog growth and blown p99 are the A/B the overload
controls are measured against (CI gates: guarded p99 within target,
bounded shed fraction, non-negative KV headroom).

The ``mesh`` section (PR 10 onward) quotes the same device kind at four
tensor-parallel mesh shapes (1x1 .. 1x8) and solves an LM-serving
instance under two pressures with all three solvers: a gamma-dominated
short-generation workload (the collective-inflated wide mesh is the worst
buy) and a KV-bound long-generation workload (the pooled cache forces the
bulk onto the widest mesh). Tracked: per-shape token shares and the
latency-vs-capacity argmax *flip*, zero pooled-KV oversubscription, the
fitted per-shape eq. 7 coefficients (sharded speedup at the widest shape
must exceed 1), and per-shape latency prediction error from an
instrumented execute (p50 within the paper's 10% band).

The ``faults`` section (PR 6 onward) runs the same instance through a
scripted three-kind fault storm — a flaky window on the Desktop
(transient blips), a finite outage on the FPGA, a corrupt window on the
GPU — scaled to the no-fault online makespan. The static leg has no
fault layer and dies on the first unhandled fault (work stranded); the
adaptive leg retries the blips, discards the corrupt records, opens the
FPGA's circuit breaker and re-admits it after a recovery probe, and
completes every task to the accuracy target (0 lost tasks) with makespan
within ``FAULT_MAKESPAN_BAR``x of the no-fault run (regression bar).
Tracked: the makespan ratio, retry/probe counts, and the breaker's
transition history.
"""
from __future__ import annotations

import json
import os

from .common import emit, timer

#: Table 2 rows: Desktop, AWS Server EC1, Local GPU 1, Local FPGA 1 —
#: one per latency/throughput regime so the instance is genuinely
#: heterogeneous.
PLATFORM_ROWS = (0, 4, 9, 14)
N_TASKS = 16
ACCURACY = 0.05
#: wall-clock fraction of each replayed latency the realtime platforms
#: occupy during the overlap A/B (keeps the section under ~5s).
TIME_SCALE = 0.05
#: canonical drift: the busiest platform slows this much at the static
#: plan's half-makespan.
SLOWDOWN_FACTOR = 4.0
ONLINE_ROUNDS = 8
#: per-platform concurrent-working-set budget for the capacity section:
#: each task consumes one unit per allocated share, so a platform can hold
#: at most this many task-equivalents (16 tasks over 4 platforms must
#: spread — the unconstrained optimum concentrates harder than this).
CAPACITY_SLOTS = 5.0
#: canonical storm: flaky-dispatch probability on the Desktop during the
#: opening window of the faults section.
FLAKY_P = 0.2
#: regression bar for the faults section: the adaptive run must complete
#: the stormed workload within this factor of the no-fault makespan.
FAULT_MAKESPAN_BAR = 1.5
OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "BENCH_allocation.json")

#: slo section: offered/measured-capacity ratios for the open-loop sweep;
#: 2.0 is the A/B point where a guardrail-off control leg rides along.
SLO_RATIOS = (0.5, 1.0, 2.0)
#: mean generated tokens per trace request (the bounded-Pareto mean the
#: load factory is tuned to) — the unit all predicted costs are priced in.
SLO_MEAN_TOK = 12.0
#: queue budget and calibration workload size, in mean-sized tasks.
SLO_QUEUE_TASKS = 40
#: SLO target as a multiple of the queue's real drain time at measured
#: capacity — 3x leaves headroom at 1x load and is breached only when the
#: backlog truly diverges.
SLO_TARGET_SCALE = 3.0

#: scaling sweep: fleet sizes x platform counts, Het-Inc (the paper's
#: fully-inconsistent hard case) with tiled task families so clustering
#: has real structure to find.
SCALING_TAUS = (10, 100, 1000)
SCALING_MUS = (4, 16, 64)
SCALING_FAMILIES = 24
SCALING_PSI = 0.25
SCALING_SEED = 11
#: unclustered MILP is attempted only up to this many A-variables — above
#: it the full model is exactly what clustering exists to avoid building.
MILP_DENSE_CELL_LIMIT = 6_400
#: ML solver settings for the scaling cells (modest: the sweep measures
#: scalability, not squeezing the last percent out of each cell).
SCALING_ML_KW = dict(chains=8, steps=2000, rounds=1, seed=0)


def scaling_instance(tau: int, mu: int, seed: int = SCALING_SEED):
    """Family-structured Het-Inc instance: SCALING_FAMILIES base tasks
    tiled to ``tau`` columns (byte-identical signatures, so
    ``cluster_tasks`` recovers exactly the families)."""
    import dataclasses

    import numpy as np

    from repro.core import synthetic

    base = synthetic.generate_case("Het-Inc", tau=min(tau, SCALING_FAMILIES),
                                   mu=mu, psi=SCALING_PSI, seed=seed)
    if tau <= SCALING_FAMILIES:
        return base
    idx = np.arange(tau) % SCALING_FAMILIES
    return dataclasses.replace(base, delta=base.delta[:, idx],
                               gamma=base.gamma[:, idx], c=base.c[idx])


def _phase_meta(alloc) -> dict:
    out = {"makespan": alloc.makespan, "total_s": alloc.solve_time}
    for key in ("build_s", "solve_s", "polish_s", "n_vars", "n_constraints",
                "n_clusters", "cluster_s"):
        if key in alloc.meta:
            out[key] = alloc.meta[key]
    return out


def scaling_cell(tau: int, mu: int, method: str, *, fast: bool = True,
                 unclustered: bool | None = None) -> dict:
    """One sweep cell: solve unclustered and clustered, report both.

    ``unclustered=None`` applies the default gate (always for heuristic
    and ML; MILP only below MILP_DENSE_CELL_LIMIT A-variables). ML solves
    are warmed up untimed at the same shape first (JIT compilation).
    """
    from repro.core import (
        capacity_ok, clustered_allocation, milp_allocation, ml_allocation,
        proportional_allocation,
    )

    problem = scaling_instance(tau, mu)
    tl = 10 if fast else 60
    if method == "heuristic":
        solve, kw = (lambda p, **k: proportional_allocation(p)), {}
    elif method == "ml":
        solve, kw = ml_allocation, dict(SCALING_ML_KW, time_limit=tl)
        solve(problem, **kw)  # warm-up: JIT compile at this shape, untimed
    else:
        solve, kw = milp_allocation, dict(time_limit=tl)
    if unclustered is None:
        unclustered = method != "milp" or tau * mu <= MILP_DENSE_CELL_LIMIT

    cell = {"tau": tau, "mu": mu, "method": method}
    if unclustered:
        cell["unclustered"] = _phase_meta(solve(problem, **kw))
    clus = clustered_allocation(problem, method, **kw)
    cell["clustered"] = _phase_meta(clus)
    cell["capacity_ok"] = bool(capacity_ok(clus.A, problem))
    if unclustered:
        cell["makespan_ratio"] = (cell["clustered"]["makespan"]
                                  / cell["unclustered"]["makespan"])
    return cell


def _dense_build_reference(problem) -> float:
    """Per-cell ``lil_matrix`` construction of the eq. 12 matrices — the
    pre-vectorisation baseline the sparse COO build replaced. Returns its
    wall seconds (csr conversion included, matching what the solver eats)."""
    import time

    import scipy.sparse as sp

    mu, tau = problem.mu, problem.tau
    n = mu * tau
    W, G = problem.work, problem.gamma
    t0 = time.perf_counter()
    eq = sp.lil_matrix((tau, 2 * n + 1))
    lat = sp.lil_matrix((mu, 2 * n + 1))
    link = sp.lil_matrix((n, 2 * n + 1))
    for i in range(mu):
        for j in range(tau):
            k = i * tau + j
            eq[j, k] = 1.0
            lat[i, k] = W[i, j]
            lat[i, n + k] = G[i, j]
            link[k, k] = 1.0
            link[k, n + k] = -1.0
        lat[i, 2 * n] = -1.0
    for m in (eq, lat, link):
        m.tocsr()
    return time.perf_counter() - t0


def _milp_build_speedup() -> dict:
    """Sparse COO vs per-cell dense construction at the largest cell."""
    import time

    from repro.core.milp import _build_relaxed

    problem = scaling_instance(1000, 64)
    t0 = time.perf_counter()
    _build_relaxed(problem)
    sparse_s = time.perf_counter() - t0
    dense_s = _dense_build_reference(problem)
    return {"tau": 1000, "mu": 64, "sparse_build_s": sparse_s,
            "dense_build_s": dense_s, "speedup": dense_s / sparse_s}


def _incremental_cell(fast: bool = True, k: int = 10) -> dict:
    """Patch k arrivals into the 1000x64 incumbent vs a from-scratch
    re-solve. Anneal effort scales with each side's own column count
    (2 steps per task placed) — the point of the O(k) patch is precisely
    that its sub-problem is k columns, not tau."""
    import time

    import numpy as np

    from repro.core import ml_allocation, patch_allocation, restrict_problem

    tau, mu = 1000, 64
    problem = scaling_instance(tau, mu)
    old = np.arange(tau - k)
    new = np.arange(tau - k, tau)
    tl = 10 if fast else 60
    kw_full = dict(SCALING_ML_KW, steps=2 * tau, time_limit=tl)
    kw_patch = dict(SCALING_ML_KW, steps=max(2 * k, 200), time_limit=tl)
    base_sub = restrict_problem(problem, tasks=old)
    ml_allocation(base_sub, **kw_full)  # warm-up (JIT at the base shape)
    base = ml_allocation(base_sub, **kw_full)
    A_base = np.zeros((mu, tau))
    A_base[:, old] = base.A

    patch_allocation(problem, A_base, new, "ml", **kw_patch)  # warm-up
    t0 = time.perf_counter()
    patched = patch_allocation(problem, A_base, new, "ml", **kw_patch)
    patch_s = time.perf_counter() - t0
    ml_allocation(problem, **kw_full)  # warm-up (JIT at the full shape)
    t0 = time.perf_counter()
    full = ml_allocation(problem, **kw_full)
    full_s = time.perf_counter() - t0
    return {
        "tau": tau, "mu": mu, "arrivals": k,
        "outcome": patched.meta.get("incremental"),
        "patch_s": patch_s, "full_s": full_s, "speedup": full_s / patch_s,
        "patched_makespan": patched.makespan, "full_makespan": full.makespan,
    }


def scaling_section(fast: bool = True) -> dict:
    """The full {tau} x {mu} x {solver} sweep plus the focused pair."""
    cells = {}
    for tau in SCALING_TAUS:
        for mu in SCALING_MUS:
            key = f"{tau}x{mu}"
            cells[key] = {}
            for method in ("heuristic", "ml", "milp"):
                cell = scaling_cell(tau, mu, method, fast=fast)
                cells[key][method] = cell
                ratio = cell.get("makespan_ratio")
                emit(f"allocation.scaling.{key}.{method}",
                     cell["clustered"]["total_s"] * 1e6,
                     f"clusters={cell['clustered'].get('n_clusters', tau)};"
                     f"ratio={'n/a' if ratio is None else f'{ratio:.3f}'}")
    build = _milp_build_speedup()
    emit("allocation.scaling.milp_build", build["sparse_build_s"] * 1e6,
         f"dense={build['dense_build_s']:.2f}s;"
         f"speedup={build['speedup']:.1f}x")
    incremental = _incremental_cell(fast)
    emit("allocation.scaling.incremental", incremental["patch_s"] * 1e6,
         f"full={incremental['full_s']:.2f}s;"
         f"speedup={incremental['speedup']:.1f}x;"
         f"outcome={incremental['outcome']}")
    return {
        "taus": list(SCALING_TAUS), "mus": list(SCALING_MUS),
        "families": SCALING_FAMILIES, "case": "Het-Inc", "psi": SCALING_PSI,
        "cells": cells, "milp_build": build, "incremental": incremental,
    }


def telemetry_section(tasks, moments, fast: bool = True) -> dict:
    """Observability cost + accountability: instrumented-vs-uninstrumented
    wall overhead on the canonical online run (min-of-N legs), the
    instrumented leg's live per-phase prediction-error quantiles, and the
    emitted trace's validation counts. ``chaos.yml`` asserts overhead
    < 5% and latency p50 error <= 10% from this section."""
    import time as _time

    from repro.obs import Tracer, validate_chrome_trace
    from repro.pricing import SimulatedPlatform, TABLE2_SPECS
    from repro.runtime import OnlineConfig, OnlineScheduler, Scheduler, make_domain

    def leg(trace):
        # the timed region is the whole instrumented pipeline —
        # characterise -> solve -> dispatch -> adapt — on the canonical
        # instance, which is exactly the surface the tracer covers
        ps = [SimulatedPlatform(TABLE2_SPECS[i], moments=moments, seed=7)
              for i in PLATFORM_ROWS]
        t0 = _time.perf_counter()
        s = Scheduler(make_domain("pricing", tasks, ps), trace=trace)
        s.characterise(seed=1, path_ladder=(1_024, 4_096, 16_384, 65_536))
        OnlineScheduler(s, OnlineConfig(rounds=ONLINE_ROUNDS)).run(
            ACCURACY, method="milp", seed=3, time_limit=30 if fast else 600)
        return _time.perf_counter() - t0, s

    reps = 3
    uninstr = min(leg(False)[0] for _ in range(reps))
    traced = [leg(Tracer()) for _ in range(reps)]
    instr = min(w for w, _s in traced)
    sched = min(traced, key=lambda ws: ws[0])[1]
    stats = validate_chrome_trace(sched.tracer.chrome_events())
    overhead = instr / uninstr - 1.0
    errors = sched.ledger.summary()
    emit("allocation.telemetry", instr * 1e6,
         f"overhead={overhead * 100:.2f}%;"
         f"spans={stats['spans']};"
         f"lat_p50={errors['latency']['p50']:.3f}")
    return {
        "reps": reps,
        "uninstrumented_wall_s": uninstr,
        "instrumented_wall_s": instr,
        "overhead": overhead,
        "trace": stats,
        "prediction_error": errors,
    }


def slo_section(fast: bool = True) -> dict:
    """Open-loop overload sweep + the 2x guarded-vs-control A/B.

    Everything is calibrated against a *measured* closed-loop task rate
    (at this scale the per-dispatch constant dominates real throughput),
    so "2x capacity" means 2x what the fleet actually sustains.
    """
    from repro.core.slo import SLOConfig, quantile
    from repro.domains.lm_serving import (
        LMRequest, SimulatedLMPlatform, kv_bytes_per_token,
    )
    from repro.runtime import (
        AdmissionConfig, OnlineConfig, OnlineScheduler, PlatformSpec,
        Scheduler, make_domain, predicted_unit_rates,
    )
    from repro.runtime.loadgen import (
        ConstantRate, LoadGenerator, lm_request_factory,
    )

    n_target = 400 if fast else 900

    def specs(per):
        # three regimes: low-RTT/slow edge, mid rack, fast/far big node;
        # KV budgets sized in 72-token request slots
        return [
            PlatformSpec("Edge", "CPU", "sim", "loc", 4.0, 0.2,
                         mem_bytes=per * 72 * 120),
            PlatformSpec("Rack", "GPU", "sim", "loc", 20.0, 1.0,
                         mem_bytes=per * 72 * 240),
            PlatformSpec("Big", "GPU", "sim", "loc", 80.0, 5.0,
                         mem_bytes=per * 72 * 480),
        ]

    # closed-loop calibration: the task rate the fleet actually sustains
    cal_reqs = [LMRequest("qwen25_3b", prompt_len=(8, 16)[i % 2],
                          gen_tokens=int(SLO_MEAN_TOK), batch=1,
                          max_new_tokens=64, task_id=i)
                for i in range(SLO_QUEUE_TASKS)]
    per = kv_bytes_per_token(cal_reqs[0].config(), 1)
    cal_fleet = [SimulatedLMPlatform(s, seed=0) for s in specs(per)]
    cal = Scheduler(make_domain("lm_serving", cal_reqs, cal_fleet))
    cal.characterise(seed=1, token_ladder=(2, 4, 8, 16))
    cal_rep = cal.execute(cal.allocate(method="heuristic"))
    busy: dict = {}
    for r in cal_rep.records:
        busy[r.platform] = busy.get(r.platform, 0.0) + abs(r.latency)
    task_rate = SLO_QUEUE_TASKS / max(busy.values())
    target = SLO_TARGET_SCALE * SLO_QUEUE_TASKS / task_rate

    def run(ratio, *, guarded):
        seeds = [LMRequest("qwen25_3b", prompt_len=pl, gen_tokens=16,
                           batch=1, max_new_tokens=64, task_id=i)
                 for i, pl in enumerate((8, 16))]
        fleet = [SimulatedLMPlatform(s, seed=0) for s in specs(per)]
        sched = Scheduler(make_domain("lm_serving", seeds, fleet))
        sched.characterise(seed=1, token_ladder=(2, 4, 8, 16))
        R = sum(predicted_unit_rates(sched.models,
                                     typical_units=SLO_MEAN_TOK).values())
        lam = ratio * task_rate
        horizon = n_target / lam
        queue_s = SLO_QUEUE_TASKS * SLO_MEAN_TOK / R
        factory = lm_request_factory(archs=("qwen25_3b",),
                                     prompt_buckets=(8, 16),
                                     batch=1, max_new_tokens=64)
        gen = LoadGenerator(ConstantRate(lam), factory, seed=0,
                            start_id=1000)
        scenario = gen.scenario(horizon)
        for p in fleet:
            p.attach_scenario(scenario)
        cfg = OnlineConfig(
            rounds=60, gamma_duty=0.0, open_loop=True,
            adopt_family_models=True,
            admission=AdmissionConfig(queue_s=queue_s,
                                      max_wait_s=target) if guarded else None,
            slo=SLOConfig(target_s=target, metric="e2e", quantile=0.99,
                          window=32, min_window=8) if guarded else None,
            degrade_steps=(0.75, 0.5) if guarded else (),
            breaker_cooldown=horizon * 0.15)
        rep = OnlineScheduler(sched, cfg).run(method="heuristic", seed=3,
                                              scenario=scenario)
        return rep, horizon

    def leg_stats(rep, horizon):
        e2e = [m["e2e"] for m in rep.task_metrics.values()]
        ttft = [m["ttft"] for m in rep.task_metrics.values()]
        active = [r.backlog_units for r in rep.rounds if r.t <= horizon]
        kv_min = min((r.kv_headroom for r in rep.rounds), default=None)
        reasons: dict = {}
        for ev in rep.shed_events:
            reasons[ev.reason] = reasons.get(ev.reason, 0) + 1
        return {
            "arrivals": rep.arrivals,
            "n_offered": rep.n_offered,
            "n_shed": rep.n_shed,
            "shed_fraction": rep.shed_fraction,
            "shed_reasons": reasons,
            "ttft": {f"p{int(q * 100)}": quantile(ttft, q)
                     for q in (0.5, 0.95, 0.99)},
            "e2e": {f"p{int(q * 100)}": quantile(e2e, q)
                    for q in (0.5, 0.95, 0.99)},
            "peak_backlog_units": max(
                (r.backlog_units for r in rep.rounds), default=0.0),
            "peak_active_backlog_units": max(active, default=0.0),
            "max_queue_depth": max(
                (r.queue_depth for r in rep.rounds), default=0),
            # None when admission is off (no barrier, nothing audited)
            "min_kv_headroom": (None if kv_min is None
                                or kv_min == float("inf") else kv_min),
            "brownout_occupancy": {str(k): v for k, v
                                   in rep.brownout_occupancy.items()},
            "brownout_rung_final": rep.brownout_rung,
            "slo": rep.slo,
        }

    ratios = {}
    for ratio in SLO_RATIOS:
        rep, horizon = run(ratio, guarded=True)
        leg = leg_stats(rep, horizon)
        ratios[f"{ratio:g}x"] = leg
        emit(f"allocation.slo.{ratio:g}x", leg["e2e"]["p99"] * 1e6,
             f"shed={leg['shed_fraction']:.2f};"
             f"p99={leg['e2e']['p99'] * 1e3:.0f}ms;"
             f"attainment={leg['slo']['attainment']:.2f}")

    ctl_rep, ctl_horizon = run(2.0, guarded=False)
    control = leg_stats(ctl_rep, ctl_horizon)
    guarded = ratios["2x"]
    ab = {
        "target_s": target,
        "guarded_p99_e2e": guarded["e2e"]["p99"],
        "control_p99_e2e": control["e2e"]["p99"],
        "guarded_within_target": guarded["e2e"]["p99"] <= target,
        "control_within_target": control["e2e"]["p99"] <= target,
        "backlog_ratio": (control["peak_active_backlog_units"]
                          / max(guarded["peak_active_backlog_units"], 1e-9)),
        "kv_oversubscribed": guarded["min_kv_headroom"] < 0.0,
    }
    emit("allocation.slo.ab", control["e2e"]["p99"] * 1e6,
         f"guarded_p99={guarded['e2e']['p99'] * 1e3:.0f}ms"
         f"(target={target * 1e3:.0f}ms);"
         f"control_p99={control['e2e']['p99'] * 1e3:.0f}ms;"
         f"backlog_ratio={ab['backlog_ratio']:.1f}x")

    return {
        "fleet": [s.name for s in specs(per)],
        "mean_gen_tokens": SLO_MEAN_TOK,
        "n_target": n_target,
        "measured_task_rate": task_rate,
        "target_s": target,
        "target_scale": SLO_TARGET_SCALE,
        "ratios": ratios,
        "control_2x": control,
        "ab": ab,
    }


def mesh_section(fast: bool = True) -> dict:
    """Mesh-sharded platforms (PR 10 onward): the same device kind quoted
    at four tensor-parallel widths (:data:`LM_MESH_FLEET_SPECS`), solved
    under two pressures. A short-generation workload is gamma-dominated —
    the collective-inflated wide mesh is the worst buy and the solvers
    concentrate tokens on narrow shapes; a long-generation workload
    outgrows the narrow shapes' KV pools and the pooled cache forces the
    bulk onto the widest mesh. Tracked per solver: per-shape token shares,
    the argmax shape under each pressure, the latency-vs-capacity *flip*,
    and zero pooled-KV oversubscription. A fitted-model leg records the
    per-shape eq. 7 coefficients (the sharded speedup at the widest shape
    must exceed 1) and an instrumented execute checks per-shape latency
    prediction error stays inside the paper's 10% band."""
    from repro.core import capacity_ok, platform_usage
    from repro.domains.lm_serving import (
        LM_MESH_FLEET_SPECS, LMRequest, SimulatedLMPlatform, build_lm_fleet,
    )
    from repro.runtime import Scheduler, make_domain

    widest = LM_MESH_FLEET_SPECS[-1]
    solver_kw = {
        "heuristic": {},
        "ml": dict(chains=8, steps=1500 if fast else 3000, rounds=1, seed=0,
                   time_limit=30 if fast else 600),
        "milp": dict(time_limit=30 if fast else 600),
    }

    def reqs_latency():
        # 6 x 8 tokens: work is microseconds, gamma milliseconds
        return [LMRequest("qwen25_3b", prompt_len=8, gen_tokens=8, batch=2,
                          max_new_tokens=16, task_id=i) for i in range(6)]

    def reqs_capacity():
        # 14 x 450 tokens at 1 KiB KV/token: the narrow shapes pool 3584
        # token-slots, so >= 2716 tokens must land on the 1x8 (cap 4096)
        return [LMRequest("qwen25_3b", prompt_len=8, gen_tokens=450, batch=2,
                          max_new_tokens=512, task_id=i) for i in range(14)]

    def characterised(reqs):
        sched = Scheduler(make_domain(
            "lm_serving", reqs, build_lm_fleet(include_local=False, mesh=True)))
        sched.characterise(seed=1, token_ladder=(2, 8, 16))
        return sched

    # -- per-shape eq. 7 coefficients ---------------------------------------
    # solo long-generation characterisation at negligible jitter: beta is
    # microseconds/token against a milliseconds gamma, so identifying it
    # needs a high-SNR fit, not the noisy fleet defaults
    model_sched = Scheduler(make_domain(
        "lm_serving",
        [LMRequest("qwen25_3b", prompt_len=8, gen_tokens=450, batch=2,
                   max_new_tokens=512, task_id=0)],
        [SimulatedLMPlatform(s, jitter=1e-5) for s in LM_MESH_FLEET_SPECS]))
    model_sched.characterise(seed=1, token_ladder=(32, 128, 450))
    per_shape = {}
    for spec in LM_MESH_FLEET_SPECS:
        m = model_sched.models[(spec.name, 0)].latency
        per_shape[spec.name] = {
            "mesh_shape": list(spec.mesh_shape),
            "beta_s_per_token": m.beta, "gamma_s": m.gamma,
            "tp_speedup_datasheet": spec.tp_speedup,
            "rtt_effective_ms": spec.effective_rtt_ms,
            "kv_pool_bytes": spec.total_mem_bytes,
        }
    narrow_beta = per_shape[LM_MESH_FLEET_SPECS[0].name]["beta_s_per_token"]
    wide_beta = per_shape[widest.name]["beta_s_per_token"]
    sharded_speedup = narrow_beta / wide_beta
    wide_gamma_gain = (per_shape[widest.name]["gamma_s"]
                       / per_shape[LM_MESH_FLEET_SPECS[0].name]["gamma_s"])
    emit("allocation.mesh.model", wide_beta * 1e6,
         f"speedup_1x{widest.model_parallel}={sharded_speedup:.2f}x"
         f"(datasheet={widest.tp_speedup:.2f}x);"
         f"gamma_gain={wide_gamma_gain:.2f}x")

    # -- the wide-vs-narrow choice under both pressures --------------------
    scheds = {"latency": characterised(reqs_latency()),
              "capacity": characterised(reqs_capacity())}
    solvers: dict = {}
    for method, kw in solver_kw.items():
        legs = {}
        for pressure, sched in scheds.items():
            alloc = sched.allocate(method=method, **kw)
            problem = sched.problem()
            tokens = (alloc.A * problem.c[None, :]).sum(axis=1)
            usage = platform_usage(alloc.A, problem)
            over = int((usage > problem.capacity * (1 + 1e-6)).sum())
            shares = {s.name: float(t)
                      for s, t in zip(LM_MESH_FLEET_SPECS, tokens)}
            legs[pressure] = {
                "tokens": shares,
                "argmax": max(shares, key=shares.get),
                "makespan": alloc.makespan,
                "solve_time_s": alloc.solve_time,
                "capacity_ok": bool(capacity_ok(alloc.A, problem)),
                "oversubscribed_platforms": over,
                "kv_usage_bytes": {s.name: float(u) for s, u
                                   in zip(LM_MESH_FLEET_SPECS, usage)},
            }
        flip = (legs["latency"]["argmax"] != widest.name
                and legs["capacity"]["argmax"] == widest.name)
        solvers[method] = {**legs, "flip": flip}
        emit(f"allocation.mesh.{method}",
             legs["capacity"]["solve_time_s"] * 1e6,
             f"latency_argmax={legs['latency']['argmax']};"
             f"capacity_argmax={legs['capacity']['argmax']};"
             f"flip={flip};"
             f"oversubscribed={legs['capacity']['oversubscribed_platforms']}")

    # -- per-shape prediction accountability on an instrumented execute ---
    ledger_reqs = [LMRequest("qwen25_3b", prompt_len=8, gen_tokens=48,
                             batch=2, max_new_tokens=64, task_id=i)
                   for i in range(6)]
    led_sched = Scheduler(make_domain(
        "lm_serving", ledger_reqs,
        build_lm_fleet(include_local=False, mesh=True)), trace=True)
    led_sched.characterise(seed=1, token_ladder=(2, 8, 16))
    led_sched.execute(led_sched.allocate(method="heuristic"))
    by_shape = {name: stats for name, stats
                in led_sched.ledger.platform_summary("latency").items()
                if name in per_shape}
    p50s = [s["p50"] for s in by_shape.values() if s["p50"] is not None]
    ledger = {
        "per_shape": by_shape,
        "max_p50_error": max(p50s) if p50s else None,
        "within_band": bool(p50s) and max(p50s) <= 0.10,
    }
    emit("allocation.mesh.ledger", (max(p50s) if p50s else 0.0) * 1e6,
         f"shapes={len(by_shape)};"
         f"max_p50={max(p50s):.3f}" if p50s else "shapes=0")

    return {
        "fleet": [s.name for s in LM_MESH_FLEET_SPECS],
        "widest": widest.name,
        "per_shape_model": per_shape,
        "sharded_speedup_widest": sharded_speedup,
        "wide_gamma_gain": wide_gamma_gain,
        "solvers": solvers,
        "ledger": ledger,
    }


def main(fast: bool = True) -> None:
    import numpy as np

    from repro.core import platform_latencies
    from repro.pricing import SimulatedPlatform, TABLE2_SPECS, table1_workload
    from repro.pricing.platforms import _TaskMoments
    from repro.runtime import (
        OnlineConfig, OnlineScheduler, Scenario, Scheduler, make_domain,
    )

    tasks = table1_workload(seed=2015, n_steps=64)[:N_TASKS]
    moments = _TaskMoments(calib_paths=16384)
    platforms = [SimulatedPlatform(TABLE2_SPECS[i], moments=moments, seed=7)
                 for i in PLATFORM_ROWS]
    sched = Scheduler(make_domain("pricing", tasks, platforms))

    with timer() as t_char:
        sched.characterise(seed=1, path_ladder=(1_024, 4_096, 16_384, 65_536))
    emit("allocation.characterise", t_char.us,
         f"pairs={len(platforms)}x{len(tasks)}")

    solvers = {}
    for method, kw in (("heuristic", {}),
                       ("ml", dict(chains=16, steps=3000, rounds=1, seed=0,
                                   time_limit=30 if fast else 600)),
                       ("milp", dict(time_limit=30 if fast else 600))):
        # warm-up solve outside the timed region: the first ML solve at a
        # shape pays JIT compilation, the first MILP pays HiGHS init —
        # neither belongs in the tracked solve_time trajectory
        sched.allocate(ACCURACY, method=method, **kw)
        alloc = sched.allocate(ACCURACY, method=method, **kw)
        rep = sched.execute(alloc, ACCURACY, seed=3)
        solvers[method] = {
            "makespan": alloc.makespan,
            "solve_time_s": alloc.solve_time,
            "predicted_makespan": rep.predicted_makespan,
            "measured_makespan": rep.measured_makespan,
            "prediction_error": rep.makespan_error,
            "optimal": alloc.optimal,
            "dual_bound": alloc.bound,
        }
        emit(f"allocation.{method}", alloc.solve_time * 1e6,
             f"makespan={alloc.makespan:.4f};"
             f"measured={rep.measured_makespan:.4f};"
             f"model_err={rep.makespan_error:.3f}")

    # -- capacity: the second constraint dimension on the same instance --
    import dataclasses

    from repro.core import (
        milp_allocation, ml_allocation, platform_usage, proportional_allocation,
    )

    base_problem = sched.problem(ACCURACY)
    cap_problem = dataclasses.replace(
        base_problem,
        resource=np.ones((len(platforms), len(tasks))),
        capacity=np.full(len(platforms), CAPACITY_SLOTS),
    )
    core_solvers = {
        "heuristic": lambda p: proportional_allocation(p),
        "ml": lambda p: ml_allocation(p, chains=16, steps=3000, rounds=1,
                                      seed=0, time_limit=30 if fast else 600),
        "milp": lambda p: milp_allocation(p, time_limit=30 if fast else 600),
    }
    capacity = {"slots_per_platform": CAPACITY_SLOTS, "solvers": {}}
    for method, solve in core_solvers.items():
        # the solvers section above already solved this exact fitted
        # problem unconstrained — reuse its makespan rather than re-solving
        un_makespan = solvers[method]["makespan"]
        con = solve(cap_problem)
        usage = platform_usage(con.A, cap_problem)
        over = int((usage > cap_problem.capacity * (1 + 1e-6)).sum())
        capacity["solvers"][method] = {
            "unconstrained_makespan": un_makespan,
            "constrained_makespan": con.makespan,
            "makespan_ratio": con.makespan / un_makespan,
            "max_usage": float(usage.max()),
            "oversubscribed_platforms": over,
            "solve_time_s": con.solve_time,
        }
        emit(f"allocation.capacity.{method}", con.solve_time * 1e6,
             f"constrained={con.makespan:.4f};"
             f"unconstrained={un_makespan:.4f};"
             f"oversubscribed={over}")

    # -- overlap A/B: sequential vs concurrent dispatch, true wall clock --
    rt_platforms = [SimulatedPlatform(TABLE2_SPECS[i], moments=moments, seed=7,
                                      realtime=TIME_SCALE)
                    for i in PLATFORM_ROWS]
    rt_sched = Scheduler(make_domain("pricing", tasks, rt_platforms))
    char_wall = {}
    for mode in ("sequential", "concurrent"):
        with timer() as t:
            rt_sched.characterise(seed=1, mode=mode,
                                  path_ladder=(1_024, 4_096, 16_384, 65_536))
        char_wall[mode] = t.seconds
    alloc = rt_sched.allocate(ACCURACY, method="milp", time_limit=30)
    reps = {mode: rt_sched.execute(alloc, ACCURACY, seed=3, mode=mode)
            for mode in ("sequential", "concurrent")}
    overlap = {
        "time_scale": TIME_SCALE,
        "execute_wall_s_sequential": reps["sequential"].wall_s,
        "execute_wall_s_concurrent": reps["concurrent"].wall_s,
        "execute_speedup": reps["sequential"].wall_s / reps["concurrent"].wall_s,
        "characterise_wall_s_sequential": char_wall["sequential"],
        "characterise_wall_s_concurrent": char_wall["concurrent"],
        "characterise_speedup": char_wall["sequential"] / char_wall["concurrent"],
        "records_identical": (reps["sequential"].records
                              == reps["concurrent"].records),
    }
    emit("allocation.overlap", reps["concurrent"].wall_s * 1e6,
         f"execute_speedup={overlap['execute_speedup']:.2f}x;"
         f"characterise_speedup={overlap['characterise_speedup']:.2f}x;"
         f"identical={overlap['records_identical']}")

    # -- online: static vs adaptive under the canonical drift scenario ----
    def fresh_scheduler(scenario=None):
        ps = [SimulatedPlatform(TABLE2_SPECS[i], moments=moments, seed=7)
              for i in PLATFORM_ROWS]
        s = Scheduler(make_domain("pricing", tasks, ps))
        s.characterise(seed=1, path_ladder=(1_024, 4_096, 16_384, 65_536))
        if scenario is not None:
            for p in ps:
                p.attach_scenario(scenario)
        return s, ps

    base, base_ps = fresh_scheduler()
    base_alloc = base.allocate(ACCURACY, method="milp", time_limit=30)
    lat = platform_latencies(base_alloc.A, base.problem(ACCURACY))
    slow_name = base_ps[int(np.argmax(lat))].spec.name
    t_half = base_alloc.makespan / 2
    scenario = Scenario().slowdown(slow_name, t_half, SLOWDOWN_FACTOR)
    cfg = OnlineConfig(rounds=ONLINE_ROUNDS)

    # unperturbed control: the feedback loop must not re-solve on noise
    ctl_sched, _ = fresh_scheduler()
    control = OnlineScheduler(ctl_sched, cfg).run(
        ACCURACY, method="milp", seed=3, time_limit=30)

    static_sched, _ = fresh_scheduler(scenario)
    static_rep = static_sched.execute(
        static_sched.allocate(ACCURACY, method="milp", time_limit=30),
        ACCURACY, seed=3)

    online_sched, _ = fresh_scheduler(scenario)
    with timer() as t_online:
        adaptive = OnlineScheduler(online_sched, cfg).run(
            ACCURACY, method="milp", seed=3, time_limit=30)
    online = {
        "scenario": {"platform": slow_name, "t": t_half,
                     "factor": SLOWDOWN_FACTOR},
        "rounds": ONLINE_ROUNDS,
        "static_makespan": static_rep.measured_makespan,
        "adaptive_makespan": adaptive.measured_makespan,
        "adaptation_speedup": (static_rep.measured_makespan
                               / adaptive.measured_makespan),
        "n_resolves": adaptive.n_resolves,
        "n_skipped": adaptive.n_skipped,
        "n_refits": adaptive.n_refits,
        "resolve_wall_s": adaptive.resolve_wall_s,
        "solve_wall_s": adaptive.solve_wall_s,
        "adaptive_wall_s": t_online.seconds,
        "control_makespan": control.measured_makespan,
        "solves_unperturbed": control.n_solves,
        "resolves_unperturbed": control.n_resolves,
    }
    emit("allocation.online", adaptive.resolve_wall_s * 1e6,
         f"speedup={online['adaptation_speedup']:.2f}x;"
         f"resolves={adaptive.n_resolves};"
         f"unperturbed_resolves={control.n_resolves}")

    # -- faults: the scripted storm A/B — static dies, adaptive survives --
    from repro.runtime import RetryPolicy
    from repro.runtime.faults import DispatchFault

    # the unperturbed control run above is exactly the no-fault baseline
    m0 = control.measured_makespan

    def storm_scenario():
        return (Scenario()
                .flaky("Desktop", p=FLAKY_P, seed=5, t=0.0, end=0.4 * m0)
                .outage("Local FPGA 1", t=0.1 * m0, end=0.35 * m0)
                .corrupt("Local GPU 1", t=0.15 * m0, end=0.2 * m0))

    static_fault_sched, _ = fresh_scheduler(storm_scenario())
    static_alloc = static_fault_sched.allocate(ACCURACY, method="milp",
                                               time_limit=30)
    try:
        static_fault_sched.execute(static_alloc, ACCURACY, seed=3)
        static_leg = {"failed": False}
    except DispatchFault as exc:
        # the demonstrable failure the fault layer exists to prevent: the
        # first unhandled fault kills the run mid-workload
        static_leg = {"failed": True, "error": type(exc).__name__,
                      "salvaged_records": len(exc.records)}

    storm_sched, _ = fresh_scheduler(storm_scenario())
    storm_cfg = OnlineConfig(rounds=ONLINE_ROUNDS,
                             breaker_cooldown=0.08 * m0, outage_failures=1,
                             retry=RetryPolicy(max_attempts=4, budget=16))
    storm_rep = OnlineScheduler(storm_sched, storm_cfg).run(
        ACCURACY, method="milp", seed=3, time_limit=30)
    lost = sum(1 for t in tasks
               if storm_rep.summary["measured_ci"][t.task_id] > ACCURACY * 1.25)
    faults = {
        "scenario": {"flaky": {"platform": "Desktop", "p": FLAKY_P,
                               "end": 0.4 * m0},
                     "outage": {"platform": "Local FPGA 1", "t": 0.1 * m0,
                                "end": 0.35 * m0},
                     "corrupt": {"platform": "Local GPU 1", "t": 0.15 * m0,
                                 "end": 0.2 * m0}},
        "no_fault_makespan": m0,
        "static": static_leg,
        "adaptive_makespan": storm_rep.measured_makespan,
        "makespan_ratio": storm_rep.measured_makespan / m0,
        "makespan_bar": FAULT_MAKESPAN_BAR,
        "n_retries": storm_rep.n_retries,
        "n_probes": storm_rep.n_probes,
        "recovered_platforms": list(storm_rep.recovered_platforms),
        "dead_platforms": list(storm_rep.dead_platforms),
        "breaker_transitions": [
            {"platform": t.platform, "from": t.frm, "to": t.to,
             "at": t.at, "round": t.round}
            for t in storm_rep.breaker_transitions],
        "fault_counts": {
            kind: sum(1 for e in storm_rep.fault_events if e.fault == kind)
            for kind in sorted({e.fault for e in storm_rep.fault_events})},
        "degraded_tasks": len({d.task_id for d in storm_rep.degradations}),
        "lost_tasks": lost,
    }
    emit("allocation.faults", storm_rep.measured_makespan * 1e6,
         f"ratio={faults['makespan_ratio']:.2f}x"
         f"(bar={FAULT_MAKESPAN_BAR}x);"
         f"retries={storm_rep.n_retries};"
         f"recovered={len(storm_rep.recovered_platforms)};"
         f"lost={lost};static_failed={static_leg['failed']}")

    # -- slo: open-loop overload sweep + the 2x guarded/control A/B -------
    slo = slo_section(fast)

    # -- mesh: wide-vs-narrow tensor-parallel shapes under two pressures --
    mesh = mesh_section(fast)

    # -- scaling: fleet-size sweep, build speedup, incremental patch ------
    scaling = scaling_section(fast)

    # -- telemetry: tracing overhead + live prediction accountability -----
    telemetry = telemetry_section(tasks, moments, fast)

    payload = {
        "benchmark": "allocation_16x4",
        "instance": {"tasks": N_TASKS, "platforms": len(platforms),
                     "platform_rows": list(PLATFORM_ROWS),
                     "accuracy": ACCURACY, "workload_seed": 2015,
                     "ladder": [1_024, 4_096, 16_384, 65_536]},
        "characterise_s": t_char.seconds,
        "solvers": solvers,
        "capacity": capacity,
        "overlap": overlap,
        "online": online,
        "faults": faults,
        "slo": slo,
        "mesh": mesh,
        "scaling": scaling,
        "telemetry": telemetry,
    }
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
    emit("allocation.json", 0.0, f"path={os.path.basename(OUT_PATH)}")


if __name__ == "__main__":
    main()
