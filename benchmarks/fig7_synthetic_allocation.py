"""Paper Fig 7: allocation-approach characterisation on synthetic data.

(a/b) solve time vs problem size and vs constant-to-coefficient ratio psi;
(c/d) improvement over the proportional heuristic for the same sweeps.
Uses the Braun-style generator with the paper's Table 3 cases.
"""
from __future__ import annotations

from repro.core import milp_allocation, ml_allocation, proportional_allocation
from repro.core.synthetic import generate_case

from .common import emit, timer

SOLVERS = {
    "heuristic": lambda p, tl: proportional_allocation(p),
    "ml": lambda p, tl: ml_allocation(p, chains=16, steps=3000, rounds=1,
                                      time_limit=tl),
    "milp": lambda p, tl: milp_allocation(p, time_limit=tl),
}


def main(fast: bool = True) -> None:
    time_limit = 30 if fast else 600
    sizes = [(4, 16), (8, 32), (16, 64)] if fast else \
        [(4, 16), (8, 32), (16, 64), (16, 128), (32, 256)]

    # (a)+(c): size sweep at psi=1, Het-Inc (the paper's hardest case)
    for mu, tau in sizes:
        prob = generate_case("Het-Inc", tau=tau, mu=mu, psi=1.0, seed=0)
        h = proportional_allocation(prob)
        for name, solve in SOLVERS.items():
            with timer() as t:
                a = solve(prob, time_limit)
            emit(f"fig7a.size_{mu}x{tau}.{name}", t.us,
                 f"makespan={a.makespan:.1f};improvement={h.makespan/a.makespan:.2f}x")

    # (b)+(d): psi sweep at fixed size — the nonlinearity knob
    mu, tau = (8, 32) if fast else (16, 64)
    for psi in (0.01, 0.1, 1.0, 10.0, 100.0):
        prob = generate_case("Het-Inc", tau=tau, mu=mu, psi=psi, seed=1)
        h = proportional_allocation(prob)
        for name, solve in SOLVERS.items():
            if name == "heuristic":
                continue
            with timer() as t:
                a = solve(prob, time_limit)
            emit(f"fig7b.psi_{psi}.{name}", t.us,
                 f"improvement={h.makespan/a.makespan:.2f}x")

    # Table 3 case sweep (Hom-Con .. Het-Inc)
    for case in ("Hom-Con", "Het-Con", "Het-Mix", "Het-Inc"):
        prob = generate_case(case, tau=32, mu=8, psi=1.0, seed=2)
        h = proportional_allocation(prob)
        a = milp_allocation(prob, time_limit=time_limit)
        emit(f"fig7.table3.{case}.milp", a.solve_time * 1e6,
             f"improvement={h.makespan/a.makespan:.2f}x;optimal={a.optimal}")


if __name__ == "__main__":
    main()
