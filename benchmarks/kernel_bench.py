"""Monte Carlo kernel benchmark: Pallas (interpret) vs jnp oracle, block
sweep. On CPU the interpreter is a correctness tool, not a speed tool —
the numbers recorded here are the blocking/shape trade-off data that the
§Perf VMEM-tiling argument reads from."""
from __future__ import annotations

from repro.kernels import ops, ref
from repro.pricing import BlackScholes, PricingTask, european

from .common import emit, timer


def main(fast: bool = True) -> None:
    task = PricingTask(underlying=BlackScholes(100.0, 0.05, 0.2),
                       option=european(100.0), maturity=1.0,
                       n_steps=16, task_id=42)
    n = 16_384
    # oracle
    ref.mc_moments_ref(task, n)  # warm
    with timer() as t:
        s, _ = ref.mc_moments_ref(task, n)
        s.block_until_ready()
    emit("kernel.oracle_jnp.16k_paths", t.us, f"sum={float(s):.1f}")
    for bp in (512, 1024, 4096):
        ops.mc_moments(task, n, seed=0, block_paths=bp)  # warm
        with timer() as t:
            s, _ = ops.mc_moments(task, n, seed=0, block_paths=bp)
            s.block_until_ready()
        emit(f"kernel.pallas_interpret.block_{bp}", t.us,
             f"blocks={n // bp};sum={float(s):.1f}")


if __name__ == "__main__":
    main()
