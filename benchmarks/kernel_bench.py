"""Monte Carlo kernel + characterisation benchmarks.

Part 1 — Pallas (interpret) vs jnp oracle, block sweep.  On CPU the
interpreter is a correctness tool, not a speed tool — the numbers recorded
here are the blocking/shape trade-off data that the §Perf VMEM-tiling
argument reads from.

Part 2 — batched vs per-task-looped characterisation.  The looped baseline
replays the seed engine's behaviour (the task is a *static* jit argument,
so every (task, rung) pair traces and compiles afresh); the batched engine
takes task parameters as runtime arrays and compiles once per (family,
ladder shape).  Reported as a JSON line for dashboards.
"""
from __future__ import annotations

import json
import time

import jax

from repro.kernels import ops, ref
from repro.pricing import (
    BlackScholes,
    LocalJaxPlatform,
    PricingTask,
    RunRecord,
    SimulatedPlatform,
    TABLE2_SPECS,
    characterise,
    european,
    group_by_family,
    mc,
)
from repro.pricing.platforms import _TaskMoments, fit_models
from repro.pricing.workload import table1_workload

from .common import emit, timer


def bench_kernels() -> None:
    task = PricingTask(underlying=BlackScholes(100.0, 0.05, 0.2),
                       option=european(100.0), maturity=1.0,
                       n_steps=16, task_id=42)
    n = 16_384
    # oracle
    ref.mc_moments_ref(task, n)  # warm
    with timer() as t:
        s, _ = ref.mc_moments_ref(task, n)
        s.block_until_ready()
    emit("kernel.oracle_jnp.16k_paths", t.us, f"sum={float(s):.1f}")
    for bp in (512, 1024, 4096):
        ops.mc_moments(task, n, seed=0, block_paths=bp)  # warm
        with timer() as t:
            s, _ = ops.mc_moments(task, n, seed=0, block_paths=bp)
            s.block_until_ready()
        emit(f"kernel.pallas_interpret.block_{bp}", t.us,
             f"blocks={n // bp};sum={float(s):.1f}")


def _looped_characterise(platforms, tasks, ladder, seed=1, calib_paths=8192):
    """The seed engine's per-task loop: task is a static jit argument, so
    every (task, rung) pair — and every simulated-platform calibration —
    is a fresh trace + XLA compile."""
    legacy = jax.jit(mc._moments, static_argnums=(0, 1))
    out = {}
    for p in platforms:
        for t_ in tasks:
            recs = []
            if hasattr(p, "moments"):  # simulated: per-task calibration
                if t_.task_id not in p.moments._cache:
                    s, s2 = legacy(t_, calib_paths, 10_007)
                    res = mc._finalize(t_, s, s2, calib_paths)
                    alpha = float(res.ci95) * (calib_paths ** 0.5)
                    p.moments._cache[t_.task_id] = (float(res.price), alpha)
                recs = [p.run(t_, int(n), seed=seed + i)
                        for i, n in enumerate(ladder)]
            else:  # local: warm + timed, per-task compile
                for i, n in enumerate(ladder):
                    legacy(t_, int(n), seed + i)  # warm — compiles per (task, n)
                    t0 = time.perf_counter()
                    s, s2 = legacy(t_, int(n), seed + i)
                    s.block_until_ready()
                    lat = time.perf_counter() - t0
                    res = mc._finalize(t_, s, s2, int(n))
                    recs.append(RunRecord(p.spec.name, t_.task_id, int(n),
                                          float(res.price), float(res.ci95),
                                          lat))
            out[(p.spec.name, t_.task_id)] = fit_models(recs)
    return out


def bench_characterise(fast: bool = True) -> None:
    """Batched vs looped characterisation wall time (the tentpole win).

    The acceptance workload: 2 platforms x 16 tasks (3 families) x a
    2-rung ladder.  The looped baseline pays one XLA compile per
    (task, rung) plus one per simulated-platform calibration; the batched
    engine compiles once per (model kind, batch size) because task
    parameters, payoff kinds, seeds and path counts are all runtime
    operands.
    """
    cats = [("BS-A", 6), ("BS-DB", 5), ("H-A", 5)] if fast else None
    n_steps = 16 if fast else 256
    calib = 8192
    ladder = (512, 2048)
    tasks = table1_workload(seed=11, n_steps=n_steps, categories=cats)

    def cluster():
        return [SimulatedPlatform(TABLE2_SPECS[0],
                                  moments=_TaskMoments(calib_paths=calib)),
                LocalJaxPlatform()]

    with timer() as t_loop:
        _looped_characterise(cluster(), tasks, ladder, calib_paths=calib)
    mc.reset_trace_counts()
    with timer() as t_batch:
        characterise(cluster(), tasks, path_ladder=ladder)
    traces = sum(mc.trace_counts().values())

    speedup = t_loop.seconds / max(t_batch.seconds, 1e-9)
    emit("characterise.looped_per_task", t_loop.us,
         f"platforms=2;tasks={len(tasks)};rungs={len(ladder)}")
    emit("characterise.batched_per_family", t_batch.us,
         f"families={len(group_by_family(tasks))};traces={traces}")
    print(json.dumps({
        "benchmark": "characterise_batched_vs_looped",
        "n_platforms": 2,
        "n_tasks": len(tasks),
        "n_families": len(group_by_family(tasks)),
        "path_ladder": list(ladder),
        "calib_paths": calib,
        "looped_seconds": round(t_loop.seconds, 4),
        "batched_seconds": round(t_batch.seconds, 4),
        "speedup": round(speedup, 2),
        "batched_traces": traces,
    }), flush=True)


def main(fast: bool = True) -> None:
    bench_kernels()
    bench_characterise(fast=fast)


if __name__ == "__main__":
    main()
