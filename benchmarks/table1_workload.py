"""Paper Table 1: the 128-task workload — generation + kFLOP/path check."""
from __future__ import annotations

from collections import Counter

from repro.pricing import kflop_per_path, price, table1_workload

from .common import emit, timer


def main(fast: bool = True) -> None:
    with timer() as t:
        tasks = table1_workload()
    counts = Counter(t.category for t in tasks)
    emit("table1.generate_128_tasks", t.us, f"categories={len(counts)}")
    for cat, n in sorted(counts.items()):
        kf = [kflop_per_path(tk) for tk in tasks if tk.category == cat]
        emit(f"table1.kflop_per_path.{cat}", 0.0,
             f"count={n};kflop={kf[0]:.3f}")
    # complexity spread must stay within an order of magnitude (the
    # paper's rejection criterion)
    kfs = [kflop_per_path(t) for t in tasks]
    emit("table1.complexity_spread", 0.0,
         f"max_over_min={max(kfs)/min(kfs):.2f}")
    # one real pricing call per underlying family (engine wall time)
    for tk in (tasks[0], tasks[40]):
        price(tk, 4096)  # warm
        with timer() as t:
            res = price(tk, 4096)
            res.price.block_until_ready()
        emit(f"table1.price_4k_paths.{tk.category}", t.us,
             f"price={float(res.price):.4f};ci95={float(res.ci95):.4f}")


if __name__ == "__main__":
    main()
