"""Paper Figs 3 & 4: latency-model incorporation + extrapolation error.

Fig 3 (incorporation): for a fixed run-time target, sweep the benchmark
budget (as a benchmark:run-time path ratio) and report the mean relative
error of the latency prediction — it must fall as the budget grows.

Fig 4 (extrapolation): fix the benchmark budget and grow the run-time
target by up to ~2 orders of magnitude — error must stay bounded.

Platforms: representative Table 2 rows (simulated; incl. the Cape Town
RTT-dominated rows that the paper calls out as hard) plus the REAL local
JAX engine, labelled `real.local_jax`.
"""
from __future__ import annotations

import numpy as np

from repro.core.metrics import relative_error
from repro.pricing import (LocalJaxPlatform, SimulatedPlatform, TABLE2_SPECS,
                           benchmark)
from repro.pricing.platforms import fit_models

from .common import emit, small_workload, timer

RATIOS = (0.01, 0.03, 0.1, 0.3, 1.0)
SIM_ROWS = {"Desktop": 0, "Local GPU 1": 9, "Remote Server": 3,
            "AWS GPU EC": 12}


def _sweep(platform, task, runtime_paths: int, label: str):
    run = platform.run(task, runtime_paths, seed=99)
    errs = []
    for ratio in RATIOS:
        bench_paths = max(int(runtime_paths * ratio), 256)
        ladder = np.unique((bench_paths * np.array([0.25, 0.5, 1.0])
                            ).astype(int))
        m = fit_models(benchmark(platform, task, ladder.tolist()))
        errs.append(float(relative_error(m.latency(runtime_paths),
                                         run.latency)))
        emit(f"fig3.incorporation.{label}.ratio_{ratio}", 0.0,
             f"rel_err={errs[-1]:.4f}")
    return errs


def main(fast: bool = True) -> None:
    tasks = small_workload(1)
    task = tasks[4]  # an H-A task (Heston Asian: mid complexity)

    for name, idx in SIM_ROWS.items():
        p = SimulatedPlatform(TABLE2_SPECS[idx])
        errs = _sweep(p, task, runtime_paths=1_000_000,
                      label="sim." + name.replace(" ", "_"))
        # incorporation property: more benchmark -> not worse
        emit(f"fig3.monotone.sim.{name.replace(' ', '_')}", 0.0,
             f"first={errs[0]:.4f};last={errs[-1]:.4f}")

    # extrapolation (Fig 4): bench at 16k paths, predict up to 64x more
    for name, idx in SIM_ROWS.items():
        p = SimulatedPlatform(TABLE2_SPECS[idx])
        m = fit_models(benchmark(p, task, (4_096, 8_192, 16_384)))
        for mult in (1, 4, 16, 64):
            n = 16_384 * mult
            run = p.run(task, n, seed=123)
            err = float(relative_error(m.latency(n), run.latency))
            emit(f"fig4.extrapolation.sim.{name.replace(' ', '_')}.x{mult}",
                 0.0, f"rel_err={err:.4f}")

    # the real platform (wall-clock ground truth)
    local = LocalJaxPlatform()
    with timer() as t:
        m = fit_models(benchmark(local, task, (2_048, 8_192, 32_768)))
    emit("fig34.real.local_jax.fit", t.us,
         f"beta={m.latency.beta:.3e};gamma={m.latency.gamma:.3e}")
    for mult in (1, 4, 16):
        n = 32_768 * mult
        run = local.run(task, n, seed=5)
        err = float(relative_error(m.latency(n), run.latency))
        emit(f"fig4.extrapolation.real.local_jax.x{mult}", 0.0,
             f"rel_err={err:.4f}")


if __name__ == "__main__":
    main()
