"""Paper Figs 5 & 6: accuracy-model incorporation + extrapolation error,
reported per task category as (min, geometric mean, max) — the paper's
radial plots. Ground truth from the REAL engine (the accuracy metric is a
statistical property, platform-independent)."""
from __future__ import annotations

import numpy as np

from repro.core.metrics import fit_accuracy_model, relative_error
from repro.pricing import price

from .common import emit, small_workload


def _true_ci(task, n, seed=7):
    return float(price(task, n, seed=seed).ci95)


def main(fast: bool = True) -> None:
    tasks = small_workload(2 if fast else 5, n_steps=32)
    runtime_paths = 65_536
    cats: dict[str, list[float]] = {}

    for ratio in (0.05, 0.25, 1.0):
        cats.clear()
        for task in tasks:
            bench = max(int(runtime_paths * ratio), 512)
            ladder = [bench // 4, bench // 2, bench]
            cis = [_true_ci(task, n) for n in ladder]
            m = fit_accuracy_model(ladder, cis)
            err = float(relative_error(m(runtime_paths),
                                       _true_ci(task, runtime_paths)))
            cats.setdefault(task.category, []).append(err)
        for cat, errs in sorted(cats.items()):
            gmean = float(np.exp(np.mean(np.log(np.maximum(errs, 1e-9)))))
            emit(f"fig5.incorporation.{cat}.ratio_{ratio}", 0.0,
                 f"min={min(errs):.4f};gmean={gmean:.4f};max={max(errs):.4f}")

    # Fig 6: fixed benchmark (16k), growing run-time target
    for mult in (1, 4, 16):
        cats.clear()
        for task in tasks:
            ladder = [4_096, 8_192, 16_384]
            m = fit_accuracy_model(ladder, [_true_ci(task, n) for n in ladder])
            n = 16_384 * mult
            err = float(relative_error(m(n), _true_ci(task, n, seed=11)))
            cats.setdefault(task.category, []).append(err)
        allerrs = [e for v in cats.values() for e in v]
        gmean = float(np.exp(np.mean(np.log(np.maximum(allerrs, 1e-9)))))
        emit(f"fig6.extrapolation.x{mult}", 0.0,
             f"gmean={gmean:.4f};max={max(allerrs):.4f}")


if __name__ == "__main__":
    main()
