"""§Roofline data: the per-cell three-term table from dry-run artifacts.

Reads artifacts/dryrun/<mesh>/*.json (produced by repro.launch.dryrun,
which needs its own 512-device process) and prints the roofline terms.
Skipped gracefully when no artifacts exist yet.
"""
from __future__ import annotations

import glob
import json
import os

from repro.roofline.analysis import analyze, format_table

from .common import emit

ART = os.environ.get("DRYRUN_ARTIFACTS", "artifacts/dryrun")


def main(fast: bool = True) -> None:
    found = False
    for mesh_dir in sorted(glob.glob(os.path.join(ART, "*"))):
        mesh = os.path.basename(mesh_dir)
        chips = 1
        for part in mesh.split("x"):
            chips *= int(part)
        rows = []
        for path in sorted(glob.glob(os.path.join(mesh_dir, "*.json"))):
            if "__" not in os.path.basename(path) or path.count("__") > 1:
                continue  # skip tagged (hillclimb) artifacts
            with open(path) as f:
                d = json.load(f)
            if not d.get("ok"):
                emit(f"roofline.{mesh}.{d['arch']}.{d['shape']}", 0.0,
                     "FAILED")
                continue
            r = analyze(d, chips=chips)
            rows.append(r)
            emit(f"roofline.{mesh}.{r.arch}.{r.shape}", r.step_time_s * 1e6,
                 f"bound={r.bottleneck};compute_s={r.compute_s:.4g};"
                 f"memory_s={r.memory_s:.4g};collective_s={r.collective_s:.4g};"
                 f"mfu={r.mfu:.4f};useful={r.useful_flops_ratio:.4f}")
            found = True
        if rows:
            print(format_table(rows))
    if not found:
        emit("roofline.no_artifacts", 0.0,
             "run `python -m repro.launch.dryrun` first")


if __name__ == "__main__":
    main()
